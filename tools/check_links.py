"""Fail on broken relative links in markdown files (no dependencies).

Checks inline markdown links ``[text](target)`` whose target is a relative
path: the target (resolved against the file's directory, fragment stripped)
must exist. External schemes (http/https/mailto) are ignored; bare fragments
(``#section``) are ignored. Directories may be given as arguments and are
scanned for ``*.md`` non-recursively.

Usage: python tools/check_links.py README.md docs
Exit status 1 when any link is broken (the CI docs step).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links only; reference-style links are not used in this repo
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path) -> list[str]:
    errors = []
    for target in LINK.findall(md.read_text(encoding="utf-8")):
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        if not (md.parent / path).exists():
            errors.append(f"{md}: broken relative link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    files: list[Path] = []
    for arg in argv or ["README.md", "docs"]:
        p = Path(arg)
        if p.is_dir():
            files.extend(sorted(p.glob("*.md")))
        else:
            files.append(p)
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
