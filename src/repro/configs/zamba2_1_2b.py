"""Zamba2 1.2B — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", arch_type="hybrid",
        num_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, attn_every=6,
        long_context_mode="swa",        # shared-attn blocks use a serve window
        source="arXiv:2411.15242",
    )
