"""Qwen2.5 14B — dense GQA decoder with QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", arch_type="dense",
        num_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064,
        qkv_bias=True, rope_theta=1_000_000.0,
        long_context_mode="swa",
        source="hf:Qwen/Qwen2.5-0.5B",
    )
