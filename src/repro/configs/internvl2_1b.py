"""InternVL2 1B — InternLM2-style language decoder consuming InternViT patch
embeddings (vision encoder is the allowed stub frontend). [arXiv:2404.16821]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", arch_type="vlm",
        num_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655,
        n_patches=256, d_vision=1024,
        long_context_mode="swa",
        source="arXiv:2404.16821",
    )
