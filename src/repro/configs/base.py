"""Config system: assigned input shapes, arch registry, reduced variants,
and ShapeDtypeStruct input specs for the dry-run (no allocation)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import ModelConfig, init_cache


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mixtral-8x22b", "stablelm-12b", "arctic-480b", "qwen2.5-14b",
    "zamba2-1.2b", "musicgen-medium", "stablelm-1.6b", "internvl2-1b",
    "mamba2-780m", "minitron-4b",
]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")
    return mod.config()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    n_heads = min(cfg.n_heads, 4) if cfg.n_heads else 0
    n_kv = max(1, min(cfg.n_kv_heads, n_heads)) if cfg.n_heads else 0
    if cfg.n_heads and cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads                                   # keep MHA archs MHA
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=256,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=64 if cfg.n_heads else 0,
        d_ff=512 if cfg.d_ff else 0,
        vocab_size=512,
        vocab_pad_multiple=128,
        n_experts=min(cfg.n_experts, 4),
        moe_group_size=128,
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_chunk=32,
        attn_every=2,
        n_patches=16,
        d_vision=64,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
        serve_window=64,
        remat=False,
    )


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; weak-type-correct, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_struct(cfg: ModelConfig, seq_len: int, batch: int, kind: str) -> dict:
    """ShapeDtypeStructs for one model-input batch."""
    i32 = jnp.int32
    if kind in ("train", "prefill"):
        if cfg.arch_type == "audio":
            out = {"tokens": _sds((batch, seq_len, cfg.n_codebooks), i32)}
            if kind == "train":
                out["labels"] = _sds((batch, seq_len, cfg.n_codebooks), i32)
            return out
        if cfg.arch_type == "vlm":
            s_txt = seq_len - cfg.n_patches
            out = {
                "tokens": _sds((batch, s_txt), i32),
                "vision": _sds((batch, cfg.n_patches, cfg.d_vision), jnp.bfloat16),
            }
            if kind == "train":
                out["labels"] = _sds((batch, s_txt), i32)
            return out
        out = {"tokens": _sds((batch, seq_len), i32)}
        if kind == "train":
            out["labels"] = _sds((batch, seq_len), i32)
        return out
    if kind == "decode":
        if cfg.arch_type == "audio":
            return {"tokens": _sds((batch, 1, cfg.n_codebooks), i32)}
        return {"tokens": _sds((batch, 1), i32)}
    raise ValueError(kind)


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """Everything the lowered step function consumes besides params/opt-state."""
    out = {"batch": batch_struct(cfg, shape.seq_len, shape.global_batch, shape.kind)}
    if shape.kind == "decode":
        out["pos"] = _sds((shape.global_batch,), jnp.int32)
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return out


def make_batch(cfg: ModelConfig, seq_len: int, batch: int, kind: str, seed: int = 0) -> dict:
    """Concrete random batch (for smoke tests / examples on CPU)."""
    structs = batch_struct(cfg, seq_len, batch, kind)
    key = jax.random.key(seed)
    out = {}
    for name, s in structs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out
