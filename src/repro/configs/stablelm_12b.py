"""StableLM-2 12B — dense GQA decoder. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", arch_type="dense",
        num_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab_size=100352,
        norm="layernorm",
        long_context_mode="swa",        # serving-only ring-buffer window
        source="hf:stabilityai/stablelm-2-1_6b",
    )
