"""MusicGen medium — decoder-only LM over EnCodec tokens (backbone only;
codec frontend is the allowed stub). [arXiv:2306.05284]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", arch_type="audio",
        num_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab_size=2048, n_codebooks=4,
        norm="layernorm", activation="gelu",
        long_context_mode="swa",
        source="arXiv:2306.05284",
    )
