"""Minitron 4B — width/depth-pruned Nemotron dense decoder. [arXiv:2407.14679]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", arch_type="dense",
        num_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=9216, vocab_size=256000,
        activation="gelu",
        long_context_mode="swa",
        source="arXiv:2407.14679",
    )
