"""Mamba2 780M — attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", arch_type="ssm",
        num_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64,
        long_context_mode="native",     # O(1) recurrent state
        source="arXiv:2405.21060",
    )
