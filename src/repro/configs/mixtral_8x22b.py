"""Mixtral 8x22B — 8-expert top-2 MoE with sliding-window attention.
[arXiv:2401.04088]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", arch_type="moe",
        num_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab_size=32768,
        n_experts=8, top_k=2,
        sliding_window=8192,            # SWA (paper §2)
        long_context_mode="native",     # SWA is native sub-quadratic serving
        source="arXiv:2401.04088",
    )
