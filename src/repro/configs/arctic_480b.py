"""Snowflake Arctic 480B — 128-expert top-2 MoE with dense residual branch.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b", arch_type="moe",
        num_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=4864, vocab_size=32000,
        n_experts=128, top_k=2, moe_dense_residual=True,
        long_context_mode="swa",
        source="hf:Snowflake/snowflake-arctic-base",
    )
