"""StableLM-2 1.6B — dense MHA decoder. [hf:stabilityai/stablelm-2-1_6b]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", arch_type="dense",
        num_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        norm="layernorm",
        long_context_mode="swa",
        source="hf:stabilityai/stablelm-2-1_6b",
    )
