from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, InputShape, get_config,
                                input_specs, make_batch, reduced)
