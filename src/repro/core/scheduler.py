"""Fulcrum: the top-level scheduler (paper Fig. 5).

Given a workload tuple, a problem configuration, and a strategy name, Fulcrum
profiles via the chosen strategy, commits to a (power mode [, beta_in
[, tau_tr]]) plan, and executes it with the trace-driven engine
(``core.simulate``). One strategy registry keyed on ``(Scenario, name)``
replaces the per-scenario factory dicts: every scenario — train / infer /
concurrent / concurrent-inference / dynamic — resolves its solver through the
same table, with fitted strategies (ALS / RND / NN) cached for reuse and GMD
always re-profiling (it is profiling). Dynamic arrival rates (§5.4) run
through a re-planning controller: per-window solutions reuse the profiler
cache (GMD) or the fitted model (everything else), and ``serve_dynamic``
executes each window over its arrival trace, emitting per-window
``ExecutionReport``s.

Contract: inputs are workload profiles + problem dataclasses; outputs are
``Plan``s (committed solutions with profiling cost attached) and engine
reports. Invariants: solving never executes and executing never re-solves —
``execute*`` replays exactly the committed plan (pm, bs, tau_tr cap) through
``core.simulate``; the engine ``backend`` argument (NumPy reference / jax
scan, resolved by ``core.backend``) changes *where* the replay runs, never
*what* plan runs. Registry entries must be pure factories: strategy state
lives in the returned object, so cached reuse is safe per workload tuple.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional, Sequence

from repro.core import problem as P
from repro.core.als import (ALSConcurrent, ALSInfer, ALSMultiTenant, ALSTrain,
                            QuadrantRanges)
from repro.core.baselines import (NNConcurrentBaseline, NNInferBaseline,
                                  NNMultiTenantBaseline, NNTrainBaseline,
                                  RNDConcurrent, RNDInfer, RNDMultiTenant,
                                  RNDTrain)
from repro.core.device_model import DeviceModel, Profiler, WorkloadProfile
from repro.core.gmd import (ConcurrentProfiler, GMDConcurrent, GMDInfer,
                            GMDMultiTenant, GMDTrain, MultiTenantProfiler)
from repro.core.interleave import ExecutionReport
from repro.core.oracle import Oracle
from repro.core.powermode import PowerModeSpace
from repro.core.simulate import (ArrivalTrace, MultiTenantReport, simulate,
                                 simulate_batch, simulate_multi_tenant,
                                 simulate_multi_tenant_batch)


class Scenario(enum.Enum):
    TRAIN = "train"
    INFER = "infer"
    CONCURRENT = "concurrent"
    CONCURRENT_INFERENCE = "concurrent_inference"
    DYNAMIC = "dynamic"
    MULTI_TENANT = "multi_tenant"

    @property
    def canonical(self) -> "Scenario":
        """The solver family a scenario maps onto: concurrent inference is
        the concurrent problem with the non-urgent inference in the training
        role, dynamic is per-window inference (§5.4)."""
        return _CANONICAL.get(self, self)


_CANONICAL = {Scenario.CONCURRENT_INFERENCE: Scenario.CONCURRENT,
              Scenario.DYNAMIC: Scenario.INFER}


def as_nonurgent(w: WorkloadProfile, bs: int = 32) -> WorkloadProfile:
    """Cast an inference workload into the training role of the concurrent
    problem: a non-urgent batch inference at a fixed minibatch size (§5.4)."""
    if w.name.endswith("-nonurgent"):
        return w
    return dataclasses.replace(w, name=f"{w.name}-nonurgent", train_bs=bs)


# ---------------------------------------------------------------------------
# strategy registry: one table for every (scenario, strategy) pair
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrategySpec:
    factory: Callable                 # (fulcrum, *workloads) -> strategy
    cached: bool = True               # fitted models are reusable; GMD is not


_REGISTRY: dict[tuple[Scenario, str], StrategySpec] = {}


def register_strategy(scenario: Scenario, name: str, factory: Callable,
                      cached: bool = True) -> None:
    _REGISTRY[(scenario, name)] = StrategySpec(factory, cached)


def available_strategies(scenario: Scenario) -> list[str]:
    canon = scenario.canonical
    return sorted(name for (sc, name) in _REGISTRY if sc is canon)


def _prof(f: "Fulcrum", w: WorkloadProfile) -> Profiler:
    return Profiler(f.device, w)


def _cprof(f: "Fulcrum", w_tr: WorkloadProfile,
           w_in: WorkloadProfile) -> ConcurrentProfiler:
    return ConcurrentProfiler(Profiler(f.device, w_tr),
                              Profiler(f.device, w_in))


register_strategy(Scenario.TRAIN, "gmd",
                  lambda f, w: GMDTrain(_prof(f, w), f.space), cached=False)
register_strategy(Scenario.TRAIN, "als50",
                  lambda f, w: ALSTrain(_prof(f, w), f.space,
                                        nn_epochs=f.nn_epochs))
register_strategy(Scenario.TRAIN, "rnd50",
                  lambda f, w: RNDTrain(_prof(f, w), 50, f.space))
register_strategy(Scenario.TRAIN, "rnd250",
                  lambda f, w: RNDTrain(_prof(f, w), 250, f.space))
register_strategy(Scenario.TRAIN, "nn250",
                  lambda f, w: NNTrainBaseline(_prof(f, w), 250, f.space,
                                               nn_epochs=f.nn_epochs))

register_strategy(Scenario.INFER, "gmd",
                  lambda f, w: GMDInfer(_prof(f, w), f.space), cached=False)
register_strategy(Scenario.INFER, "als145",
                  lambda f, w: ALSInfer(_prof(f, w), f.quadrants, f.space,
                                        nn_epochs=f.nn_epochs))
register_strategy(Scenario.INFER, "rnd150",
                  lambda f, w: RNDInfer(_prof(f, w), 150, f.space))
register_strategy(Scenario.INFER, "rnd250",
                  lambda f, w: RNDInfer(_prof(f, w), 250, f.space))
register_strategy(Scenario.INFER, "nn250",
                  lambda f, w: NNInferBaseline(_prof(f, w), 250, f.space,
                                               nn_epochs=f.nn_epochs))

register_strategy(Scenario.CONCURRENT, "gmd",
                  lambda f, w_tr, w_in: GMDConcurrent(_cprof(f, w_tr, w_in),
                                                      f.space), cached=False)
register_strategy(Scenario.CONCURRENT, "als145",
                  lambda f, w_tr, w_in: ALSConcurrent(
                      _cprof(f, w_tr, w_in), f.quadrants, f.space,
                      nn_epochs=f.nn_epochs))
register_strategy(Scenario.CONCURRENT, "rnd150",
                  lambda f, w_tr, w_in: RNDConcurrent(_cprof(f, w_tr, w_in),
                                                      150, f.space))
register_strategy(Scenario.CONCURRENT, "rnd250",
                  lambda f, w_tr, w_in: RNDConcurrent(_cprof(f, w_tr, w_in),
                                                      250, f.space))
register_strategy(Scenario.CONCURRENT, "nn250",
                  lambda f, w_tr, w_in: NNConcurrentBaseline(
                      _cprof(f, w_tr, w_in), 250, f.space,
                      nn_epochs=f.nn_epochs))


def _mtprof(f: "Fulcrum", w_tr: Optional[WorkloadProfile],
            *stream_ws: WorkloadProfile) -> MultiTenantProfiler:
    return MultiTenantProfiler(
        Profiler(f.device, w_tr) if w_tr is not None else None,
        [Profiler(f.device, w) for w in stream_ws])


register_strategy(Scenario.MULTI_TENANT, "gmd",
                  lambda f, w_tr, *ws: GMDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      f.space), cached=False)
register_strategy(Scenario.MULTI_TENANT, "als145",
                  lambda f, w_tr, *ws: ALSMultiTenant(
                      _mtprof(f, w_tr, *ws), f.quadrants, f.space,
                      nn_epochs=f.nn_epochs))
register_strategy(Scenario.MULTI_TENANT, "rnd150",
                  lambda f, w_tr, *ws: RNDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      150, f.space))
register_strategy(Scenario.MULTI_TENANT, "rnd250",
                  lambda f, w_tr, *ws: RNDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      250, f.space))
register_strategy(Scenario.MULTI_TENANT, "nn250",
                  lambda f, w_tr, *ws: NNMultiTenantBaseline(
                      _mtprof(f, w_tr, *ws), 250, f.space,
                      nn_epochs=f.nn_epochs))


# ---------------------------------------------------------------------------
# plans and per-window results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    solution: P.Solution
    strategy: str
    profiling_runs: int
    profiling_cost_s: float
    scenario: Optional[Scenario] = None


@dataclasses.dataclass
class WindowReport:
    """One §5.4 rate window: the rate (a per-stream tuple for multi-tenant
    windows), the (re)planned solution, and the engine's execution report
    (a MultiTenantReport for multi-tenant windows) over that window's
    arrival trace(s)."""
    rate: object                      # float | tuple[float, ...]
    solution: Optional[object]        # Solution | MultiTenantSolution
    report: Optional[object]          # ExecutionReport | MultiTenantReport


class Fulcrum:
    def __init__(self, device: Optional[DeviceModel] = None,
                 space: Optional[PowerModeSpace] = None,
                 quadrants: Optional[QuadrantRanges] = None,
                 nn_epochs: int = 400):
        self.device = device or DeviceModel()
        self.space = space or PowerModeSpace()
        self.quadrants = quadrants or QuadrantRanges(latency=(0.05, 2.0),
                                                     arrival=(30.0, 120.0))
        self.nn_epochs = nn_epochs
        self.oracle = Oracle(self.device, self.space)
        self._fitted: dict = {}     # reusable fitted strategies (ALS/RND/NN)

    # -- solve --------------------------------------------------------------
    def solve(self, scenario, workloads: Sequence[WorkloadProfile], prob,
              strategy: str = "gmd") -> Optional[Plan]:
        scenario = Scenario(scenario)
        s = self._strategy(scenario, strategy, *workloads)
        return self._plan(s.solve(prob), s, strategy, scenario)

    def solve_train(self, w: WorkloadProfile, prob: P.TrainProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.TRAIN, (w,), prob, strategy)

    def solve_infer(self, w: WorkloadProfile, prob: P.InferProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.INFER, (w,), prob, strategy)

    def solve_concurrent(self, w_tr: WorkloadProfile, w_in: WorkloadProfile,
                         prob: P.ConcurrentProblem,
                         strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.CONCURRENT, (w_tr, w_in), prob, strategy)

    def solve_concurrent_inference(self, w_nonurgent: WorkloadProfile,
                                   w_urgent: WorkloadProfile,
                                   prob: P.ConcurrentProblem,
                                   strategy: str = "gmd",
                                   nonurgent_bs: int = 32) -> Optional[Plan]:
        """§5.4 concurrent inferences: maximize the non-urgent inference's
        throughput under the urgent inference's latency deadline."""
        return self.solve(Scenario.CONCURRENT_INFERENCE,
                          (as_nonurgent(w_nonurgent, nonurgent_bs), w_urgent),
                          prob, strategy)

    def solve_multi_tenant(self, w_tr: Optional[WorkloadProfile],
                           prob: P.MultiTenantProblem,
                           strategy: str = "gmd") -> Optional[Plan]:
        """N tenant inference streams + a training fill workload under one
        power budget; stream workloads come from the problem's StreamSpecs.
        The Plan's solution is a MultiTenantSolution (per-stream bs/latency)."""
        ws = tuple(s.workload for s in prob.streams)
        if any(w is None for w in ws):
            raise ValueError("every StreamSpec needs a workload to solve a "
                             "multi-tenant scenario")
        if prob.train and w_tr is None:
            raise ValueError("prob.train is set but no train workload given")
        return self.solve(Scenario.MULTI_TENANT,
                          (w_tr if prob.train else None,) + ws, prob, strategy)

    def strategy_for(self, scenario, name: str, *workloads: WorkloadProfile):
        """Resolve (scenario, strategy) through the registry; fitted
        strategies are cached per workload tuple, GMD never is."""
        return self._strategy(Scenario(scenario), name, *workloads)

    def _strategy(self, scenario: Scenario, name: str,
                  *workloads: WorkloadProfile):
        if scenario is Scenario.CONCURRENT_INFERENCE:
            # the scenario's defining cast (non-urgent inference in the
            # training role, fixed bs), applied regardless of entry point
            workloads = (as_nonurgent(workloads[0]),) + workloads[1:]
        spec = _REGISTRY.get((scenario.canonical, name))
        if spec is None:
            raise KeyError(
                f"no strategy {name!r} for scenario {scenario.value!r}; "
                f"available: {available_strategies(scenario)}")
        if not spec.cached:
            return spec.factory(self, *workloads)
        key = (scenario.canonical.value, name,
               tuple(w.name if w is not None else None for w in workloads))
        if key not in self._fitted:
            self._fitted[key] = spec.factory(self, *workloads)
        return self._fitted[key]

    def _plan(self, sol, strat, name, scenario=None) -> Optional[Plan]:
        if sol is None:
            return None
        prof = getattr(strat, "profiler", None) or getattr(strat, "cp", None) \
            or getattr(strat, "mp", None)
        runs = prof.num_runs if prof is not None else 0
        cost = prof.profile_cost_s if prof is not None else 0.0
        return Plan(solution=sol, strategy=name, profiling_runs=runs,
                    profiling_cost_s=cost, scenario=scenario)

    # -- execute (trace-driven engine over the device model) ----------------
    def execute(self, plan: Plan, w_in: WorkloadProfile,
                w_tr: Optional[WorkloadProfile] = None,
                arrival_rate: Optional[float] = None,
                duration: float = 120.0,
                trace: Optional[ArrivalTrace] = None,
                approach: str = "managed", seed: int = 0,
                backend: Optional[str] = None) -> ExecutionReport:
        """Execute a solved plan: the plan's power mode and minibatch size
        drive the engine, managed slack-fill is capped at the committed
        tau_tr, and the returned report carries the trace that was run.
        ``backend`` selects the engine implementation (NumPy reference or
        the jax max-plus scan), as in ``core.simulate.simulate``."""
        if trace is None:
            if arrival_rate is None:
                raise ValueError("execute() needs an arrival_rate or a trace")
            trace = ArrivalTrace.uniform(arrival_rate, duration)
        sol = plan.solution
        if sol.bs is None:
            raise ValueError(
                f"plan ({plan.strategy}) has no inference minibatch size; "
                "solve an infer/concurrent scenario before executing")
        return simulate(self.device, w_tr, w_in, sol.pm, sol.bs, trace,
                        approach=approach, seed=seed, tau_cap=sol.tau_tr,
                        backend=backend)

    def execute_multi_tenant(self, plan: Plan, prob: P.MultiTenantProblem,
                             w_tr: Optional[WorkloadProfile] = None,
                             traces: Optional[Sequence[ArrivalTrace]] = None,
                             duration: float = 120.0,
                             arrivals: str = "uniform",
                             seed: int = 0,
                             backend: Optional[str] = None
                             ) -> MultiTenantReport:
        """Execute a multi-tenant plan: per-stream minibatch sizes drive the
        N-stream managed engine over one trace per tenant (built from each
        stream's arrival rate unless given), slack-fill capped at tau_tr."""
        sol = plan.solution
        if not isinstance(sol, P.MultiTenantSolution):
            raise ValueError(f"plan ({plan.strategy}) is not multi-tenant; "
                             "use execute()")
        if prob.train and w_tr is None:
            raise ValueError("prob.train is set but no train workload given; "
                             "executing without it would silently drop the "
                             "plan's training fill")
        specs = prob.streams
        if traces is None:
            traces = [ArrivalTrace.uniform(s.arrival_rate, duration)
                      if arrivals == "uniform"
                      else ArrivalTrace.poisson(s.arrival_rate, duration,
                                                seed + j)
                      for j, s in enumerate(specs)]
        return simulate_multi_tenant(
            self.device, w_tr if prob.train else None,
            [s.workload for s in specs], sol.pm, sol.bss, traces,
            tau_cap=sol.tau_tr, backend=backend)

    # -- dynamic arrival rates (§5.4): re-planning controller ----------------
    def solve_dynamic(self, w: WorkloadProfile, power_budget: float,
                      latency_budget: float, rates: Sequence[float],
                      strategy: str = "gmd") -> list[Optional[P.Solution]]:
        """One solution per rate window, reusing planning state across
        windows: GMD keeps its profiler cache and only re-searches/backtracks
        when the existing observations stop satisfying the new rate; fitted
        strategies (ALS/RND/NN) are fitted once and answer every window."""
        probs = [P.InferProblem(power_budget, latency_budget, float(r))
                 for r in rates]
        if strategy == "gmd":
            # one shared profiler: cached profiles are free, so every window
            # re-searches at full budget but mostly hits the cache; only
            # genuinely new (pm, bs) profiles count against max_tries (§5.4)
            prof = Profiler(self.device, w)
            sols: list[Optional[P.Solution]] = []
            for prob in probs:
                sol = P.solve_infer(prob, prof.observed())
                if sol is None:
                    GMDInfer(prof, self.space).solve(prob)
                    sol = P.solve_infer(prob, prof.observed())
                sols.append(sol)
            return sols
        strat = self._strategy(Scenario.DYNAMIC, strategy, w)
        if hasattr(strat, "solve_batch"):
            return list(strat.solve_batch(probs))
        return [strat.solve(prob) for prob in probs]

    def solve_dynamic_multi_tenant(self, specs: Sequence[P.StreamSpec],
                                   power_budget: float,
                                   rate_windows: Sequence[Sequence[float]],
                                   strategy: str = "gmd",
                                   w_tr: Optional[WorkloadProfile] = None
                                   ) -> list[Optional[P.MultiTenantSolution]]:
        """Dynamic multi-tenant re-planning: one window per per-stream rate
        vector. GMD shares one MultiTenantProfiler across windows (cached
        profiles are free, as in solve_dynamic); fitted strategies answer
        every window from one model."""
        train = w_tr is not None
        probs = [P.MultiTenantProblem(
            power_budget,
            tuple(s.with_rate(r) for s, r in zip(specs, rvec)), train=train)
            for rvec in rate_windows]
        for rvec in rate_windows:
            if len(rvec) != len(specs):
                raise ValueError("each rate window needs one rate per stream")
        if strategy == "gmd":
            mp = _mtprof(self, w_tr, *[s.workload for s in specs])
            sols: list[Optional[P.MultiTenantSolution]] = []
            for prob in probs:
                tobs = mp.train.observed_modes() if mp.train else None
                sol = P.solve_multi_tenant(prob, tobs, mp.infer_observed())
                if sol is None:
                    GMDMultiTenant(mp, self.space).solve(prob)
                    tobs = mp.train.observed_modes() if mp.train else None
                    sol = P.solve_multi_tenant(prob, tobs,
                                               mp.infer_observed())
                sols.append(sol)
            return sols
        strat = self._strategy(Scenario.MULTI_TENANT, strategy,
                               w_tr if train else None,
                               *[s.workload for s in specs])
        return list(strat.solve_batch(probs))

    def serve_dynamic(self, w, power_budget: float,
                      latency_budget: Optional[float], rates: Sequence,
                      strategy: str = "gmd", window_duration: float = 30.0,
                      arrivals: str = "uniform", seed: int = 0,
                      w_tr: Optional[WorkloadProfile] = None,
                      backend: Optional[str] = None) -> list[WindowReport]:
        """Solve and *execute* a dynamic trace: re-plan per rate window, then
        run the engine over each window's arrival trace (uniform ticks or
        seeded Poisson), emitting one ExecutionReport per window. On
        ``backend="jax"`` every solved window's replay runs as one batched
        max-plus-scan program (one lane per window).

        Multi-tenant form: pass ``w`` as a sequence of StreamSpecs (their
        latency budgets apply; ``latency_budget`` is ignored) and each entry
        of ``rates`` as a per-stream rate vector; windows then re-plan the
        N-stream problem and execute the merged trace, reporting one
        MultiTenantReport per window."""
        if isinstance(w, (list, tuple)) and w \
                and isinstance(w[0], P.StreamSpec):
            return self._serve_dynamic_multi(tuple(w), power_budget, rates,
                                             strategy, window_duration,
                                             arrivals, seed, w_tr, backend)
        sols = self.solve_dynamic(w, power_budget, latency_budget, rates,
                                  strategy)
        lanes = []       # solved windows, executed as one engine batch
        for i, (rate, sol) in enumerate(zip(rates, sols)):
            if sol is not None:
                trace = (ArrivalTrace.uniform(rate, window_duration)
                         if arrivals == "uniform"
                         else ArrivalTrace.poisson(rate, window_duration,
                                                   seed + i))
                lanes.append((i, sol, trace))
        reps = simulate_batch(self.device, None, w,
                              [sol.pm for _, sol, _ in lanes],
                              [sol.bs for _, sol, _ in lanes],
                              [tr for _, _, tr in lanes], backend=backend)
        by_window = {i: rep for (i, _, _), rep in zip(lanes, reps)}
        return [WindowReport(float(rate), sol, by_window.get(i))
                for i, (rate, sol) in enumerate(zip(rates, sols))]

    def _serve_dynamic_multi(self, specs, power_budget, rate_windows,
                             strategy, window_duration, arrivals, seed,
                             w_tr, backend=None) -> list[WindowReport]:
        sols = self.solve_dynamic_multi_tenant(specs, power_budget,
                                               rate_windows, strategy, w_tr)
        lanes = []
        for i, (rvec, sol) in enumerate(zip(rate_windows, sols)):
            if sol is not None:
                traces = [ArrivalTrace.uniform(r, window_duration)
                          if arrivals == "uniform"
                          else ArrivalTrace.poisson(r, window_duration,
                                                    seed + i * 101 + j)
                          for j, r in enumerate(rvec)]
                lanes.append((i, sol, traces))
        reps = simulate_multi_tenant_batch(
            self.device, w_tr, [[s.workload for s in specs] for _ in lanes],
            [sol.pm for _, sol, _ in lanes],
            [sol.bss for _, sol, _ in lanes],
            [traces for _, _, traces in lanes],
            tau_caps=[sol.tau_tr for _, sol, _ in lanes], backend=backend)
        by_window = {i: rep for (i, _, _), rep in zip(lanes, reps)}
        return [WindowReport(tuple(float(r) for r in rvec), sol,
                             by_window.get(i))
                for i, (rvec, sol) in enumerate(zip(rate_windows, sols))]
