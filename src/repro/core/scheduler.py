"""Fulcrum: the top-level scheduler (paper Fig. 5).

Given a workload tuple, a problem configuration, and a strategy name, Fulcrum
profiles via the chosen strategy, commits to a (power mode [, beta_in
[, tau_tr]]) plan, and executes it with the trace-driven engine
(``core.simulate``). One strategy registry keyed on ``(Scenario, name)``
replaces the per-scenario factory dicts: every scenario — train / infer /
concurrent / concurrent-inference / dynamic — resolves its solver through the
same table, with fitted strategies (ALS / RND / NN) cached for reuse and GMD
always re-profiling (it is profiling). Dynamic arrival rates (§5.4) run
through a re-planning controller: per-window solutions reuse the profiler
cache (GMD) or the fitted model (everything else), and ``serve_dynamic``
executes each window over its arrival trace, emitting per-window
``ExecutionReport``s. ``serve_dynamic`` is a thin driver over the
``core.controller`` loop: the default ``ControllerConfig`` is the open-loop
oracle-rate configuration (windows independent, replayed as one engine
batch, byte-identical on NumPy to PR-4), while a closed-loop config plans
each window from the previous window's *executed* report — EWMA-estimated
rates, feedback-scaled latency budgets, carried backlog, and mode-switch
cost charged against the switching window.

Contract: inputs are workload profiles + problem dataclasses; outputs are
``Plan``s (committed solutions with profiling cost attached) and engine
reports. Invariants: solving never executes and executing never re-solves —
``execute*`` replays exactly the committed plan (pm, bs, tau_tr cap) through
``core.simulate``; the engine ``backend`` argument (NumPy reference / jax
scan, resolved by ``core.backend``) changes *where* the replay runs, never
*what* plan runs. Registry entries must be pure factories: strategy state
lives in the returned object, so cached reuse is safe per workload tuple.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional, Sequence

from repro.core import problem as P
from repro.core.als import (ALSConcurrent, ALSInfer, ALSMultiTenant, ALSTrain,
                            QuadrantRanges)
from repro.core.controller import ControllerConfig, ControllerState
from repro.core.baselines import (NNConcurrentBaseline, NNInferBaseline,
                                  NNMultiTenantBaseline, NNTrainBaseline,
                                  RNDConcurrent, RNDInfer, RNDMultiTenant,
                                  RNDTrain)
from repro.core.device_model import DeviceModel, Profiler, WorkloadProfile
from repro.core.gmd import (ConcurrentProfiler, GMDConcurrent, GMDInfer,
                            GMDMultiTenant, GMDTrain, MultiTenantProfiler)
from repro.core.interleave import ExecutionReport
from repro.core.oracle import Oracle
from repro.core.powermode import PowerModeSpace
from repro.core.simulate import (ArrivalTrace, MultiTenantReport, QueueState,
                                 first_backlog_crossing, simulate,
                                 simulate_batch, simulate_multi_tenant,
                                 simulate_multi_tenant_batch)

import numpy as np


class Scenario(enum.Enum):
    TRAIN = "train"
    INFER = "infer"
    CONCURRENT = "concurrent"
    CONCURRENT_INFERENCE = "concurrent_inference"
    DYNAMIC = "dynamic"
    MULTI_TENANT = "multi_tenant"
    FLEET = "fleet"

    @property
    def canonical(self) -> "Scenario":
        """The solver family a scenario maps onto: concurrent inference is
        the concurrent problem with the non-urgent inference in the training
        role, dynamic is per-window inference (§5.4), and fleet is K
        per-device dynamic loops — per-window inference again, batched
        over the device axis."""
        return _CANONICAL.get(self, self)


_CANONICAL = {Scenario.CONCURRENT_INFERENCE: Scenario.CONCURRENT,
              Scenario.DYNAMIC: Scenario.INFER,
              Scenario.FLEET: Scenario.INFER}


def as_nonurgent(w: WorkloadProfile, bs: int = 32) -> WorkloadProfile:
    """Cast an inference workload into the training role of the concurrent
    problem: a non-urgent batch inference at a fixed minibatch size (§5.4)."""
    if w.name.endswith("-nonurgent"):
        return w
    return dataclasses.replace(w, name=f"{w.name}-nonurgent", train_bs=bs)


# ---------------------------------------------------------------------------
# strategy registry: one table for every (scenario, strategy) pair
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrategySpec:
    factory: Callable                 # (fulcrum, *workloads) -> strategy
    cached: bool = True               # fitted models are reusable; GMD is not


_REGISTRY: dict[tuple[Scenario, str], StrategySpec] = {}


def register_strategy(scenario: Scenario, name: str, factory: Callable,
                      cached: bool = True) -> None:
    _REGISTRY[(scenario, name)] = StrategySpec(factory, cached)


def available_strategies(scenario: Scenario) -> list[str]:
    canon = scenario.canonical
    return sorted(name for (sc, name) in _REGISTRY if sc is canon)


def _prof(f: "Fulcrum", w: WorkloadProfile) -> Profiler:
    return Profiler(f.device, w)


def _cprof(f: "Fulcrum", w_tr: WorkloadProfile,
           w_in: WorkloadProfile) -> ConcurrentProfiler:
    return ConcurrentProfiler(Profiler(f.device, w_tr),
                              Profiler(f.device, w_in))


register_strategy(Scenario.TRAIN, "gmd",
                  lambda f, w: GMDTrain(_prof(f, w), f.space), cached=False)
register_strategy(Scenario.TRAIN, "als50",
                  lambda f, w: ALSTrain(_prof(f, w), f.space,
                                        nn_epochs=f.nn_epochs))
register_strategy(Scenario.TRAIN, "rnd50",
                  lambda f, w: RNDTrain(_prof(f, w), 50, f.space))
register_strategy(Scenario.TRAIN, "rnd250",
                  lambda f, w: RNDTrain(_prof(f, w), 250, f.space))
register_strategy(Scenario.TRAIN, "nn250",
                  lambda f, w: NNTrainBaseline(_prof(f, w), 250, f.space,
                                               nn_epochs=f.nn_epochs))

register_strategy(Scenario.INFER, "gmd",
                  lambda f, w: GMDInfer(_prof(f, w), f.space), cached=False)
register_strategy(Scenario.INFER, "als145",
                  lambda f, w: ALSInfer(_prof(f, w), f.quadrants, f.space,
                                        nn_epochs=f.nn_epochs))
register_strategy(Scenario.INFER, "rnd150",
                  lambda f, w: RNDInfer(_prof(f, w), 150, f.space))
register_strategy(Scenario.INFER, "rnd250",
                  lambda f, w: RNDInfer(_prof(f, w), 250, f.space))
register_strategy(Scenario.INFER, "nn250",
                  lambda f, w: NNInferBaseline(_prof(f, w), 250, f.space,
                                               nn_epochs=f.nn_epochs))

register_strategy(Scenario.CONCURRENT, "gmd",
                  lambda f, w_tr, w_in: GMDConcurrent(_cprof(f, w_tr, w_in),
                                                      f.space), cached=False)
register_strategy(Scenario.CONCURRENT, "als145",
                  lambda f, w_tr, w_in: ALSConcurrent(
                      _cprof(f, w_tr, w_in), f.quadrants, f.space,
                      nn_epochs=f.nn_epochs))
register_strategy(Scenario.CONCURRENT, "rnd150",
                  lambda f, w_tr, w_in: RNDConcurrent(_cprof(f, w_tr, w_in),
                                                      150, f.space))
register_strategy(Scenario.CONCURRENT, "rnd250",
                  lambda f, w_tr, w_in: RNDConcurrent(_cprof(f, w_tr, w_in),
                                                      250, f.space))
register_strategy(Scenario.CONCURRENT, "nn250",
                  lambda f, w_tr, w_in: NNConcurrentBaseline(
                      _cprof(f, w_tr, w_in), 250, f.space,
                      nn_epochs=f.nn_epochs))


def _mtprof(f: "Fulcrum", w_tr: Optional[WorkloadProfile],
            *stream_ws: WorkloadProfile) -> MultiTenantProfiler:
    return MultiTenantProfiler(
        Profiler(f.device, w_tr) if w_tr is not None else None,
        [Profiler(f.device, w) for w in stream_ws])


register_strategy(Scenario.MULTI_TENANT, "gmd",
                  lambda f, w_tr, *ws: GMDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      f.space), cached=False)
register_strategy(Scenario.MULTI_TENANT, "als145",
                  lambda f, w_tr, *ws: ALSMultiTenant(
                      _mtprof(f, w_tr, *ws), f.quadrants, f.space,
                      nn_epochs=f.nn_epochs))
register_strategy(Scenario.MULTI_TENANT, "rnd150",
                  lambda f, w_tr, *ws: RNDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      150, f.space))
register_strategy(Scenario.MULTI_TENANT, "rnd250",
                  lambda f, w_tr, *ws: RNDMultiTenant(_mtprof(f, w_tr, *ws),
                                                      250, f.space))
register_strategy(Scenario.MULTI_TENANT, "nn250",
                  lambda f, w_tr, *ws: NNMultiTenantBaseline(
                      _mtprof(f, w_tr, *ws), 250, f.space,
                      nn_epochs=f.nn_epochs))


# ---------------------------------------------------------------------------
# plans and per-window results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Plan:
    solution: P.Solution
    strategy: str
    profiling_runs: int
    profiling_cost_s: float
    scenario: Optional[Scenario] = None


@dataclasses.dataclass
class WindowReport:
    """One §5.4 rate window: the rate (a per-stream tuple for multi-tenant
    windows), the (re)planned solution, and the engine's execution report
    (a MultiTenantReport for multi-tenant windows) over that window's
    arrival trace(s). The controller fields record how the window was
    planned: the rate it was actually planned for (the announced rate under
    the open-loop oracle configuration, the estimate under ``"ewma"``),
    whether the committed plan differs from the previous window's,
    the wall seconds charged for switching power modes into this window's
    plan, and how many backlogged requests were carried into the window.

    The burst-survival fields account for graceful degradation
    (``AdmissionPolicy``): how many of the window's offered requests were
    shed at admission, how many were deferred to the next window
    (re-submission semantics — their latency clock restarts), the goodput —
    requests served within the *nominal* latency budget as a fraction of
    the window's own offered arrivals (deferred re-offers served this
    window count toward the numerator, so a drain window can transiently
    exceed 1) — and how many times the window was split for mid-window
    re-planning."""
    rate: object                      # float | tuple[float, ...]
    solution: Optional[object]        # Solution | MultiTenantSolution
    report: Optional[object]          # ExecutionReport | MultiTenantReport
    estimated_rate: Optional[object] = None   # float | tuple[float, ...]
    replanned: bool = False
    mode_switch_s: float = 0.0
    carried_requests: int = 0
    shed_requests: int = 0
    deferred_requests: int = 0
    goodput: Optional[float] = None
    offered_requests: int = 0         # the window's own arrivals (0 when
    splits: int = 0                   # the trace was never generated)


def _poisson_seed(seed: int, window: int, stream: int, n_streams: int) -> int:
    """Collision-free per-(window, stream) Poisson trace seed: windows
    advance in strides of the stream count, so distinct (window, stream)
    pairs never share a seed. (The previous ``seed + 101*window + stream``
    scheme collided whenever a later window's low stream landed on an
    earlier window's stream index >= 101 — impossible per call today, but a
    silent trap for wider tenant counts; the stride now adapts.)"""
    return seed + window * max(1, int(n_streams)) + stream


def _open_goodput(rep, latency_budget) -> Optional[float]:
    """Open-loop goodput: requests served within the nominal budget as a
    fraction of the window's offered arrivals (open loop never sheds, so
    offered == served; an unsolved window offers everything and serves
    nothing). ``None`` when there is no budget to judge against."""
    if latency_budget is None:
        return None
    if rep is None:
        return 0.0
    lats = np.asarray(rep.latencies, np.float64)
    offered = len(rep.trace) if rep.trace is not None else int(lats.size)
    good = int(np.count_nonzero(lats <= float(latency_budget)))
    return good / offered if offered else 1.0


def _replan_flags(sols: Sequence, key) -> list[bool]:
    """Whether each window's committed plan differs from the previously
    committed one (unsolved windows commit nothing)."""
    flags, prev = [], None
    for sol in sols:
        if sol is None:
            flags.append(False)
            continue
        k = key(sol)
        flags.append(k != prev)
        prev = k
    return flags


class Fulcrum:
    def __init__(self, device: Optional[DeviceModel] = None,
                 space: Optional[PowerModeSpace] = None,
                 quadrants: Optional[QuadrantRanges] = None,
                 nn_epochs: int = 400):
        self.device = device or DeviceModel()
        self.space = space or PowerModeSpace()
        self.quadrants = quadrants or QuadrantRanges(latency=(0.05, 2.0),
                                                     arrival=(30.0, 120.0))
        self.nn_epochs = nn_epochs
        self.oracle = Oracle(self.device, self.space)
        self._fitted: dict = {}     # reusable fitted strategies (ALS/RND/NN)

    # -- solve --------------------------------------------------------------
    def solve(self, scenario, workloads: Sequence[WorkloadProfile], prob,
              strategy: str = "gmd") -> Optional[Plan]:
        scenario = Scenario(scenario)
        s = self._strategy(scenario, strategy, *workloads)
        return self._plan(s.solve(prob), s, strategy, scenario)

    def solve_train(self, w: WorkloadProfile, prob: P.TrainProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.TRAIN, (w,), prob, strategy)

    def solve_infer(self, w: WorkloadProfile, prob: P.InferProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.INFER, (w,), prob, strategy)

    def solve_concurrent(self, w_tr: WorkloadProfile, w_in: WorkloadProfile,
                         prob: P.ConcurrentProblem,
                         strategy: str = "gmd") -> Optional[Plan]:
        return self.solve(Scenario.CONCURRENT, (w_tr, w_in), prob, strategy)

    def solve_concurrent_inference(self, w_nonurgent: WorkloadProfile,
                                   w_urgent: WorkloadProfile,
                                   prob: P.ConcurrentProblem,
                                   strategy: str = "gmd",
                                   nonurgent_bs: int = 32) -> Optional[Plan]:
        """§5.4 concurrent inferences: maximize the non-urgent inference's
        throughput under the urgent inference's latency deadline."""
        return self.solve(Scenario.CONCURRENT_INFERENCE,
                          (as_nonurgent(w_nonurgent, nonurgent_bs), w_urgent),
                          prob, strategy)

    def solve_multi_tenant(self, w_tr: Optional[WorkloadProfile],
                           prob: P.MultiTenantProblem,
                           strategy: str = "gmd") -> Optional[Plan]:
        """N tenant inference streams + a training fill workload under one
        power budget; stream workloads come from the problem's StreamSpecs.
        The Plan's solution is a MultiTenantSolution (per-stream bs/latency)."""
        ws = tuple(s.workload for s in prob.streams)
        if any(w is None for w in ws):
            raise ValueError("every StreamSpec needs a workload to solve a "
                             "multi-tenant scenario")
        if prob.train and w_tr is None:
            raise ValueError("prob.train is set but no train workload given")
        return self.solve(Scenario.MULTI_TENANT,
                          (w_tr if prob.train else None,) + ws, prob, strategy)

    def strategy_for(self, scenario, name: str, *workloads: WorkloadProfile):
        """Resolve (scenario, strategy) through the registry; fitted
        strategies are cached per workload tuple, GMD never is."""
        return self._strategy(Scenario(scenario), name, *workloads)

    def _strategy(self, scenario: Scenario, name: str,
                  *workloads: WorkloadProfile):
        if scenario is Scenario.CONCURRENT_INFERENCE:
            # the scenario's defining cast (non-urgent inference in the
            # training role, fixed bs), applied regardless of entry point
            workloads = (as_nonurgent(workloads[0]),) + workloads[1:]
        spec = _REGISTRY.get((scenario.canonical, name))
        if spec is None:
            raise KeyError(
                f"no strategy {name!r} for scenario {scenario.value!r}; "
                f"available: {available_strategies(scenario)}")
        if not spec.cached:
            return spec.factory(self, *workloads)
        key = (scenario.canonical.value, name,
               tuple(w.name if w is not None else None for w in workloads))
        if key not in self._fitted:
            self._fitted[key] = spec.factory(self, *workloads)
        return self._fitted[key]

    def _plan(self, sol, strat, name, scenario=None) -> Optional[Plan]:
        if sol is None:
            return None
        prof = getattr(strat, "profiler", None) or getattr(strat, "cp", None) \
            or getattr(strat, "mp", None)
        runs = prof.num_runs if prof is not None else 0
        cost = prof.profile_cost_s if prof is not None else 0.0
        return Plan(solution=sol, strategy=name, profiling_runs=runs,
                    profiling_cost_s=cost, scenario=scenario)

    # -- execute (trace-driven engine over the device model) ----------------
    def execute(self, plan: Plan, w_in: WorkloadProfile,
                w_tr: Optional[WorkloadProfile] = None,
                arrival_rate: Optional[float] = None,
                duration: float = 120.0,
                trace: Optional[ArrivalTrace] = None,
                approach: str = "managed", seed: int = 0,
                backend: Optional[str] = None) -> ExecutionReport:
        """Execute a solved plan: the plan's power mode and minibatch size
        drive the engine, managed slack-fill is capped at the committed
        tau_tr, and the returned report carries the trace that was run.
        ``backend`` selects the engine implementation (NumPy reference or
        the jax max-plus scan), as in ``core.simulate.simulate``."""
        if trace is None:
            if arrival_rate is None:
                raise ValueError("execute() needs an arrival_rate or a trace")
            trace = ArrivalTrace.uniform(arrival_rate, duration)
        sol = plan.solution
        if sol.bs is None:
            raise ValueError(
                f"plan ({plan.strategy}) has no inference minibatch size; "
                "solve an infer/concurrent scenario before executing")
        return simulate(self.device, w_tr, w_in, sol.pm, sol.bs, trace,
                        approach=approach, seed=seed, tau_cap=sol.tau_tr,
                        backend=backend)

    def execute_multi_tenant(self, plan: Plan, prob: P.MultiTenantProblem,
                             w_tr: Optional[WorkloadProfile] = None,
                             traces: Optional[Sequence[ArrivalTrace]] = None,
                             duration: float = 120.0,
                             arrivals: str = "uniform",
                             seed: int = 0,
                             backend: Optional[str] = None
                             ) -> MultiTenantReport:
        """Execute a multi-tenant plan: per-stream minibatch sizes drive the
        N-stream managed engine over one trace per tenant (built from each
        stream's arrival rate unless given), slack-fill capped at tau_tr."""
        sol = plan.solution
        if not isinstance(sol, P.MultiTenantSolution):
            raise ValueError(f"plan ({plan.strategy}) is not multi-tenant; "
                             "use execute()")
        if prob.train and w_tr is None:
            raise ValueError("prob.train is set but no train workload given; "
                             "executing without it would silently drop the "
                             "plan's training fill")
        specs = prob.streams
        if traces is None:
            traces = [ArrivalTrace.uniform(s.arrival_rate, duration)
                      if arrivals == "uniform"
                      else ArrivalTrace.poisson(s.arrival_rate, duration,
                                                seed + j)
                      for j, s in enumerate(specs)]
        return simulate_multi_tenant(
            self.device, w_tr if prob.train else None,
            [s.workload for s in specs], sol.pm, sol.bss, traces,
            tau_cap=sol.tau_tr, backend=backend)

    # -- dynamic arrival rates (§5.4): re-planning controller ----------------
    def _dynamic_solver(self, w: WorkloadProfile, strategy: str
                        ) -> tuple[Callable, Optional[Callable],
                                   Optional[Callable]]:
        """One-window solvers carrying planning state across windows (the
        §5.4 reuse rules): GMD shares one profiler — cached profiles are
        free, so every window re-searches at full budget but mostly hits
        the cache; only genuinely new (pm, bs) profiles count against
        max_tries — and fitted strategies (ALS/RND/NN) answer every window
        from one model. Returns ``(solve, interval_solve, capacity_solve)``:
        ``interval_solve(prob, rate_hi)`` plans the rate interval
        [prob.arrival_rate, rate_hi] (closed-loop margin headroom);
        ``capacity_solve(power_budget)`` returns the max-service-rate plan
        over the profiled observations (the ``degrade-bs`` admission
        fallback). Both are None for fitted strategies, which only answer
        point problems."""
        if strategy == "gmd":
            prof = Profiler(self.device, w)

            def solve(prob: P.InferProblem) -> Optional[P.Solution]:
                sol = P.solve_infer(prob, prof.observed())
                if sol is None:
                    GMDInfer(prof, self.space).solve(prob)
                    sol = P.solve_infer(prob, prof.observed())
                return sol

            def interval_solve(prob: P.InferProblem,
                               rate_hi: float) -> Optional[P.Solution]:
                sol = P.solve_infer_interval(prob, rate_hi, prof.observed())
                if sol is None:
                    # profile modes able to serve the high-rate demand,
                    # then re-scan the interval over the grown cache
                    GMDInfer(prof, self.space).solve(
                        dataclasses.replace(prob, arrival_rate=rate_hi))
                    sol = P.solve_infer_interval(prob, rate_hi,
                                                 prof.observed())
                return sol

            def capacity_solve(power_budget: float) -> Optional[P.Solution]:
                return P.solve_infer_capacity(power_budget, prof.observed())

            return solve, interval_solve, capacity_solve
        return self._strategy(Scenario.DYNAMIC, strategy, w).solve, None, None

    def solve_dynamic(self, w: WorkloadProfile, power_budget: float,
                      latency_budget: float, rates: Sequence[float],
                      strategy: str = "gmd") -> list[Optional[P.Solution]]:
        """One solution per rate window, reusing planning state across
        windows: GMD keeps its profiler cache and only re-searches/backtracks
        when the existing observations stop satisfying the new rate; fitted
        strategies (ALS/RND/NN) are fitted once and answer every window."""
        probs = [P.InferProblem(power_budget, latency_budget, float(r))
                 for r in rates]
        if strategy != "gmd":
            strat = self._strategy(Scenario.DYNAMIC, strategy, w)
            if hasattr(strat, "solve_batch"):
                return list(strat.solve_batch(probs))
        solve, _, _ = self._dynamic_solver(w, strategy)
        return [solve(prob) for prob in probs]

    def _dynamic_multi_solver(self, specs: Sequence[P.StreamSpec],
                              strategy: str,
                              w_tr: Optional[WorkloadProfile]
                              ) -> tuple[Callable, Optional[Callable]]:
        """The multi-tenant counterpart of ``_dynamic_solver``: GMD shares
        one MultiTenantProfiler across windows; fitted strategies answer
        every window from one model. Returns ``(solve, interval_solve)`` —
        the second only for GMD, judging sustainability and training
        throughput at margined per-stream rates while the latency budgets
        hold at the unmargined estimates (``solve_multi_tenant_interval``);
        fitted strategies answer point problems only and get ``None``."""
        if strategy == "gmd":
            mp = _mtprof(self, w_tr, *[s.workload for s in specs])

            def solve(prob: P.MultiTenantProblem
                      ) -> Optional[P.MultiTenantSolution]:
                tobs = mp.train.observed_modes() if mp.train else None
                sol = P.solve_multi_tenant(prob, tobs, mp.infer_observed())
                if sol is None:
                    GMDMultiTenant(mp, self.space).solve(prob)
                    tobs = mp.train.observed_modes() if mp.train else None
                    sol = P.solve_multi_tenant(prob, tobs,
                                               mp.infer_observed())
                return sol

            def interval_solve(prob: P.MultiTenantProblem,
                               rate_his: Sequence[float]
                               ) -> Optional[P.MultiTenantSolution]:
                tobs = mp.train.observed_modes() if mp.train else None
                sol = P.solve_multi_tenant_interval(prob, rate_his, tobs,
                                                    mp.infer_observed())
                if sol is None:
                    # profile toward the margined rates so modes with that
                    # much service headroom enter the observation set
                    GMDMultiTenant(mp, self.space).solve(
                        P.MultiTenantProblem(
                            prob.power_budget,
                            tuple(dataclasses.replace(
                                s, arrival_rate=float(h))
                                for s, h in zip(prob.streams, rate_his)),
                            train=prob.train, priorities=prob.priorities))
                    tobs = mp.train.observed_modes() if mp.train else None
                    sol = P.solve_multi_tenant_interval(
                        prob, rate_his, tobs, mp.infer_observed())
                return sol

            return solve, interval_solve
        return self._strategy(Scenario.MULTI_TENANT, strategy, w_tr,
                              *[s.workload for s in specs]).solve, None

    def solve_dynamic_multi_tenant(self, specs: Sequence[P.StreamSpec],
                                   power_budget: float,
                                   rate_windows: Sequence[Sequence[float]],
                                   strategy: str = "gmd",
                                   w_tr: Optional[WorkloadProfile] = None
                                   ) -> list[Optional[P.MultiTenantSolution]]:
        """Dynamic multi-tenant re-planning: one window per per-stream rate
        vector. GMD shares one MultiTenantProfiler across windows (cached
        profiles are free, as in solve_dynamic); fitted strategies answer
        every window from one model."""
        train = w_tr is not None
        probs = [P.MultiTenantProblem(
            power_budget,
            tuple(s.with_rate(r) for s, r in zip(specs, rvec)), train=train)
            for rvec in rate_windows]
        for rvec in rate_windows:
            if len(rvec) != len(specs):
                raise ValueError("each rate window needs one rate per stream")
        if strategy != "gmd":
            strat = self._strategy(Scenario.MULTI_TENANT, strategy,
                                   w_tr if train else None,
                                   *[s.workload for s in specs])
            return list(strat.solve_batch(probs))
        solve, _ = self._dynamic_multi_solver(specs, strategy, w_tr)
        return [solve(prob) for prob in probs]

    def serve_dynamic(self, w, power_budget: float,
                      latency_budget: Optional[float], rates: Sequence,
                      strategy: str = "gmd", window_duration: float = 30.0,
                      arrivals: str = "uniform", seed: int = 0,
                      w_tr: Optional[WorkloadProfile] = None,
                      backend: Optional[str] = None,
                      controller: Optional[ControllerConfig] = None
                      ) -> list[WindowReport]:
        """Solve and *execute* a dynamic trace: re-plan per rate window, then
        run the engine over each window's arrival trace (uniform ticks or
        seeded Poisson), emitting one ExecutionReport per window.

        ``controller`` selects the loop (``core.controller``). The default
        config is *open loop* — each window planned from its announced rate
        with the nominal budget, windows independent — and windows then
        replay as one engine batch (one max-plus-scan lane per window on
        ``backend="jax"``), byte-identical on NumPy to the PR-4 behavior.
        A closed-loop config (EWMA rate estimation, executed-latency
        feedback, backlog carryover, mode-switch cost) runs the windows
        sequentially in absolute time: window k+1 is planned from window
        k's executed report and resumes from its queue state.

        Multi-tenant form: pass ``w`` as a sequence of StreamSpecs (their
        latency budgets apply; ``latency_budget`` is ignored) and each entry
        of ``rates`` as a per-stream rate vector; windows then re-plan the
        N-stream problem and execute the merged trace, reporting one
        MultiTenantReport per window. Controller state (rate estimates,
        budget feedback) is kept per stream."""
        cfg = controller if controller is not None else ControllerConfig()
        if isinstance(w, (list, tuple)) and w \
                and isinstance(w[0], P.StreamSpec):
            return self._serve_dynamic_multi(tuple(w), power_budget, rates,
                                             strategy, window_duration,
                                             arrivals, seed, w_tr, backend,
                                             cfg)
        if cfg.closed_loop:
            return self._serve_closed_loop(w, power_budget, latency_budget,
                                           rates, strategy, window_duration,
                                           arrivals, seed, backend, cfg)
        sols = self.solve_dynamic(w, power_budget, latency_budget, rates,
                                  strategy)
        lanes = []       # solved windows, executed as one engine batch
        for i, (rate, sol) in enumerate(zip(rates, sols)):
            if sol is not None:
                trace = (ArrivalTrace.uniform(rate, window_duration)
                         if arrivals == "uniform"
                         else ArrivalTrace.poisson(rate, window_duration,
                                                   seed + i))
                lanes.append((i, sol, trace))
        reps = simulate_batch(self.device, None, w,
                              [sol.pm for _, sol, _ in lanes],
                              [sol.bs for _, sol, _ in lanes],
                              [tr for _, _, tr in lanes], backend=backend)
        by_window = {i: rep for (i, _, _), rep in zip(lanes, reps)}
        replanned = _replan_flags(sols, lambda s: (s.pm, s.bs, s.tau_tr))
        return [WindowReport(float(rate), sol, by_window.get(i),
                             estimated_rate=float(rate), replanned=rp,
                             goodput=_open_goodput(by_window.get(i),
                                                   latency_budget),
                             offered_requests=len(by_window[i].trace)
                             if i in by_window
                             and by_window[i].trace is not None else 0)
                for i, (rate, sol, rp)
                in enumerate(zip(rates, sols, replanned))]

    def serve_fleet(self, w: WorkloadProfile, power_budget: float,
                    latency_budget: float, rates: Sequence[float],
                    fleet, window_duration: float = 30.0,
                    arrivals: str = "uniform", seed: int = 0,
                    backend: Optional[str] = None,
                    controller: Optional[ControllerConfig] = None):
        """``Scenario.FLEET``: serve one aggregate dynamic trace on a
        K-device heterogeneous fleet (``fleet`` is a ``core.fleet.FleetSpec``
        or a device count), dispatching each window's arrivals across
        devices and stepping all K closed-loop controller windows as one
        batched program (one batched grid solve per ladder rung, one
        ``simulate_batch`` with per-lane devices per window). Fleet-wide
        resource control is opt-in: ``controller.admission`` runs the exact
        deadline-drop mask per device with rejected requests shed or
        re-entering the *dispatcher* (defer), ``FleetSpec.migrate_backlog``
        re-dispatches carried backlog between windows, and
        ``FleetSpec.fleet_power_budget`` water-fills one shared cap into
        per-device budgets. Returns one ``FleetWindowReport`` per window;
        bitwise-identical on NumPy to K sequential single-device loops
        (``fleet.serve_fleet_sequential``) for every feature combination."""
        from repro.core import fleet as F
        spec = F.FleetSpec(int(fleet)) if not isinstance(fleet, F.FleetSpec) \
            else fleet
        return F.serve_fleet(w, power_budget, latency_budget, rates, spec,
                             window_duration=window_duration,
                             arrivals=arrivals, seed=seed, backend=backend,
                             controller=controller, space=self.space)

    def _serve_closed_loop(self, w, power_budget, latency_budget, rates,
                           strategy, window_duration, arrivals, seed,
                           backend, cfg) -> list[WindowReport]:
        """Single-stream closed loop: one window at a time, in absolute
        time (window k starts at k * window_duration), each plan fed by the
        controller's rate estimate and effective budget, each executed
        report folded back into the controller state. Burst survival rides
        on top (``_closed_loop_window``): admission control trims the
        window's trace before execution, burst-quantile planning sizes the
        service headroom at the window's Poisson arrival-count quantile,
        and a backlog crossing splits the window to re-enter the controller
        early. With those knobs at their defaults the pass degenerates to
        the plain PR-5 loop, byte-identical on NumPy."""
        state = ControllerState(cfg, 1)
        adm = cfg.admission_policy()
        solvers = self._dynamic_solver(w, strategy)
        out: list[WindowReport] = []
        prev_key = None
        for i, rate in enumerate(rates):
            t0 = i * window_duration
            win = (ArrivalTrace.uniform(rate, window_duration)
                   if arrivals == "uniform"
                   else ArrivalTrace.poisson(rate, window_duration,
                                             seed + i)).shifted(t0)
            wr = self._closed_loop_window(
                w, power_budget, latency_budget, float(rate), win, t0,
                t0 + window_duration, window_duration, state, adm, cfg,
                solvers, backend, prev_key)
            if wr.solution is not None:
                prev_key = (wr.solution.pm, wr.solution.bs,
                            wr.solution.tau_tr)
            out.append(wr)
        return out

    def _closed_loop_window(self, w, power_budget, latency_budget, rate,
                            win, t0, t1, window_duration, state, adm, cfg,
                            solvers, backend, prev_key) -> WindowReport:
        """One announced rate window of the single-stream closed loop,
        possibly served as several sub-windows: plan, admission-trim,
        execute — and when the backlog crosses ``cfg.split_backlog``
        mid-window, replay only the prefix up to the crossing arrival (a
        bitwise prefix of the full run, by the carryover replay contract),
        fold it into the controller state, and re-enter planning at the
        crossing. Deferred requests re-enter the next sub-window
        re-timestamped at its start."""
        solve, interval_solve, capacity_solve = solvers
        t_cur, remaining = t0, win
        splits = 0
        subs = []                 # (sol, rep, switch_s) per executed piece
        shed = deferred_out = 0
        est0 = carried0 = None
        unserved = False
        while True:
            # first sub-window: the exact PR-5 planning inputs (dur is the
            # announced duration, not t1 - t0, which can differ in the last
            # float ulp)
            dur = window_duration if t_cur == t0 else t1 - t_cur
            hi = state.plan_rates([rate], t_cur, dur)[0]
            # the interval's low end is the raw rate estimate — no backlog
            # compensation: once the carried backlog drains, arrivals
            # resume at the estimate, and that is the rate the batch-fill
            # wait (and so the budget check) must be judged at
            est = state.plan_rates([rate], t_cur, dur,
                                   margin=1.0, pressure=False)[0]
            if cfg.burst_quantile > 0.0:
                # survive the window's upper-tail arrival count, not just
                # its mean: service headroom sized at the Poisson quantile
                hi = max(hi, P.burst_rate(est, dur, cfg.burst_quantile))
            bud = state.plan_budgets([latency_budget])[0]
            carried = len(state.carry) if cfg.carry_backlog \
                and state.carry is not None else 0
            if est0 is None:
                est0, carried0 = est, carried
            sol = None
            if hi > est:
                # margin headroom: sustainable up to the margined rate,
                # latency budget held at the estimate — the batch-fill
                # wait (bs-1)/alpha is longest at the LOW rate, so a plan
                # sized for the high rate alone would silently break the
                # budget whenever fewer requests actually arrive. When the
                # full-margin interval is infeasible (the device cannot
                # give that much headroom and stay within budget), shrink
                # the margin rather than forfeiting all headroom at once.
                if interval_solve is not None:
                    sol = interval_solve(
                        P.InferProblem(power_budget, bud, est), hi)
                    if sol is None:
                        # dead zone: no plan serves the margined rate AND
                        # holds the budget at the estimate. Prefer the
                        # high end — an unsustainable plan floods the
                        # window (and, with carryover, taxes the next),
                        # while a too-big batch overshoots the budget by a
                        # bounded fill-wait only
                        sol = solve(P.InferProblem(power_budget, bud, hi))
                else:
                    # fitted strategies answer point problems only: take
                    # the margined plan if it passes the down-move guard
                    cand = solve(P.InferProblem(power_budget, bud, hi))
                    if cand is not None:
                        t_in = cand.time - P.queueing_time(cand.bs, hi)
                        if P.peak_latency(cand.bs, est, t_in) <= bud + 1e-12:
                            sol = cand
            if sol is None:
                sol = solve(P.InferProblem(power_budget, bud, est))
            if sol is None and bud < latency_budget:
                # a budget our own feedback tightened into infeasibility:
                # serving at the nominal budget beats not serving at all
                sol = solve(P.InferProblem(power_budget,
                                           float(latency_budget), est))
            deferred_in = state.pop_deferred(t_cur)[0] if adm.active \
                else None
            if adm.mode == "degrade-bs" and sol is not None:
                sol = self._degrade_plan(w, power_budget, sol, est, carried
                                         + (deferred_in.size
                                            if deferred_in is not None
                                            else 0),
                                         dur, hi, solve, capacity_solve)
            if sol is None:
                if deferred_in is not None and deferred_in.size:
                    # nothing serves this piece: re-defer the re-offers
                    shed += state.push_deferred([int(deferred_in.size)])
                state.observe_unserved([remaining], dur)
                unserved = True
                break
            switch_s = state.mode_switch(sol.pm)
            carry_in = state.window_carry_in(t_cur, switch_s)
            eff = remaining
            if deferred_in is not None and deferred_in.size:
                eff = ArrivalTrace.concat(
                    [ArrivalTrace(deferred_in, remaining.duration,
                                  remaining.kind), remaining],
                    duration=remaining.duration)
            run_trace, run_carry = eff, carry_in
            rej_times = None
            if adm.trims:
                t_in = self.device.time_power(w, sol.pm, sol.bs)[0]
                k0 = len(carry_in)
                all_times = np.concatenate([carry_in.pending, eff.times])
                mask = adm.admit(all_times, latency_budget, sol.bs, t_in,
                                 carry_in.clock)
                if not mask.all():
                    run_carry = QueueState(carry_in.pending[mask[:k0]],
                                           carry_in.clock)
                    run_trace = ArrivalTrace(eff.times[mask[k0:]],
                                             eff.duration, eff.kind)
                    rej_times = all_times[~mask]
            rep = simulate(self.device, None, w, sol.pm, sol.bs, run_trace,
                           "managed", tau_cap=sol.tau_tr, backend=backend,
                           carry_in=run_carry)
            split_t = None
            if cfg.split_backlog is not None and splits < cfg.max_splits:
                split_t = self._find_split(run_carry, run_trace, rep,
                                           sol.bs, cfg.split_backlog,
                                           t_cur, t1, window_duration)
            if split_t is not None:
                # serve only the prefix up to the crossing — a bitwise
                # prefix of the run above (clip keeps absolute times; the
                # chained QueueState re-enters the identical recurrence) —
                # and re-plan the remainder from the crossing
                rep = simulate(self.device, None, w, sol.pm, sol.bs,
                               run_trace.clip(t_cur, split_t), "managed",
                               tau_cap=sol.tau_tr, backend=backend,
                               carry_in=run_carry)
            t_hi = t1 if split_t is None else split_t
            if rej_times is not None:
                # admission decisions stand only for the piece that ran;
                # rejections at/after a split are re-decided next pass
                n_rej = int(np.count_nonzero(rej_times < t_hi))
                if adm.mode == "defer":
                    dropped = state.push_deferred([n_rej])
                    deferred_out += n_rej - dropped
                    shed += dropped
                else:
                    shed += n_rej
            raw_obs = remaining if split_t is None \
                else remaining.clip(t_cur, split_t)
            state.observe([raw_obs], [rep], [latency_budget],
                          dur if split_t is None else split_t - t_cur,
                          rep.queue_state)
            subs.append((sol, rep, switch_s))
            if split_t is None:
                break
            splits += 1
            t_cur = split_t
            remaining = remaining.clip(split_t, t1)
        offered = len(win)
        if not subs:
            return WindowReport(rate, None, None, estimated_rate=est0,
                                carried_requests=carried0,
                                shed_requests=shed,
                                deferred_requests=deferred_out,
                                goodput=0.0 if offered else 1.0,
                                offered_requests=offered, splits=splits)
        sol_f, rep_f, _ = subs[-1]
        if len(subs) == 1 and not unserved:
            rep, switch_total = rep_f, subs[0][2]
        else:
            lats = np.concatenate([np.asarray(r.latencies, np.float64)
                                   for _, r, _ in subs])
            rep = ExecutionReport(
                "managed", lats,
                sum(r.train_minibatches for _, r, _ in subs),
                window_duration, max(r.power for _, r, _ in subs), win,
                queue_state=rep_f.queue_state)
            switch_total = sum(s for _, _, s in subs)
        good = int(np.count_nonzero(np.asarray(rep.latencies, np.float64)
                                    <= latency_budget))
        gp = good / offered if offered else 1.0
        rep.shed_requests, rep.deferred_requests = shed, deferred_out
        rep.goodput = gp
        key = (sol_f.pm, sol_f.bs, sol_f.tau_tr)
        return WindowReport(rate, sol_f, rep, estimated_rate=est0,
                            replanned=key != prev_key,
                            mode_switch_s=switch_total,
                            carried_requests=carried0,
                            shed_requests=shed,
                            deferred_requests=deferred_out,
                            goodput=gp, offered_requests=offered,
                            splits=splits)

    def _degrade_plan(self, w, power_budget, sol, est, n_waiting, dur, hi,
                      solve, capacity_solve):
        """The ``degrade-bs`` admission mode: when the window's demand
        (carried backlog + deferred re-offers + estimated arrivals) is not
        drainable under the committed plan, swap in a higher-capacity plan
        and accept the latency violations — serve everything, degraded.
        GMD takes the max-service-rate plan over its profiled observations;
        fitted strategies (no observation dict) re-solve at the margined
        rate with the latency budget waived."""
        t_in = self.device.time_power(w, sol.pm, sol.bs)[0]
        if P.drainable(n_waiting, est, sol.bs, t_in, dur):
            return sol
        cand = capacity_solve(power_budget) if capacity_solve is not None \
            else solve(P.InferProblem(power_budget, float("inf"), hi))
        if cand is None:
            return sol
        c_t = self.device.time_power(w, cand.pm, cand.bs)[0]
        return cand if cand.bs / c_t > sol.bs / t_in else sol

    def _find_split(self, carry, trace, rep, bs, threshold, t_cur, t1,
                    window_duration):
        """Where to split a running window for mid-window re-planning: the
        timestamp of the first arrival whose backlog exceeds the threshold,
        provided it falls strictly inside the piece and leaves a meaningful
        remainder (>= 5% of the window) to re-plan."""
        bs = int(bs)
        lats = np.asarray(rep.latencies, np.float64)
        times = np.concatenate([carry.pending, trace.times]) if len(carry) \
            else trace.times
        # batch completions, recovered from the report's latencies (the
        # last request of each minibatch: latency + arrival = completion;
        # ulp-level roundtrip error cannot move a count-based crossing)
        comps = lats[bs - 1::bs] + times[bs - 1:lats.size:bs]
        idx = first_backlog_crossing(times, comps, bs, threshold)
        if idx is None:
            return None
        ts = float(times[idx])
        if ts <= t_cur or (t1 - ts) < 0.05 * window_duration:
            return None
        return ts

    def _serve_dynamic_multi(self, specs, power_budget, rate_windows,
                             strategy, window_duration, arrivals, seed,
                             w_tr, backend, cfg) -> list[WindowReport]:
        if cfg.closed_loop:
            return self._serve_multi_closed_loop(
                specs, power_budget, rate_windows, strategy, window_duration,
                arrivals, seed, w_tr, backend, cfg)
        n = len(specs)
        sols = self.solve_dynamic_multi_tenant(specs, power_budget,
                                               rate_windows, strategy, w_tr)
        lanes = []
        for i, (rvec, sol) in enumerate(zip(rate_windows, sols)):
            if sol is not None:
                traces = [ArrivalTrace.uniform(r, window_duration)
                          if arrivals == "uniform"
                          else ArrivalTrace.poisson(
                              r, window_duration, _poisson_seed(seed, i, j, n))
                          for j, r in enumerate(rvec)]
                lanes.append((i, sol, traces))
        reps = simulate_multi_tenant_batch(
            self.device, w_tr, [[s.workload for s in specs] for _ in lanes],
            [sol.pm for _, sol, _ in lanes],
            [sol.bss for _, sol, _ in lanes],
            [traces for _, _, traces in lanes],
            tau_caps=[sol.tau_tr for _, sol, _ in lanes], backend=backend)
        by_window = {i: rep for (i, _, _), rep in zip(lanes, reps)}
        replanned = _replan_flags(
            sols, lambda s: (s.pm, tuple(s.bss), s.tau_tr))
        nominals = [s.latency_budget for s in specs]
        gps, offers = {}, {}
        for (i, _, traces), rep in zip(lanes, reps):
            offered = sum(len(tr) for tr in traces)
            good = sum(int(np.count_nonzero(
                np.asarray(r.latencies, np.float64) <= nb))
                for r, nb in zip(rep.streams, nominals))
            gps[i] = good / offered if offered else 1.0
            offers[i] = offered
            rep.goodput = gps[i]
        return [WindowReport(tuple(float(r) for r in rvec), sol,
                             by_window.get(i),
                             estimated_rate=tuple(float(r) for r in rvec),
                             replanned=rp, goodput=gps.get(i, 0.0),
                             offered_requests=offers.get(i, 0))
                for i, (rvec, sol, rp)
                in enumerate(zip(rate_windows, sols, replanned))]

    def _serve_multi_closed_loop(self, specs, power_budget, rate_windows,
                                 strategy, window_duration, arrivals, seed,
                                 w_tr, backend, cfg) -> list[WindowReport]:
        """N-stream closed loop: per-stream rate estimators and feedback
        policies (each tenant's budget tightens and relaxes independently),
        one merged engine run per window with shared backlog carryover.

        Burst survival mirrors the single-stream driver: GMD plans through
        the rate-*interval* solve (``solve_multi_tenant_interval`` —
        sustainability and training throughput judged at the margined
        per-stream rates, latency budgets at the unmargined estimates;
        fitted strategies keep the point solve + down-move guard), the
        burst quantile lifts each stream's high rate to its window arrival-
        count quantile, and a ``shed``/``defer`` policy trims the merged
        arrival vector through the priority-aware multi gate before the
        engine runs. Windows are not split mid-flight here (the N-stream
        engine's merged batching makes a prefix replay stream-coupled);
        ``degrade-bs`` likewise degenerates to no trimming — both are
        single-stream refinements."""
        n = len(specs)
        state = ControllerState(cfg, n)
        adm = cfg.admission_policy()
        solve, interval_solve = self._dynamic_multi_solver(specs, strategy,
                                                           w_tr)
        nominals = [s.latency_budget for s in specs]
        train = w_tr is not None
        out: list[WindowReport] = []
        prev_key = None
        for i, rvec in enumerate(rate_windows):
            if len(rvec) != n:
                raise ValueError("each rate window needs one rate per stream")
            t0 = i * window_duration
            traces = [(ArrivalTrace.uniform(r, window_duration)
                       if arrivals == "uniform"
                       else ArrivalTrace.poisson(
                           r, window_duration,
                           _poisson_seed(seed, i, j, n))).shifted(t0)
                      for j, r in enumerate(rvec)]
            est = state.plan_rates(rvec, t0, window_duration)
            # low end raw (no backlog compensation), as in the single-
            # stream driver: the budget guard belongs at the estimate
            base = state.plan_rates(rvec, t0, window_duration, margin=1.0,
                                    pressure=False)
            if cfg.burst_quantile > 0.0:
                # survive each stream's upper-tail arrival count, not just
                # its mean: headroom sized at the Poisson window quantile
                est = [max(e, P.burst_rate(b, window_duration,
                                           cfg.burst_quantile))
                       for e, b in zip(est, base)]
            buds = state.plan_budgets(nominals)
            carried = len(state.carry) if cfg.carry_backlog \
                and state.carry is not None else 0

            def _prob(rs, bs_):
                return P.MultiTenantProblem(
                    power_budget,
                    tuple(dataclasses.replace(s, arrival_rate=float(r),
                                              latency_budget=float(b))
                          for s, r, b in zip(specs, rs, bs_)), train=train,
                    priorities=cfg.priorities)

            sol = None
            if est != base:
                if interval_solve is not None:
                    # rate-interval plan: sustainability and training
                    # throughput at the margined rates, latency budgets
                    # pinned at the unmargined estimates
                    sol = interval_solve(_prob(base, buds), est)
                    if sol is None:
                        # dead zone — prefer the high end, as in the
                        # single-stream driver: an unsustainable plan
                        # floods every stream's shared queue
                        sol = solve(_prob(est, buds))
                else:
                    # fitted strategies answer point problems only: keep
                    # the margined plan if every stream's batch-fill wait
                    # still fits its budget at the unmargined estimate
                    sol = solve(_prob(est, buds))
                    if sol is not None:
                        for lam, b_, rm, rb, bud in zip(sol.times, sol.bss,
                                                        est, base, buds):
                            t_in = lam - P.queueing_time(b_, rm)
                            if P.peak_latency(b_, rb, t_in) > bud + 1e-12:
                                sol = None
                                break
            if sol is None:
                est = base
                sol = solve(_prob(est, buds))
            if sol is None and any(b < nb
                                   for b, nb in zip(buds, nominals)):
                # feedback-tightened into infeasibility: fall back to the
                # nominal per-stream budgets rather than dropping the window
                sol = solve(P.MultiTenantProblem(
                    power_budget,
                    tuple(dataclasses.replace(s, arrival_rate=float(r))
                          for s, r in zip(specs, est)), train=train,
                    priorities=cfg.priorities))
            rate = tuple(float(r) for r in rvec)
            deferred_in = state.pop_deferred(t0) if adm.active else None
            shed = deferred_out = 0
            if sol is None:
                if deferred_in is not None:
                    # nothing serves this window: re-defer the re-offers
                    shed += state.push_deferred(
                        [int(d.size) for d in deferred_in])
                state.observe_unserved(traces, window_duration)
                offered = sum(len(tr) for tr in traces)
                out.append(WindowReport(rate, None, None,
                                        estimated_rate=tuple(est),
                                        carried_requests=carried,
                                        shed_requests=shed,
                                        goodput=0.0 if offered else 1.0,
                                        offered_requests=offered))
                continue
            switch_s = state.mode_switch(sol.pm)
            carry_in = state.window_carry_in(t0, switch_s)
            eff = traces
            if deferred_in is not None and any(d.size for d in deferred_in):
                eff = [ArrivalTrace(np.concatenate([d, tr.times]),
                                    tr.duration, tr.kind) if d.size else tr
                       for d, tr in zip(deferred_in, traces)]
            run_traces, run_carry = eff, carry_in
            rej = [0] * n
            if adm.trims:
                t_ins = [self.device.time_power(s.workload, sol.pm, b)[0]
                         for s, b in zip(specs, sol.bss)]
                pend = carry_in.pending
                psids = carry_in.stream_ids if carry_in.stream_ids \
                    is not None else np.zeros(len(pend), np.int64)
                cat_times = np.concatenate(
                    [pend] + [tr.times for tr in eff])
                cat_sids = np.concatenate(
                    [psids] + [np.full(len(tr), j, np.int64)
                               for j, tr in enumerate(eff)])
                order = np.argsort(cat_times, kind="stable")
                m_sorted = adm.admit_multi(
                    cat_times[order], cat_sids[order], sol.bss, t_ins,
                    nominals, carry_in.clock)
                mask = np.empty(cat_times.size, bool)
                mask[order] = m_sorted
                if not mask.all():
                    k0 = pend.size
                    run_carry = QueueState(pend[mask[:k0]], carry_in.clock,
                                           psids[mask[:k0]])
                    run_traces, off = [], k0
                    for j, tr in enumerate(eff):
                        mj = mask[off:off + len(tr)]
                        off += len(tr)
                        rej[j] = int(np.count_nonzero(~mj))
                        run_traces.append(
                            tr if mj.all()
                            else ArrivalTrace(tr.times[mj], tr.duration,
                                              tr.kind))
                    rej = [r + int(np.count_nonzero(~mask[:k0]
                                                    & (psids == j)))
                           for j, r in enumerate(rej)]
            rep = simulate_multi_tenant(
                self.device, w_tr if train else None,
                [s.workload for s in specs], sol.pm, sol.bss, run_traces,
                tau_cap=sol.tau_tr, backend=backend, carry_in=run_carry)
            if any(rej):
                if adm.mode == "defer":
                    dropped = state.push_deferred(rej)
                    deferred_out += sum(rej) - dropped
                    shed += dropped
                else:
                    shed += sum(rej)
            state.observe(traces, rep.streams, nominals, window_duration,
                          rep.queue_state)
            offered = sum(len(tr) for tr in traces)
            good = sum(int(np.count_nonzero(
                np.asarray(r.latencies, np.float64) <= nb))
                for r, nb in zip(rep.streams, nominals))
            gp = good / offered if offered else 1.0
            rep.shed_requests, rep.deferred_requests = shed, deferred_out
            rep.goodput = gp
            key = (sol.pm, tuple(sol.bss), sol.tau_tr)
            out.append(WindowReport(rate, sol, rep,
                                    estimated_rate=tuple(est),
                                    replanned=key != prev_key,
                                    mode_switch_s=switch_s,
                                    carried_requests=carried,
                                    shed_requests=shed,
                                    deferred_requests=deferred_out,
                                    goodput=gp, offered_requests=offered))
            prev_key = key
        return out
