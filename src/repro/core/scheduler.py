"""Fulcrum: the top-level scheduler (paper Fig. 5).

Given a workload (train / infer / concurrent pair / concurrent-inference
pair), a problem configuration, and a strategy name, Fulcrum profiles via the
chosen strategy, commits to a (power mode, beta_in, tau_tr) plan, and executes
it with managed interleaving. Also supports dynamic arrival rates (§5.4):
profiled modes are reused; GMD only backtracks to a different bs when the new
rate invalidates the current plan.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import problem as P
from repro.core.als import ALSConcurrent, ALSInfer, ALSTrain, QuadrantRanges
from repro.core.baselines import (NNConcurrentBaseline, NNInferBaseline,
                                  NNTrainBaseline, RNDConcurrent, RNDInfer,
                                  RNDTrain)
from repro.core.device_model import DeviceModel, Profiler, WorkloadProfile
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent, GMDInfer, GMDTrain
from repro.core.interleave import ExecutionReport, simulate_managed
from repro.core.oracle import Oracle
from repro.core.powermode import PowerModeSpace


@dataclasses.dataclass
class Plan:
    solution: P.Solution
    strategy: str
    profiling_runs: int
    profiling_cost_s: float


class Fulcrum:
    def __init__(self, device: Optional[DeviceModel] = None,
                 space: Optional[PowerModeSpace] = None,
                 quadrants: Optional[QuadrantRanges] = None,
                 nn_epochs: int = 400):
        self.device = device or DeviceModel()
        self.space = space or PowerModeSpace()
        self.quadrants = quadrants or QuadrantRanges(latency=(0.05, 2.0),
                                                     arrival=(30.0, 120.0))
        self.nn_epochs = nn_epochs
        self.oracle = Oracle(self.device, self.space)
        self._fitted: dict = {}     # reusable fitted strategies (ALS/RND/NN)

    # -- strategy factories -------------------------------------------------
    def _train_strategy(self, name: str, w: WorkloadProfile):
        key = (name, w.name)
        if name == "gmd":
            return GMDTrain(Profiler(self.device, w), self.space)
        if key not in self._fitted:
            prof = Profiler(self.device, w)
            self._fitted[key] = {
                "als50": ALSTrain(prof, self.space, nn_epochs=self.nn_epochs),
                "rnd50": RNDTrain(prof, 50, self.space),
                "rnd250": RNDTrain(prof, 250, self.space),
                "nn250": NNTrainBaseline(prof, 250, self.space,
                                         nn_epochs=self.nn_epochs),
            }[name]
        return self._fitted[key]

    def _infer_strategy(self, name: str, w: WorkloadProfile):
        key = (name, w.name)
        if name == "gmd":
            return GMDInfer(Profiler(self.device, w), self.space)
        if key not in self._fitted:
            prof = Profiler(self.device, w)
            self._fitted[key] = {
                "als145": ALSInfer(prof, self.quadrants, self.space,
                                   nn_epochs=self.nn_epochs),
                "rnd150": RNDInfer(prof, 150, self.space),
                "rnd250": RNDInfer(prof, 250, self.space),
                "nn250": NNInferBaseline(prof, 250, self.space,
                                         nn_epochs=self.nn_epochs),
            }[name]
        return self._fitted[key]

    def _concurrent_strategy(self, name: str, w_tr, w_in):
        key = (name, w_tr.name, w_in.name)
        if name == "gmd":
            cp = ConcurrentProfiler(Profiler(self.device, w_tr),
                                    Profiler(self.device, w_in))
            return GMDConcurrent(cp, self.space)
        if key not in self._fitted:
            cp = ConcurrentProfiler(Profiler(self.device, w_tr),
                                    Profiler(self.device, w_in))
            self._fitted[key] = {
                "als145": ALSConcurrent(cp, self.quadrants, self.space,
                                        nn_epochs=self.nn_epochs),
                "rnd150": RNDConcurrent(cp, 150, self.space),
                "rnd250": RNDConcurrent(cp, 250, self.space),
                "nn250": NNConcurrentBaseline(cp, 250, self.space,
                                              nn_epochs=self.nn_epochs),
            }[name]
        return self._fitted[key]

    # -- solve --------------------------------------------------------------
    def solve_train(self, w: WorkloadProfile, prob: P.TrainProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        s = self._train_strategy(strategy, w)
        sol = s.solve(prob)
        return self._plan(sol, s, strategy)

    def solve_infer(self, w: WorkloadProfile, prob: P.InferProblem,
                    strategy: str = "gmd") -> Optional[Plan]:
        s = self._infer_strategy(strategy, w)
        sol = s.solve(prob)
        return self._plan(sol, s, strategy)

    def solve_concurrent(self, w_tr: WorkloadProfile, w_in: WorkloadProfile,
                         prob: P.ConcurrentProblem,
                         strategy: str = "gmd") -> Optional[Plan]:
        s = self._concurrent_strategy(strategy, w_tr, w_in)
        sol = s.solve(prob)
        return self._plan(sol, s, strategy)

    def _plan(self, sol, strat, name) -> Optional[Plan]:
        if sol is None:
            return None
        prof = getattr(strat, "profiler", None) or getattr(strat, "cp", None)
        runs = prof.num_runs if prof is not None else 0
        cost = prof.profile_cost_s if prof is not None else 0.0
        return Plan(solution=sol, strategy=name, profiling_runs=runs,
                    profiling_cost_s=cost)

    # -- execute (managed interleaving over the device model) ---------------
    def execute(self, plan: Plan, w_in: WorkloadProfile,
                w_tr: Optional[WorkloadProfile], arrival_rate: float,
                duration: float = 120.0) -> ExecutionReport:
        sol = plan.solution
        return simulate_managed(self.device, w_tr, w_in, sol.pm,
                                sol.bs or 1, arrival_rate, duration)

    # -- dynamic arrival rates (§5.4) ----------------------------------------
    def solve_dynamic(self, w: WorkloadProfile, power_budget: float,
                      latency_budget: float, rates: list[float],
                      strategy: str = "gmd") -> list[Optional[P.Solution]]:
        """One solution per rate window, reusing profiling history: GMD keeps
        its profiler cache and only re-searches/backtracks when the existing
        observations stop satisfying the new rate."""
        sols: list[Optional[P.Solution]] = []
        if strategy == "gmd":
            # one shared profiler: cached profiles are free, so every window
            # re-searches at full budget but mostly hits the cache; only
            # genuinely new (pm, bs) profiles count against max_tries (§5.4)
            prof = Profiler(self.device, w)
            for rate in rates:
                prob = P.InferProblem(power_budget, latency_budget, rate)
                sol = P.solve_infer(prob, prof.observed())
                if sol is None:
                    GMDInfer(prof, self.space).solve(prob)
                    sol = P.solve_infer(prob, prof.observed())
                sols.append(sol)
            return sols
        for rate in rates:
            prob = P.InferProblem(power_budget, latency_budget, rate)
            plan = self.solve_infer(w, prob, strategy)
            sols.append(plan.solution if plan else None)
        return sols
