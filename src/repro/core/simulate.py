"""Vectorized trace-driven execution engine (paper §3 Fig. 2, §5.4).

The seed simulators in ``core.interleave`` replayed every request in a Python
loop; after PR 1 made the solvers batched, execution dominated benchmark wall
time. This module replaces the per-request loops with NumPy array kernels
over arrival-time vectors:

 * ``ArrivalTrace`` — the workload input: a sorted vector of request arrival
   timestamps. Constructors cover the paper's scenarios: ``uniform`` (the
   seed's fixed-rate ticks), ``poisson`` (seeded stochastic arrivals), and
   ``piecewise`` (per-window rates, the §5.4 dynamic traces produced by
   ``bench_dynamic.make_traces``-style rate lists).
 * ``simulate`` — one entry point dispatching to the managed / native /
   streams kernels; ``core.interleave.simulate_*`` remain as thin wrappers.

Exactness contract (mirrors ``core.grid_eval``): the managed path is
*deterministic* and the vectorized kernel reproduces the scalar reference
loop exactly — identical latency lists, training-minibatch counts, and power.
The kernel exploits the loop's structure: training slack-fill never pushes
``now`` past the batch-ready time, so completion times obey the max-plus
recurrence ``c_k = fl(max(c_{k-1}, ready_k) + t_in)`` independent of
training. The no-backlog candidate ``ready + t_in`` is vectorized; backlogged
runs (rare under sustainable plans) are resolved with the exact scalar
recurrence. Slack-fill counts come from a vectorized floor division, with an
exact replay of the reference's repeated-addition loop on the (measure-zero)
boundary cases where floating-point accumulation could flip the count —
``tests/test_simulate.py`` enforces equality property-style.

The native / streams paths are stochastic by design (contention jitter); they
use seeded NumPy generators and a cumulative-sum service-time kernel
(``c = max-accumulate(ready - cumsum_prev) + cumsum``), deterministic per
seed but not bitwise-coupled to the seed's ``random.Random`` streams.

Backlog carryover (§5.4 closed loop): the managed engines accept a
``carry_in`` ``QueueState`` — the previous window's unserved requests
(original arrival times) plus the engine clock — and every managed report
returns the end-of-window ``queue_state``. Replaying one long trace as K
windows chained through queue states is *bitwise identical* on NumPy to
replaying it in one call (the carried floats re-enter the identical
recurrence; see ``docs/exactness.md``), and tolerance-identical on jax.

Backends (contract; see ``docs/exactness.md`` for the full ladder):

 * ``backend="numpy"`` (default) — the **reference**: managed results are
   bitwise-equal to the scalar loops above; this is what the identity tests
   pin and what every other backend is judged against.
 * ``backend="jax"`` — the managed kernel expressed as a max-plus
   ``jax.lax.associative_scan`` (``c_k = max(c_{k-1}, ready_k) + e_k`` is the
   composition of affine max-plus maps ``x -> max(x + e_k, ready_k + e_k)``),
   jit + vmap'd over a *lane* axis so many (power mode, batch size, trace)
   simulations — including multi-tenant lanes with padded event axes — run as
   one on-accelerator program (``simulate_batch`` /
   ``simulate_multi_tenant_batch``). The scan reassociates float adds and
   skips the boundary replay of ``_fill_counts``, so jax results are
   *tolerance-checked* against NumPy (|Δlatency| ≲ K·eps·T, enforced at
   atol=1e-8 s / rtol=1e-9 by ``tests/test_simulate.py``; train-minibatch
   counts may differ only on quotient-boundary cases), **not** bitwise.
 * ``backend="pallas"`` — the same contract served by the hand-written
   Pallas kernels (``repro.kernels.fulcrum``): a lane-blocked Hillis-Steele
   max-plus scan fused with the slack-fill count, and the report builder's
   per-lane padded sort as a bitonic network. Same tolerance rung as jax
   (the sort itself is a pure permutation — checked for equality);
   ``interpret=True`` off-TPU, so the kernels run on CPU CI.

Backend selection follows ``core.backend.resolve_backend``: ``None`` defers
to ``FULCRUM_ENGINE_BACKEND`` and degrades pallas → jax → numpy when a tier
is unavailable. Reports from the batched paths are built by one vectorized
report builder: a chunked padded sort fills every lane's quantile /
violation-rate cache.

Lane scaling (10⁴–10⁵ lanes): the accelerator paths never materialize one
giant padded matrix — lanes are dispatched in ``_LANE_CHUNK``-sized chunks
padded to power-of-two lane buckets and one *global* power-of-two event
count, so every chunk of a sweep (and of the next sweep) hits the same
compiled program. The compiled kernels live in module-level caches keyed by
backend (jit itself caches per padded shape); ``engine_trace_count()``
exposes a retrace counter so tests can pin the no-retrace contract. Scan
input buffers are donated (``donate_argnums``) — they are per-call padded
copies, never reused host-side.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import random
import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.backend import record_dispatch, require_jax, resolve_backend
from repro.core.device_model import DeviceModel, WorkloadProfile
from repro.core.powermode import PowerMode

_EPS = float(np.finfo(np.float64).eps)

# Exact slack-fill replay is O(count); past this the floor estimate stands
# (its error bound is still astronomically below the decision boundary).
_MAX_EXACT_FILL = 2_000_000


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class ArrivalTrace:
    """Sorted request-arrival timestamps (seconds) driving one simulation.
    ``stream_ids`` (multi-tenant traces) records which tenant each request
    belongs to; ``merge``/``split`` round-trip that provenance."""
    times: np.ndarray
    duration: float
    kind: str = "uniform"
    stream_ids: Optional[np.ndarray] = None
    n_streams: Optional[int] = None   # tenant count of a merged trace

    def __post_init__(self):
        object.__setattr__(self, "times",
                           np.ascontiguousarray(self.times, np.float64))
        if self.stream_ids is not None:
            object.__setattr__(self, "stream_ids",
                               np.ascontiguousarray(self.stream_ids, np.int64))

    def __len__(self) -> int:
        return int(self.times.size)

    @property
    def mean_rate(self) -> float:
        return len(self) / self.duration if self.duration > 0 else 0.0

    def shifted(self, t0: float) -> "ArrivalTrace":
        return ArrivalTrace(self.times + t0, self.duration, self.kind,
                            self.stream_ids, self.n_streams)

    def clip(self, t0: float, t1: float, rebase: bool = False) -> "ArrivalTrace":
        """The [t0, t1) window view of this trace. Times stay absolute —
        the carryover convention, so slicing a long trace into windows and
        replaying them with ``QueueState`` chaining reproduces the long run
        bitwise — unless ``rebase`` shifts them to the window origin."""
        if t1 < t0:
            raise ValueError(f"empty window: t1={t1} < t0={t0}")
        m = (self.times >= t0) & (self.times < t1)
        ids = self.stream_ids[m] if self.stream_ids is not None else None
        return ArrivalTrace(self.times[m] - (t0 if rebase else 0.0),
                            t1 - t0, self.kind, ids, self.n_streams)

    @staticmethod
    def concat(traces: Sequence["ArrivalTrace"],
               duration: Optional[float] = None) -> "ArrivalTrace":
        """Concatenate traces whose times are already in nondecreasing order
        (e.g. carried-over pending requests followed by the next window's
        arrivals). ``duration`` defaults to the longest piece's."""
        if not traces:
            return ArrivalTrace(np.empty(0), float(duration or 0.0))
        times = np.concatenate([t.times for t in traces])
        if times.size > 1 and np.any(np.diff(times) < 0):
            raise ValueError("concat needs nondecreasing times across pieces;"
                             " use merge() for interleaved streams")
        ids = None
        if all(t.stream_ids is not None for t in traces):
            ids = np.concatenate([t.stream_ids for t in traces])
        n_streams = max((t.n_streams for t in traces
                         if t.n_streams is not None), default=None)
        if duration is None:
            duration = max(t.duration for t in traces)
        return ArrivalTrace(times, float(duration), traces[0].kind,
                            ids, n_streams)

    @staticmethod
    def merge(traces: Sequence["ArrivalTrace"]) -> "ArrivalTrace":
        """Merge per-stream traces into one multi-tenant trace. Stream ``j``
        of the result is ``traces[j]``; arrival order is a stable sort on
        time, so simultaneous arrivals keep stream order. ``split`` recovers
        the per-stream traces (idle tenants included — the stream count is
        recorded, not inferred from the ids)."""
        if not traces:
            return ArrivalTrace(np.empty(0), 0.0, "merged",
                                np.empty(0, np.int64), 0)
        times = np.concatenate([t.times for t in traces])
        ids = np.concatenate([np.full(len(t), j, np.int64)
                              for j, t in enumerate(traces)])
        order = np.argsort(times, kind="stable")
        duration = max(t.duration for t in traces)
        return ArrivalTrace(times[order], float(duration), "merged",
                            ids[order], len(traces))

    def split(self, n_streams: Optional[int] = None) -> list["ArrivalTrace"]:
        """Per-stream traces of a merged trace (provenance round-trip)."""
        if self.stream_ids is None:
            raise ValueError("trace has no stream provenance; use merge()")
        n = n_streams if n_streams is not None else self.n_streams
        if n is None:       # foreign ids without a recorded count: infer
            n = int(self.stream_ids.max() + 1) if len(self) else 0
        return [ArrivalTrace(self.times[self.stream_ids == j], self.duration,
                             self.kind) for j in range(int(n))]

    @classmethod
    def uniform(cls, rate: float, duration: float) -> "ArrivalTrace":
        """Fixed-rate ticks at i/rate — bitwise identical to the seed's
        ``[i / arrival_rate for i in range(int(rate * duration))]``."""
        n = int(rate * duration)
        return cls(np.arange(n, dtype=np.float64) / rate, float(duration))

    @classmethod
    def poisson(cls, rate: float, duration: float, seed: int = 0) -> "ArrivalTrace":
        """Seeded Poisson process: exponential inter-arrival gaps."""
        if rate <= 0.0:                       # idle window: no arrivals
            return cls(np.empty(0), float(duration), "poisson")
        rng = np.random.default_rng(seed)
        mean = rate * duration
        n = max(8, int(mean + 6.0 * math.sqrt(mean) + 8))
        t = np.cumsum(rng.exponential(1.0 / rate, n))
        while t.size and t[-1] < duration:        # undershoot: extend (rare)
            t = np.concatenate([t, t[-1] + np.cumsum(
                rng.exponential(1.0 / rate, n))])
        return cls(t[t < duration], float(duration), "poisson")

    @classmethod
    def piecewise(cls, rates: Sequence[float], window_duration: float,
                  seed: Optional[int] = None) -> "ArrivalTrace":
        """Piecewise-rate trace: one window per rate (the §5.4 dynamic
        scenario; ``bench_dynamic.make_traces`` emits such rate lists).
        Uniform ticks within each window, Poisson when ``seed`` is given."""
        parts, t0 = [], 0.0
        for i, r in enumerate(rates):
            if r > 0:
                w = (cls.uniform(r, window_duration) if seed is None
                     else cls.poisson(r, window_duration, seed + i))
                parts.append(t0 + w.times)
            t0 += window_duration
        times = np.concatenate(parts) if parts else np.empty(0)
        return cls(times, t0, "piecewise")


# ---------------------------------------------------------------------------
# window-boundary queue state (backlog carryover)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class QueueState:
    """Managed-engine state at a window boundary, enabling backlog carryover
    across re-planning windows (§5.4 closed loop).

    ``pending`` holds the *original* arrival timestamps of requests that were
    never served (the trailing partial minibatch — every full minibatch is
    always executed, even if its completion overruns the window). ``clock``
    is the completion time of the last executed minibatch: the engine may not
    start work before it, so an overrunning window delays the next one.
    ``stream_ids`` aligns with ``pending`` for multi-tenant windows.

    Contract (enforced by ``tests/test_controller.py``): replaying a long
    trace as K windows chained through ``QueueState`` is bitwise identical on
    NumPy to replaying it in one call — the carried floats re-enter the same
    recurrence at the same positions (boundary-replay style,
    ``docs/exactness.md``). Fleet backlog migration
    (``fleet._migrate_backlog``) re-dispatches these pending vectors across
    devices between windows: a request that stays keeps its timestamp (its
    replay is bitwise this contract), one that moves is re-timestamped at
    the window start so the receiving device's pending vector stays
    nondecreasing — the migration-replay corollary in
    ``docs/exactness.md``."""
    pending: np.ndarray
    clock: float = 0.0
    stream_ids: Optional[np.ndarray] = None

    def __post_init__(self):
        object.__setattr__(self, "pending",
                           np.ascontiguousarray(self.pending, np.float64))
        if self.stream_ids is not None:
            object.__setattr__(self, "stream_ids",
                               np.ascontiguousarray(self.stream_ids, np.int64))

    def __len__(self) -> int:
        return int(self.pending.size)

    def pending_for(self, j: int) -> np.ndarray:
        """Pending arrivals of stream ``j`` of a multi-tenant state."""
        if self.stream_ids is None:
            return self.pending if j == 0 else np.empty(0)
        return self.pending[self.stream_ids == j]


# ---------------------------------------------------------------------------
# execution report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecutionReport:
    approach: str
    latencies: Sequence[float]        # per-request latency (s), queue + exec;
    train_minibatches: int            # a list (scalar refs) or float64 array
    duration: float
    power: float
    trace: Optional[ArrivalTrace] = None   # the arrivals that were executed
    queue_state: Optional[QueueState] = dataclasses.field(   # end-of-window
        default=None, repr=False, compare=False)             # engine state
    drift_s: Optional[float] = None   # runtime-vs-engine max |Δlatency| (s),
    #                                   filled by runtime.attach_drift
    # graceful-degradation accounting (§5.4 burst survival), filled by the
    # serving drivers / runtime admission gate — 0 / None when no admission
    # control ran
    shed_requests: int = 0            # offered requests dropped at admission
    deferred_requests: int = 0        # offered requests pushed to next window
    goodput: Optional[float] = None   # in-budget served / offered fraction
    # fleet / tenant power accounting: this report's time-weighted share of
    # the device's interleaved-window power (busy time of this stream over
    # total busy time; the training share lives on the parent multi-tenant
    # report). Shares across a window sum to the device power; an idle
    # window (nothing ran) attributes 0.
    attributed_power: Optional[float] = dataclasses.field(
        default=None, compare=False)
    _sorted: Optional[np.ndarray] = dataclasses.field(
        default=None, init=False, repr=False, compare=False)

    @property
    def train_throughput(self) -> float:
        return self.train_minibatches / self.duration

    @property
    def sorted_latencies(self) -> np.ndarray:
        """Ascending latencies; the cache behind every quantile / violation
        query. The batched report builder (``_presort_reports``) fills it
        with one vectorized sort across all lanes of a batch."""
        if self._sorted is None:
            self._sorted = np.sort(np.asarray(self.latencies, np.float64))
        return self._sorted

    def latency_quantile(self, q: float) -> float:
        """Nearest-rank quantile: the smallest sample with at least a q
        fraction of the distribution at or below it (ceil(q*n)-th order
        statistic), so q=0.75 over 4 samples is the 3rd, not the max."""
        n = len(self.latencies)
        if n == 0:
            return 0.0
        xs = self.sorted_latencies
        return float(xs[min(n - 1, max(0, math.ceil(q * n) - 1))])

    def violation_rate(self, latency_budget: float) -> float:
        n = len(self.latencies)
        if n == 0:
            return 0.0
        xs = self.sorted_latencies
        return float(n - np.searchsorted(xs, latency_budget, side="right")) / n


# ---------------------------------------------------------------------------
# array kernels
# ---------------------------------------------------------------------------

def _batch_ready(times: np.ndarray, bs: int) -> np.ndarray:
    """Arrival time of the bs-th request of each full minibatch; a trailing
    partial batch never runs (as in the scalar loops)."""
    return times[bs - 1::bs]


def _managed_completions_var(ready: np.ndarray, exec_t: np.ndarray,
                             clock: float = 0.0) -> np.ndarray:
    """Exact batch completion times for the per-event-service recurrence
    c_k = fl(max(c_{k-1}, ready_k) + e_k), started from c_0 = ``clock`` (a
    carried-over window boundary; 0.0 for a fresh run): the vectorized
    no-backlog candidate everywhere, with backlogged runs (candidate
    finishing after the next batch is ready — including a carry-in clock
    overrunning the first batches) replayed by the scalar recurrence —
    identical float ops, so bitwise-equal results."""
    c = ready + exec_t
    K = c.size
    if K and clock > ready[0]:
        prev, k = float(clock), 0
        while k < K and prev > ready[k]:
            prev = prev + float(exec_t[k])
            c[k] = prev
            k += 1
    if K <= 1:
        return c
    bad = np.flatnonzero(c[:-1] > ready[1:])
    i, K = 0, c.size
    while i < bad.size:
        k = int(bad[i]) + 1
        prev = float(c[k - 1])
        while k < K and prev > ready[k]:
            prev = prev + float(exec_t[k])
            c[k] = prev
            k += 1
        while i < bad.size and bad[i] < k:
            i += 1
    return c


def _managed_completions(ready: np.ndarray, t_in: float,
                         clock: float = 0.0) -> np.ndarray:
    """Constant-service special case (the pair engine's kernel)."""
    return _managed_completions_var(
        ready, np.broadcast_to(np.float64(t_in), ready.shape), clock)


def _fill_count_exact(start: float, ready: float, t_tr: float) -> int:
    now, m = start, 0
    while now + t_tr <= ready and m < _MAX_EXACT_FILL:
        now += t_tr
        m += 1
    return m


def _fill_counts(ready: np.ndarray, completions: np.ndarray,
                 t_tr: float, clock: float = 0.0) -> np.ndarray:
    """Training minibatches filled into each batch's slack, matching the
    reference's repeated-addition loop exactly. The vectorized estimate is
    floor(slack / t_tr); only entries whose quotient sits within the
    floating-point error bound of an integer boundary — where repeated
    addition could round the other way — are replayed exactly. ``clock`` is
    the fill start before the first batch (a carried window boundary)."""
    if not math.isfinite(t_tr) or t_tr <= 0.0:
        return np.zeros(ready.size, np.int64)
    start = np.empty_like(ready)
    if ready.size:
        start[0] = clock
        start[1:] = completions[:-1]
    slack = ready - start
    q = slack / t_tr
    m = np.maximum(np.floor(q), 0.0)
    # |accumulated error| <= m*eps*max|s| and |division rounding| <= eps*q,
    # both covered (generously) by this threshold in quotient units
    thr = _EPS * (m + 4.0) * (2.0 + (np.abs(start) + np.abs(ready)) / t_tr)
    suspicious = np.flatnonzero((slack > 0) & (np.abs(q - np.rint(q)) <= thr)
                                & (m < _MAX_EXACT_FILL))
    m = m.astype(np.int64)
    for k in suspicious:
        m[k] = _fill_count_exact(float(start[k]), float(ready[k]), t_tr)
    return m


def first_backlog_crossing(times: np.ndarray, completions: np.ndarray,
                           bs: int, threshold: int) -> Optional[int]:
    """Index of the first arrival at which the backlog — requests arrived
    but not yet completed, counting the arriving request itself — exceeds
    ``threshold``, given the run's batch completion times (each completion
    retires one ``bs``-sized minibatch). ``None`` when the backlog never
    crosses. ``times`` must be the *effective* arrival vector of the run
    (carried pending requests first, as the managed engine sees them).

    The mid-window re-planning driver splits the window at the returned
    arrival's timestamp via ``ArrivalTrace.clip`` + ``QueueState`` chaining;
    the carryover replay contract (windowed == long trace, bitwise on NumPy)
    makes the split exact by construction — this function only has to pick
    the split point deterministically."""
    times = np.asarray(times, np.float64)
    if times.size == 0:
        return None
    comps = np.asarray(completions, np.float64)
    done = int(bs) * np.searchsorted(comps, times, side="right")
    backlog = np.arange(1, times.size + 1) - done
    idx = np.flatnonzero(backlog > int(threshold))
    return int(idx[0]) if idx.size else None


def _queue_completions(ready: np.ndarray, exec_t: np.ndarray) -> np.ndarray:
    """c_k = max(c_{k-1}, ready_k) + exec_k as one array program:
    c_k = max_{j<=k}(ready_j - E_{j-1}) + E_k with E = cumsum(exec)."""
    if ready.size == 0:
        return ready.copy()
    E = np.cumsum(exec_t)
    offset = np.concatenate(([0.0], E[:-1]))
    return np.maximum.accumulate(ready - offset) + E


def _latencies(completions: np.ndarray, times: np.ndarray,
               bs: int) -> np.ndarray:
    return np.repeat(completions, bs) - times[:completions.size * bs]


# Cap on lanes x requests elements per padded sort matrix: ~32 MB float64.
# One full-batch matrix at 10^5 ragged lanes would not survive; chunking
# keeps peak memory flat and lets each chunk pad to its OWN max length.
_SORT_CHUNK_ELEMS = 4 << 20


def _sort_lane_chunk(lats: list[np.ndarray], reports, backend: str) -> None:
    """Sort one chunk of lanes through a padded (lane, request) matrix.
    Sorting permutes values — the sorted arrays are identical float64
    multisets whichever backend sorts, so the NumPy path stays bitwise and
    the Pallas bitonic kernel is interchangeable (equality-checked)."""
    R = max(a.size for a in lats)
    total = sum(a.size for a in lats)
    if len(lats) * R > 4 * total:      # highly ragged: padding would cost
        for r, a in zip(reports, lats):        # far more than it batches
            r._sorted = np.sort(a)
        return
    mat = np.full((len(lats), R), np.inf)
    for i, a in enumerate(lats):
        mat[i, :a.size] = a
    if backend == "pallas":
        mat = np.asarray(_pallas_lane_sort()(mat))
    else:
        mat.sort(axis=1)
    for i, (r, a) in enumerate(zip(reports, lats)):
        # copy: a view would pin the whole padded matrix per report
        r._sorted = mat[i, :a.size].copy()


def _presort_reports(reports: Sequence[ExecutionReport],
                     backend: str = "numpy") -> None:
    """Batched report builder: fill every report's quantile/violation cache
    with chunked vectorized sorts over padded (lane, request) matrices, so
    per-lane statistics of a batch are computed vectorized rather than one
    Python-level sort per report. +inf padding keeps each lane's real
    latencies as the leading prefix after the sort; chunks are cut so no
    padded matrix exceeds ``_SORT_CHUNK_ELEMS`` elements (each chunk pads to
    its own max length, so one long lane cannot inflate the whole batch).
    ``backend="pallas"`` routes the chunk sorts through the bitonic lane-sort
    kernel — identical sorted values, NumPy remains the bitwise reference."""
    lats = [np.asarray(r.latencies, np.float64) for r in reports]
    if max((a.size for a in lats), default=0) == 0:
        for r in reports:
            r._sorted = np.empty(0)
        return
    i = 0
    while i < len(lats):
        j, width = i + 1, max(lats[i].size, 1)
        while j < len(lats):
            width = max(width, lats[j].size)
            if (j + 1 - i) * width > _SORT_CHUNK_ELEMS:
                break
            j += 1
        _sort_lane_chunk(lats[i:j], reports[i:j], backend)
        i = j


def _time_power(device: DeviceModel, w: WorkloadProfile, pm: PowerMode,
                bs: Optional[int]) -> tuple[float, float]:
    """Device timings are pure functions of (workload, mode, bs); memoize
    them on the device instance so repeated executions (per-window
    re-planning, benchmark sweeps) pay the deterministic-perturbation
    hashing once, as the Profiler does. The cache dies with the device."""
    cache = device.__dict__.setdefault("_simulate_time_power_cache", {})
    key = (w, pm, bs)
    out = cache.get(key)
    if out is None:
        out = cache[key] = device.time_power(w, pm, bs)
    return out


def _attribute_power(power: float, busys: Sequence[float]) -> list[float]:
    """Time-weighted power attribution: split a device's interleaved-window
    power across its consumers proportionally to busy time. The managed
    engine runs one DNN at a time, so busy time IS the fraction of the
    window each consumer held the device; the shares sum to ``power`` by
    construction. An idle window (no work ran) attributes 0 to everyone —
    the plan's static power belongs to no tenant."""
    total = float(sum(busys))
    if total <= 0.0:
        return [0.0 for _ in busys]
    return [power * (b / total) for b in busys]


# ---------------------------------------------------------------------------
# the three execution approaches
# ---------------------------------------------------------------------------

def _carry_times(trace: ArrivalTrace,
                 carry_in: Optional[QueueState]) -> tuple[np.ndarray, float]:
    """A window's effective arrival vector and starting clock: carried
    pending requests (original timestamps) re-enter ahead of the window's
    own arrivals, and the engine resumes from the carried clock."""
    if carry_in is None:
        return trace.times, 0.0
    times = trace.times if not len(carry_in) \
        else np.concatenate([carry_in.pending, trace.times])
    return times, float(carry_in.clock)


def _managed_engine(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                    w_in: WorkloadProfile, pm: PowerMode, bs: int,
                    trace: ArrivalTrace, seed: int = 0,
                    tau_cap: Optional[int] = None,
                    carry_in: Optional[QueueState] = None) -> ExecutionReport:
    """Fulcrum managed interleaving: one DNN at a time, switched at minibatch
    boundaries; training fills slack conservatively (never delaying the next
    inference batch). ``tau_cap`` bounds slack-fill at the plan's committed
    tau_tr minibatches per cycle. ``carry_in`` resumes from a previous
    window's queue state; the report's ``queue_state`` carries the trailing
    partial minibatch and the engine clock out for the next window."""
    t_in, p_in = _time_power(device, w_in, pm, bs)
    t_tr, p_tr = _time_power(device, w_tr, pm, None) if w_tr \
        else (float("inf"), 0.0)
    times, clock = _carry_times(trace, carry_in)
    ready = _batch_ready(times, bs)
    c = _managed_completions(ready, t_in, clock)
    trained = 0
    if w_tr:
        fills = _fill_counts(ready, c, t_tr, clock)
        if tau_cap is not None:
            fills = np.minimum(fills, max(0, int(tau_cap)))
        trained = int(fills.sum())
    power = max(p_in, p_tr if trained else 0.0)
    state = QueueState(times[ready.size * bs:],
                       float(c[-1]) if c.size else clock)
    attr = _attribute_power(power, [c.size * t_in,
                                    trained * t_tr if trained else 0.0])
    return ExecutionReport("managed", _latencies(c, times, bs), trained,
                           trace.duration, power, trace, queue_state=state,
                           attributed_power=attr[0])


def _native_engine(device: DeviceModel, w_tr: WorkloadProfile,
                   w_in: WorkloadProfile, pm: PowerMode, bs: int,
                   trace: ArrivalTrace, seed: int = 0,
                   tau_cap: Optional[int] = None) -> ExecutionReport:
    """Native kernel-level time-sharing: inference contends with training
    (~2x slowdown +- jitter); training gets the leftover GPU share."""
    rng = np.random.default_rng(seed)
    t_in, p_in = _time_power(device, w_in, pm, bs)
    t_tr, p_tr = _time_power(device, w_tr, pm, None)
    ready = _batch_ready(trace.times, bs)
    exec_t = t_in * (1.0 + rng.uniform(0.5, 1.6, ready.size))
    c = _queue_completions(ready, exec_t)
    train_share = max(0.0, trace.duration - float(exec_t.sum())) \
        * float(rng.uniform(0.85, 0.95))
    trained = int(train_share / t_tr)
    return ExecutionReport("native", _latencies(c, trace.times, bs), trained,
                           trace.duration, max(p_in, p_tr), trace)


def _streams_engine(device: DeviceModel, w_tr: WorkloadProfile,
                    w_in: WorkloadProfile, pm: PowerMode, bs: int,
                    trace: ArrivalTrace, seed: int = 0,
                    tau_cap: Optional[int] = None) -> ExecutionReport:
    """CUDA-streams space sharing, inference on the high-priority stream:
    throughput-friendly, but non-deterministic block-level resource blocking
    fattens the tail."""
    rng = np.random.default_rng(seed)
    t_in, p_in = _time_power(device, w_in, pm, bs)
    t_tr, p_tr = _time_power(device, w_tr, pm, None)
    ready = _batch_ready(trace.times, bs)
    K = ready.size
    slowdown = 1.0 + rng.uniform(0.05, 0.45, K)
    blocked = rng.random(K) < 0.18
    extra = rng.uniform(0.5, 2.0, K) * (t_tr / max(t_in, 1e-6))
    exec_t = t_in * (slowdown + np.where(blocked, extra, 0.0))
    c = _queue_completions(ready, exec_t)
    trained = int(trace.duration * float(rng.uniform(0.75, 0.9)) / t_tr)
    return ExecutionReport("streams", _latencies(c, trace.times, bs), trained,
                           trace.duration, max(p_in, p_tr) * 1.03, trace)


ENGINES: dict[str, Callable[..., ExecutionReport]] = {
    "managed": _managed_engine,
    "native": _native_engine,
    "streams": _streams_engine,
}


# ---------------------------------------------------------------------------
# jax / pallas backends: the managed kernel as a vmapped max-plus scan.
# c_k = max(c_{k-1}, ready_k) + e_k is the composition of affine max-plus
# maps f_k(x) = max(x + a_k, b_k) with a_k = e_k, b_k = ready_k + e_k;
# (f_r . f_l) keeps that form with (a, b) = (a_l + a_r, max(b_l + a_r, b_r)),
# so an associative scan over the (a, b) pairs yields every prefix
# composition, and c_k = prefix_k applied to c_0 = 0 = max(A_k, B_k).
# Lanes are padded with ready = +inf, exec = 0 (absorbing for both ops).
# The "jax" tier uses jax.lax.associative_scan; the "pallas" tier the
# hand-written lane-blocked kernel (repro.kernels.fulcrum.maxplus_scan).
# ---------------------------------------------------------------------------

# compiled scan runners, keyed by backend tier ("managed" = the jax tier's
# historical key, kept so tests/monkeypatches keep working; "pallas" = the
# Pallas kernel wrapper; "lane_sort" = the report builder's bitonic sort)
_JAX_ENGINE_CACHE: dict = {}

# lanes dispatched per compiled call: bounds the padded chunk matrix to
# _LANE_CHUNK x K_pad floats (~4 MB at K=64) however many lanes a sweep has
_LANE_CHUNK = 8192

# retrace counters: bumped inside traced function bodies, so they count
# compilations (trace-time side effects), not calls. engine_trace_count()
# lets tests pin the shape-bucketing no-retrace contract.
_TRACE_COUNTS = {"engine": 0}


def engine_trace_count() -> int:
    """Number of scan-kernel (re)traces since import, across backends."""
    return _TRACE_COUNTS["engine"]


@contextlib.contextmanager
def _quiet_donation():
    # donation is best-effort: on CPU XLA may decline a buffer and warn.
    # The fallback (a copy) is exactly the pre-donation behavior.
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        yield


def _jax_engine() -> Callable:
    if "managed" in _JAX_ENGINE_CACHE:
        return _JAX_ENGINE_CACHE["managed"]
    jax, jnp, enable_x64 = require_jax()

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l + a_r, jnp.maximum(b_l + a_r, b_r)

    def one_lane(ready, exec_t, t_tr, tau_cap, clock):
        a, b = jax.lax.associative_scan(combine, (exec_t, ready + exec_t))
        # prefix compositions applied to c_0 = clock (the carried window
        # boundary; 0 for a fresh run): c_k = max(clock + A_k, B_k)
        c = jnp.maximum(clock + a, b)
        start = jnp.concatenate([jnp.full(1, clock), c[:-1]])
        # floor estimate only — no boundary replay on-accelerator, hence the
        # jax backend's tolerance (not bitwise) contract for trained counts
        fills = jnp.clip(jnp.floor((ready - start) / t_tr), 0.0, tau_cap)
        fills = jnp.where(jnp.isfinite(ready), fills, 0.0)
        return c, fills.sum()

    def batch(ready, exec_t, t_tr, tau_cap, clock):
        _TRACE_COUNTS["engine"] += 1           # fires at trace time only
        return jax.vmap(one_lane)(ready, exec_t, t_tr, tau_cap, clock)

    # the padded event buffers are fresh per-call copies — donate them so
    # XLA reuses the allocation instead of holding both live
    kernel = jax.jit(batch, donate_argnums=(0, 1))

    def run(ready, exec_t, t_tr, tau_cap, clock):
        record_dispatch("engine")
        with enable_x64(), _quiet_donation():
            c, trained = kernel(jnp.asarray(ready), jnp.asarray(exec_t),
                                jnp.asarray(t_tr), jnp.asarray(tau_cap),
                                jnp.asarray(clock))
        return np.asarray(c), np.asarray(trained)

    _JAX_ENGINE_CACHE["managed"] = run
    return run


def _pallas_engine() -> Callable:
    """The Pallas-tier scan runner: same contract as ``_jax_engine``'s, the
    arithmetic done by the hand-written lane-blocked kernel. Jitted so the
    interpret-mode kernel body is traced once per padded shape (and so the
    retrace counter counts its compilations the same way)."""
    if "pallas" in _JAX_ENGINE_CACHE:
        return _JAX_ENGINE_CACHE["pallas"]
    jax, jnp, enable_x64 = require_jax()
    from repro.kernels.fulcrum.maxplus_scan import maxplus_scan

    def batch(ready, exec_t, t_tr, tau_cap, clock):
        _TRACE_COUNTS["engine"] += 1           # fires at trace time only
        return maxplus_scan(ready, exec_t, t_tr, tau_cap, clock)

    kernel = jax.jit(batch, donate_argnums=(0, 1))

    def run(ready, exec_t, t_tr, tau_cap, clock):
        record_dispatch("engine")
        with enable_x64(), _quiet_donation():
            c, trained = kernel(jnp.asarray(ready), jnp.asarray(exec_t),
                                jnp.asarray(t_tr), jnp.asarray(tau_cap),
                                jnp.asarray(clock))
        return np.asarray(c), np.asarray(trained)

    _JAX_ENGINE_CACHE["pallas"] = run
    return run


def _pallas_lane_sort() -> Callable:
    """Jitted wrapper of the bitonic lane-sort kernel (report builder)."""
    if "lane_sort" in _JAX_ENGINE_CACHE:
        return _JAX_ENGINE_CACHE["lane_sort"]
    jax, jnp, enable_x64 = require_jax()
    from repro.kernels.fulcrum.lane_sort import lane_sort
    kernel = jax.jit(lane_sort, donate_argnums=(0,))

    def run(mat):
        with enable_x64(), _quiet_donation():
            return np.asarray(kernel(jnp.asarray(mat)))

    _JAX_ENGINE_CACHE["lane_sort"] = run
    return run


def _pow2(n: int, floor: int = 8) -> int:
    return max(floor, 1 << max(0, n - 1).bit_length())


def _pad_lanes(readies: Sequence[np.ndarray], execs: Sequence[np.ndarray],
               lanes_pad: Optional[int] = None,
               k_pad: Optional[int] = None) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged per-lane event vectors into (lanes_pad, k_pad) arrays.
    Both axes default to the next power of two so trace-length and
    lane-count jitter across calls reuses a handful of jit compilations
    instead of one per distinct shape. Padding lanes/events are absorbing
    (ready = +inf, exec = 0)."""
    if k_pad is None:
        k_pad = _pow2(max((r.size for r in readies), default=0))
    if lanes_pad is None:
        lanes_pad = _pow2(len(readies))
    ready = np.full((lanes_pad, k_pad), np.inf)
    exec_t = np.zeros((lanes_pad, k_pad))
    for i, (r, e) in enumerate(zip(readies, execs)):
        ready[i, :r.size] = r
        exec_t[i, :e.size] = e
    return ready, exec_t


def _run_engine(backend: str, readies: Sequence[np.ndarray],
                execs: Sequence[np.ndarray], t_trs: np.ndarray,
                tau_caps: np.ndarray, clocks: np.ndarray,
                ) -> tuple[list[np.ndarray], np.ndarray]:
    """Chunked lane dispatch for the accelerator scan tiers.

    Lanes run in ``_LANE_CHUNK``-sized chunks so 10^5-lane sweeps never
    materialize one giant padded matrix; every chunk is padded to a
    power-of-two lane bucket and ONE global power-of-two event count
    (computed over *all* lanes), so all full chunks — and the same-shaped
    chunks of the next sweep — hit the same compiled program. Padding lanes
    are absorbing (+inf ready, 0 exec, +inf t_tr, clock 0). Returns each
    lane's trimmed completion vector plus the per-lane fill sums."""
    run = _pallas_engine() if backend == "pallas" else _jax_engine()
    n = len(readies)
    k_pad = _pow2(max((r.size for r in readies), default=0))
    comps: list[np.ndarray] = []
    trained = np.empty(n)
    for s in range(0, n, _LANE_CHUNK):
        e = min(n, s + _LANE_CHUNK)
        m = e - s
        lanes_pad = min(_LANE_CHUNK, _pow2(m))
        ready, exec_t = _pad_lanes(readies[s:e], execs[s:e],
                                   lanes_pad=lanes_pad, k_pad=k_pad)
        ttr = np.full(lanes_pad, np.inf)
        ttr[:m] = t_trs[s:e]
        cap = np.full(lanes_pad, np.inf)
        cap[:m] = tau_caps[s:e]
        clk = np.zeros(lanes_pad)
        clk[:m] = clocks[s:e]
        c, f = run(ready, exec_t, ttr, cap, clk)
        comps.extend(c[i, :readies[s + i].size] for i in range(m))
        trained[s:e] = f[:m]
    return comps, trained


def _tau_array(tau_caps: Sequence[Optional[int]]) -> np.ndarray:
    return np.array([np.inf if c is None else float(max(0, int(c)))
                     for c in tau_caps])


# ---------------------------------------------------------------------------
# multi-tenant managed interleaving: N inference streams + training fill
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultiTenantReport:
    """Per-tenant execution reports plus the shared training/power account
    of one N-stream managed run."""
    streams: list                     # one ExecutionReport per tenant
    train_minibatches: int
    duration: float
    power: float
    trace: Optional[ArrivalTrace] = None   # the merged trace that was run
    queue_state: Optional[QueueState] = dataclasses.field(  # end-of-window
        default=None, repr=False, compare=False)            # engine state
    # graceful-degradation accounting (§5.4 burst survival) across all
    # tenants, filled by the serving drivers / runtime admission gate
    shed_requests: int = 0
    deferred_requests: int = 0
    goodput: Optional[float] = None
    # the training job's time-weighted share of the device power; each
    # tenant's share is on its stream report — together they sum to
    # ``power`` (0 everywhere for an idle window)
    train_attributed_power: Optional[float] = None

    @property
    def train_throughput(self) -> float:
        return self.train_minibatches / self.duration

    def worst_latency_quantile(self, q: float) -> float:
        return max((r.latency_quantile(q) for r in self.streams), default=0.0)

    def violation_rates(self, budgets: Sequence[float]) -> list:
        return [r.violation_rate(b) for r, b in zip(self.streams, budgets)]


def _carry_stream_traces(traces: Sequence[ArrivalTrace],
                         carry_in: Optional[QueueState],
                         ) -> tuple[list[ArrivalTrace], float]:
    """Per-stream effective traces of a multi-tenant window: each stream's
    carried pending requests re-enter ahead of its window arrivals."""
    if carry_in is None:
        return list(traces), 0.0
    out = []
    for j, tr in enumerate(traces):
        pend = carry_in.pending_for(j)
        times = tr.times if pend.size == 0 \
            else np.concatenate([pend, tr.times])
        out.append(ArrivalTrace(times, tr.duration, tr.kind))
    return out, float(carry_in.clock)


def _multi_tenant_state(times_by_stream: Sequence[np.ndarray],
                        bss: Sequence[int], completions: np.ndarray,
                        clock: float) -> QueueState:
    """End-of-window queue state of an N-stream run: each stream's trailing
    partial minibatch, merged back into (time, stream) order."""
    pend = [t[(t.size // int(b)) * int(b):]
            for t, b in zip(times_by_stream, bss)]
    times = np.concatenate(pend) if pend else np.empty(0)
    ids = np.concatenate([np.full(p.size, j, np.int64)
                          for j, p in enumerate(pend)]) \
        if pend else np.empty(0, np.int64)
    order = np.argsort(times, kind="stable")
    out_clock = float(completions[-1]) if completions.size else clock
    return QueueState(times[order], out_clock, ids[order])


def _merge_events(traces: Sequence[ArrivalTrace], bss: Sequence[int],
                  t_ins: Sequence[float]):
    """Batch-ready events of all streams merged into device order: a stable
    sort on ready time, ties by stream index (the scalar loop's order).
    Returns (ready, exec_t, stream_of_event)."""
    readies = [_batch_ready(tr.times, int(b)) for tr, b in zip(traces, bss)]
    ready = np.concatenate(readies) if readies else np.empty(0)
    sid = np.concatenate([np.full(r.size, j, np.int64)
                          for j, r in enumerate(readies)]) \
        if readies else np.empty(0, np.int64)
    order = np.argsort(ready, kind="stable")
    ready, sid = ready[order], sid[order]
    exec_t = np.asarray(t_ins, np.float64)[sid] if ready.size \
        else np.empty(0)
    return ready, exec_t, sid


def simulate_multi_tenant(device: DeviceModel,
                          w_tr: Optional[WorkloadProfile],
                          stream_workloads: Sequence[WorkloadProfile],
                          pm: PowerMode, bss: Sequence[int],
                          traces: Sequence[ArrivalTrace],
                          tau_cap: Optional[int] = None,
                          backend: Optional[str] = None,
                          carry_in: Optional[QueueState] = None,
                          ) -> MultiTenantReport:
    """N-stream managed interleaving on one device: streams' minibatches are
    served in ready order (one DNN at a time), training fills the remaining
    slack conservatively. With one stream this is exactly the pair managed
    engine (and the seed scalar loop) — the engine's exactness contract.
    ``backend="jax"`` routes through the batched scan engine (one lane).
    ``carry_in`` resumes from a previous window's per-stream queue state."""
    n = len(stream_workloads)
    if not (len(bss) == len(traces) == n):
        raise ValueError("stream workloads / batch sizes / traces must align")
    backend = resolve_backend(backend)
    if backend != "numpy":
        return simulate_multi_tenant_batch(
            device, w_tr, [stream_workloads], [pm], [bss], [traces],
            tau_caps=[tau_cap], carry_ins=[carry_in], backend=backend)[0]
    tps = [_time_power(device, w, pm, int(b))
           for w, b in zip(stream_workloads, bss)]
    t_ins = [t for t, _ in tps]
    t_tr, p_tr = _time_power(device, w_tr, pm, None) if w_tr \
        else (float("inf"), 0.0)
    eff_traces, clock = _carry_stream_traces(traces, carry_in)
    ready, exec_t, sid = _merge_events(eff_traces, bss, t_ins)
    c = _managed_completions_var(ready, exec_t, clock)
    trained = 0
    if w_tr:
        fills = _fill_counts(ready, c, t_tr, clock)
        if tau_cap is not None:
            fills = np.minimum(fills, max(0, int(tau_cap)))
        trained = int(fills.sum())
    power = p_tr if trained else 0.0
    for _, p_in in tps:
        power = max(power, p_in)
    duration = max((tr.duration for tr in traces), default=0.0)
    reports, busys = [], []
    for j, (tr, b) in enumerate(zip(eff_traces, bss)):
        comp_j = c[sid == j]
        lat = np.repeat(comp_j, int(b)) - tr.times[:comp_j.size * int(b)]
        busys.append(comp_j.size * t_ins[j])
        reports.append(ExecutionReport("managed", lat, 0, tr.duration,
                                       power, tr))
    attr = _attribute_power(power,
                            busys + [trained * t_tr if trained else 0.0])
    for rep, a in zip(reports, attr):
        rep.attributed_power = a
    state = _multi_tenant_state([tr.times for tr in eff_traces], bss, c,
                                clock)
    return MultiTenantReport(reports, trained, duration, power,
                             ArrivalTrace.merge(eff_traces),
                             queue_state=state,
                             train_attributed_power=attr[-1])


def simulate_multi_tenant_batch(
        device: DeviceModel, w_tr: Optional[WorkloadProfile],
        stream_workloads: Sequence[Sequence[WorkloadProfile]],
        pms: Sequence[PowerMode], bsss: Sequence[Sequence[int]],
        tracess: Sequence[Sequence[ArrivalTrace]],
        tau_caps: Optional[Sequence[Optional[int]]] = None,
        backend: Optional[str] = None,
        carry_ins: Optional[Sequence[Optional[QueueState]]] = None,
        ) -> list[MultiTenantReport]:
    """Run many N-stream managed simulations as one batch (one lane per
    multi-tenant run; lanes may have *different* tenant counts — the merged
    event axis is padded per lane, so a 2-tenant and a 4-tenant run share
    one vmapped program). Per-stream event merging (stable time sort, ties
    by stream index) happens host-side exactly as the NumPy engine does;
    only the scan arithmetic differs on jax. All reports across all lanes
    and streams share one vectorized report-builder pass. ``carry_ins``
    gives each lane a carried per-stream ``QueueState``."""
    n = len(pms)
    if not (len(stream_workloads) == len(bsss) == len(tracess) == n):
        raise ValueError("stream_workloads / pms / bsss / tracess must align")
    caps = list(tau_caps) if tau_caps is not None else [None] * n
    if len(caps) != n:
        raise ValueError("tau_caps must align with the lanes")
    carries = list(carry_ins) if carry_ins is not None else [None] * n
    if len(carries) != n:
        raise ValueError("carry_ins must align with the lanes")
    if n == 0:
        return []
    backend = resolve_backend(backend)
    if backend == "numpy":
        # pass the resolved backend through: a default (env-var) jax
        # request must not bounce each lane back into the jax path
        reports = [simulate_multi_tenant(device, w_tr, ws, pm, bss, traces,
                                         tau_cap=cap, backend="numpy",
                                         carry_in=ci)
                   for ws, pm, bss, traces, cap, ci
                   in zip(stream_workloads, pms, bsss, tracess, caps,
                          carries)]
        _presort_reports([r for mt in reports for r in mt.streams])
        return reports
    lanes = []
    for ws, pm, bss, traces, cap, ci in zip(stream_workloads, pms, bsss,
                                            tracess, caps, carries):
        if not (len(ws) == len(bss) == len(traces)):
            raise ValueError("stream workloads / batch sizes / traces "
                             "must align")
        tps = [_time_power(device, w, pm, int(b)) for w, b in zip(ws, bss)]
        ttr = _time_power(device, w_tr, pm, None) if w_tr else (np.inf, 0.0)
        eff, clock = _carry_stream_traces(traces, ci)
        ready, exec_t, sid = _merge_events(eff, bss, [t for t, _ in tps])
        lanes.append((tps, ttr, ready, exec_t, sid, eff, clock))
    comps, trained_f = _run_engine(backend,
                                   [ln[2] for ln in lanes],
                                   [ln[3] for ln in lanes],
                                   np.array([ln[1][0] for ln in lanes]),
                                   _tau_array(caps),
                                   np.array([ln[6] for ln in lanes]))
    out, flat = [], []
    for i, (tps, ttr, ready_i, _, sid, eff, clock) in enumerate(lanes):
        comp = comps[i]
        trained = int(round(float(trained_f[i]))) if w_tr else 0
        power = ttr[1] if trained else 0.0
        for _, p_in in tps:
            power = max(power, p_in)
        duration = max((tr.duration for tr in tracess[i]), default=0.0)
        streams, busys = [], []
        for j, (tr, b) in enumerate(zip(eff, bsss[i])):
            comp_j = comp[sid == j]
            lat = np.repeat(comp_j, int(b)) - tr.times[:comp_j.size * int(b)]
            busys.append(comp_j.size * tps[j][0])
            streams.append(ExecutionReport("managed", lat, 0, tr.duration,
                                           power, tr))
        attr = _attribute_power(power,
                                busys + [trained * ttr[0] if trained
                                         else 0.0])
        for rep, a in zip(streams, attr):
            rep.attributed_power = a
        flat.extend(streams)
        state = _multi_tenant_state([tr.times for tr in eff], bsss[i], comp,
                                    clock)
        out.append(MultiTenantReport(streams, trained, duration, power,
                                     ArrivalTrace.merge(eff),
                                     queue_state=state,
                                     train_attributed_power=attr[-1]))
    _presort_reports(flat, backend=backend)
    return out


def simulate(device: DeviceModel, w_tr: Optional[WorkloadProfile],
             w_in: WorkloadProfile, pm: PowerMode, bs: int,
             trace: ArrivalTrace, approach: str = "managed", seed: int = 0,
             tau_cap: Optional[int] = None,
             backend: Optional[str] = None,
             carry_in: Optional[QueueState] = None) -> ExecutionReport:
    """Run one execution approach over an arrival trace.

    ``backend`` selects the engine implementation for the deterministic
    managed kernel: ``"numpy"`` (the reference) or ``"jax"`` (max-plus scan);
    ``None`` resolves via ``core.backend.resolve_backend``. The stochastic
    native/streams models always run on NumPy. ``carry_in`` (managed only)
    resumes from a previous window's ``QueueState``."""
    try:
        engine = ENGINES[approach]
    except KeyError:
        raise ValueError(f"unknown approach {approach!r}; "
                         f"use one of {sorted(ENGINES)}") from None
    if carry_in is not None and approach != "managed":
        raise ValueError("carry-in backlog is only defined for the "
                         "deterministic managed approach")
    backend = resolve_backend(backend)
    if backend != "numpy" and approach == "managed":
        return simulate_batch(device, w_tr, w_in, [pm], [bs], [trace],
                              tau_caps=[tau_cap], carry_ins=[carry_in],
                              backend=backend)[0]
    if approach == "managed":
        return engine(device, w_tr, w_in, pm, bs, trace, seed, tau_cap,
                      carry_in)
    return engine(device, w_tr, w_in, pm, bs, trace, seed, tau_cap)


def simulate_batch(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                   w_in: WorkloadProfile, pms: Sequence[PowerMode],
                   bss: Sequence[int], traces: Sequence[ArrivalTrace],
                   tau_caps: Optional[Sequence[Optional[int]]] = None,
                   approach: str = "managed", seed: int = 0,
                   backend: Optional[str] = None,
                   carry_ins: Optional[Sequence[Optional[QueueState]]] = None,
                   devices: Optional[Sequence[DeviceModel]] = None,
                   ) -> list[ExecutionReport]:
    """Run many (power mode, batch size, trace) simulations as one batch.

    One report per lane. On ``backend="jax"`` all managed lanes run as a
    single jit + vmap max-plus-scan program (lanes padded to a shared event
    count); on NumPy the per-lane kernels run in a loop. Either way the
    reports' quantile/violation caches are filled by the vectorized report
    builder. Only the managed approach is deterministic enough to batch on
    jax; native/streams lanes always use the seeded NumPy models.
    ``carry_ins`` (managed only) gives each lane a carried ``QueueState``.
    ``devices`` gives each lane its own device model (the fleet tier: lanes
    ARE devices); the scan arithmetic is unchanged — heterogeneity enters
    only through each lane's (t, p) timings."""
    n = len(pms)
    if not (len(bss) == len(traces) == n):
        raise ValueError("pms / bss / traces must align")
    caps = list(tau_caps) if tau_caps is not None else [None] * n
    if len(caps) != n:
        raise ValueError("tau_caps must align with the lanes")
    carries = list(carry_ins) if carry_ins is not None else [None] * n
    if len(carries) != n:
        raise ValueError("carry_ins must align with the lanes")
    devs = list(devices) if devices is not None else [device] * n
    if len(devs) != n:
        raise ValueError("devices must align with the lanes")
    if approach != "managed" and any(ci is not None for ci in carries):
        raise ValueError("carry-in backlog is only defined for the "
                         "deterministic managed approach")
    if n == 0:
        return []
    backend = resolve_backend(backend)
    if backend == "numpy" or approach != "managed":
        engine = ENGINES[approach]
        if approach == "managed":
            reports = [engine(dv, w_tr, w_in, pm, int(bs), tr, seed, cap,
                              ci)
                       for dv, pm, bs, tr, cap, ci
                       in zip(devs, pms, bss, traces, caps, carries)]
        else:
            reports = [engine(dv, w_tr, w_in, pm, int(bs), tr, seed, cap)
                       for dv, pm, bs, tr, cap
                       in zip(devs, pms, bss, traces, caps)]
        _presort_reports(reports)
        return reports
    tps = [_time_power(dv, w_in, pm, int(bs))
           for dv, pm, bs in zip(devs, pms, bss)]
    ttr = [_time_power(dv, w_tr, pm, None) if w_tr else (np.inf, 0.0)
           for dv, pm in zip(devs, pms)]
    lane_times = [_carry_times(tr, ci) for tr, ci in zip(traces, carries)]
    readies = [_batch_ready(times, int(bs))
               for (times, _), bs in zip(lane_times, bss)]
    execs = [np.broadcast_to(np.float64(t), r.shape)
             for (t, _), r in zip(tps, readies)]
    comps, trained_f = _run_engine(backend, readies, execs,
                                   np.array([t for t, _ in ttr]),
                                   _tau_array(caps),
                                   np.array([cl for _, cl in lane_times]))
    reports = []
    for i, (tr, bs) in enumerate(zip(traces, bss)):
        comp = comps[i]
        times, clock = lane_times[i]
        trained = int(round(float(trained_f[i]))) if w_tr else 0
        power = max(tps[i][1], ttr[i][1] if trained else 0.0)
        state = QueueState(times[comp.size * int(bs):],
                           float(comp[-1]) if comp.size else clock)
        attr = _attribute_power(power, [comp.size * tps[i][0],
                                        trained * ttr[i][0] if trained
                                        else 0.0])
        reports.append(ExecutionReport(
            "managed", _latencies(comp, times, int(bs)), trained,
            tr.duration, power, tr, queue_state=state,
            attributed_power=attr[0]))
    _presort_reports(reports, backend=backend)
    return reports


# ---------------------------------------------------------------------------
# scalar reference loops (the seed implementations, generalized to traces).
# Kept as the verification oracle for the identity tests and the baseline
# for benchmarks/bench_interleave_engine.py — not for production use.
# ---------------------------------------------------------------------------

def managed_scalar(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                   w_in: WorkloadProfile, pm: PowerMode, bs: int,
                   trace: ArrivalTrace, tau_cap: Optional[int] = None,
                   carry_in: Optional[QueueState] = None) -> ExecutionReport:
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm) if w_tr else (float("inf"), 0.0)
    times, clock = _carry_times(trace, carry_in)
    arrivals = times.tolist()
    latencies: list[float] = []
    now, trained, i = clock, 0, 0
    while i + bs <= len(arrivals):
        batch_ready = arrivals[i + bs - 1]
        filled = 0
        while w_tr and now + t_tr <= batch_ready \
                and (tau_cap is None or filled < tau_cap):
            now += t_tr
            trained += 1
            filled += 1
        now = max(now, batch_ready)
        now += t_in
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    power = max(p_in, p_tr if trained else 0.0)
    attr = _attribute_power(power, [(i // bs) * t_in,
                                    trained * t_tr if trained else 0.0])
    return ExecutionReport("managed", latencies, trained, trace.duration,
                           power, trace,
                           queue_state=QueueState(times[i:], now),
                           attributed_power=attr[0])


def batch_ready_events(arrivals: Sequence[Sequence[float]],
                       bss: Sequence[int]) -> list[tuple]:
    """Per-stream batch-ready events merged into device order: one
    ``(ready time, stream index, start request index)`` tuple per full
    minibatch, sorted by ready time with ties broken by stream then
    position — the managed engines' merge order. Shared by the scalar
    reference and the real runtime so their replay order cannot drift."""
    events = []
    for j, (arr, b) in enumerate(zip(arrivals, bss)):
        b = int(b)
        for k in range(len(arr) // b):
            events.append((arr[k * b + b - 1], j, k * b))
    events.sort()
    return events


def multi_tenant_scalar(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                        stream_workloads: Sequence[WorkloadProfile],
                        pm: PowerMode, bss: Sequence[int],
                        traces: Sequence[ArrivalTrace],
                        tau_cap: Optional[int] = None,
                        carry_in: Optional[QueueState] = None,
                        ) -> MultiTenantReport:
    """Scalar reference for the N-stream managed engine: replay every
    batch-ready event in (time, stream) order with the seed loop's float
    ops. One stream degenerates to ``managed_scalar``."""
    tps = [device.time_power(w, pm, int(b))
           for w, b in zip(stream_workloads, bss)]
    t_tr, p_tr = device.time_power(w_tr, pm) if w_tr else (float("inf"), 0.0)
    eff_traces, clock = _carry_stream_traces(traces, carry_in)
    arrivals = [tr.times.tolist() for tr in eff_traces]
    events = batch_ready_events(arrivals, bss)
    latencies: list[list[float]] = [[] for _ in stream_workloads]
    now, trained = clock, 0
    for ready, j, start in events:
        filled = 0
        while w_tr and now + t_tr <= ready \
                and (tau_cap is None or filled < tau_cap):
            now += t_tr
            trained += 1
            filled += 1
        now = max(now, ready)
        now += tps[j][0]
        latencies[j].extend(now - arrivals[j][i]
                            for i in range(start, start + int(bss[j])))
    power = p_tr if trained else 0.0
    for _, p_in in tps:
        power = max(power, p_in)
    duration = max((tr.duration for tr in traces), default=0.0)
    reports = [ExecutionReport("managed", lat, 0, tr.duration, power, tr)
               for lat, tr in zip(latencies, eff_traces)]
    attr = _attribute_power(
        power, [(len(lat) // int(b)) * tps[j][0]
                for j, (lat, b) in enumerate(zip(latencies, bss))]
        + [trained * t_tr if trained else 0.0])
    for rep, a in zip(reports, attr):
        rep.attributed_power = a
    state = _multi_tenant_state(
        [tr.times for tr in eff_traces], bss,
        np.asarray([now] if events else [], np.float64), clock)
    return MultiTenantReport(reports, trained, duration, power,
                             ArrivalTrace.merge(eff_traces),
                             queue_state=state,
                             train_attributed_power=attr[-1])


def native_scalar(device: DeviceModel, w_tr: WorkloadProfile,
                  w_in: WorkloadProfile, pm: PowerMode, bs: int,
                  trace: ArrivalTrace, seed: int = 0) -> ExecutionReport:
    rng = random.Random(seed)
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm)
    arrivals = trace.times.tolist()
    latencies: list[float] = []
    now, i, infer_busy = 0.0, 0, 0.0
    while i + bs <= len(arrivals):
        now = max(now, arrivals[i + bs - 1])
        exec_t = t_in * (1.0 + rng.uniform(0.5, 1.6))
        now += exec_t
        infer_busy += exec_t
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    train_share = max(0.0, trace.duration - infer_busy) * rng.uniform(0.85, 0.95)
    trained = int(train_share / t_tr)
    return ExecutionReport("native", latencies, trained, trace.duration,
                           max(p_in, p_tr), trace)


def streams_scalar(device: DeviceModel, w_tr: WorkloadProfile,
                   w_in: WorkloadProfile, pm: PowerMode, bs: int,
                   trace: ArrivalTrace, seed: int = 0) -> ExecutionReport:
    rng = random.Random(seed)
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm)
    arrivals = trace.times.tolist()
    latencies: list[float] = []
    now, i = 0.0, 0
    while i + bs <= len(arrivals):
        now = max(now, arrivals[i + bs - 1])
        slowdown = 1.0 + rng.uniform(0.05, 0.45)
        if rng.random() < 0.18:
            slowdown += rng.uniform(0.5, 2.0) * t_tr / max(t_in, 1e-6)
        now += t_in * slowdown
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    trained = int(trace.duration * rng.uniform(0.75, 0.9) / t_tr)
    return ExecutionReport("streams", latencies, trained, trace.duration,
                           max(p_in, p_tr) * 1.03, trace)
