"""NN time/power predictor (paper §5.2, after PowerTrain [31]).

4 dense layers (256/128/64/1), ReLU + linear head, Adam @ 1e-3, and a custom
MAPE loss that penalizes under-predictions 4x (under-predicted power causes
budget violations). Inputs are standardized [cores, cpuf, gpuf, memf (, bs)].
Pure JAX; training is a lax.scan over full-batch Adam steps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

LAYERS = (256, 128, 64, 1)
UNDER_PENALTY = 4.0


def _init_params(key, d_in: int):
    params = []
    dims = (d_in,) + LAYERS
    for i in range(len(LAYERS)):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (dims[i], dims[i + 1])) * jnp.sqrt(2.0 / dims[i])
        params.append({"w": w, "b": jnp.zeros((dims[i + 1],))})
    return params


def _apply(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x[..., 0]


def _loss(params, x, y):
    pred = _apply(params, x)
    err = (pred - y) / jnp.maximum(jnp.abs(y), 1e-6)
    w = jnp.where(err < 0, UNDER_PENALTY, 1.0)     # under-prediction penalized
    return jnp.mean(w * jnp.abs(err))


@dataclasses.dataclass
class NNPredictor:
    params: list
    mean: jnp.ndarray
    std: jnp.ndarray

    @classmethod
    def fit(cls, features: np.ndarray, targets: np.ndarray, *,
            epochs: int = 1000, lr: float = 1e-3, seed: int = 0) -> "NNPredictor":
        x = jnp.asarray(features, jnp.float32)
        y = jnp.asarray(targets, jnp.float32)
        mean = x.mean(0)
        std = jnp.maximum(x.std(0), 1e-6)
        xn = (x - mean) / std
        params = _init_params(jax.random.key(seed), x.shape[1])
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)

        def step(carry, i):
            params, m, v = carry
            g = jax.grad(_loss)(params, xn, y)
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * jnp.square(b), v, g)
            t = i.astype(jnp.float32) + 1
            mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), params, mh, vh)
            return (params, m, v), None

        (params, _, _), _ = jax.lax.scan(step, (params, m, v), jnp.arange(epochs))
        return cls(params=params, mean=mean, std=std)

    def predict(self, features: np.ndarray) -> np.ndarray:
        x = (jnp.asarray(features, jnp.float32) - self.mean) / self.std
        return np.asarray(_apply(self.params, x))

    def mape(self, features: np.ndarray, targets: np.ndarray) -> float:
        pred = self.predict(features)
        return float(np.mean(np.abs(pred - targets) / np.maximum(np.abs(targets), 1e-6)))


def mode_features(pm, bs: Optional[int] = None) -> list[float]:
    f = [float(pm.cores), float(pm.cpuf), float(pm.gpuf), float(pm.memf)]
    if bs is not None:
        f.append(float(bs))
    return f
