"""Ground-truth "optimal" solutions (paper §6): exhaustively evaluate the 441
uniformly spaced power modes (x 5 inference minibatch sizes) on the device
model and solve by observed-Pareto lookup. Profiling cost is not charged to
the oracle — it is the nominal optimum strategies are compared against."""
from __future__ import annotations

import functools
from typing import Optional

from repro.core import problem as P
from repro.core.device_model import DeviceModel, WorkloadProfile
from repro.core.powermode import PowerMode, PowerModeSpace


class Oracle:
    def __init__(self, device: DeviceModel, space: Optional[PowerModeSpace] = None,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.device = device
        self.space = space or PowerModeSpace()
        self.batch_sizes = batch_sizes
        self._train_obs: dict[str, dict] = {}
        self._infer_obs: dict[str, dict] = {}

    def train_observations(self, w: WorkloadProfile) -> dict:
        if w.name not in self._train_obs:
            self._train_obs[w.name] = {
                pm: self.device.time_power(w, pm) for pm in self.space.all_modes()}
        return self._train_obs[w.name]

    def infer_observations(self, w: WorkloadProfile) -> dict:
        if w.name not in self._infer_obs:
            self._infer_obs[w.name] = {
                (pm, bs): self.device.time_power(w, pm, bs)
                for pm in self.space.all_modes() for bs in self.batch_sizes}
        return self._infer_obs[w.name]

    def solve_train(self, w: WorkloadProfile, prob: P.TrainProblem):
        return P.solve_train(prob, self.train_observations(w))

    def solve_infer(self, w: WorkloadProfile, prob: P.InferProblem):
        return P.solve_infer(prob, self.infer_observations(w))

    def solve_concurrent(self, w_tr: WorkloadProfile, w_in: WorkloadProfile,
                         prob: P.ConcurrentProblem):
        return P.solve_concurrent(prob, self.train_observations(w_tr),
                                  self.infer_observations(w_in))
