"""Ground-truth "optimal" solutions (paper §6): exhaustively evaluate the 441
uniformly spaced power modes (x 5 inference minibatch sizes) on the device
model and solve by observed-Pareto lookup. Profiling cost is not charged to
the oracle — it is the nominal optimum strategies are compared against.

The oracle runs on the vectorized grid engine (`core.grid_eval`): dense
time/power tensors are materialized once per workload and every problem
configuration — or a whole batch of them via ``solve_*_batch`` — is solved as
a masked reduction, bitwise identical to the scalar `problem.solve_*` loops.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core.device_model import DeviceModel, WorkloadProfile
from repro.core.powermode import PowerModeSpace


class Oracle:
    def __init__(self, device: DeviceModel, space: Optional[PowerModeSpace] = None,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.device = device
        self.space = space or PowerModeSpace()
        self.batch_sizes = batch_sizes
        self._train_grids: dict[str, G.ObservationGrid] = {}
        self._infer_grids: dict[str, G.ObservationGrid] = {}
        self._train_obs: dict[str, dict] = {}
        self._infer_obs: dict[str, dict] = {}

    # -- dense grids (materialized once per workload) -----------------------
    def train_grid(self, w: WorkloadProfile) -> G.ObservationGrid:
        if w.name not in self._train_grids:
            self._train_grids[w.name] = G.materialize(self.device, w, self.space)
        return self._train_grids[w.name]

    def infer_grid(self, w: WorkloadProfile) -> G.ObservationGrid:
        if w.name not in self._infer_grids:
            self._infer_grids[w.name] = G.materialize(
                self.device, w, self.space, self.batch_sizes)
        return self._infer_grids[w.name]

    # -- dict views (legacy interface; same insertion order as the grids) ---
    def train_observations(self, w: WorkloadProfile) -> dict:
        if w.name not in self._train_obs:
            self._train_obs[w.name] = self.train_grid(w).to_dict()
        return self._train_obs[w.name]

    def infer_observations(self, w: WorkloadProfile) -> dict:
        if w.name not in self._infer_obs:
            self._infer_obs[w.name] = self.infer_grid(w).to_dict()
        return self._infer_obs[w.name]

    # -- ground-truth lookups (no hashing in the hot loop) ------------------
    def true_train(self, w: WorkloadProfile, pm) -> tuple[float, float]:
        """Ground-truth (t, p) for a training workload at ``pm``."""
        grid = self.train_grid(w)
        if pm in grid.index:
            return grid.lookup(pm)
        return self.device.time_power(w, pm)

    def true_infer(self, w: WorkloadProfile, pm, bs: int) -> tuple[float, float]:
        """Ground-truth (t, p) for an inference workload at ``(pm, bs)``."""
        grid = self.infer_grid(w)
        if (pm, bs) in grid.index:
            return grid.lookup(pm, bs)
        return self.device.time_power(w, pm, bs)

    # -- single-problem solves (vectorized path, batch of one) --------------
    def solve_train(self, w: WorkloadProfile, prob: P.TrainProblem):
        return self.solve_train_batch(w, [prob])[0]

    def solve_infer(self, w: WorkloadProfile, prob: P.InferProblem):
        return self.solve_infer_batch(w, [prob])[0]

    def solve_concurrent(self, w_tr: WorkloadProfile, w_in: WorkloadProfile,
                         prob: P.ConcurrentProblem):
        return self.solve_concurrent_batch(w_tr, w_in, [prob])[0]

    # -- batched solves: the full problem grid in one array program ---------
    def solve_train_batch(self, w: WorkloadProfile,
                          probs: Sequence[P.TrainProblem],
                          backend: str = "numpy") -> list[Optional[P.Solution]]:
        return G.solve_train_batch(probs, self.train_grid(w), backend)

    def solve_infer_batch(self, w: WorkloadProfile,
                          probs: Sequence[P.InferProblem],
                          backend: str = "numpy") -> list[Optional[P.Solution]]:
        return G.solve_infer_batch(probs, self.infer_grid(w), backend)

    def solve_concurrent_batch(self, w_tr: WorkloadProfile,
                               w_in: WorkloadProfile,
                               probs: Sequence[P.ConcurrentProblem],
                               backend: str = "numpy") -> list[Optional[P.Solution]]:
        return G.solve_concurrent_batch(probs, self.train_grid(w_tr),
                                        self.infer_grid(w_in), backend)

    # -- multi-tenant: stream workloads come from the problem's specs -------
    def solve_multi_tenant(self, w_tr: Optional[WorkloadProfile],
                           prob: P.MultiTenantProblem,
                           backend: str = "numpy"):
        return self.solve_multi_tenant_batch(w_tr, [prob], backend)[0]

    def solve_multi_tenant_batch(self, w_tr: Optional[WorkloadProfile],
                                 probs: Sequence[P.MultiTenantProblem],
                                 backend: str = "numpy"
                                 ) -> list[Optional[P.MultiTenantSolution]]:
        """Ground-truth N-stream solves: one dense grid per distinct stream
        workload (shared streams share the materialization)."""
        if not probs:
            return []
        specs = probs[0].streams
        if any(s.workload is None for s in specs):
            raise ValueError("oracle multi-tenant solves need StreamSpec."
                             "workload set on every stream")
        grids = [self.infer_grid(s.workload) for s in specs]
        tg = self.train_grid(w_tr) if probs[0].train else None
        return G.solve_multi_tenant_batch(probs, tg, grids, backend)
