"""The fused fleet window: solve + simulate as ONE jitted program.

The PR-8/9 fleet loop is dispatch-bound on jax: every window pays up to four
host-synchronized ``solve_infer_fleet_batch`` rungs (each with Python-side
``InferProblem`` construction and ``Solution`` materialization), an optional
per-device admission loop, and a separate ``simulate_batch`` launch. At
K=512 that overhead leaves the batched jax path ~4x *behind* NumPy
(BENCH_fleet.json) — the accelerator idles between launches.

This module collapses the whole window into one persistent-jitted program
per (K-bucket, event-bucket) shape:

 * **masked ladder rungs** — the PR-5 planning ladder (interval solve ->
   dead-zone high end -> point estimate -> nominal-budget retry) runs as
   four unconditional masked argmins over the (device, grid-entry) plane,
   combined by ``jnp.where`` gates over the device axis that replicate the
   host loop's "only still-unsolved devices" masks. Each rung replays
   ``grid_eval``'s fleet-kernel row math exactly (same elementwise IEEE
   ops, same first-occurrence argmin), so the selected entries match the
   per-rung path bitwise; computing a rung for an already-solved device is
   free parallel work whose result the gate discards.
 * **in-program admission** — the exact deadline-drop recurrence
   (``controller._admit_mask``) expressed as a ``lax.scan`` over arrivals
   with a ``max_bs`` ring buffer of forming-batch members and a bounded
   ``while_loop`` for the drop-from-front rule (total drops across a window
   are <= the arrival count). Rejected requests are compacted out by a
   stable sort against +inf — admitted times are a nondecreasing
   subsequence, so the sort yields exactly the trimmed vector the unfused
   path would rebuild on the host.
 * **fused execution** — the selected ``(bs, t_in)`` lanes feed straight
   into the max-plus associative scan (the PR-4 engine kernel, same
   combine), with batch-ready events gathered by traced-``bs`` indexing
   instead of host-side strided slicing. Mode-switch costs are charged
   in-program from the previous window's committed mode ids.

Solve -> admit -> simulate never crosses the host boundary: one launch per
window (``backend.dispatch_count("fused")`` tracks it; the legacy path pays
up to five). The grid tensors ride along as device-resident arrays
(``grid_eval.device_grid_arrays``), uploaded once per grid instance.

Exactness: this is a jax-tier program — the ladder/admission arithmetic is
bitwise the reference's (reassociation-free elementwise ops and compares),
while completion times inherit the associative scan's tolerance rung
(``docs/exactness.md``); the unfused NumPy path remains the authoritative
reference and the default. Shapes are pow2-bucketed on both the device and
event axes, so steady-state serving hits one compilation —
``fleet_trace_count()`` pins the no-retrace contract like the solver and
engine counters. The (K_pad x grid) rung temporaries are materialized
unchunked (one program is the point); at the 441x5 grid that is ~9 MB per
temporary at K=512 — callers far beyond K~4096 should stay on the chunked
per-rung path.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.backend import record_dispatch, require_jax
from repro.core.grid_eval import ObservationGrid, device_grid_arrays
from repro.core.simulate import _pow2

# one compiled program per (trims, max_bs) variant x jit shape bucket
_FUSED_CACHE: dict = {}

# retrace counter, bumped inside the traced program body (fires at compile
# time only). Mirrors grid_eval.solver_trace_count / simulate.engine_trace_count.
_TRACE_COUNTS = {"fleet": 0}

# the admission slack, exactly controller._admit_mask's
_ADMIT_EPS = 1e-12


def fleet_trace_count() -> int:
    """Number of fused fleet-window (re)traces since import."""
    return _TRACE_COUNTS["fleet"]


def grid_mode_ids(grid: ObservationGrid) -> np.ndarray:
    """Per-grid-entry power-mode ids (first-appearance order), memoized on
    the grid: the fused program compares these ints to charge mode-switch
    costs in-program (``PowerMode`` equality == id equality)."""
    ids = grid.__dict__.get("_mode_ids")
    if ids is None:
        first: dict = {}
        ids = np.array([first.setdefault(pm, len(first))
                        for pm in grid.modes], np.int32)
        grid.__dict__["_mode_ids"] = ids
    return ids


def _device_mode_ids(grid: ObservationGrid):
    """Device-resident copy of ``grid_mode_ids``, cached like the columns."""
    dev = grid.__dict__.get("_device_mode_ids")
    if dev is None:
        _jax, jnp, enable_x64 = require_jax()
        with enable_x64():
            dev = jnp.asarray(grid_mode_ids(grid))
        grid.__dict__["_device_mode_ids"] = dev
    return dev


def _fused_kernel(trims: bool, max_bs: int):
    """The compiled window program for one (admission-on, ring-size)
    variant; jit handles the per-shape-bucket caching underneath."""
    key = (trims, max_bs)
    if key in _FUSED_CACHE:
        return _FUSED_CACHE[key]
    jax, jnp, enable_x64 = require_jax()

    def combine(left, right):           # the max-plus affine composition,
        a_l, b_l = left                 # exactly simulate._jax_engine's
        a_r, b_r = right
        return a_l + a_r, jnp.maximum(b_l + a_r, b_r)

    def admit_lane(tv, n, bs, t_in, budget, clock):
        # controller._admit_mask as a scan: ring buffer of forming-batch
        # member indices (members are a window [h, h+m) mod max_bs), one
        # bounded drop loop per filled batch. Same float ops, same 1e-12
        # slack, so the mask matches the host recurrence bitwise.
        T = tv.shape[0]

        def step(carry, i):
            admit, c, buf, h, m = carry
            valid = i < n
            pos = (h + m) % max_bs
            buf = buf.at[pos].set(jnp.where(valid, i, buf[pos]))
            m = jnp.where(valid, m + 1, m)
            full = valid & (m == bs)
            comp = jnp.maximum(c, tv[i]) + t_in

            def cond(s):
                _a, h_, m_ = s
                j = buf[h_ % max_bs]
                return full & (m_ > 0) & (comp - tv[j] > budget + _ADMIT_EPS)

            def body(s):
                a_, h_, m_ = s
                j = buf[h_ % max_bs]
                return a_.at[j].set(False), h_ + 1, m_ - 1

            admit, h, m = jax.lax.while_loop(cond, body, (admit, h, m))
            commit = full & (m == bs)
            c = jnp.where(commit, comp, c)
            m = jnp.where(commit, 0, m)
            return (admit, c, buf, h, m), None

        init = (jnp.ones(T, bool), clock, jnp.zeros(max_bs, jnp.int32),
                jnp.int32(0), jnp.int32(0))
        (admit, _, _, _, _), _ = jax.lax.scan(
            step, init, jnp.arange(T, dtype=jnp.int32))
        return admit

    def window(t, p, bsf, mode_ids, ts, ps, pb, bud, nom, est, hi, live,
               prev_mode, times, n_times, n_carry, clock0, switch_cost,
               adm_budget):
        _TRACE_COUNTS["fleet"] += 1        # fires at trace time only
        inf = jnp.inf
        tk = t[None, :] * ts[:, None]      # per-device scaled grid rows —
        pk = p[None, :] * ps[:, None]      # the PerturbedDeviceModel law

        def rung(need, ar, b_h, b_l):
            # one masked-argmin solve per device row, replaying
            # grid_eval's fleet kernel (sustainable at b_h, objective and
            # latency budget at the low rate ar). ``need`` — "some device
            # is still unsolved at this rung" — wraps the whole (K x grid)
            # plane in a lax.cond, so the program pays for exactly the
            # rungs the host-masked loop would have launched: in steady
            # state (rung 1 solves everyone) rungs 2-4 cost one branch
            # predicate, not three dense solves
            K_pad = ts.shape[0]

            def solve(_):
                lam = (bsf[None, :] - 1.0) / ar[:, None] + tk
                feas = ((pk <= pb[:, None])
                        & (tk <= bsf[None, :] / b_h[:, None])
                        & (lam <= b_l[:, None]))
                lam_sel = jnp.where(feas, lam, inf)
                idx = jnp.argmin(lam_sel, axis=1)
                lam_i = jnp.take_along_axis(lam_sel, idx[:, None],
                                            axis=1)[:, 0]
                return idx.astype(jnp.int32), feas.any(axis=1), lam_i

            def skip(_):
                return (jnp.zeros(K_pad, jnp.int32),
                        jnp.zeros(K_pad, bool), jnp.full(K_pad, inf))

            return jax.lax.cond(need, solve, skip, operand=None)

        # the PR-5 ladder: rung r's gate reproduces the host loop's
        # "live & still-unsolved" mask at rung r; a rung nobody needs is
        # skipped at runtime (its gate is identically False either way)
        interval = live & (hi > est)
        idx1, ok1, lam1 = rung(interval.any(), est, jnp.maximum(hi, est),
                               bud)
        g1 = interval & ok1
        idx2, ok2, lam2 = rung((interval & ~g1).any(), hi, hi, bud)
        g2 = interval & ~g1 & ok2
        un12 = live & ~g1 & ~g2
        idx3, ok3, lam3 = rung(un12.any(), est, est, bud)
        g3 = un12 & ok3
        idx4, ok4, lam4 = rung((un12 & ~g3 & (bud < nom)).any(), est, est,
                               nom)
        g4 = un12 & ~g3 & (bud < nom) & ok4
        solved = g1 | g2 | g3 | g4
        sel = jnp.where(g1, idx1, jnp.where(g2, idx2,
                        jnp.where(g3, idx3, idx4)))
        lam_sel = jnp.where(g1, lam1, jnp.where(g2, lam2,
                            jnp.where(g3, lam3, lam4)))

        bs_i = bsf[sel].astype(jnp.int32)
        t_in = t[sel] * ts
        p_out = p[sel] * ps
        msel = mode_ids[sel]
        switch = jnp.where(solved & (prev_mode >= 0) & (msel != prev_mode),
                           switch_cost, 0.0)
        clock_in = clock0 + switch

        T = times.shape[1]
        iota = jnp.arange(T, dtype=jnp.int32)
        if trims:
            admit = jax.vmap(admit_lane)(times, n_times, bs_i, t_in,
                                         adm_budget, clock_in)
            admit = admit | ~solved[:, None]    # unsolved lanes: untouched
            in_range = iota[None, :] < n_times[:, None]
            rej = (~admit) & in_range
            n_rej = rej.sum(axis=1, dtype=jnp.int32)
            n_carry_rej = (rej & (iota[None, :] < n_carry[:, None])
                           ).sum(axis=1, dtype=jnp.int32)
            # admitted times are a nondecreasing subsequence: a stable sort
            # against +inf IS the compaction the host path rebuilds
            ctimes = jnp.sort(jnp.where(admit, times, inf), axis=1,
                              stable=True)
            n_adm = n_times - n_rej
        else:
            n_rej = jnp.zeros_like(n_times)
            n_carry_rej = n_rej
            ctimes = times
            n_adm = n_times

        # batch-ready gather with a traced bs, then the max-plus scan —
        # solve feeds simulate without leaving the program
        bs_c = jnp.maximum(bs_i, 1)
        nb = n_adm // bs_c
        last = (iota[None, :] + 1) * bs_c[:, None] - 1
        validb = iota[None, :] < nb[:, None]
        ready = jnp.where(
            validb,
            jnp.take_along_axis(ctimes, jnp.clip(last, 0, T - 1), axis=1),
            inf)
        ex = jnp.where(validb, t_in[:, None], 0.0)
        a, b = jax.lax.associative_scan(combine, (ex, ready + ex), axis=1)
        comp = jnp.maximum(clock_in[:, None] + a, b)
        bidx = jnp.clip(iota[None, :] // bs_c[:, None], 0, T - 1)
        served = iota[None, :] < (nb * bs_c)[:, None]
        lat = jnp.where(served,
                        jnp.take_along_axis(comp, bidx, axis=1) - ctimes,
                        inf)
        clock_out = jnp.where(
            nb > 0,
            jnp.take_along_axis(comp, jnp.clip(nb - 1, 0, T - 1)[:, None],
                                axis=1)[:, 0],
            clock_in)
        return (solved, sel, lam_sel, p_out, switch, clock_in, n_rej,
                n_carry_rej, ctimes, n_adm, nb, lat, clock_out)

    kernel = jax.jit(window)

    def run(grid_cols, mode_ids, *host_args):
        record_dispatch("fused")
        with enable_x64():
            res = kernel(*grid_cols, mode_ids,
                         *[jnp.asarray(a) for a in host_args])
            return tuple(np.asarray(r) for r in res)

    _FUSED_CACHE[key] = run
    return run


def fused_fleet_window(grid: ObservationGrid, ts: np.ndarray, ps: np.ndarray,
                       pbud: np.ndarray, bud: np.ndarray, nominal: np.ndarray,
                       est: np.ndarray, hi: np.ndarray, live: np.ndarray,
                       prev_mode: np.ndarray,
                       eff_times: Sequence[np.ndarray],
                       n_carry: np.ndarray, clock0: np.ndarray,
                       switch_cost: float, adm_budget: float,
                       trims: bool) -> dict:
    """Run one fleet window fused: plan ladder + admission + engine in a
    single compiled launch over pow2-padded (device, event) buckets.

    ``eff_times[d]`` is device d's effective arrival vector ``[carried
    pending, dispatched window arrivals]`` (the ``_carry_times`` order),
    ``n_carry[d]`` its pending prefix length, ``clock0[d]`` the pre-switch
    engine clock ``max(carry clock, t0)``. Returns per-device NumPy arrays
    (rows sliced back to K): the selection (``solved``/``sel``/``lam``/
    ``power``/``mode_id``), the in-program mode-switch charge and resulting
    clocks, the admission account (``n_rej``/``n_carry_rej``), and the
    execution results over the admitted compaction (``adm_times`` padded
    with +inf, ``n_adm``, ``n_batches``, ``latencies`` padded, and
    ``clock_out``)."""
    K = len(eff_times)
    K_pad = _pow2(K)
    T_pad = _pow2(max((len(v) for v in eff_times), default=0))
    times = np.full((K_pad, T_pad), np.inf)
    n_times = np.zeros(K_pad, np.int32)
    for d, v in enumerate(eff_times):
        times[d, :len(v)] = v
        n_times[d] = len(v)

    def pad1(v, fill, dtype=np.float64):
        out = np.full(K_pad, fill, dtype)
        out[:K] = v
        return out

    run = _fused_kernel(bool(trims), _grid_max_bs(grid))
    (solved, sel, lam, power, switch, clock_in, n_rej, n_carry_rej,
     ctimes, n_adm, nb, lat, clock_out) = run(
        device_grid_arrays(grid), _device_mode_ids(grid),
        pad1(ts, 1.0), pad1(ps, 1.0), pad1(pbud, 0.0), pad1(bud, np.inf),
        pad1(nominal, np.inf), pad1(est, 0.0), pad1(hi, 0.0),
        pad1(live, False, bool), pad1(prev_mode, -1, np.int32),
        times, n_times, pad1(n_carry, 0, np.int32), pad1(clock0, 0.0),
        np.float64(switch_cost), pad1(np.full(K, float(adm_budget)), 0.0))
    mode_ids = grid_mode_ids(grid)
    return {"solved": solved[:K], "sel": sel[:K], "lam": lam[:K],
            "power": power[:K], "mode_id": mode_ids[sel[:K]],
            "switch": switch[:K], "clock_in": clock_in[:K],
            "n_rej": n_rej[:K], "n_carry_rej": n_carry_rej[:K],
            "adm_times": ctimes[:K], "n_adm": n_adm[:K],
            "n_batches": nb[:K], "latencies": lat[:K],
            "clock_out": clock_out[:K]}


def _grid_max_bs(grid: ObservationGrid) -> int:
    """The admission ring-buffer size: the grid's largest batch size (a
    forming batch never holds more members than its bs)."""
    return int(grid.bs.max()) if grid.bs is not None and len(grid) else 1
