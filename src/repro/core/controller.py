"""Closed-loop dynamic serving controller (paper §5.4, ROADMAP closed-loop
item).

``Fulcrum.serve_dynamic`` re-plans once per rate window. The open-loop form
is told each window's true arrival rate in advance and forgets everything at
every window boundary. This module supplies the state that closes the loop,
in three pieces the scheduler's window driver composes:

 * ``RateEstimator`` — what rate to plan the next window for. ``"oracle"``
   passes the announced rate through (the open-loop §5.4 configuration);
   ``"ewma"`` estimates it from the *observed* arrival timestamps of executed
   windows — an exponentially weighted moving average over inter-arrival
   gaps, warm-started from the previous window's state (PowerTrain-style
   feedback adaptation, arXiv 2407.13944).
 * ``FeedbackPolicy`` — what latency budget to plan the next window against.
   Scales the nominal budget by a state in (0, 1]: tightened when the
   previous window's *executed* violation rate / tail latency broke the
   budget, relaxed back toward nominal while windows run clean. Monotone:
   a higher executed violation rate never yields a looser next budget.
 * ``ControllerState`` — one estimator + one policy per stream (multi-tenant
   windows keep per-tenant state), the carried ``QueueState`` (backlogged
   requests do not vanish at window boundaries), and the previous window's
   power mode for mode-switch accounting: switching power modes costs
   ``mode_switch_s`` wall seconds charged against the window that switches
   (concurrent-serving switch costs measured on Jetson, arXiv 2508.08430).

``ControllerConfig`` bundles the knobs. The default config is *open loop*
(oracle rates, no feedback, no carryover, free mode switches): the scheduler
detects ``closed_loop == False`` and runs the PR-4 batched window replay,
byte-identical on NumPy. Every closed-loop run is sequential by nature —
window k+1's plan depends on window k's executed report.

This layer is solver-agnostic: it never imports the scheduler or the
strategies. The scheduler's ``serve_dynamic`` drives it against either
engine backend (NumPy reference / jax scan) — and, because both consume
``ArrivalTrace`` and emit ``ExecutionReport``, against the real runtime
(``runtime.interleave_runtime``) as well.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.simulate import QueueState

_ESTIMATORS = ("oracle", "ewma")
_ADMISSIONS = ("none", "shed", "defer", "degrade-bs")


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Knobs of one closed-loop dynamic serving session.

    The defaults are the open-loop §5.4 configuration (oracle rates, no
    feedback, no backlog carryover, free mode switches) — ``closed_loop``
    is then False and ``serve_dynamic`` keeps its PR-4 batched replay,
    byte-identical on NumPy."""
    rate_estimator: str = "oracle"   # "oracle" (announced) | "ewma" (observed)
    ewma_alpha: float = 0.01         # per-gap EWMA weight; effective memory
    #   is ~(2-alpha)/alpha gaps (~200 at the default — a few seconds of
    #   arrivals at paper rates, so the estimate still turns over well
    #   within one window but averages enough exponential gaps to hold its
    #   relative error near 1/sqrt(ESS) ~ 7% on Poisson traces)
    rate_margin: float = 1.0         # plan for margin * estimated rate
    feedback: bool = False           # executed-latency budget feedback
    tighten: float = 0.5             # max fractional budget cut per window
    relax: float = 0.5               # recovery fraction toward nominal
    target_violation: float = 0.0    # tolerated executed violation rate
    tail_quantile: float = 0.95      # executed tail the policy reacts to
    min_budget_scale: float = 0.2    # effective budget floor (x nominal)
    mode_switch_s: float = 0.0       # wall cost charged when the pm changes
    carry_backlog: bool = False      # chain QueueState across windows
    # -- burst survival (admission control + mid-window re-planning) --------
    admission: str = "none"          # AdmissionPolicy mode (see _ADMISSIONS)
    admission_headroom: float = 1.0  # admit against headroom * nominal budget
    burst_quantile: float = 0.0      # plan service headroom at the window's
    #   Poisson arrival-count quantile (0 = plan at the mean-rate estimate)
    split_backlog: Optional[int] = None   # re-enter the controller when the
    #   backlog crosses this mid-window (None = window boundaries only)
    max_splits: int = 2              # re-planning splits per window, at most
    defer_cap: Optional[int] = None  # max deferred backlog (overflow is shed)
    priorities: Optional[tuple] = None    # per-stream admission priorities
    #   (multi-tenant hook: lower-priority streams shed earlier)

    def __post_init__(self):
        if self.rate_estimator not in _ESTIMATORS:
            raise ValueError(f"unknown rate estimator "
                             f"{self.rate_estimator!r}; use {_ESTIMATORS}")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.rate_margin <= 0.0:
            raise ValueError("rate_margin must be positive")
        if not 0.0 <= self.tighten <= 1.0 or not 0.0 <= self.relax <= 1.0:
            raise ValueError("tighten/relax must be in [0, 1]")
        if not 0.0 < self.min_budget_scale <= 1.0:
            raise ValueError("min_budget_scale must be in (0, 1]")
        if self.mode_switch_s < 0.0:
            raise ValueError("mode_switch_s must be >= 0")
        if self.admission not in _ADMISSIONS:
            raise ValueError(f"unknown admission mode {self.admission!r}; "
                             f"use {_ADMISSIONS}")
        if self.admission_headroom <= 0.0:
            raise ValueError("admission_headroom must be positive")
        if not 0.0 <= self.burst_quantile < 1.0:
            raise ValueError("burst_quantile must be in [0, 1)")
        if self.split_backlog is not None and self.split_backlog <= 0:
            raise ValueError("split_backlog must be positive (or None)")
        if self.max_splits < 0:
            raise ValueError("max_splits must be >= 0")
        if self.defer_cap is not None and self.defer_cap < 0:
            raise ValueError("defer_cap must be >= 0 (or None)")
        if self.priorities is not None:
            pr = tuple(float(p) for p in self.priorities)
            if not pr or any(p <= 0.0 for p in pr):
                raise ValueError("priorities must be positive floats")
            object.__setattr__(self, "priorities", pr)

    @property
    def closed_loop(self) -> bool:
        """True when any knob makes window k+1 depend on window k."""
        return (self.rate_estimator != "oracle" or self.rate_margin != 1.0
                or self.feedback or self.carry_backlog
                or self.mode_switch_s > 0.0
                or self.admission != "none" or self.burst_quantile > 0.0
                or self.split_backlog is not None)

    def admission_policy(self) -> "AdmissionPolicy":
        """The config's admission knobs bundled for the serving drivers."""
        return AdmissionPolicy(self.admission, self.admission_headroom,
                               self.priorities)


# ---------------------------------------------------------------------------
# SLO-aware admission control (§5.4 burst survival)
# ---------------------------------------------------------------------------

def _admit_mask(times: np.ndarray, budgets: np.ndarray, bs: int, t_in: float,
                clock: float) -> np.ndarray:
    """Deadline-drop admission over one window's effective arrivals (carried
    pending requests first, then the window's own — exactly the vector the
    managed engine would run). A virtual copy of the engine runs the same
    recurrence over the *admitted* subsequence: ``clock`` is when the device
    frees up, ``batch`` the forming minibatch's member indices. Whenever the
    batch fills, its completion is ``max(clock, ready) + t_in`` — the
    engine's own fold — and the oldest members whose wait already exceeds
    their budget are dropped (deadline-expired work is shed rather than
    served late, the classic load-shedding rule, implementable online
    because a member's deadline passes *before* the batch it slows down
    commits). Dropping re-opens the batch, so the next arrival both refills
    it and re-times it; the batch only commits when every member meets its
    budget. The admitted subsequence therefore replays through the engine
    with zero violations by construction — identical recurrence, identical
    float64 ops — and on an uncongested feasible window nothing drops.

    Rejected requests never occupy a batch slot: admission is what keeps
    the virtual queue inside the budget, which is why admitted-request
    satisfaction holds even when the offered load cannot drain. A trailing
    partial batch is admitted untouched — the engine carries it to the next
    window, where the next admission pass re-judges it as backlog."""
    times = np.asarray(times, np.float64)
    n = times.size
    admit = np.ones(n, bool)
    if n == 0:
        return admit
    budgets = np.asarray(budgets, np.float64)
    c = float(clock)
    bs, t_in = int(bs), float(t_in)
    batch: list[int] = []
    for i in range(n):
        batch.append(i)
        if len(batch) < bs:
            continue
        comp = max(c, float(times[i])) + t_in
        while batch and (comp - float(times[batch[0]])
                         > float(budgets[batch[0]]) + 1e-12):
            admit[batch.pop(0)] = False
        if len(batch) == bs:
            c = comp
            batch = []
    return admit


def _admit_mask_multi(times: np.ndarray, sids: np.ndarray,
                      bss: Sequence[int], t_ins: Sequence[float],
                      budgets: np.ndarray, clock: float) -> np.ndarray:
    """N-stream form of ``_admit_mask``: one shared virtual device clock
    (every tenant's batches serialize on the accelerator, so congestion in
    one stream delays all), per-stream forming batches. ``budgets`` is
    per-*request* (the policy bakes priorities in before calling),
    ``times``/``sids`` must be time-sorted."""
    times = np.asarray(times, np.float64)
    n = times.size
    admit = np.ones(n, bool)
    if n == 0:
        return admit
    sids = np.asarray(sids, np.int64)
    budgets = np.asarray(budgets, np.float64)
    bss = [int(b) for b in bss]
    t_ins = [float(t) for t in t_ins]
    batches: list[list[int]] = [[] for _ in bss]
    c = float(clock)
    for i in range(n):
        j = int(sids[i])
        batches[j].append(i)
        if len(batches[j]) < bss[j]:
            continue
        comp = max(c, float(times[i])) + t_ins[j]
        while batches[j] and (comp - float(times[batches[j][0]])
                              > float(budgets[batches[j][0]]) + 1e-12):
            admit[batches[j].pop(0)] = False
        if len(batches[j]) == bss[j]:
            c = comp
            batches[j] = []
    return admit


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    """SLO-aware admission control for the closed-loop serving drivers.

    Modes:
     * ``"none"``    — admit everything (the PR-5 loop, byte-identical).
     * ``"shed"``    — drop requests whose predicted completion under the
       committed plan cannot meet the latency budget (load the window
       provably cannot drain), including carried backlog already past it.
     * ``"defer"``   — same predictor, but rejected requests re-enter the
       next (sub-)window re-timestamped at its start: re-submission
       semantics — the latency clock restarts, and the config's
       ``defer_cap`` bounds the deferred backlog (overflow is shed).
     * ``"degrade-bs"`` — trim nothing; when the window's demand is not
       drainable under the committed plan, swap in the max-service-rate
       plan (``problem.solve_infer_capacity``) and accept the violations:
       the goodput-over-latency end of the tradeoff curve.

    ``headroom`` scales the admission threshold (< 1 rejects earlier,
    buying slack against fill-time variance). ``priorities`` is the
    multi-tenant hook: per-stream positive weights, normalized to the
    largest; a stream's admission budget is scaled by its weight, so as the
    shared queue builds, lower-priority streams start shedding while
    higher-priority tenants still admit."""
    mode: str = "none"
    headroom: float = 1.0
    priorities: Optional[tuple] = None

    def __post_init__(self):
        if self.mode not in _ADMISSIONS:
            raise ValueError(f"unknown admission mode {self.mode!r}; "
                             f"use {_ADMISSIONS}")
        if self.headroom <= 0.0:
            raise ValueError("admission headroom must be positive")

    @property
    def active(self) -> bool:
        return self.mode != "none"

    @property
    def trims(self) -> bool:
        """Whether this mode removes requests from the window's trace."""
        return self.mode in ("shed", "defer")

    def stream_budget_scales(self, n_streams: int) -> np.ndarray:
        """Per-stream admission-budget scales: headroom times the priority
        weight (normalized so the highest-priority stream keeps the full
        headroom). All-ones priorities when none are configured."""
        if self.priorities is None:
            pr = np.ones(n_streams)
        else:
            if len(self.priorities) != n_streams:
                raise ValueError(f"{len(self.priorities)} priorities for "
                                 f"{n_streams} streams")
            pr = np.asarray(self.priorities, np.float64)
            pr = pr / pr.max()
        return self.headroom * pr

    def admit(self, times: np.ndarray, nominal_budget: float, bs: int,
              t_in: float, clock: float) -> np.ndarray:
        """Single-stream admission mask over the effective arrival vector."""
        buds = np.full(np.asarray(times).shape[0] if np.ndim(times) else 0,
                       self.headroom * float(nominal_budget))
        return _admit_mask(times, buds, bs, t_in, clock)

    def admit_multi(self, times: np.ndarray, sids: np.ndarray,
                    bss: Sequence[int], t_ins: Sequence[float],
                    nominal_budgets: Sequence[float],
                    clock: float) -> np.ndarray:
        """Multi-tenant admission mask over time-sorted merged arrivals."""
        scales = self.stream_budget_scales(len(nominal_budgets))
        per_stream = scales * np.asarray(nominal_budgets, np.float64)
        sids = np.asarray(sids, np.int64)
        buds = per_stream[sids] if sids.size else np.empty(0)
        return _admit_mask_multi(times, sids, bss, t_ins, buds, clock)

    def gate(self, bs: int, t_in: float, budget: float):
        """A trace-trimming callable for the real runtime
        (``runtime.interleave_runtime``): ``gate(trace) -> (admitted_trace,
        n_shed)`` applying exactly the engine-side admission mask, so a
        runtime run under a FakeClock sheds the identical request set."""
        from repro.core.simulate import ArrivalTrace

        def _gate(trace):
            if not self.trims:
                return trace, 0
            mask = self.admit(trace.times, budget, bs, t_in, 0.0)
            if mask.all():
                return trace, 0
            return (ArrivalTrace(trace.times[mask], trace.duration,
                                 trace.kind),
                    int(np.count_nonzero(~mask)))
        return _gate


class RateEstimator:
    """Arrival-rate estimate for one stream, fed by executed windows.

    ``"oracle"`` returns the announced rate untouched. ``"ewma"`` keeps an
    exponentially weighted moving average of observed inter-arrival gaps
    (per-gap weight ``alpha``), warm-started across windows: the mean gap —
    and the last arrival timestamp, so the gap spanning a window boundary
    counts too — carries from window to window, and the estimate is its
    reciprocal. Before anything was observed (window 0) the announced rate
    bootstraps the estimate. A window with fewer than two arrivals folds one
    right-censored pseudo-gap equal to the window duration, so idle windows
    decay the estimate instead of pinning it."""

    def __init__(self, kind: str = "ewma", alpha: float = 0.2):
        if kind not in _ESTIMATORS:
            raise ValueError(f"unknown rate estimator {kind!r}; "
                             f"use {_ESTIMATORS}")
        self.kind = kind
        self.alpha = float(alpha)
        self._mean_gap: Optional[float] = None
        self._last_arrival: Optional[float] = None

    def estimate(self, announced_rate: float) -> float:
        """The rate to plan the next window for."""
        if self.kind == "oracle" or self._mean_gap is None:
            return float(announced_rate)
        return 1.0 / self._mean_gap if self._mean_gap > 0.0 else 0.0

    def observe(self, times: np.ndarray, duration: float) -> None:
        """Fold one executed window's observed arrival timestamps (this
        window's own arrivals only — carried-over requests were observed by
        the window they arrived in) into the estimate."""
        if self.kind == "oracle":
            return
        times = np.asarray(times, np.float64)
        gaps = np.diff(times)
        if (self._last_arrival is not None and times.size
                and times[0] > self._last_arrival):
            gaps = np.concatenate([[times[0] - self._last_arrival], gaps])
        if times.size:
            self._last_arrival = float(times[-1])
        if gaps.size == 0:
            gaps = np.array([float(duration)])
            if times.size == 0:
                # the idle span is folded as this pseudo-gap; drop the
                # boundary anchor so the next window's first arrival does
                # not fold the same span again as a real gap
                self._last_arrival = None
        if self._mean_gap is None:
            m, gaps = float(gaps[0]), gaps[1:]
        else:
            m = self._mean_gap
        if gaps.size:
            # exact EWMA over the gap sequence, vectorized:
            # m <- (1-a)^n m + a * sum_i (1-a)^(n-1-i) g_i
            a = self.alpha
            decay = (1.0 - a) ** np.arange(gaps.size - 1, -1, -1)
            m = (1.0 - a) ** gaps.size * m + a * float(decay @ gaps)
        self._mean_gap = m


class FeedbackPolicy:
    """Effective-latency-budget governor for one stream.

    State is ``scale`` in (0, 1]: the next window is planned against
    ``scale * nominal`` while the *executed* violation rate is judged
    against the nominal budget. After each executed window:

     * violating (rate above ``target_violation``): multiply the scale by
       ``1 - tighten * severity`` where severity is the larger of the
       executed violation rate and the executed tail's fractional overshoot
       of the nominal budget, both clipped to 1 — monotone in the violation
       rate, floored at ``min_budget_scale``. The cut is deliberately
       *bounded per window* (at most a ``tighten`` fraction): a queue-
       flooded window can report tails orders of magnitude over budget, and
       jumping the scale straight to ``nominal/tail`` would demand plans no
       power mode can deliver (the next window would go unserved, worse
       than the violation being corrected).
     * clean: move the scale back toward 1 by ``relax`` of the remaining
       gap (never above nominal).

    With ``feedback`` off the policy is inert (scale pinned at 1)."""

    def __init__(self, cfg: ControllerConfig):
        self.cfg = cfg
        self.scale = 1.0

    def effective_budget(self, nominal: float) -> float:
        return float(nominal) * self.scale

    def update(self, violation_rate: float, tail_latency: float,
               nominal: float) -> None:
        if not self.cfg.feedback:
            return
        c = self.cfg
        if violation_rate > c.target_violation:
            overshoot = float(tail_latency) / max(float(nominal), 1e-12) - 1.0
            severity = min(1.0, max(float(violation_rate),
                                    min(1.0, max(0.0, overshoot))))
            self.scale = max(c.min_budget_scale,
                             self.scale * (1.0 - c.tighten * severity))
        else:
            self.scale = min(1.0, self.scale + c.relax * (1.0 - self.scale))


class ControllerState:
    """Cross-window state of one closed-loop serving session: per-stream
    rate estimators and feedback policies, the carried queue state, and the
    previously committed power mode."""

    def __init__(self, cfg: ControllerConfig, n_streams: int = 1):
        self.cfg = cfg
        self.estimators = [RateEstimator(cfg.rate_estimator, cfg.ewma_alpha)
                           for _ in range(n_streams)]
        self.policies = [FeedbackPolicy(cfg) for _ in range(n_streams)]
        self.carry: Optional[QueueState] = None
        self.prev_pm = None
        # deferred-request backlog (AdmissionPolicy mode "defer"): per-stream
        # counts only — a deferred request re-enters re-timestamped at the
        # next (sub-)window start, so its original arrival time is moot
        self.deferred = np.zeros(n_streams, np.int64)

    # -- deferred requests (admission mode "defer") --------------------------
    def push_deferred(self, counts: Sequence[int]) -> int:
        """Queue per-stream rejected-request counts for re-submission at the
        next (sub-)window start. The config's ``defer_cap`` bounds the total
        deferred backlog — without it, sustained overload would snowball the
        re-offer queue forever; overflow is trimmed from the streams with
        the largest deferred counts and returned (the driver records it as
        shed)."""
        self.deferred = self.deferred + np.asarray(counts, np.int64)
        cap = self.cfg.defer_cap
        dropped = 0
        if cap is not None:
            total = int(self.deferred.sum())
            while total > cap:
                j = int(np.argmax(self.deferred))
                take = min(int(self.deferred[j]), total - cap)
                self.deferred[j] -= take
                total -= take
                dropped += take
        return dropped

    def pop_deferred(self, t0: float) -> list[np.ndarray]:
        """The deferred backlog re-submitted at ``t0``: one arrival vector
        per stream, every request re-timestamped to the (sub-)window start
        (its latency clock restarts at re-submission). Clears the backlog —
        requests the next admission pass rejects again are re-deferred (or
        shed) by the driver."""
        out = [np.full(int(c), float(t0)) for c in self.deferred]
        self.deferred = np.zeros_like(self.deferred)
        return out

    # -- planning inputs ----------------------------------------------------
    def plan_rates(self, announced: Sequence[float], t0: float = 0.0,
                   duration: Optional[float] = None,
                   margin: Optional[float] = None,
                   pressure: bool = True) -> list[float]:
        """Per-stream rates to plan the next window for: the margin-scaled
        estimate, compensated for queue pressure when backlog carries — a
        window starting at ``t0`` that inherits a clock overrun has only
        ``duration - overrun`` seconds to serve both its own arrivals and
        the carried pending requests, so the plan must sustain
        ``(rate * duration + pending) / (duration - overrun)`` to drain the
        backlog within the window (overrun capped at 90% of the window, or
        the required rate would explode). ``margin`` overrides the config's
        rate margin; ``pressure=False`` skips the backlog compensation —
        the drivers use that for the latency-budget side of an interval
        plan, where the *true* arrival-rate estimate governs the batch-fill
        wait once the backlog has drained."""
        m = self.cfg.rate_margin if margin is None else float(margin)
        rates = [m * e.estimate(r)
                 for e, r in zip(self.estimators, announced)]
        if (not pressure or not self.cfg.carry_backlog or self.carry is None
                or duration is None or duration <= 0.0):
            return rates
        overrun = max(0.0, min(0.9 * float(duration),
                               float(self.carry.clock) - float(t0)))
        avail = float(duration) - overrun
        return [(r * float(duration) + len(self.carry.pending_for(j)))
                / avail for j, r in enumerate(rates)]

    def plan_budgets(self, nominal: Sequence[float]) -> list[float]:
        """Per-stream effective latency budgets for the next plan."""
        return [p.effective_budget(b)
                for p, b in zip(self.policies, nominal)]

    # -- mode-switch accounting ---------------------------------------------
    def mode_switch(self, pm) -> float:
        """Commit to a power mode; the wall cost this window pays for
        switching into it (0 for the first window — nothing to switch
        from — and while the mode is unchanged)."""
        cost = self.cfg.mode_switch_s \
            if self.prev_pm is not None and pm != self.prev_pm else 0.0
        self.prev_pm = pm
        return cost

    # -- engine carry-in ----------------------------------------------------
    def window_carry_in(self, t0: float, switch_s: float) -> QueueState:
        """The engine's carry-in for a window starting at ``t0``: the carried
        backlog (when enabled) with the clock advanced by the mode-switch
        cost — the engine may not serve before the switch completes."""
        pending, ids, clock = np.empty(0), None, float(t0)
        if self.cfg.carry_backlog and self.carry is not None:
            pending, ids = self.carry.pending, self.carry.stream_ids
            clock = max(float(self.carry.clock), clock)
        return QueueState(pending, clock + float(switch_s), ids)

    def observe_unserved(self, traces: Sequence, duration: float) -> None:
        """An unsolvable window: nothing serves, but arrivals were still
        observable (the estimators fold them in) and, with carryover
        enabled, they queue for the next solvable window."""
        for est, tr in zip(self.estimators, traces):
            est.observe(tr.times, duration)
        self.defer_window(traces)

    def defer_window(self, traces: Sequence) -> None:
        """Queue an unserved window's arrivals into the carried backlog
        (backlogged requests do not vanish); no-op with carryover off."""
        if not self.cfg.carry_backlog:
            return
        carry = self.carry if self.carry is not None \
            else QueueState(np.empty(0), 0.0, np.empty(0, np.int64))
        times = np.concatenate([carry.pending] + [t.times for t in traces])
        ids = np.concatenate(
            [carry.stream_ids if carry.stream_ids is not None
             else np.zeros(len(carry.pending), np.int64)]
            + [np.full(len(t), j, np.int64) for j, t in enumerate(traces)])
        order = np.argsort(times, kind="stable")
        self.carry = QueueState(times[order], carry.clock, ids[order])

    # -- executed-window feedback -------------------------------------------
    def observe(self, traces: Sequence, reports: Sequence,
                nominal_budgets: Sequence[float], duration: float,
                queue_state: Optional[QueueState]) -> None:
        """Fold one executed window back into the state: per-stream arrival
        observations (the window's own trace, not carried requests),
        executed violation/tail feedback against the *nominal* budgets, and
        the end-of-window queue state."""
        for est, pol, tr, rep, bud in zip(self.estimators, self.policies,
                                          traces, reports, nominal_budgets):
            est.observe(tr.times, duration)
            pol.update(rep.violation_rate(bud),
                       rep.latency_quantile(self.cfg.tail_quantile), bud)
        self.carry = queue_state


class FleetControllerState:
    """Array-of-struct controller state for a K-device fleet
    (``Scenario.FLEET``): device ``d`` is governed by exactly the scalar
    ``ControllerState(cfg, 1)`` a standalone single-device closed loop
    would hold, so parity with K sequential loops is by construction —
    same estimator floats, same feedback scales, same carried queue
    states. The ``plan_*`` methods return per-device arrays the batched
    fleet planner consumes; this O(K) Python bookkeeping is negligible
    against the batched solve + batched simulate it feeds (measured in
    ``benchmarks/bench_fleet.py``)."""

    def __init__(self, cfg: ControllerConfig, n_devices: int):
        if n_devices <= 0:
            raise ValueError("a fleet needs at least one device")
        self.cfg = cfg
        self.devices = [ControllerState(cfg, 1) for _ in range(n_devices)]
        # fleet-level deferred backlog (admission mode "defer"): unlike the
        # per-device ``ControllerState.deferred`` counters, a request a
        # device rejects re-enters the *dispatcher* at the next window
        # start — it may land on any device, not the one it bounced off
        self.fleet_deferred = 0

    def __len__(self) -> int:
        return len(self.devices)

    # -- fleet-level deferred requests (admission mode "defer") -------------
    def push_fleet_deferred(self, n: int) -> int:
        """Queue ``n`` rejected requests for fleet-wide re-submission at the
        next window start (they re-enter the dispatcher, re-timestamped).
        The config's ``defer_cap`` bounds the fleet's total deferred
        backlog; the overflow is returned for the driver to record as shed
        — charged, like the per-device counters, to the device that pushed
        it."""
        self.fleet_deferred += int(n)
        cap = self.cfg.defer_cap
        if cap is None or self.fleet_deferred <= cap:
            return 0
        dropped = self.fleet_deferred - cap
        self.fleet_deferred = cap
        return dropped

    def pop_fleet_deferred(self) -> int:
        """Drain the fleet's deferred backlog for re-dispatch: the count of
        requests to prepend (re-timestamped at the window start) to the next
        window's aggregate arrivals. Requests the next admission pass
        rejects again are re-deferred (or shed) by the driver."""
        n, self.fleet_deferred = self.fleet_deferred, 0
        return n

    def plan_rates(self, announced: Sequence[float], t0: float = 0.0,
                   duration: Optional[float] = None,
                   margin: Optional[float] = None,
                   pressure: bool = True) -> np.ndarray:
        """Per-device planning rates (one announced rate per device)."""
        return np.array([st.plan_rates([r], t0, duration, margin=margin,
                                       pressure=pressure)[0]
                         for st, r in zip(self.devices, announced)])

    def plan_budgets(self, nominal: Sequence[float]) -> np.ndarray:
        """Per-device effective latency budgets."""
        return np.array([st.plan_budgets([b])[0]
                         for st, b in zip(self.devices, nominal)])

    def mode_switch(self, d: int, pm) -> float:
        """Commit device ``d`` to a power mode (solved devices only — an
        unsolved device keeps its previous mode, as in the scalar loop)."""
        return self.devices[d].mode_switch(pm)

    def window_carry_in(self, d: int, t0: float, switch_s: float) -> QueueState:
        return self.devices[d].window_carry_in(t0, switch_s)

    def observe(self, d: int, trace, report, nominal_budget: float,
                duration: float, queue_state: Optional[QueueState]) -> None:
        self.devices[d].observe([trace], [report], [nominal_budget],
                                duration, queue_state)

    def observe_unserved(self, d: int, trace, duration: float) -> None:
        self.devices[d].observe_unserved([trace], duration)
