"""Shared NumPy/jax backend plumbing for the batched engines.

Both vectorized layers — the grid-evaluation solvers (``core.grid_eval``) and
the trace-driven execution engine (``core.simulate``) — expose the same two
backends: ``"numpy"`` (the reference implementation, always available) and
``"jax"`` (jit + vmap, runs on-accelerator). This module centralizes the
selection rules so every entry point behaves identically:

 * ``check_backend``   — validate an explicit backend name.
 * ``jax_available``   — cached import probe; monkeypatchable in tests.
 * ``resolve_backend`` — map a request (``None`` / ``"numpy"`` / ``"jax"``)
   to the backend that will actually run. ``None`` defers to the
   ``FULCRUM_ENGINE_BACKEND`` environment variable and **defaults to NumPy**;
   an env-var ``jax`` request silently falls back to NumPy when jax is
   missing (the default path must never fail), while an *explicit*
   ``backend="jax"`` argument raises, so a caller that asked for the
   accelerator is told it is absent.
 * ``require_jax``     — the lazy jax import used by both jax kernels, with
   one shared error message.

The reference-backend invariant (NumPy results are authoritative; jax is
cross-checked against them) is documented in ``docs/exactness.md``.
"""
from __future__ import annotations

import os
from typing import Optional

#: Environment variable consulted when no explicit backend is requested.
ENGINE_BACKEND_ENV = "FULCRUM_ENGINE_BACKEND"

_JAX_OK: Optional[bool] = None      # memoized import probe (tests patch this)

_JAX_MISSING_MSG = ("backend='jax' requires jax; "
                    "use the default NumPy backend")


def jax_available() -> bool:
    """True when jax imports; probed once and memoized."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax  # noqa: F401
            _JAX_OK = True
        except Exception:
            _JAX_OK = False
    return _JAX_OK


def check_backend(backend: str) -> None:
    if backend not in ("numpy", "jax"):
        raise ValueError(f"unknown backend {backend!r}; use 'numpy' or 'jax'")


def resolve_backend(backend: Optional[str] = None,
                    env: str = ENGINE_BACKEND_ENV) -> str:
    """Resolve a backend request to the backend that will run.

    ``None`` reads ``env`` (default ``"numpy"``, the bitwise/exact reference)
    and degrades an env-level ``jax`` request to ``"numpy"`` when jax is
    unavailable. An explicit ``"jax"`` argument raises ``RuntimeError``
    instead of degrading.
    """
    defaulted = backend is None
    if defaulted:
        backend = os.environ.get(env, "").strip().lower() or "numpy"
    check_backend(backend)
    if backend == "jax" and not jax_available():
        if defaulted:
            return "numpy"
        raise RuntimeError(_JAX_MISSING_MSG)
    return backend


def require_jax():
    """Import (jax, jax.numpy, enable_x64), raising the shared message when
    jax is absent. Both kernel caches build through this."""
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
    except Exception as e:  # pragma: no cover - jax is baked into the image
        raise RuntimeError(_JAX_MISSING_MSG) from e
    return jax, jnp, enable_x64
