"""Shared NumPy/jax/Pallas backend plumbing for the batched engines.

The vectorized layers — the grid-evaluation solvers (``core.grid_eval``) and
the trace-driven execution engine (``core.simulate``) — share a backend
vocabulary resolved here so every entry point behaves identically:

 * ``"numpy"``  — the reference implementation, always available.
 * ``"jax"``    — jit + vmap programs, runs on-accelerator.
 * ``"pallas"`` — the engine's hand-written Pallas kernels
   (``src/repro/kernels/fulcrum/``); engine-only — the grid solvers accept
   ``numpy``/``jax`` (their masked reductions have no hand-written kernel).

Selection rules:

 * ``check_backend``     — validate an explicit backend name against the
   caller's allowed set.
 * ``jax_available`` / ``pallas_available`` — cached import probes;
   monkeypatchable in tests.
 * ``resolve_backend``   — map a request (``None`` / a backend name) to the
   backend that will actually run. ``None`` defers to the
   ``FULCRUM_ENGINE_BACKEND`` environment variable and **defaults to NumPy**;
   an env-var request degrades down the tier order pallas → jax → numpy when
   the requested tier is missing (the default path must never fail), while an
   *explicit* backend argument raises, so a caller that asked for an
   accelerator tier is told it is absent.
 * ``require_jax``       — the lazy jax import used by the jax kernels, with
   one shared error message.

The reference-backend invariant (NumPy results are authoritative; jax and
Pallas are cross-checked against them) is documented in ``docs/exactness.md``.

This module also hosts the **host-dispatch counters**: every compiled-program
launch (a host->accelerator synchronization point) is recorded here by the
layer that made it — ``"solver"`` for the grid-solver kernels
(``core.grid_eval``), ``"engine"`` for the max-plus scan runners
(``core.simulate``, one per lane chunk), ``"fused"`` for the fused
fleet-window program (``core.fused_window``). ``dispatch_count()`` lets
benchmarks report dispatches-per-window as a tracked number (the fused
window's whole point is driving it to 1) and lets tests pin it.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

#: Environment variable consulted when no explicit backend is requested.
ENGINE_BACKEND_ENV = "FULCRUM_ENGINE_BACKEND"

#: Engine tiers, fastest-intent first; resolve_backend degrades rightward.
BACKEND_TIERS = ("pallas", "jax", "numpy")

_JAX_OK: Optional[bool] = None      # memoized import probes (tests patch)
_PALLAS_OK: Optional[bool] = None

_JAX_MISSING_MSG = ("backend='jax' requires jax; "
                    "use the default NumPy backend")
_PALLAS_MISSING_MSG = ("backend='pallas' requires jax.experimental.pallas; "
                       "use the 'jax' or default NumPy backend")


def jax_available() -> bool:
    """True when jax imports; probed once and memoized."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import jax  # noqa: F401
            _JAX_OK = True
        except Exception:
            _JAX_OK = False
    return _JAX_OK


def pallas_available() -> bool:
    """True when the Pallas kernel tier can run: jax imports *and*
    ``jax.experimental.pallas`` is present (interpret mode makes it runnable
    on CPU — no TPU needed; see ``src/repro/kernels/fulcrum/``)."""
    global _PALLAS_OK
    if _PALLAS_OK is None:
        if not jax_available():
            _PALLAS_OK = False
        else:
            try:
                from jax.experimental import pallas  # noqa: F401
                _PALLAS_OK = True
            except Exception:
                _PALLAS_OK = False
    return _PALLAS_OK


def check_backend(backend: str,
                  allowed: Sequence[str] = BACKEND_TIERS) -> None:
    """Validate an explicit backend name against the caller's allowed set
    (the grid solvers pass ``("numpy", "jax")`` — no Pallas solver tier)."""
    if backend not in allowed:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"use one of {'/'.join(repr(a) for a in allowed)}")


def resolve_backend(backend: Optional[str] = None,
                    env: str = ENGINE_BACKEND_ENV) -> str:
    """Resolve a backend request to the backend that will run.

    ``None`` reads ``env`` (default ``"numpy"``, the bitwise/exact reference)
    and degrades an env-level request down the pallas → jax → numpy tier
    order when the requested tier is unavailable. An explicit ``"jax"`` /
    ``"pallas"`` argument raises ``RuntimeError`` instead of degrading.
    """
    defaulted = backend is None
    if defaulted:
        backend = os.environ.get(env, "").strip().lower() or "numpy"
    check_backend(backend)
    if backend == "pallas" and not pallas_available():
        if not defaulted:
            raise RuntimeError(_PALLAS_MISSING_MSG)
        backend = "jax"                       # degrade one tier and re-check
    if backend == "jax" and not jax_available():
        if defaulted:
            return "numpy"
        raise RuntimeError(_JAX_MISSING_MSG)
    return backend


# compiled-program launches since import, by layer. Unlike the retrace
# counters (trace-time side effects in grid_eval/simulate/fused_window),
# these count *calls* — each one is a host boundary crossing.
_DISPATCH_COUNTS: dict = {"solver": 0, "engine": 0, "fused": 0}


def record_dispatch(kind: str) -> None:
    """Record one compiled-program launch of the given layer."""
    _DISPATCH_COUNTS[kind] = _DISPATCH_COUNTS.get(kind, 0) + 1


def dispatch_count(kind: Optional[str] = None) -> int:
    """Compiled-program launches since import: one layer's count, or the
    total across layers (``kind=None``) — the number a serving loop's
    dispatches-per-window is measured from."""
    if kind is not None:
        return _DISPATCH_COUNTS.get(kind, 0)
    return sum(_DISPATCH_COUNTS.values())


def require_jax():
    """Import (jax, jax.numpy, enable_x64), raising the shared message when
    jax is absent. The jax and Pallas kernel caches build through this."""
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64
    except Exception as e:  # pragma: no cover - jax is baked into the image
        raise RuntimeError(_JAX_MISSING_MSG) from e
    return jax, jnp, enable_x64
