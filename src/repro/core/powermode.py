"""Jetson Orin AGX power-mode space (paper Table 3).

A power mode is (CPU cores, CPU freq, GPU freq, memory freq). The full Orin
space is 12 x 29 x 13 x 4 = 18,096 modes; the paper's ground-truth experiment
grid is the uniformly spaced 3 x 7 x 7 x 3 = 441 subset, which we mirror
exactly (the midpoint mode works out to 8c/1344/727/2133, as in §5.1.2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

DIMS = ("cores", "cpuf", "gpuf", "memf")

# Full Orin AGX value lists (MHz; cores is a count).
CORES_ALL = list(range(1, 13))                                         # 12
CPUF_ALL = [115, 192, 268, 345, 422, 499, 576, 652, 729, 806, 883,
            960, 1036, 1113, 1190, 1267, 1344, 1420, 1497, 1574, 1651,
            1728, 1804, 1881, 1958, 2035, 2112, 2188, 2201]            # 29
GPUF_ALL = [115, 217, 319, 421, 522, 624, 727, 828, 930, 1032, 1134,
            1236, 1300]                                                # 13
MEMF_ALL = [665, 1600, 2133, 3199]                                     # 4

# Experiment grid (441 modes, paper Table 3c).
CORES_EXP = [4, 8, 12]
CPUF_EXP = [422, 729, 1036, 1344, 1651, 1958, 2201]
GPUF_EXP = [115, 319, 522, 727, 930, 1134, 1300]
MEMF_EXP = [665, 2133, 3199]


@dataclasses.dataclass(frozen=True, order=True)
class PowerMode:
    cores: int
    cpuf: int
    gpuf: int
    memf: int

    def replace(self, **kw) -> "PowerMode":
        return dataclasses.replace(self, **kw)

    def value(self, dim: str) -> int:
        return getattr(self, dim)

    def __str__(self) -> str:
        return f"{self.cores}c/{self.cpuf}/{self.gpuf}/{self.memf}"


MAXN = PowerMode(12, 2201, 1300, 3199)


class PowerModeSpace:
    """A rectangular grid of modes with per-dimension value lists.

    Generic over the mode dataclass: subclasses may redefine MODE_CLS and the
    dimension dict (the GMD machinery only relies on .values, .index and the
    mode's .value()/.replace() protocol) — see core.tpu_adapter for the
    TPU-knob reuse."""

    MODE_CLS = PowerMode

    def __init__(self, cores: Sequence[int] = CORES_EXP,
                 cpuf: Sequence[int] = CPUF_EXP,
                 gpuf: Sequence[int] = GPUF_EXP,
                 memf: Sequence[int] = MEMF_EXP):
        self.values = {"cores": sorted(cores), "cpuf": sorted(cpuf),
                       "gpuf": sorted(gpuf), "memf": sorted(memf)}

    def make_mode(self, **kw):
        return self.MODE_CLS(**kw)

    @classmethod
    def full_orin(cls) -> "PowerModeSpace":
        return cls(CORES_ALL, CPUF_ALL, GPUF_ALL, MEMF_ALL)

    def __len__(self) -> int:
        n = 1
        for v in self.values.values():
            n *= len(v)
        return n

    def all_modes(self) -> list:
        names = list(self.values)
        return [self.make_mode(**dict(zip(names, combo)))
                for combo in itertools.product(*self.values.values())]

    def mid(self, dim: str) -> int:
        vals = self.values[dim]
        return vals[len(vals) // 2]

    def midpoint(self):
        return self.make_mode(**{d: self.mid(d) for d in self.values})

    def lowest(self, dim: str) -> int:
        return self.values[dim][0]

    def highest(self, dim: str) -> int:
        return self.values[dim][-1]

    def maxn(self):
        return self.make_mode(**{d: self.highest(d) for d in self.values})

    def minn(self):
        return self.make_mode(**{d: self.lowest(d) for d in self.values})

    def index(self, dim: str, value: int) -> int:
        return self.values[dim].index(value)

    def contains(self, pm) -> bool:
        return all(pm.value(d) in self.values[d] for d in self.values)
