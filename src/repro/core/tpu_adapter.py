"""Beyond-paper: Fulcrum's GMD retargeted at TPU-pod configuration.

There are no "power modes" on a TPU pod, but the paper's core insight — a
profile-guided, slope-ratio-prioritized multi-dimensional bisection over a
discrete knob space with a monotone resource constraint — transfers directly:

  Jetson knob                  TPU knob
  ---------------------------  ------------------------------------------
  CPU cores / CPU / GPU / mem  data-parallel width (dp, chips/dp = tensor-
  frequencies                  parallel width), microbatch count, remat
  power budget  p <= p-hat     per-chip HBM bytes <= 16 GiB
  minibatch time               roofline step time (compute+memory+coll.)
  Profile(pm) on the board     analytic roofline model (or a dry-run
                               lower+compile, ~seconds, on the real fleet)

Monotone "power": HBM per chip strictly grows with dp (FSDP replication
narrows), with fewer microbatches, and with remat off — so GMD's half-line
pruning stays sound. Time is non-monotone across dims (the compute/collective
trade), exactly like Jetson minibatch time; that is what the slope ratios
navigate.

This gives a scheduler that picks (dp, microbatch, remat) for any assigned
architecture x input shape with ~10 "profiles", each of which on real
hardware is one lower+compile dry-run instead of a 40-minibatch run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import problem as P
from repro.core.gmd import _GMDBase
from repro.core.powermode import PowerModeSpace
from repro.launch.mesh import HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16
from repro.models.model import ModelConfig


MAX_ACC = 16


@dataclasses.dataclass(frozen=True, order=True)
class TPUMode:
    """One point in the TPU configuration space. Every dimension is ordered
    so that a LARGER value uses MORE per-chip HBM — the monotone-"power"
    property GMD's half-line pruning requires:

    tp:    tensor/model-parallel width (data-parallel = chips // tp);
           larger tp -> larger per-chip activation slice (batch shards less).
    acc:   accumulation width; microbatches = MAX_ACC // acc, so larger acc
           -> fewer microbatches -> bigger live activation working set.
    remat: 0 = activation checkpointing ON, 1 = OFF (more HBM).
    """
    tp: int
    acc: int
    remat: int

    @property
    def microbatches(self) -> int:
        return MAX_ACC // self.acc

    def value(self, dim: str) -> int:
        return getattr(self, dim)

    def replace(self, **kw) -> "TPUMode":
        return dataclasses.replace(self, **kw)

    def __str__(self):
        return (f"tp{self.tp}/mb{self.microbatches}/"
                f"remat{'off' if self.remat else 'on'}")


class TPUKnobSpace(PowerModeSpace):
    MODE_CLS = TPUMode

    def __init__(self, chips: int = 256,
                 tp=(4, 8, 16, 32, 64),
                 acc=(1, 2, 4, 8, 16), remat=(0, 1)):
        self.chips = chips
        self.values = {"tp": sorted(tp), "acc": sorted(acc),
                       "remat": sorted(remat)}

    def make_mode(self, **kw):
        return TPUMode(**kw)


class RooflineTPUModel:
    """Analytic per-step roofline of (arch x shape) under a TPU config.

    time  = compute + exposed-memory + collective terms (same three-term
            decomposition as EXPERIMENTS.md §Roofline)
    "power" = per-chip HBM bytes (params/optimizer/activations/cache).
    On real hardware this is replaced by a lower+compile dry-run profile.
    """

    def __init__(self, cfg: ModelConfig, seq_len: int, global_batch: int,
                 kind: str = "train", chips: int = 256):
        self.cfg, self.seq, self.batch, self.kind = cfg, seq_len, global_batch, kind
        self.chips = chips

    def time_power(self, m: TPUMode) -> tuple[float, float]:
        cfg = self.cfg
        tp = m.tp
        dp = max(1, self.chips // tp)
        micro = m.microbatches
        tokens = self.batch * self.seq
        n_active = cfg.active_param_count()
        n_total = cfg.param_count()
        mult = 3.0 if self.kind == "train" else 1.0         # fwd+bwd vs fwd
        remat_mult = (4 / 3 if (self.kind == "train" and m.remat == 0) else 1.0)
        flops_dev = 2.0 * n_active * tokens * mult * remat_mult / self.chips
        compute_s = flops_dev / PEAK_FLOPS_BF16

        # HBM traffic: weights streamed once per microbatch + activations
        act_bytes = tokens * cfg.d_model * cfg.num_layers * 2 * 4 / self.chips
        weight_bytes = n_active / tp * 2 * micro * mult
        memory_s = (act_bytes + weight_bytes) / HBM_BW

        # collectives: Megatron 2 all-reduce/layer fwd (+2 bwd) of the
        # activation shard + DP gradient reduce-scatter of the param shard
        ar_bytes = (2 * mult * cfg.num_layers
                    * (tokens / dp) * cfg.d_model * 2 * (tp - 1) / max(tp, 1))
        dp_bytes = (2.0 * n_total / tp * 4 * (dp - 1) / max(dp, 1)
                    if self.kind == "train" else 0.0)
        coll_s = (ar_bytes + dp_bytes) / ICI_BW

        time_s = compute_s + memory_s + coll_s

        # per-chip HBM footprint ("power"); params/optimizer FSDP over all
        # chips (constant in the knobs), activations set the gradient.
        opt_mult = (4 + 4 + 4 + 2) if self.kind == "train" else 2
        param_hbm = n_total * opt_mult / self.chips
        act_live = (tokens * tp / self.chips / micro) * cfg.d_model * 2 \
            * (2 if m.remat == 0 else cfg.num_layers) \
            * (1 if self.kind == "train" else 0.25)
        kv_hbm = 0.0
        if self.kind != "train" and cfg.n_kv_heads:
            kv_hbm = (self.batch * tp / self.chips) * cfg.num_layers * 2 \
                * cfg.n_kv_heads * cfg.resolved_head_dim * self.seq * 2
        hbm = param_hbm + act_live + kv_hbm
        return time_s, hbm


class GMDForTPU(_GMDBase):
    """GMD over the TPU knob space: min step time s.t. per-chip HBM <= budget.

    Identical machinery to GMDTrain — the 'profiler' is the roofline model
    (or a real dry-run), the 'power budget' is HBM_BYTES.
    """

    def __init__(self, model: RooflineTPUModel,
                 space: Optional[TPUKnobSpace] = None,
                 hbm_budget: float = float(HBM_BYTES), max_tries: int = 10):
        super().__init__(profiler=None, space=space or TPUKnobSpace(model.chips),
                         max_tries=max_tries)
        self.model = model
        self.hbm_budget = hbm_budget
        self.num_profiles = 0
        self._obs: dict[TPUMode, tuple[float, float]] = {}

    def solve(self) -> Optional[P.Solution]:
        """Slope-prioritized coordinate search, adapted for TPU knobs.

        One departure from Jetson GMD, documented in DESIGN.md: on the Orin,
        minibatch time falls monotonically along every dimension, so "feasible
        => everything below is dominated" prunes half-lines. On TPU knobs time
        is NON-monotone (e.g. raising tp trades collective bytes against
        weight-streaming bytes), so the objective-side pruning is replaced by
        a convex line search per dimension; the monotone-HBM pruning (the
        power-budget analogue) is kept verbatim.
        """
        self._obs = {}
        self.num_profiles = 0
        sp = self.space
        mid = sp.midpoint()
        self._profile(mid)
        current = mid

        # initial probes at both ends of each dim -> time slopes
        slopes = {}
        for dim, vals in sp.values.items():
            if len(vals) < 2:
                continue
            lo = current.replace(**{dim: vals[0]})
            hi = current.replace(**{dim: vals[-1]})
            t_lo, _ = self._profile(lo)
            t_hi, h_hi = self._profile(hi)
            slopes[dim] = abs(t_hi - t_lo) / (vals[-1] - vals[0])

        # coordinate descent in decreasing slope order; per-dim convex search
        for dim in sorted(slopes, key=slopes.get, reverse=True):
            vals = sp.values[dim]
            lo_i, hi_i = 0, len(vals) - 1
            while hi_i - lo_i > 1 and self.num_profiles < self.max_tries + 8:
                m1 = lo_i + (hi_i - lo_i) // 3
                m2 = hi_i - (hi_i - lo_i) // 3
                if m2 == m1:
                    m2 = m1 + 1
                t1, h1 = self._profile(current.replace(**{dim: vals[m1]}))
                t2, h2 = self._profile(current.replace(**{dim: vals[m2]}))
                # monotone-HBM pruning: an over-budget point rules out
                # everything above it on this line
                if h1 > self.hbm_budget:
                    hi_i = m1 - 1
                    continue
                if h2 > self.hbm_budget:
                    hi_i = m2 - 1
                    continue
                if t1 <= t2:
                    hi_i = m2 - 1 if m2 > m1 else hi_i - 1
                else:
                    lo_i = m1 + 1
            # anchor at the best feasible value seen on this line
            best_v = None
            best_t = float("inf")
            for mode, (t, h) in self._obs.items():
                if h <= self.hbm_budget and t < best_t and all(
                        mode.value(d) == current.value(d)
                        for d in sp.values if d != dim):
                    best_v, best_t = mode.value(dim), t
            if best_v is not None:
                current = current.replace(**{dim: best_v})

        best = None
        for mode, (t, hbm) in self._obs.items():
            if hbm <= self.hbm_budget and (best is None or t < best.time):
                best = P.Solution(pm=mode, time=t, power=hbm,
                                  throughput=1.0 / t)
        return best

    def _profile(self, mode):
        if mode not in self._obs:
            self.num_profiles += 1
            self._obs[mode] = self.model.time_power(mode)
        return self._obs[mode]

    def _power_budget(self):
        return self.hbm_budget

    def _note_candidate(self, mode, t, p):
        self._obs[mode] = (t, p)


def exhaustive_best(model: RooflineTPUModel,
                    space: Optional[TPUKnobSpace] = None,
                    hbm_budget: float = float(HBM_BYTES)):
    """Oracle over the (small) TPU knob grid, for evaluating GMDForTPU."""
    space = space or TPUKnobSpace(model.chips)
    best = None
    for mode in space.all_modes():
        t, hbm = model.time_power(mode)
        if hbm <= hbm_budget and (best is None or t < best[1]):
            best = (mode, t, hbm)
    return best
