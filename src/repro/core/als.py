"""ALS: Active Learning-based Sampling (paper §5.3, Alg. 2).

Greedy Sampling on the output (GSy): an NN predictor, trained on profiled
modes, guides *which* modes to profile next — those on the predicted Pareto
whose predicted power is farthest from already-profiled powers (max power
diversity). Crucially the NN never answers the optimization query: only the
**observed** partial Pareto does, so ALS cannot violate budgets through
prediction error (§5.3.1).

 * training:   10 random init + 8 rounds x 5 greedy samples  (<= 50 modes)
 * inference:  25 init (5 per bs) + 6 rounds x 4 quadrants x 5 (<= 145)
 * concurrent: 25 init + 3 rounds x 4 quadrants x 10           (<= 145)
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

import numpy as np

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core.device_model import Profiler
from repro.core.gmd import ConcurrentProfiler
from repro.core.nn_model import NNPredictor, mode_features
from repro.core.pareto import pareto_front
from repro.core.powermode import PowerMode, PowerModeSpace


def _greedy_power_diverse(cand_powers: dict, seen_powers: list[float], k: int) -> list:
    """Pick k candidates maximizing min |predicted power - profiled powers|."""
    seen = list(seen_powers)
    picked = []
    cands = dict(cand_powers)
    for _ in range(min(k, len(cands))):
        key = max(cands, key=lambda c: min((abs(cands[c] - s) for s in seen),
                                           default=float("inf")))
        picked.append(key)
        seen.append(cands[key])
        del cands[key]
    return picked


class ALSTrain:
    def __init__(self, profiler: Profiler, space: Optional[PowerModeSpace] = None,
                 rounds: int = 8, init_samples: int = 10, per_round: int = 5,
                 nn_epochs: int = 400, seed: int = 0):
        self.profiler = profiler
        self.space = space or PowerModeSpace()
        self.rounds, self.init_samples, self.per_round = rounds, init_samples, per_round
        self.nn_epochs = nn_epochs
        self.seed = seed
        self._fitted = False

    def fit(self) -> None:
        """Sample + profile; reusable for any problem config of this workload."""
        rng = random.Random(self.seed)
        modes = self.space.all_modes()
        train_set = rng.sample(modes, self.init_samples)
        for pm in train_set:
            self.profiler.profile(pm)

        for rnd in range(self.rounds):
            obs = self.profiler.observed()
            feats = np.array([mode_features(pm) for (pm, _) in obs])
            times = np.array([t for (t, _) in obs.values()])
            pows = np.array([p for (_, p) in obs.values()])
            nn_t = NNPredictor.fit(feats, times, epochs=self.nn_epochs, seed=rnd)
            nn_p = NNPredictor.fit(feats, pows, epochs=self.nn_epochs, seed=rnd + 100)

            test = [pm for pm in modes if (pm, None) not in obs]
            if not test:
                break
            tf = np.array([mode_features(pm) for pm in test])
            pred_t = nn_t.predict(tf)
            pred_p = nn_p.predict(tf)
            points = {pm: (float(pp), float(tt))
                      for pm, pp, tt in zip(test, pred_p, pred_t)}
            front = pareto_front(points)               # predicted Pareto
            cand_powers = {pm: pw for pm, (pw, _) in front.items()}
            seen_powers = [p for (_, p) in obs.values()]
            for pm in _greedy_power_diverse(cand_powers, seen_powers, self.per_round):
                self.profiler.profile(pm)
        self._fitted = True

    def solve(self, prob: P.TrainProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs, backend: str = "numpy"):
        """Answer a batch of problems from the observed profiles in one
        masked reduction (profiling stays point-by-point via the Profiler)."""
        if not self._fitted:
            self.fit()
        grid = G.cached_grid(self, "_grid", self.profiler.observed_modes(),
                             "train")
        return G.solve_train_batch(probs, grid, backend)


# ---------------------------------------------------------------------------
# inference: 4-quadrant sampling over (latency budget, arrival rate)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuadrantRanges:
    latency: tuple[float, float]        # full (lo, hi) range of budgets
    arrival: tuple[float, float]

    def quadrants(self):
        lmid = 0.5 * (self.latency[0] + self.latency[1])
        amid = 0.5 * (self.arrival[0] + self.arrival[1])
        for lat in ((self.latency[0], lmid), (lmid, self.latency[1])):
            for arr in ((self.arrival[0], amid), (amid, self.arrival[1])):
                yield lat, arr


class ALSInfer:
    def __init__(self, profiler: Profiler, ranges: QuadrantRanges,
                 space: Optional[PowerModeSpace] = None,
                 rounds: int = 6, init_per_bs: int = 5, per_quadrant: int = 5,
                 nn_epochs: int = 400, seed: int = 0,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.profiler = profiler
        self.ranges = ranges
        self.space = space or PowerModeSpace()
        self.rounds, self.init_per_bs, self.per_quadrant = rounds, init_per_bs, per_quadrant
        self.nn_epochs = nn_epochs
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def _predictors(self):
        obs = self.profiler.observed()
        feats = np.array([mode_features(pm, bs) for (pm, bs) in obs])
        times = np.array([t for (t, _) in obs.values()])
        pows = np.array([p for (_, p) in obs.values()])
        nn_t = NNPredictor.fit(feats, times, epochs=self.nn_epochs)
        nn_p = NNPredictor.fit(feats, pows, epochs=self.nn_epochs, seed=1)
        return nn_t, nn_p

    def fit(self) -> None:
        rng = random.Random(self.seed)
        modes = self.space.all_modes()
        for bs in self.batch_sizes:
            for pm in rng.sample(modes, self.init_per_bs):
                self.profiler.profile(pm, bs)

        for rnd in range(self.rounds):
            nn_t, nn_p = self._predictors()
            obs = self.profiler.observed()
            test = [(pm, bs) for pm in modes for bs in self.batch_sizes
                    if (pm, bs) not in obs]
            if not test:
                break
            tf = np.array([mode_features(pm, bs) for pm, bs in test])
            pred_t, pred_p = nn_t.predict(tf), nn_p.predict(tf)
            seen_powers = [p for (_, p) in obs.values()]

            for lat_rng, arr_rng in self.ranges.quadrants():
                # conservative pruning: keep candidates meeting the quadrant's
                # peak latency and its lowest arrival rate (§5.3.3)
                keep = {}
                for (pm, bs), tt, pp in zip(test, pred_t, pred_p):
                    lam = P.peak_latency(bs, arr_rng[0], float(tt))
                    if lam <= lat_rng[1] and P.sustainable(bs, arr_rng[0], float(tt)):
                        keep[(pm, bs)] = (float(pp), lam)
                if not keep:
                    continue
                front = pareto_front(keep)
                cand_powers = {k: pw for k, (pw, _) in front.items()}
                for pm, bs in _greedy_power_diverse(cand_powers, seen_powers,
                                                    self.per_quadrant):
                    self.profiler.profile(pm, bs)
                    seen_powers.append(self.profiler.observed()[(pm, bs)][1])
        self._fitted = True

    def solve(self, prob: P.InferProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs, backend: str = "numpy"):
        if not self._fitted:
            self.fit()
        grid = G.cached_grid(self, "_grid", self.profiler.observed(), "infer")
        return G.solve_infer_batch(probs, grid, backend)


# ---------------------------------------------------------------------------
# concurrent training + inference
# ---------------------------------------------------------------------------

class ALSConcurrent:
    def __init__(self, cprofiler: ConcurrentProfiler, ranges: QuadrantRanges,
                 space: Optional[PowerModeSpace] = None,
                 rounds: int = 3, init_modes: int = 25, per_quadrant: int = 10,
                 nn_epochs: int = 400, seed: int = 0,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.cp = cprofiler
        self.ranges = ranges
        self.space = space or PowerModeSpace()
        self.rounds, self.init_modes, self.per_quadrant = rounds, init_modes, per_quadrant
        self.nn_epochs = nn_epochs
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def fit(self) -> None:
        rng = random.Random(self.seed)
        modes = self.space.all_modes()
        for pm in rng.sample(modes, self.init_modes):
            self.cp.profile(pm, rng.choice(self.batch_sizes))

        for rnd in range(self.rounds):
            iobs = self.cp.infer.observed()
            tobs = self.cp.train.observed()
            ifeats = np.array([mode_features(pm, bs) for (pm, bs) in iobs])
            nn_ti = NNPredictor.fit(ifeats, np.array([t for t, _ in iobs.values()]),
                                    epochs=self.nn_epochs)
            nn_pi = NNPredictor.fit(ifeats, np.array([p for _, p in iobs.values()]),
                                    epochs=self.nn_epochs, seed=1)
            tfeats = np.array([mode_features(pm) for (pm, _) in tobs])
            nn_tt = NNPredictor.fit(tfeats, np.array([t for t, _ in tobs.values()]),
                                    epochs=self.nn_epochs, seed=2)
            nn_pt = NNPredictor.fit(tfeats, np.array([p for _, p in tobs.values()]),
                                    epochs=self.nn_epochs, seed=3)

            test = [(pm, bs) for pm in modes for bs in self.batch_sizes
                    if (pm, bs) not in iobs]
            if not test:
                break
            itf = np.array([mode_features(pm, bs) for pm, bs in test])
            ttf = np.array([mode_features(pm) for pm, _ in test])
            p_ti, p_pi = nn_ti.predict(itf), nn_pi.predict(itf)
            p_tt, p_pt = nn_tt.predict(ttf), nn_pt.predict(ttf)
            seen_powers = [p for (_, p) in iobs.values()] + \
                          [p for (_, p) in tobs.values()]

            for lat_rng, arr_rng in self.ranges.quadrants():
                keep = {}
                for (pmbs, tti, ppi, ttt, ppt) in zip(test, p_ti, p_pi, p_tt, p_pt):
                    pm, bs = pmbs
                    lam = P.peak_latency(bs, arr_rng[0], float(tti))
                    if lam > lat_rng[1] or not P.sustainable(bs, arr_rng[0], float(tti)):
                        continue
                    theta = P.train_throughput(bs, arr_rng[0], float(tti), max(float(ttt), 1e-6))
                    dom_p = max(float(ppi), float(ppt))   # dominant power
                    keep[(pm, bs)] = (dom_p, theta)
                if not keep:
                    continue
                # Pareto of predicted throughput (higher better) vs power
                front = pareto_front(keep, lower_is_better=False)
                cand_powers = {k: pw for k, (pw, _) in front.items()}
                for pm, bs in _greedy_power_diverse(cand_powers, seen_powers,
                                                    self.per_quadrant):
                    self.cp.profile(pm, bs)
                    seen_powers.append(self.cp.infer.observed()[(pm, bs)][1])
        self._fitted = True

    def solve(self, prob: P.ConcurrentProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs, backend: str = "numpy"):
        if not self._fitted:
            self.fit()
        return G.solve_concurrent_batch(
            probs,
            G.cached_grid(self, "_tgrid", self.cp.train.observed_modes(), "train"),
            G.cached_grid(self, "_igrid", self.cp.infer.observed(), "infer"),
            backend)


# ---------------------------------------------------------------------------
# multi-tenant: N streams, GSy sampling with per-stream predictors
# ---------------------------------------------------------------------------

class ALSMultiTenant:
    """ALS over the N-stream problem: one mode visit profiles every stream
    (and the train workload), per-stream NNs predict (time, power), and the
    per-quadrant predicted Pareto of (dominant power, predicted training
    throughput) guides sampling. Candidates use one shared bs per visit — a
    sampling heuristic only; the solve scans the full per-stream cross
    product of observations."""

    def __init__(self, mtprofiler, ranges: QuadrantRanges,
                 space: Optional[PowerModeSpace] = None,
                 rounds: int = 3, init_modes: int = 25, per_quadrant: int = 10,
                 nn_epochs: int = 400, seed: int = 0,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.mp = mtprofiler
        self.ranges = ranges
        self.space = space or PowerModeSpace()
        self.rounds, self.init_modes, self.per_quadrant = rounds, init_modes, per_quadrant
        self.nn_epochs = nn_epochs
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def fit(self) -> None:
        rng = random.Random(self.seed)
        modes = self.space.all_modes()
        n = self.mp.n_streams
        for pm in rng.sample(modes, self.init_modes):
            bs = rng.choice(self.batch_sizes)
            self.mp.profile(pm, [bs] * n)

        for rnd in range(self.rounds):
            stream_nns = []
            for j, prof in enumerate(self.mp.streams):
                obs = prof.observed()
                feats = np.array([mode_features(pm, bs) for (pm, bs) in obs])
                nn_t = NNPredictor.fit(
                    feats, np.array([t for t, _ in obs.values()]),
                    epochs=self.nn_epochs, seed=2 * j + rnd)
                nn_p = NNPredictor.fit(
                    feats, np.array([p for _, p in obs.values()]),
                    epochs=self.nn_epochs, seed=2 * j + rnd + 50)
                stream_nns.append((nn_t, nn_p))
            nn_tt = nn_pt = None
            if self.mp.train:
                tobs = self.mp.train.observed()
                tfeats = np.array([mode_features(pm) for (pm, _) in tobs])
                nn_tt = NNPredictor.fit(
                    tfeats, np.array([t for t, _ in tobs.values()]),
                    epochs=self.nn_epochs, seed=rnd + 100)
                nn_pt = NNPredictor.fit(
                    tfeats, np.array([p for _, p in tobs.values()]),
                    epochs=self.nn_epochs, seed=rnd + 150)

            visited = {(pm, bss[0]) for (pm, bss) in self.mp.visited}
            test = [(pm, bs) for pm in modes for bs in self.batch_sizes
                    if (pm, bs) not in visited]
            if not test:
                break
            itf = np.array([mode_features(pm, bs) for pm, bs in test])
            preds = [(nn_t.predict(itf), nn_p.predict(itf))
                     for nn_t, nn_p in stream_nns]
            if nn_tt is not None:
                ttf = np.array([mode_features(pm) for pm, _ in test])
                p_tt, p_pt = nn_tt.predict(ttf), nn_pt.predict(ttf)
            seen_powers = [p for prof in self.mp.streams
                           for (_, p) in prof.observed().values()]

            for lat_rng, arr_rng in self.ranges.quadrants():
                keep = {}
                for i, (pm, bs) in enumerate(test):
                    t_ins = [float(pt[i]) for pt, _ in preds]
                    bss = [bs] * n
                    rates = [arr_rng[0]] * n
                    if not P.multi_sustainable(bss, rates, t_ins):
                        continue
                    if any(P.multi_peak_latency(bss, rates, t_ins, j)
                           > lat_rng[1] for j in range(n)):
                        continue
                    dom_p = max(float(pp[i]) for _, pp in preds)
                    if nn_tt is not None:
                        t_tr = max(float(p_tt[i]), 1e-6)
                        tau = P.multi_interleave_tau(bss, rates, t_ins, t_tr)
                        obj = tau / P.multi_cycle(bss, rates)
                        dom_p = max(dom_p, float(p_pt[i]))
                    else:
                        obj = -max(P.multi_peak_latency(bss, rates, t_ins, j)
                                   for j in range(n))
                    keep[(pm, bs)] = (dom_p, obj)
                if not keep:
                    continue
                front = pareto_front(keep, lower_is_better=False)
                cand_powers = {k: pw for k, (pw, _) in front.items()}
                for pm, bs in _greedy_power_diverse(cand_powers, seen_powers,
                                                    self.per_quadrant):
                    self.mp.profile(pm, [bs] * n)
                    seen_powers.append(
                        self.mp.streams[0].observed()[(pm, bs)][1])
        self._fitted = True

    def solve(self, prob: P.MultiTenantProblem) -> Optional[P.MultiTenantSolution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs, backend: str = "numpy"):
        if not self._fitted:
            self.fit()
        tgrid = G.cached_grid(self, "_tgrid", self.mp.train.observed_modes(),
                              "train") if self.mp.train else None
        igrids = [G.cached_grid(self, f"_igrid{j}", prof.observed(), "infer")
                  for j, prof in enumerate(self.mp.streams)]
        return G.solve_multi_tenant_batch(probs, tgrid, igrids, backend)
