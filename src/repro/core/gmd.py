"""GMD: Gradient-descent based Multi-Dimensional search (paper §5.1, Alg. 1).

Profiles a midpoint mode plus one probe per dimension, fits time/power slopes,
and repeatedly bisects along the dimension with the highest slope ratio
rho = m_time / m_pow, pruning half-lines via power monotonicity. Variants:

 * training   — power is the only constraint; ~10 profiles (§5.1.2)
 * inference  — bs is a special dimension: search at bs=1 first, then
   backtrack to larger bs for modes that satisfy power but cannot keep up
   with the arrival rate; 11 profiles (§5.1.3)
 * concurrent — branch-and-bound the largest feasible bs at MAXN (from 64
   down), search with the *dominant* workload's slopes, backtrack to smaller
   bs; 15 profiles (§5.1.4)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import problem as P
from repro.core.device_model import Profiler
from repro.core.powermode import DIMS, PowerMode, PowerModeSpace

POWER_SLOPE_EPS = 0.25   # W; below this a power delta is noise (thresholding
                         # logic of §5.1.2 - avoids artificially inflated rho)


@dataclasses.dataclass
class _DimState:
    lo: int                  # inclusive candidate index range
    hi: int
    rho: float = 0.0
    last: Optional[tuple[int, float, float]] = None   # (idx, t, p) for slope updates

    @property
    def empty(self) -> bool:
        return self.lo > self.hi


class _GMDBase:
    """Shared bisection machinery; subclasses define feasibility/objective."""

    def __init__(self, profiler: Profiler, space: Optional[PowerModeSpace] = None,
                 max_tries: int = 10):
        self.profiler = profiler
        self.space = space or PowerModeSpace()
        self.max_tries = max_tries

    # -- hooks -------------------------------------------------------------
    def _profile(self, pm: PowerMode) -> tuple[float, float]:
        raise NotImplementedError

    def _runs_used(self) -> Optional[int]:
        """Fresh profiling runs consumed so far (None -> count probes)."""
        return None

    def _power_budget(self) -> float:
        raise NotImplementedError

    def _note_candidate(self, pm: PowerMode, t: float, p: float) -> None:
        pass

    # -- slope bookkeeping ---------------------------------------------------
    def _slope(self, v1, t1, p1, v2, t2, p2) -> float:
        if v1 == v2:
            return 0.0
        m_time = (t2 - t1) / (v2 - v1)
        m_pow = (p2 - p1) / (v2 - v1)
        if abs(p2 - p1) < POWER_SLOPE_EPS:   # negligible power change
            return 0.0
        return abs(m_time / m_pow)

    def _need_reserve(self) -> bool:
        """True if the search should stop early to save profiles for
        backtracking (no solution exists yet among observations)."""
        return False

    RESERVE = 0

    # -- main search ---------------------------------------------------------
    def search(self) -> None:
        """Run the multi-dimensional bisection; candidates are reported via
        _note_candidate. Total profile budget = max_tries (probes included)."""
        sp = self.space
        budget = self._power_budget()
        runs0 = self._runs_used()

        def spent(fallback: int) -> int:
            used = self._runs_used()
            return fallback if used is None else used - runs0

        mid = sp.midpoint()
        t_mid, p_mid = self._profile(mid)
        self._note_candidate(mid, t_mid, p_mid)
        over = p_mid > budget
        tries = 1

        # 4 probes: one per dimension, lowest value if over budget else
        # highest (step (2) of §5.1.2). They fit the initial slopes and count
        # against the profiling budget; pruning uses only the midpoint.
        dims: dict[str, _DimState] = {}
        current = mid
        for dim in self.space.values:
            vals = sp.values[dim]
            mi = sp.index(dim, mid.value(dim))
            st = _DimState(lo=0, hi=mi - 1) if over else \
                _DimState(lo=mi + 1, hi=len(vals) - 1)
            probe_idx = 0 if over else len(vals) - 1
            if probe_idx != mi and spent(tries) < self.max_tries:
                pm = mid.replace(**{dim: vals[probe_idx]})
                t, p = self._profile(pm)
                tries += 1
                self._note_candidate(pm, t, p)
                st.rho = self._slope(vals[probe_idx], t, p, vals[mi], t_mid, p_mid)
                st.last = (probe_idx, t, p)
            dims[dim] = st

        # bisect along the highest-slope-ratio dimension, anchored at
        # `current`; feasible profiles raise the anchor (joint exploration),
        # infeasible ones in the over-budget regime lower it.
        while spent(tries) < self.max_tries:
            if self.RESERVE and spent(tries) >= self.max_tries - self.RESERVE \
                    and self._need_reserve():
                break               # keep budget for bs backtracking
            live = {d: s for d, s in dims.items() if not s.empty}
            if not live:
                break
            dim = max(live, key=lambda d: live[d].rho)
            st = live[dim]
            vals = sp.values[dim]
            idx = (st.lo + st.hi) // 2
            pm = current.replace(**{dim: vals[idx]})
            if pm.value(dim) == current.value(dim) and st.lo == st.hi:
                st.lo = st.hi + 1      # nothing new on this line
                continue
            t, p = self._profile(pm)
            tries += 1
            self._note_candidate(pm, t, p)
            if p > budget:
                st.hi = idx - 1
                if over:
                    # anchor down so the other dims search a feasible region
                    down = vals[st.lo] if not st.empty else vals[0]
                    current = current.replace(**{dim: down})
            else:
                st.lo = idx + 1
                current = pm           # anchor later lines at feasible value
            if st.last is not None:
                st.rho = self._slope(vals[st.last[0]], st.last[1], st.last[2],
                                     vals[idx], t, p)
            st.last = (idx, t, p)


# ---------------------------------------------------------------------------
# standalone training
# ---------------------------------------------------------------------------

class GMDTrain(_GMDBase):
    def __init__(self, profiler: Profiler, space=None, max_tries: int = 10):
        super().__init__(profiler, space, max_tries)

    def solve(self, prob: P.TrainProblem) -> Optional[P.Solution]:
        self._prob = prob
        self._obs: dict[PowerMode, tuple[float, float]] = {}
        self.search()
        return P.solve_train(prob, self._obs)

    def _profile(self, pm):
        return self.profiler.profile(pm)

    def _runs_used(self):
        return self.profiler.num_runs

    def _power_budget(self):
        return self._prob.power_budget

    def _note_candidate(self, pm, t, p):
        self._obs[pm] = (t, p)


# ---------------------------------------------------------------------------
# standalone inference
# ---------------------------------------------------------------------------

class GMDInfer(_GMDBase):
    def __init__(self, profiler: Profiler, space=None, max_tries: int = 11,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        super().__init__(profiler, space, max_tries)
        self.batch_sizes = list(batch_sizes)

    RESERVE = 3

    def _need_reserve(self) -> bool:
        return P.solve_infer(self._prob, self._obs) is None

    def solve(self, prob: P.InferProblem) -> Optional[P.Solution]:
        self._prob = prob
        self._bs = self.batch_sizes[0]          # start at bs=1 (min latency)
        self._obs: dict[tuple[PowerMode, int], tuple[float, float]] = {}
        self._solve_runs0 = self.profiler.num_runs
        # probe MAXN first (cf. the concurrent variant's branch-and-bound):
        # it bounds the achievable latency — if MAXN cannot sustain the rate
        # at this bs, no slower mode can, and backtracking skips the bs.
        maxn = self.space.maxn()
        t, p = self.profiler.profile(maxn, self._bs)
        self._obs[(maxn, self._bs)] = (t, p)
        self.search()
        sol = P.solve_infer(prob, self._obs)
        if sol is not None:
            return sol
        # Backtracking (§5.1.3): modes under the power budget whose inference
        # rate cannot keep up at bs=1 -> sublinear time growth means a larger
        # bs can satisfy the arrival rate. Fastest feasible-power modes first.
        feas = [(pm, t, p) for (pm, b), (t, p) in self._obs.items()
                if b == self._bs and p <= prob.power_budget]
        feas.sort(key=lambda x: x[1])
        cands = feas[:1]
        # second candidate with power headroom (power grows with bs)
        headroom = [c for c in feas[1:] if c[2] <= 0.85 * prob.power_budget]
        cands += headroom[:1] if headroom else feas[1:2]
        # secondary goal is MIN latency: spend the remaining budget even
        # after a first feasible solution appears (smaller bs first).
        for bs in self.batch_sizes[1:]:
            for pm, t1, _ in cands:
                if self.profiler.num_runs - self._solve_runs0 >= self.max_tries:
                    return P.solve_infer(prob, self._obs)
                # skip bs values provably unsustainable even at perfectly
                # sublinear scaling (t(bs) >= t(1) always)
                if t1 > bs / prob.arrival_rate:
                    continue
                t, p = self.profiler.profile(pm, bs)
                self._obs[(pm, bs)] = (t, p)
        return P.solve_infer(prob, self._obs)

    def _profile(self, pm):
        return self.profiler.profile(pm, self._bs)

    def _runs_used(self):
        return self.profiler.num_runs

    def _power_budget(self):
        return self._prob.power_budget

    def _note_candidate(self, pm, t, p):
        self._obs[(pm, self._bs)] = (t, p)


# ---------------------------------------------------------------------------
# concurrent training + inference
# ---------------------------------------------------------------------------

class ConcurrentProfiler:
    """Profiles a (train, infer) pair: one visit to a power mode runs both
    workloads (interleaved), counting a single profiling run."""

    def __init__(self, train_profiler: Profiler, infer_profiler: Profiler):
        self.train = train_profiler
        self.infer = infer_profiler
        self.visited: set = set()

    @property
    def num_runs(self) -> int:
        return len(self.visited)

    @property
    def profile_cost_s(self) -> float:
        return self.train.profile_cost_s + self.infer.profile_cost_s

    def profile(self, pm: PowerMode, bs: int):
        t_tr, p_tr = self.train.profile(pm)
        t_in, p_in = self.infer.profile(pm, bs)
        self.visited.add((pm, bs))
        return (t_tr, p_tr), (t_in, p_in)


class GMDConcurrent(_GMDBase):
    def __init__(self, cprofiler: ConcurrentProfiler, space=None,
                 max_tries: int = 15, batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        super().__init__(cprofiler.infer, space, max_tries)
        self.cp = cprofiler
        self.batch_sizes = list(batch_sizes)

    def solve(self, prob: P.ConcurrentProblem) -> Optional[P.Solution]:
        self._prob = prob
        self._train_obs: dict[PowerMode, tuple[float, float]] = {}
        self._infer_obs: dict[tuple[PowerMode, int], tuple[float, float]] = {}

        # Branch and bound (E): largest bs whose latency MAXN can meet; any
        # slower mode only increases execution time, so bigger bs are dead.
        maxn = self.space.maxn()
        chosen = None
        for bs in sorted(self.batch_sizes, reverse=True):
            t_in, p_in = self.cp.infer.profile(maxn, bs)
            self._infer_obs[(maxn, bs)] = (t_in, p_in)
            lam = P.peak_latency(bs, prob.arrival_rate, t_in)
            if lam <= prob.latency_budget and P.sustainable(bs, prob.arrival_rate, t_in):
                chosen = bs
                break
        if chosen is None:
            return None
        t_tr, p_tr = self.cp.train.profile(maxn)
        self._train_obs[maxn] = (t_tr, p_tr)
        self._bs = chosen

        self.search()
        sol = self._solve_obs()
        if sol is not None:
            return sol

        # Backtracking (F): only modes that keep up with the arrival rate can
        # be rescued by a smaller bs (smaller bs further lowers the rate).
        cands = []
        for (pm, b), (t_in, p_in) in self._infer_obs.items():
            if b != self._bs or pm in (maxn,):
                continue
            if p_in <= prob.power_budget and P.sustainable(b, prob.arrival_rate, t_in):
                cands.append((pm, P.peak_latency(b, prob.arrival_rate, t_in)))
        cands.sort(key=lambda x: x[1])
        lower = [b for b in self.batch_sizes if b < self._bs]
        for bs in sorted(lower, reverse=True):
            for pm, _ in cands:
                if self.cp.num_runs >= self.max_tries:
                    break
                (t_tr, p_tr), (t_in, p_in) = self.cp.profile(pm, bs)
                self._train_obs[pm] = (t_tr, p_tr)
                self._infer_obs[(pm, bs)] = (t_in, p_in)
                sol = self._solve_obs()
                if sol is not None:
                    return sol
        return self._solve_obs()

    def _solve_obs(self):
        return P.solve_concurrent(self._prob, self._train_obs, self._infer_obs)

    # -- hooks: profile both, use the dominant workload's time for slopes ----
    def _profile(self, pm):
        (t_tr, p_tr), (t_in, p_in) = self.cp.profile(pm, self._bs)
        self._train_obs[pm] = (t_tr, p_tr)
        self._infer_obs[(pm, self._bs)] = (t_in, p_in)
        # dominant workload = the one drawing more power (§5.1.4); power is a
        # system-wide constraint set by the max of the two.
        if p_tr >= p_in:
            return t_tr, max(p_tr, p_in)
        return t_in, max(p_tr, p_in)

    def _power_budget(self):
        return self._prob.power_budget

    def _note_candidate(self, pm, t, p):
        pass   # candidates tracked via _train_obs/_infer_obs


# ---------------------------------------------------------------------------
# multi-tenant: train + N inference streams
# ---------------------------------------------------------------------------

class MultiTenantProfiler:
    """Profiles a train workload plus N inference streams: one visit to a
    power mode runs every workload back-to-back (interleaved), counting a
    single profiling run — the N-stream ConcurrentProfiler."""

    def __init__(self, train_profiler: Optional[Profiler],
                 stream_profilers: list):
        self.train = train_profiler
        self.streams = list(stream_profilers)
        self.visited: set = set()

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    @property
    def num_runs(self) -> int:
        return len(self.visited)

    @property
    def profile_cost_s(self) -> float:
        cost = self.train.profile_cost_s if self.train else 0.0
        return cost + sum(p.profile_cost_s for p in self.streams)

    def profile(self, pm: PowerMode, bss):
        train_tp = self.train.profile(pm) if self.train else None
        stream_tps = [p.profile(pm, int(b))
                      for p, b in zip(self.streams, bss)]
        self.visited.add((pm, tuple(int(b) for b in bss)))
        return train_tp, stream_tps

    def infer_observed(self) -> list:
        return [p.observed() for p in self.streams]


class GMDMultiTenant(_GMDBase):
    """GMD over the N-stream problem: branch-and-bound each stream's largest
    feasible minibatch size at MAXN, bisect with the *dominant* workload's
    slopes (one mode visit profiles all streams), then backtrack streams to
    smaller bs. Profiling budget grows with the tenant count."""

    def __init__(self, mtprofiler: MultiTenantProfiler, space=None,
                 max_tries: Optional[int] = None,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        n = mtprofiler.n_streams
        if max_tries is None:
            max_tries = 15 + 4 * (n - 1)       # 15 at N=1 (§5.1.4)
        super().__init__(mtprofiler.streams[0], space, max_tries)
        self.mp = mtprofiler
        self.batch_sizes = list(batch_sizes)

    def solve(self, prob: P.MultiTenantProblem) -> Optional[P.MultiTenantSolution]:
        self._prob = prob
        maxn = self.space.maxn()
        rates = [s.arrival_rate for s in prob.streams]

        # Branch and bound per stream: largest bs whose solo latency MAXN
        # can meet — any slower mode only increases execution time, so
        # bigger bs are dead (cf. GMDConcurrent step E).
        allowed = []
        chosen = []
        for j, spec in enumerate(prob.streams):
            allowed.append([b for b in self.batch_sizes
                            if spec.batch_sizes is None
                            or b in spec.batch_sizes])
            pick = None
            for bs in sorted(allowed[j], reverse=True):
                t_in, _ = self.mp.streams[j].profile(maxn, bs)
                lam = P.peak_latency(bs, spec.arrival_rate, t_in)
                if lam <= spec.latency_budget and \
                        P.sustainable(bs, spec.arrival_rate, t_in):
                    pick = bs
                    break
            if pick is None:
                return None
            chosen.append(pick)
        # Blocking-aware shrink to fixpoint (the N>1 coupling the pair B&B
        # has no analogue for): with every tenant at its solo pick, a
        # stream's peak latency also carries the other tenants' service
        # times — shrink violating streams one step until all budgets fit
        # at MAXN (profiles are cached, so re-evaluation is free).
        while len(chosen) > 1:
            t_ins = [self.mp.streams[j].profile(maxn, b)[0]
                     for j, b in enumerate(chosen)]

            def shrink(k) -> bool:
                lower = [b for b in allowed[k] if b < chosen[k]]
                if lower:
                    chosen[k] = max(lower)
                return bool(lower)

            viol = [j for j, spec in enumerate(prob.streams)
                    if P.multi_peak_latency(chosen, rates, t_ins, j)
                    > spec.latency_budget]
            if not viol:
                break
            moved = False
            for j in viol:
                # own queueing + service overruns -> only stream j can help
                if P.peak_latency(chosen[j], rates[j], t_ins[j]) \
                        > prob.streams[j].latency_budget:
                    moved |= shrink(j)
            if not moved:
                # blocking-bound: the largest service time is the blocker
                for k in sorted(range(len(chosen)),
                                key=lambda k: -t_ins[k]):
                    if shrink(k):
                        moved = True
                        break
            if not moved:
                break
        if self.mp.train:
            self.mp.train.profile(maxn)
        self.mp.visited.add((maxn, tuple(chosen)))
        self._bss = chosen

        self.search()
        sol = self._solve_obs()
        if sol is not None:
            return sol

        # Backtracking: shrink one stream at a time (largest-contribution
        # first) on modes that keep up with every arrival rate.
        cands = []
        obs = self.mp.infer_observed()
        for pm in {pm for (pm, _) in self.mp.visited}:
            if pm == maxn:
                continue
            try:
                t_ins = [obs[j][(pm, b)][0] for j, b in enumerate(self._bss)]
            except KeyError:
                continue
            if all(P.sustainable(b, r, t)
                   for b, r, t in zip(self._bss, rates, t_ins)):
                cands.append((pm, max(t_ins)))
        cands.sort(key=lambda x: x[1])
        for j in range(self.mp.n_streams):
            lower = [b for b in self.batch_sizes if b < self._bss[j]]
            for bs in sorted(lower, reverse=True):
                for pm, _ in cands:
                    if self.mp.num_runs >= self.max_tries:
                        return self._solve_obs()
                    bss = list(self._bss)
                    bss[j] = bs
                    self.mp.profile(pm, bss)
                    sol = self._solve_obs()
                    if sol is not None:
                        return sol
        return self._solve_obs()

    def _solve_obs(self):
        train_obs = self.mp.train.observed_modes() if self.mp.train else None
        return P.solve_multi_tenant(self._prob, train_obs,
                                    self.mp.infer_observed())

    # -- hooks: profile everything, dominant workload drives the slopes -----
    def _profile(self, pm):
        train_tp, stream_tps = self.mp.profile(pm, self._bss)
        cands = list(stream_tps) + ([train_tp] if train_tp else [])
        t_dom, p_dom = max(cands, key=lambda tp: tp[1])   # dominant = max power
        p_sys = max(p for _, p in cands)
        return t_dom, p_sys

    def _runs_used(self):
        return self.mp.num_runs

    def _power_budget(self):
        return self._prob.power_budget

    def _need_reserve(self) -> bool:
        return self._solve_obs() is None

    RESERVE = 3

    def _note_candidate(self, pm, t, p):
        pass   # candidates tracked via the profilers' caches
