"""Execution approaches for concurrent training + inference (paper §3, Fig 2).

Event-driven simulators over the device model:
 * managed   — Fulcrum's approach: explicit alternation at minibatch
   granularity; a training minibatch is launched only if it finishes before
   the next inference batch is ready, so inference never queues behind
   training (tight latency distribution).
 * native    — GPU time-slicing at kernel granularity: when both run, each
   progresses at ~half speed with heavy jitter (variable latency, Q3
   violations as in Fig 2).
 * streams   — space-sharing via priority streams: better throughput, but
   non-deterministic resource blocking still inflates tail latency.

All three obey the measured interleaving laws: t_interleaved = sum(t_i) and
p = max(p_i). Randomness is deterministic per seed.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Optional

from repro.core.device_model import DeviceModel, Profiler, WorkloadProfile
from repro.core.powermode import PowerMode


@dataclasses.dataclass
class ExecutionReport:
    approach: str
    latencies: list[float]            # per-request latency (s), queue + exec
    train_minibatches: int
    duration: float
    power: float

    @property
    def train_throughput(self) -> float:
        return self.train_minibatches / self.duration

    def latency_quantile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        i = min(len(xs) - 1, int(q * len(xs)))
        return xs[i]

    def violation_rate(self, latency_budget: float) -> float:
        if not self.latencies:
            return 0.0
        return sum(1 for x in self.latencies if x > latency_budget) / len(self.latencies)


def _arrivals(arrival_rate: float, duration: float) -> list[float]:
    n = int(arrival_rate * duration)
    return [i / arrival_rate for i in range(n)]


def simulate_managed(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                     w_in: WorkloadProfile, pm: PowerMode, bs: int,
                     arrival_rate: float, duration: float = 120.0) -> ExecutionReport:
    """Fulcrum managed interleaving: one DNN at a time, switched at minibatch
    boundaries; training fills slack conservatively."""
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm) if w_tr else (float("inf"), 0.0)
    arrivals = _arrivals(arrival_rate, duration)
    latencies: list[float] = []
    now = 0.0
    trained = 0
    i = 0
    while i + bs <= len(arrivals):
        batch_ready = arrivals[i + bs - 1]       # bs-th request queued
        # fill slack with integral training minibatches that finish in time
        while w_tr and now + t_tr <= batch_ready:
            now += t_tr
            trained += 1
        now = max(now, batch_ready)
        now += t_in                              # run the inference minibatch
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    power = max(p_in, p_tr if trained else 0.0)
    return ExecutionReport("managed", latencies, trained, duration, power)


def simulate_native(device: DeviceModel, w_tr: WorkloadProfile,
                    w_in: WorkloadProfile, pm: PowerMode, bs: int,
                    arrival_rate: float, duration: float = 120.0,
                    seed: int = 0) -> ExecutionReport:
    """Native kernel-level time-sharing: both processes always runnable;
    inference kernels contend with training kernels (~2x slowdown +- jitter)."""
    rng = random.Random(seed)
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm)
    arrivals = _arrivals(arrival_rate, duration)
    latencies: list[float] = []
    now = 0.0
    i = 0
    infer_busy = 0.0
    while i + bs <= len(arrivals):
        batch_ready = arrivals[i + bs - 1]
        now = max(now, batch_ready)
        slowdown = 1.0 + rng.uniform(0.5, 1.6)    # contention w/ training
        exec_t = t_in * slowdown
        now += exec_t
        infer_busy += exec_t
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    # training gets the remaining GPU share, also degraded by switching
    train_share = max(0.0, duration - infer_busy) * rng.uniform(0.85, 0.95)
    trained = int(train_share / t_tr)
    return ExecutionReport("native", latencies, trained, duration, max(p_in, p_tr))


def simulate_streams(device: DeviceModel, w_tr: WorkloadProfile,
                     w_in: WorkloadProfile, pm: PowerMode, bs: int,
                     arrival_rate: float, duration: float = 120.0,
                     seed: int = 0) -> ExecutionReport:
    """CUDA-streams space sharing, inference on the high-priority stream:
    throughput-friendly, but block-level resource blocking adds tail jitter."""
    rng = random.Random(seed)
    t_in, p_in = device.time_power(w_in, pm, bs)
    t_tr, p_tr = device.time_power(w_tr, pm)
    arrivals = _arrivals(arrival_rate, duration)
    latencies: list[float] = []
    now = 0.0
    i = 0
    while i + bs <= len(arrivals):
        batch_ready = arrivals[i + bs - 1]
        now = max(now, batch_ready)
        slowdown = 1.0 + rng.uniform(0.05, 0.45)
        if rng.random() < 0.18:                   # non-deterministic blocking
            slowdown += rng.uniform(0.5, 2.0) * t_tr / max(t_in, 1e-6)
        now += t_in * slowdown
        latencies.extend(now - arrivals[j] for j in range(i, i + bs))
        i += bs
    # training stream runs concurrently at reduced efficiency
    trained = int(duration * rng.uniform(0.75, 0.9) / t_tr)
    return ExecutionReport("streams", latencies, trained, duration,
                           max(p_in, p_tr) * 1.03)
