"""Execution approaches for concurrent training + inference (paper §3, Fig 2).

Thin wrappers over the vectorized trace-driven engine in ``core.simulate``:
 * managed   — Fulcrum's approach: explicit alternation at minibatch
   granularity; a training minibatch is launched only if it finishes before
   the next inference batch is ready, so inference never queues behind
   training (tight latency distribution).
 * native    — GPU time-slicing at kernel granularity: when both run, each
   progresses at ~half speed with heavy jitter (variable latency, Q3
   violations as in Fig 2).
 * streams   — space-sharing via priority streams: better throughput, but
   non-deterministic resource blocking still inflates tail latency.

All three obey the measured interleaving laws: t_interleaved = sum(t_i) and
p = max(p_i). Randomness is deterministic per seed. These wrappers keep the
seed's fixed-rate signature; call ``core.simulate.simulate`` directly to
execute over Poisson or piecewise-rate ``ArrivalTrace``s.
"""
from __future__ import annotations

from typing import Optional

from repro.core.device_model import DeviceModel, WorkloadProfile
from repro.core.powermode import PowerMode
from repro.core.simulate import (ArrivalTrace, ExecutionReport,  # noqa: F401
                                 simulate)


def simulate_managed(device: DeviceModel, w_tr: Optional[WorkloadProfile],
                     w_in: WorkloadProfile, pm: PowerMode, bs: int,
                     arrival_rate: float, duration: float = 120.0) -> ExecutionReport:
    """Fulcrum managed interleaving: one DNN at a time, switched at minibatch
    boundaries; training fills slack conservatively."""
    return simulate(device, w_tr, w_in, pm, bs,
                    ArrivalTrace.uniform(arrival_rate, duration),
                    approach="managed")


def simulate_native(device: DeviceModel, w_tr: WorkloadProfile,
                    w_in: WorkloadProfile, pm: PowerMode, bs: int,
                    arrival_rate: float, duration: float = 120.0,
                    seed: int = 0) -> ExecutionReport:
    """Native kernel-level time-sharing: both processes always runnable;
    inference kernels contend with training kernels (~2x slowdown +- jitter)."""
    return simulate(device, w_tr, w_in, pm, bs,
                    ArrivalTrace.uniform(arrival_rate, duration),
                    approach="native", seed=seed)


def simulate_streams(device: DeviceModel, w_tr: WorkloadProfile,
                     w_in: WorkloadProfile, pm: PowerMode, bs: int,
                     arrival_rate: float, duration: float = 120.0,
                     seed: int = 0) -> ExecutionReport:
    """CUDA-streams space sharing, inference on the high-priority stream:
    throughput-friendly, but block-level resource blocking adds tail jitter."""
    return simulate(device, w_tr, w_in, pm, bs,
                    ArrivalTrace.uniform(arrival_rate, duration),
                    approach="streams", seed=seed)
