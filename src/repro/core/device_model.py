"""Calibrated analytical performance/power model of a Jetson Orin AGX.

This is the dry-run stand-in for the physical device the paper profiles: GMD,
ALS and all baselines only ever see it through ``Profile(pm, [bs]) ->
(minibatch_time, power)``, exactly as on the real board. The phenomenology
reproduces the paper's measurements:

 * minibatch time is an *additive* GPU/CPU/memory pipeline, so time-vs-GPU-
   frequency drops sharply and then saturates (Fig. 7a) while power rises
   monotonically (Fig. 7b);
 * power grows superlinearly with frequency (~f^1.3, DVFS-less f*V^2 trend)
   and monotonically along every dimension (the property GMD's pruning uses);
 * inference time is sublinear in minibatch size, with a DNN-specific fixed
   overhead (MobileNet 3x from bs 1->32; BERT ~29x: §2);
 * interleaved execution obeys t = sum(t_i), p = max(p_i) (§6 validation);
 * a deterministic per-(workload, dim-value) perturbation (<~2%) keeps the
   Pareto non-trivial without breaking monotonicity (adjacent grid steps
   move power by ~5-15%).

Anchors used for calibration (paper §2): ResNet-18 training 59.5 ms / 51.1 W
at MAXN vs 491 ms / 14.7 W at 4c/422/115/665; MobileNet inference bs=64
102 ms / 39.5 W at MAXN; BERT-L inference bs=1 66 ms / 56 W.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Optional

from repro.core.powermode import MAXN, DIMS, PowerMode

MAX_CPUF, MAX_GPUF, MAX_MEMF, MAX_CORES = 2201.0, 1300.0, 3199.0, 12.0


@dataclasses.dataclass(frozen=True)
class WorkloadProfile:
    """Descriptor of one DNN workload (training or inference).

    Work terms are seconds-at-MAXN per minibatch, split by the resource that
    bounds them. For inference, each term has a fixed part and a per-sample
    part: t(bs) = fixed + per_sample * bs.
    """
    name: str
    kind: str                      # "train" | "infer"
    gpu_fixed: float               # s at max GPU freq
    gpu_per_sample: float
    cpu_fixed: float               # s at max CPU freq / all cores
    cpu_per_sample: float
    mem_fixed: float               # s at max mem freq
    mem_per_sample: float
    cpu_parallelism: float = 6.0   # cores beyond this don't help (dataloader)
    # power model
    p_gpu: float = 28.0            # W at full GPU utilization, max freq
    p_cpu: float = 8.0
    p_mem: float = 6.0
    p_idle: float = 12.0
    util_half_bs: float = 4.0      # bs at which utilization reaches ~2/3
    train_bs: int = 16


def _pert(workload: str, dim: str, value: int, scale: float = 0.05) -> float:
    """Deterministic per-(workload, dimension, value) multiplier in
    [1-scale, 1+scale]; preserves monotonicity because adjacent grid points
    differ in time/power by far more than 2*scale."""
    h = hashlib.md5(f"{workload}|{dim}|{value}".encode()).digest()
    u = int.from_bytes(h[:4], "little") / 2**32
    return 1.0 + scale * (2.0 * u - 1.0)


class DeviceModel:
    """The simulated Orin. ``minibatch_time_power`` is the ground truth that
    profiling observes; strategies never see the internals."""

    def time_power(self, w: WorkloadProfile, pm: PowerMode,
                   bs: Optional[int] = None) -> tuple[float, float]:
        bs_eff = float(bs if bs is not None else w.train_bs)

        gpu_s = (pm.gpuf / MAX_GPUF) * _pert(w.name, "gpuf", pm.gpuf)
        cores_eff = min(pm.cores, w.cpu_parallelism) / w.cpu_parallelism
        cpu_s = ((pm.cpuf / MAX_CPUF) ** 0.9) * (cores_eff ** 0.7) \
            * _pert(w.name, "cpuf", pm.cpuf) * _pert(w.name, "cores", pm.cores)
        mem_s = (pm.memf / MAX_MEMF) * _pert(w.name, "memf", pm.memf)

        t_gpu = (w.gpu_fixed + w.gpu_per_sample * bs_eff) / gpu_s
        t_cpu = (w.cpu_fixed + w.cpu_per_sample * bs_eff) / cpu_s
        t_mem = (w.mem_fixed + w.mem_per_sample * bs_eff) / mem_s
        t = t_gpu + t_cpu + t_mem

        util = bs_eff / (bs_eff + w.util_half_bs)
        # busy fractions: a resource burns dynamic power while it is the
        # active pipeline stage
        f_gpu, f_cpu, f_mem = t_gpu / t, t_cpu / t, t_mem / t
        p = (w.p_idle
             + w.p_gpu * (0.35 + 0.65 * util) * f_gpu_power(pm) * (0.4 + 0.6 * f_gpu)
             + w.p_cpu * f_cpu_power(pm) * (0.5 + 0.5 * f_cpu)
             + w.p_mem * (pm.memf / MAX_MEMF) ** 1.1 * (0.5 + 0.5 * f_mem))
        p *= _pert(w.name, "power", pm.gpuf * 31 + pm.cpuf * 7 + pm.memf, 0.015)
        return t, p

    # -- interleaving laws validated by the paper (§6) ---------------------
    @staticmethod
    def interleaved_time(times: list[float]) -> float:
        return sum(times)

    @staticmethod
    def interleaved_power(powers: list[float]) -> float:
        return max(powers)


def _device_pert(seed: int, index: int, field: str, scale: float) -> float:
    """Deterministic multiplier in [1-scale, 1+scale] for device ``index`` of
    a fleet sampled with ``seed``.

    The hash key is the *delimited string* ``"fleet|{seed}|{index}|{field}"``,
    never an arithmetic combination of the parts: PR 5's ``_poisson_seed``
    collided streams with windows because ``seed + window*k + stream`` maps
    distinct (window, stream) pairs onto the same integer. A delimited string
    key is injective in (seed, index, field) by construction, so no two
    devices of any fleet can share a perturbation draw (regression-tested at
    K=512 in tests/test_fleet.py)."""
    h = hashlib.md5(f"fleet|{seed}|{index}|{field}".encode()).digest()
    u = int.from_bytes(h[:4], "little") / 2**32
    return 1.0 + scale * (2.0 * u - 1.0)


class PerturbedDeviceModel(DeviceModel):
    """One device of a heterogeneous fleet: the base Orin model with scalar
    time/power multipliers. The scaling is applied to the *output* of
    ``time_power`` rather than to the model internals, so a device's
    observation grid is an elementwise rescale of the base model's grid —
    the property the fleet planner exploits to materialize one dense grid
    and scale it per device, bitwise-identical to profiling each device
    point by point (same IEEE multiply either way)."""

    def __init__(self, time_scale: float = 1.0, power_scale: float = 1.0,
                 index: int = 0):
        self.time_scale = float(time_scale)
        self.power_scale = float(power_scale)
        self.index = int(index)

    def time_power(self, w: WorkloadProfile, pm: PowerMode,
                   bs: Optional[int] = None) -> tuple[float, float]:
        t, p = DeviceModel.time_power(self, w, pm, bs)
        return t * self.time_scale, p * self.power_scale


def fleet_device(index: int, seed: int = 0, time_spread: float = 0.10,
                 power_spread: float = 0.05) -> PerturbedDeviceModel:
    """Device ``index`` of the fleet sampled with ``seed``: deterministic
    heterogeneity from collision-free per-(seed, index, field) draws."""
    return PerturbedDeviceModel(
        time_scale=_device_pert(seed, index, "time", time_spread),
        power_scale=_device_pert(seed, index, "power", power_spread),
        index=index)


def f_gpu_power(pm: PowerMode) -> float:
    return (pm.gpuf / MAX_GPUF) ** 1.3


def f_cpu_power(pm: PowerMode) -> float:
    return (pm.cores / MAX_CORES) ** 0.8 * (pm.cpuf / MAX_CPUF) ** 1.3


# ---------------------------------------------------------------------------
# The paper's DNN workloads (Table 4), calibrated to the §2 anchors.
# ---------------------------------------------------------------------------

TRAIN_WORKLOADS = {
    "resnet18": WorkloadProfile(    # 59.5ms/51.1W MAXN; 491ms/14.7W low
        "resnet18-train", "train",
        gpu_fixed=0.004, gpu_per_sample=0.0020,
        cpu_fixed=0.004, cpu_per_sample=0.0004,
        mem_fixed=0.002, mem_per_sample=0.0004,
        p_gpu=40.0, p_cpu=10.0, p_mem=8.0),
    "mobilenet": WorkloadProfile(
        "mobilenet-train", "train",
        gpu_fixed=0.006, gpu_per_sample=0.0011,
        cpu_fixed=0.006, cpu_per_sample=0.0005,
        mem_fixed=0.003, mem_per_sample=0.0003,
        p_gpu=28.0, p_cpu=11.0, p_mem=7.0),
    "yolov8n": WorkloadProfile(
        "yolov8n-train", "train",
        gpu_fixed=0.010, gpu_per_sample=0.0030,
        cpu_fixed=0.012, cpu_per_sample=0.0006,
        mem_fixed=0.004, mem_per_sample=0.0005,
        cpu_parallelism=2.0,        # single dataloader worker (paper fn. 3)
        p_gpu=34.0, p_cpu=12.0, p_mem=7.0),
    "bert": WorkloadProfile(
        "bert-train", "train",
        gpu_fixed=0.015, gpu_per_sample=0.0110,
        cpu_fixed=0.006, cpu_per_sample=0.0006,
        mem_fixed=0.006, mem_per_sample=0.0020,
        p_gpu=48.0, p_cpu=8.0, p_mem=10.0),
    "lstm": WorkloadProfile(
        "lstm-train", "train",
        gpu_fixed=0.008, gpu_per_sample=0.0009,
        cpu_fixed=0.010, cpu_per_sample=0.0007,
        mem_fixed=0.002, mem_per_sample=0.0002,
        p_gpu=20.0, p_cpu=11.0, p_mem=6.0),
}

INFER_WORKLOADS = {
    "mobilenet": WorkloadProfile(   # bs1 18ms/20.9W, bs32 54ms/38.2W, bs64 102ms/39.5W
        "mobilenet-infer", "infer",
        gpu_fixed=0.0080, gpu_per_sample=0.00075,
        cpu_fixed=0.0045, cpu_per_sample=0.00015,
        mem_fixed=0.0030, mem_per_sample=0.00045,
        p_gpu=26.0, p_cpu=8.0, p_mem=7.0, util_half_bs=3.0),
    "resnet50": WorkloadProfile(
        "resnet50-infer", "infer",
        gpu_fixed=0.0090, gpu_per_sample=0.00300,
        cpu_fixed=0.0040, cpu_per_sample=0.00020,
        mem_fixed=0.0030, mem_per_sample=0.00080,
        p_gpu=35.0, p_cpu=7.0, p_mem=8.0, util_half_bs=2.5),
    "yolov8n": WorkloadProfile(
        "yolov8n-infer", "infer",
        gpu_fixed=0.0110, gpu_per_sample=0.00180,
        cpu_fixed=0.0060, cpu_per_sample=0.00030,
        mem_fixed=0.0030, mem_per_sample=0.00050,
        p_gpu=30.0, p_cpu=9.0, p_mem=7.0, util_half_bs=3.0),
    "bert": WorkloadProfile(        # bs1 66ms/56W, bs32 1.94s/61.8W (BERT-Large)
        "bert-infer", "infer",
        gpu_fixed=0.0080, gpu_per_sample=0.05500,
        cpu_fixed=0.0030, cpu_per_sample=0.00030,
        mem_fixed=0.0030, mem_per_sample=0.00500,
        p_gpu=52.0, p_cpu=6.0, p_mem=10.0, util_half_bs=0.4),
    "lstm": WorkloadProfile(
        "lstm-infer", "infer",
        gpu_fixed=0.0060, gpu_per_sample=0.00060,
        cpu_fixed=0.0050, cpu_per_sample=0.00020,
        mem_fixed=0.0015, mem_per_sample=0.00015,
        p_gpu=16.0, p_cpu=9.0, p_mem=6.0, util_half_bs=4.0),
}


def workload_from_model_config(cfg, kind: str, tokens_per_sample: int = 512) -> WorkloadProfile:
    """Map one of the assigned architectures onto a WorkloadProfile so Fulcrum
    can schedule *our* models: GPU work from active-param FLOPs, memory work
    from parameter bytes, CPU work from layer-dispatch overhead."""
    n_active = cfg.active_param_count()
    flops_per_sample = (6.0 if kind == "train" else 2.0) * n_active * tokens_per_sample
    edge_flops = 5e12                  # Orin-class sustained FLOP/s
    edge_bw = 2.04e11                  # LPDDR5 bytes/s
    gpu_s = flops_per_sample / edge_flops
    mem_s = cfg.param_count() * 2 / edge_bw
    cpu_s = cfg.num_layers * 2.5e-4    # kernel-launch / host overhead
    return WorkloadProfile(
        name=f"{cfg.name}-{kind}", kind=kind,
        gpu_fixed=0.3 * gpu_s, gpu_per_sample=0.7 * gpu_s / 16,
        cpu_fixed=0.8 * cpu_s, cpu_per_sample=0.2 * cpu_s / 16,
        mem_fixed=0.7 * mem_s, mem_per_sample=0.3 * mem_s / 16,
        p_gpu=20 + min(18.0, n_active / 5e8),
        p_cpu=8.0, p_mem=6.0)


# ---------------------------------------------------------------------------
# Profiler: the only interface strategies may use.
# ---------------------------------------------------------------------------

PROFILE_MINIBATCHES = 40       # paper: ~40 minibatches per profiling run
PROFILE_OVERHEAD_S = 5.0       # mode switch + power stabilization (2-3 s)


class Profiler:
    """Profiles (power mode [, inference bs]) pairs against the device model,
    accounting simulated profiling cost and caching results for reuse
    (paper: profiled modes are reusable across problem configurations)."""

    def __init__(self, device: DeviceModel, workload: WorkloadProfile):
        self.device = device
        self.workload = workload
        self.cache: dict[tuple[PowerMode, Optional[int]], tuple[float, float]] = {}
        self.profile_cost_s = 0.0
        self.num_runs = 0

    def profile(self, pm: PowerMode, bs: Optional[int] = None) -> tuple[float, float]:
        key = (pm, bs)
        if key not in self.cache:
            t, p = self.device.time_power(self.workload, pm, bs)
            self.cache[key] = (t, p)
            self.profile_cost_s += PROFILE_MINIBATCHES * t + PROFILE_OVERHEAD_S
            self.num_runs += 1
        return self.cache[key]

    def observed(self) -> dict:
        return dict(self.cache)

    def observed_modes(self) -> dict:
        """Training-style view: {pm: (t, p)} (bs-less profiles)."""
        return {pm: tp for (pm, _), tp in self.cache.items()}
