"""Problem formulation (§4): the optimization variants, the managed-
interleaving feasibility math, and the observed-profile solver every strategy
(oracle, RND, ALS, GMD backtracking) shares.

Notation follows Table 2: a solution is (pm [, beta_in [, tau_tr]]).

The paper evaluates a training+inference *pair*; the multi-tenant
generalization (``StreamSpec`` / ``MultiTenantProblem`` /
``solve_multi_tenant``) models N inference streams sharing the accelerator
with an optional training fill workload. ``ConcurrentProblem`` and
``InferProblem`` are the N=1 views of it: ``as_multi_tenant()`` lifts them,
and the N=1 multi-tenant math replays the pair expressions bitwise (the
exactness contract enforced by ``tests/test_multi_tenant.py``).

Contract: this module is the **scalar reference** for the whole solver layer.
Inputs are problem dataclasses plus observation dicts ``{pm: (t, p)}`` /
``{(pm, bs): (t, p)}`` whose iteration order is authoritative (ties resolve
to the first-scanned entry); no randomness, no NumPy — pure-Python float
ops define the IEEE-754 expression trees that ``core.grid_eval`` must replay
bitwise. Invariants: solvers never mutate their inputs; a returned solution
is always feasible under the problem's budgets and the sustainability/
blocking math defined here; infeasible problems return ``None``. See
``docs/architecture.md`` for where this layer sits.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.core.powermode import PowerMode

INFER_BATCH_SIZES = [1, 4, 16, 32, 64]   # paper §6 (BERT capped at 32)


@dataclasses.dataclass(frozen=True)
class TrainProblem:
    power_budget: float                       # p-hat (W)


@dataclasses.dataclass(frozen=True)
class InferProblem:
    power_budget: float
    latency_budget: float                     # lambda-hat (s/request, peak)
    arrival_rate: float                       # alpha (requests/s)

    def as_multi_tenant(self, workload=None,
                        batch_sizes=None) -> "MultiTenantProblem":
        """This problem as a single-stream multi-tenant problem (no train)."""
        return MultiTenantProblem(
            self.power_budget,
            (StreamSpec(self.arrival_rate, self.latency_budget, workload,
                        batch_sizes),),
            train=False)


@dataclasses.dataclass(frozen=True)
class ConcurrentProblem:
    power_budget: float
    latency_budget: float
    arrival_rate: float

    def as_multi_tenant(self, workload=None,
                        batch_sizes=None) -> "MultiTenantProblem":
        """This problem as a train + single-stream multi-tenant problem."""
        return MultiTenantProblem(
            self.power_budget,
            (StreamSpec(self.arrival_rate, self.latency_budget, workload,
                        batch_sizes),),
            train=True)


# ---------------------------------------------------------------------------
# multi-tenant problems: one train workload + N inference streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One inference tenant: its arrival rate, per-request latency budget,
    the workload it runs (a WorkloadProfile; opaque to this layer), and the
    minibatch sizes its plan may choose (None = any observed size)."""
    arrival_rate: float
    latency_budget: float
    workload: Optional[object] = None
    batch_sizes: Optional[tuple] = None

    def with_rate(self, rate: float) -> "StreamSpec":
        return dataclasses.replace(self, arrival_rate=float(rate))


@dataclasses.dataclass(frozen=True)
class MultiTenantProblem:
    """N tenant inference streams sharing one accelerator (and one power
    mode) with — when ``train`` — a training workload filling the slack.
    Primary objective: max training throughput (min worst-tenant latency
    when ``train`` is False); secondary: min worst-tenant latency.

    ``priorities`` (one positive weight per stream, optional) makes the
    latency side of the objective priority-aware: the solver minimizes the
    worst *priority-weighted* latency ``max_j(w_j * lam_j)`` with
    ``w_j = priority_j / max(priorities)``, so a high-priority tenant's
    latency dominates the tie-break and low-priority tenants absorb the
    slack. Unset (the default) means no weighting is applied at all —
    today's unweighted results are reproduced bitwise. Per-stream latency
    *budgets* stay hard constraints regardless of priority."""
    power_budget: float
    streams: tuple
    train: bool = True
    priorities: Optional[tuple] = None

    def __post_init__(self):
        object.__setattr__(self, "streams", tuple(self.streams))
        if not self.streams:
            raise ValueError("MultiTenantProblem needs at least one stream")
        if self.priorities is not None:
            pr = tuple(float(p) for p in self.priorities)
            if len(pr) != len(self.streams):
                raise ValueError(f"expected {len(self.streams)} priorities, "
                                 f"got {len(pr)}")
            if any(p <= 0.0 for p in pr):
                raise ValueError("priorities must be positive")
            object.__setattr__(self, "priorities", pr)

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    def priority_weights(self) -> Optional[tuple]:
        """Per-stream objective weights ``priority_j / max(priorities)``;
        ``None`` (no weighting applied — the bitwise default) when
        priorities are unset."""
        if self.priorities is None:
            return None
        mx = max(self.priorities)
        return tuple(p / mx for p in self.priorities)

    def pair_view(self) -> ConcurrentProblem:
        """The equivalent pair problem (requires exactly one stream)."""
        if self.n_streams != 1:
            raise ValueError(f"{self.n_streams} streams have no pair view")
        s = self.streams[0]
        if self.train:
            return ConcurrentProblem(self.power_budget, s.latency_budget,
                                     s.arrival_rate)
        raise ValueError("pair_view of a no-train problem is an InferProblem; "
                         "use infer_view()")

    def infer_view(self) -> InferProblem:
        if self.n_streams != 1:
            raise ValueError(f"{self.n_streams} streams have no infer view")
        s = self.streams[0]
        return InferProblem(self.power_budget, s.latency_budget,
                            s.arrival_rate)


@dataclasses.dataclass(frozen=True)
class Solution:
    pm: PowerMode
    bs: Optional[int] = None
    tau_tr: Optional[int] = None
    # achieved metrics (as observed/predicted by the solving strategy)
    time: float = 0.0            # train minibatch time or inference latency
    power: float = 0.0
    throughput: float = 0.0      # training minibatches/s (concurrent)


@dataclasses.dataclass(frozen=True)
class MultiTenantSolution:
    """A committed multi-tenant plan: one power mode, one minibatch size per
    stream, the interleave factor, and the per-stream achieved latencies."""
    pm: PowerMode
    bss: tuple                   # one minibatch size per stream
    tau_tr: Optional[int] = None
    times: tuple = ()            # per-stream peak latency (s)
    power: float = 0.0
    throughput: float = 0.0      # training minibatches/s (0 when no train)

    @property
    def time(self) -> float:
        """Worst-tenant peak latency."""
        return max(self.times) if self.times else 0.0

    @property
    def bs(self) -> Optional[int]:
        """The single-stream view's minibatch size (N=1 only)."""
        return int(self.bss[0]) if len(self.bss) == 1 else None

    def stream_solution(self, i: int) -> Solution:
        """Stream ``i``'s slice of the plan as a pair-shaped Solution."""
        return Solution(pm=self.pm, bs=int(self.bss[i]), tau_tr=self.tau_tr,
                        time=float(self.times[i]), power=self.power,
                        throughput=self.throughput)


# ---------------------------------------------------------------------------
# managed-interleaving math (§4, Fig. 3/4)
# ---------------------------------------------------------------------------

def queueing_time(bs: int, arrival_rate: float) -> float:
    return (bs - 1) / arrival_rate


def peak_latency(bs: int, arrival_rate: float, t_in: float) -> float:
    """lambda_in = (beta-1)/alpha + t_in."""
    return queueing_time(bs, arrival_rate) + t_in


def sustainable(bs: int, arrival_rate: float, t_in: float) -> bool:
    """Inference rate keeps up with arrival rate (Fig. 3b): processing one
    minibatch must not take longer than it takes the next one to queue up."""
    return t_in <= bs / arrival_rate


def interleave_tau(bs: int, arrival_rate: float, t_in: float, t_tr: float) -> int:
    """Integral number of training minibatches per inference cycle."""
    slack = bs / arrival_rate - t_in
    return max(0, int(math.floor(slack / t_tr)))


def train_throughput(bs: int, arrival_rate: float, t_in: float, t_tr: float) -> float:
    """theta_tr under managed interleaving (train minibatches / s)."""
    tau = interleave_tau(bs, arrival_rate, t_in, t_tr)
    return tau / (bs / arrival_rate)


# ---------------------------------------------------------------------------
# N-stream feasibility math. One stream replays the pair expressions bitwise;
# N > 1 charges each stream's service time pro-rata against the shortest
# stream period (the base interleaving cycle) and adds worst-case head-of-
# line blocking (one in-flight batch of every other tenant) to peak latency.
# ---------------------------------------------------------------------------

def multi_cycle(bss: Sequence[int], rates: Sequence[float]) -> float:
    """Base interleaving cycle: the shortest stream batch period."""
    return min(b / r for b, r in zip(bss, rates))


def multi_slack(bss: Sequence[int], rates: Sequence[float],
                t_ins: Sequence[float]) -> float:
    """Idle time per base cycle once every stream is served at its rate."""
    cycle = multi_cycle(bss, rates)
    if len(bss) == 1:                      # the exact pair expression
        return cycle - t_ins[0]
    busy = 0.0
    for b, r, t in zip(bss, rates, t_ins):
        busy += t * (cycle * r / b)        # fractional batches per cycle
    return cycle - busy


def multi_blocking(t_ins: Sequence[float], i: int) -> float:
    """Worst-case head-of-line blocking seen by stream ``i``: one batch of
    every other tenant in service/queued ahead (total-minus-own form, so the
    vectorized solver reproduces it exactly)."""
    if len(t_ins) == 1:
        return 0.0
    total = 0.0
    for t in t_ins:
        total += t
    return total - t_ins[i]


def multi_peak_latency(bss, rates, t_ins, i: int) -> float:
    """Stream ``i``'s peak latency: queueing + own service + blocking."""
    lam = peak_latency(bss[i], rates[i], t_ins[i])
    blk = multi_blocking(t_ins, i)
    return lam if blk == 0.0 else lam + blk


def multi_sustainable(bss, rates, t_ins) -> bool:
    """Every stream keeps up on its own AND the joint schedule has
    non-negative slack (a single device serves all streams)."""
    for b, r, t in zip(bss, rates, t_ins):
        if not sustainable(b, r, t):
            return False
    return len(bss) == 1 or multi_slack(bss, rates, t_ins) >= 0.0


def multi_interleave_tau(bss, rates, t_ins, t_tr: float) -> int:
    """Training minibatches per base cycle under N-stream interleaving."""
    slack = multi_slack(bss, rates, t_ins)
    return max(0, int(math.floor(slack / t_tr)))


# ---------------------------------------------------------------------------
# observed-profile solvers
# observations: {pm: (t, p)} for training; {(pm, bs): (t, p)} for inference.
# concurrent: train_obs {pm: (t,p)} + infer_obs {(pm,bs): (t,p)}
#
# These are the scalar reference implementations. For sweeps over many
# problem configurations use core.grid_eval.solve_*_batch — bitwise-identical
# vectorized counterparts that solve a whole batch as one array program.
# ---------------------------------------------------------------------------

def solve_train(problem: TrainProblem, obs: dict) -> Optional[Solution]:
    """arg max theta_tr  s.t.  p_tr <= p-hat."""
    best = None
    for pm, (t, p) in obs.items():
        if p <= problem.power_budget and (best is None or t < best.time):
            best = Solution(pm=pm, time=t, power=p, throughput=1.0 / t)
    return best


def solve_infer(problem: InferProblem, obs: dict) -> Optional[Solution]:
    """arg min lambda_in  s.t.  lambda <= budget, p <= budget, sustainable."""
    best = None
    for (pm, bs), (t, p) in obs.items():
        if p > problem.power_budget:
            continue
        if not sustainable(bs, problem.arrival_rate, t):
            continue
        lam = peak_latency(bs, problem.arrival_rate, t)
        if lam > problem.latency_budget:
            continue
        if best is None or lam < best.time:
            best = Solution(pm=pm, bs=bs, time=lam, power=p)
    return best


def solve_infer_interval(problem: InferProblem, rate_hi: float,
                         obs: dict) -> Optional[Solution]:
    """``solve_infer`` for a rate *interval*: the closed-loop controller
    plans against an estimated rate (``problem.arrival_rate``, the low end)
    but wants service headroom up to a margined ``rate_hi``. Sustainability
    must hold at the high rate (that is where the queue would build), while
    the latency budget — and the objective — are judged at the low rate,
    where the batch-fill wait ``(bs-1)/alpha`` is longest. Degenerates to
    ``solve_infer`` when ``rate_hi == arrival_rate``. Same scan order and
    first-strict-improvement tie-break as every scalar solver here."""
    best = None
    for (pm, bs), (t, p) in obs.items():
        if p > problem.power_budget:
            continue
        if not sustainable(bs, max(rate_hi, problem.arrival_rate), t):
            continue
        lam = peak_latency(bs, problem.arrival_rate, t)
        if lam > problem.latency_budget:
            continue
        if best is None or lam < best.time:
            best = Solution(pm=pm, bs=bs, time=lam, power=p)
    return best


# ---------------------------------------------------------------------------
# burst-quantile planning + drainability (§5.4 burst survival). A Poisson
# window at mean rate alpha sees alpha*T arrivals only on average; planning
# at the mean leaves every upper-tail window queueing-infeasible. These
# helpers let the closed loop plan at the window's arrival-count quantile
# and check whether a committed plan can drain the window's demand — and if
# not, how much must be shed or deferred. Pure-Python float ops, like every
# solver in this module.
# ---------------------------------------------------------------------------

def _norm_ppf(q: float) -> float:
    """Standard-normal quantile via Newton iteration on ``math.erf`` (the
    CDF is smooth and monotone, so this converges fast from 0 for any
    non-degenerate q); used only where the exact Poisson pmf underflows."""
    x = 0.0
    for _ in range(64):
        cdf = 0.5 * (1.0 + math.erf(x / math.sqrt(2.0)))
        pdf = math.exp(-0.5 * x * x) / math.sqrt(2.0 * math.pi)
        if pdf <= 0.0:
            break
        step = (cdf - q) / pdf
        x -= step
        if abs(step) < 1e-12:
            break
    return x


def poisson_quantile(mean: float, q: float) -> int:
    """Smallest k with P[N <= k] >= q for N ~ Poisson(mean).

    Exact pmf summation (the recursion p_k = p_{k-1} * mean / k) while
    ``exp(-mean)`` is representable; above that (mean > ~700 — far past any
    window this repo plans) a Cornish-Fisher-corrected normal quantile
    ``mean + z*sqrt(mean) + (z^2 - 1)/6``, whose error is O(1) counts."""
    if not 0.0 <= q < 1.0:
        raise ValueError(f"quantile must be in [0, 1), got {q}")
    if mean <= 0.0:
        return 0
    if mean <= 700.0:
        p = math.exp(-mean)
        cdf, k = p, 0
        while cdf < q:
            k += 1
            p *= mean / k
            cdf += p
        return k
    z = _norm_ppf(q)
    return max(0, int(math.ceil(mean + math.sqrt(mean) * z
                                + (z * z - 1.0) / 6.0)))


def burst_rate(rate: float, duration: float, q: float) -> float:
    """The rate to size a window's service headroom for: the window's
    Poisson arrival-count q-quantile divided by the duration — never below
    the mean rate, and the mean rate itself when quantile planning is off
    (q <= 0) or the window is degenerate."""
    if q <= 0.0 or rate <= 0.0 or duration <= 0.0:
        return float(rate)
    return max(float(rate),
               poisson_quantile(float(rate) * float(duration), q)
               / float(duration))


def drain_capacity(bs: int, t_in: float, duration: float) -> int:
    """Requests a committed (bs, t_in) plan can serve within ``duration``
    seconds of exclusive managed service: full minibatches only (a trailing
    partial batch never runs, as in the engine)."""
    if duration <= 0.0:
        return 0
    if t_in <= 0.0:
        return int(1e18)
    return int(math.floor(duration / t_in)) * int(bs)


def min_shed(n_requests: int, bs: int, t_in: float, duration: float) -> int:
    """The minimal number of requests to shed (or defer past the window) so
    the remainder can drain within the window under the committed plan."""
    return max(0, int(n_requests) - drain_capacity(bs, t_in, duration))


def drainable(n_pending: int, rate: float, bs: int, t_in: float,
              duration: float) -> bool:
    """Given the carried backlog (``n_pending`` requests already queued) and
    the estimated arrival rate, can the committed plan drain the window's
    demand within the window?"""
    demand = int(n_pending) + int(math.ceil(max(0.0, float(rate))
                                            * float(duration)))
    return min_shed(demand, bs, t_in, duration) == 0


def solve_infer_capacity(power_budget: float, obs: dict) -> Optional[Solution]:
    """Graceful-degradation plan (AdmissionPolicy mode ``degrade-bs``): when
    no plan can drain the window within the latency budget, pick the highest
    service rate bs/t_in under the power budget alone — latency and
    sustainability are waived; violations are accepted to preserve goodput.
    The returned ``time`` is the plan's *service* time (not a peak latency —
    there is no rate this plan is judged against). First-scanned entry wins
    ties, as in every scalar solver here."""
    best, best_cap = None, -1.0
    for (pm, bs), (t, p) in obs.items():
        if p > power_budget:
            continue
        cap = bs / t if t > 0.0 else float("inf")
        if cap > best_cap:
            best = Solution(pm=pm, bs=bs, time=t, power=p)
            best_cap = cap
    return best


def water_fill(demands: np.ndarray, total: float) -> np.ndarray:
    """Water-filling allocation of one shared budget across demands: when
    the demands fit (``sum(demands) <= total``) every demand is met and the
    slack is split evenly; otherwise the classic level allocation
    ``min(demand_i, level)`` with the level chosen so the grants sum exactly
    to ``total`` — small demands are met in full, large demands are clipped
    to the common level. Deterministic closed form (sort + prefix sums), so
    the batched and sequential fleet drivers compute bitwise-identical
    per-device power budgets (``FleetSpec.fleet_power_budget``)."""
    d = np.asarray(demands, np.float64)
    total = float(total)
    if d.ndim != 1 or d.size == 0:
        raise ValueError("water_fill needs a 1-D, non-empty demand vector")
    if total < 0.0 or np.any(d < 0.0):
        raise ValueError("demands and total must be non-negative")
    if float(d.sum()) <= total:
        return d + (total - float(d.sum())) / d.size
    ds = np.sort(d, kind="stable")
    K = d.size
    filled = 0.0               # sum of demands already met in full
    for k in range(K):
        level = (total - filled) / (K - k)
        if level <= float(ds[k]):
            return np.minimum(d, level)
        filled += float(ds[k])
    return np.minimum(d, float(ds[-1]))     # unreachable: sum(d) > total


def solve_concurrent(problem: ConcurrentProblem, train_obs: dict,
                     infer_obs: dict) -> Optional[Solution]:
    """Primary: arg max theta_tr s.t. lambda <= budget and max(p) <= budget.
    Secondary: arg min lambda_in."""
    best = None
    for (pm, bs), (t_in, p_in) in infer_obs.items():
        if pm not in train_obs:
            continue
        t_tr, p_tr = train_obs[pm]
        p = max(p_in, p_tr)
        if p > problem.power_budget:
            continue
        if not sustainable(bs, problem.arrival_rate, t_in):
            continue
        lam = peak_latency(bs, problem.arrival_rate, t_in)
        if lam > problem.latency_budget:
            continue
        tau = interleave_tau(bs, problem.arrival_rate, t_in, t_tr)
        theta = tau / (bs / problem.arrival_rate)
        cand = Solution(pm=pm, bs=bs, tau_tr=tau, time=lam, power=p, throughput=theta)
        if best is None or (cand.throughput, -cand.time) > (best.throughput, -best.time):
            best = cand
    return best


def _stream_candidates(obs: dict, spec: StreamSpec) -> dict:
    """{pm: [(bs, t, p), ...]} in observation order, restricted to the
    spec's allowed minibatch sizes."""
    allowed = None if spec.batch_sizes is None else set(spec.batch_sizes)
    out: dict = {}
    for (pm, bs), (t, p) in obs.items():
        if allowed is not None and bs not in allowed:
            continue
        out.setdefault(pm, []).append((bs, t, p))
    return out


def solve_multi_tenant(problem: MultiTenantProblem, train_obs: Optional[dict],
                       infer_obs: Sequence[dict]) -> Optional[MultiTenantSolution]:
    """Scalar reference for the N-stream problem: scan the cross-product of
    per-stream (pm, bs) observations sharing one power mode. Primary
    objective: training throughput (worst-tenant latency when no train);
    secondary: min worst-tenant latency. With one stream this replays
    ``solve_concurrent`` / ``solve_infer`` op-for-op (bitwise contract)."""
    n = problem.n_streams
    if len(infer_obs) != n:
        raise ValueError(f"expected {n} observation sets, got {len(infer_obs)}")
    rates = [s.arrival_rate for s in problem.streams]
    spec0 = problem.streams[0]
    allowed0 = None if spec0.batch_sizes is None else set(spec0.batch_sizes)
    rest = [_stream_candidates(obs, s)
            for obs, s in zip(infer_obs[1:], problem.streams[1:])]
    weights = problem.priority_weights()
    best = None
    best_key = None
    # stream 0 scans its observations in dict order — with one stream this
    # is solve_concurrent's/solve_infer's exact scan (and tie-break) order
    for (pm, bs0), (t0, p0) in infer_obs[0].items():
        if allowed0 is not None and bs0 not in allowed0:
            continue
        if problem.train and (train_obs is None or pm not in train_obs):
            continue
        per_stream = [c.get(pm) for c in rest]
        if any(ps is None for ps in per_stream):
            continue
        t_tr = p_tr = None
        if problem.train:
            t_tr, p_tr = train_obs[pm]
        for combo in _cross(per_stream):
            bss = [bs0] + [c[0] for c in combo]
            t_ins = [t0] + [c[1] for c in combo]
            p = p0
            for c in combo:
                p = max(p, c[2])
            if p_tr is not None:
                p = max(p, p_tr)
            if p > problem.power_budget:
                continue
            if not multi_sustainable(bss, rates, t_ins):
                continue
            lams = [multi_peak_latency(bss, rates, t_ins, i)
                    for i in range(n)]
            if any(lam > s.latency_budget
                   for lam, s in zip(lams, problem.streams)):
                continue
            worst = max(lams) if weights is None \
                else max(w * lam for w, lam in zip(weights, lams))
            if problem.train:
                tau = multi_interleave_tau(bss, rates, t_ins, t_tr)
                theta = tau / multi_cycle(bss, rates)
                key = (theta, -worst)
            else:
                tau, theta = None, 0.0
                key = (-worst,)
            if best is None or key > best_key:
                best = MultiTenantSolution(pm=pm, bss=tuple(bss), tau_tr=tau,
                                           times=tuple(lams), power=p,
                                           throughput=theta)
                best_key = key
    return best


def solve_multi_tenant_interval(problem: MultiTenantProblem,
                                rate_his: Sequence[float],
                                train_obs: Optional[dict],
                                infer_obs: Sequence[dict]
                                ) -> Optional[MultiTenantSolution]:
    """``solve_multi_tenant`` for per-stream rate *intervals* — the N-stream
    counterpart of ``solve_infer_interval``. Sustainability (and the joint
    slack) must hold at each stream's margined high rate ``max(rate_hi,
    arrival_rate)``, where the queue would build; the per-stream latency
    budgets — and the latency side of the objective — are judged at the
    problem's (low-end estimate) rates, where the batch-fill wait is
    longest. The training-throughput objective is judged at the high rates
    too: the committed tau_tr is the slack *guaranteed* under the margined
    load (the engine fills conservatively regardless). Degenerates to
    ``solve_multi_tenant`` when every high rate equals the stream rate, and
    with one stream replays ``solve_infer_interval`` op-for-op. Same scan
    order and first-strict-improvement tie-break as every solver here."""
    n = problem.n_streams
    if len(rate_his) != n:
        raise ValueError(f"expected {n} high rates, got {len(rate_his)}")
    rates = [s.arrival_rate for s in problem.streams]
    his = [max(float(h), r) for h, r in zip(rate_his, rates)]
    spec0 = problem.streams[0]
    allowed0 = None if spec0.batch_sizes is None else set(spec0.batch_sizes)
    rest = [_stream_candidates(obs, s)
            for obs, s in zip(infer_obs[1:], problem.streams[1:])]
    weights = problem.priority_weights()
    best = None
    best_key = None
    for (pm, bs0), (t0, p0) in infer_obs[0].items():
        if allowed0 is not None and bs0 not in allowed0:
            continue
        if problem.train and (train_obs is None or pm not in train_obs):
            continue
        per_stream = [c.get(pm) for c in rest]
        if any(ps is None for ps in per_stream):
            continue
        t_tr = p_tr = None
        if problem.train:
            t_tr, p_tr = train_obs[pm]
        for combo in _cross(per_stream):
            bss = [bs0] + [c[0] for c in combo]
            t_ins = [t0] + [c[1] for c in combo]
            p = p0
            for c in combo:
                p = max(p, c[2])
            if p_tr is not None:
                p = max(p, p_tr)
            if p > problem.power_budget:
                continue
            if not multi_sustainable(bss, his, t_ins):
                continue
            lams = [multi_peak_latency(bss, rates, t_ins, i)
                    for i in range(n)]
            if any(lam > s.latency_budget
                   for lam, s in zip(lams, problem.streams)):
                continue
            worst = max(lams) if weights is None \
                else max(w * lam for w, lam in zip(weights, lams))
            if problem.train:
                tau = multi_interleave_tau(bss, his, t_ins, t_tr)
                theta = tau / multi_cycle(bss, his)
                key = (theta, -worst)
            else:
                tau, theta = None, 0.0
                key = (-worst,)
            if best is None or key > best_key:
                best = MultiTenantSolution(pm=pm, bss=tuple(bss), tau_tr=tau,
                                           times=tuple(lams), power=p,
                                           throughput=theta)
                best_key = key
    return best


def _cross(per_stream):
    """Cross product of per-stream candidate lists, earlier-stream-major
    (the enumeration order the vectorized solver reproduces)."""
    if not per_stream:
        yield ()
        return
    for c in per_stream[0]:
        for tail in _cross(per_stream[1:]):
            yield (c,) + tail
