"""Problem formulation (§4): the three optimization variants, the managed-
interleaving feasibility math, and the observed-profile solver every strategy
(oracle, RND, ALS, GMD backtracking) shares.

Notation follows Table 2: a solution is (pm [, beta_in [, tau_tr]]).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.powermode import PowerMode

INFER_BATCH_SIZES = [1, 4, 16, 32, 64]   # paper §6 (BERT capped at 32)


@dataclasses.dataclass(frozen=True)
class TrainProblem:
    power_budget: float                       # p-hat (W)


@dataclasses.dataclass(frozen=True)
class InferProblem:
    power_budget: float
    latency_budget: float                     # lambda-hat (s/request, peak)
    arrival_rate: float                       # alpha (requests/s)


@dataclasses.dataclass(frozen=True)
class ConcurrentProblem:
    power_budget: float
    latency_budget: float
    arrival_rate: float


@dataclasses.dataclass(frozen=True)
class Solution:
    pm: PowerMode
    bs: Optional[int] = None
    tau_tr: Optional[int] = None
    # achieved metrics (as observed/predicted by the solving strategy)
    time: float = 0.0            # train minibatch time or inference latency
    power: float = 0.0
    throughput: float = 0.0      # training minibatches/s (concurrent)


# ---------------------------------------------------------------------------
# managed-interleaving math (§4, Fig. 3/4)
# ---------------------------------------------------------------------------

def queueing_time(bs: int, arrival_rate: float) -> float:
    return (bs - 1) / arrival_rate


def peak_latency(bs: int, arrival_rate: float, t_in: float) -> float:
    """lambda_in = (beta-1)/alpha + t_in."""
    return queueing_time(bs, arrival_rate) + t_in


def sustainable(bs: int, arrival_rate: float, t_in: float) -> bool:
    """Inference rate keeps up with arrival rate (Fig. 3b): processing one
    minibatch must not take longer than it takes the next one to queue up."""
    return t_in <= bs / arrival_rate


def interleave_tau(bs: int, arrival_rate: float, t_in: float, t_tr: float) -> int:
    """Integral number of training minibatches per inference cycle."""
    slack = bs / arrival_rate - t_in
    return max(0, int(math.floor(slack / t_tr)))


def train_throughput(bs: int, arrival_rate: float, t_in: float, t_tr: float) -> float:
    """theta_tr under managed interleaving (train minibatches / s)."""
    tau = interleave_tau(bs, arrival_rate, t_in, t_tr)
    return tau / (bs / arrival_rate)


# ---------------------------------------------------------------------------
# observed-profile solvers
# observations: {pm: (t, p)} for training; {(pm, bs): (t, p)} for inference.
# concurrent: train_obs {pm: (t,p)} + infer_obs {(pm,bs): (t,p)}
#
# These are the scalar reference implementations. For sweeps over many
# problem configurations use core.grid_eval.solve_*_batch — bitwise-identical
# vectorized counterparts that solve a whole batch as one array program.
# ---------------------------------------------------------------------------

def solve_train(problem: TrainProblem, obs: dict) -> Optional[Solution]:
    """arg max theta_tr  s.t.  p_tr <= p-hat."""
    best = None
    for pm, (t, p) in obs.items():
        if p <= problem.power_budget and (best is None or t < best.time):
            best = Solution(pm=pm, time=t, power=p, throughput=1.0 / t)
    return best


def solve_infer(problem: InferProblem, obs: dict) -> Optional[Solution]:
    """arg min lambda_in  s.t.  lambda <= budget, p <= budget, sustainable."""
    best = None
    for (pm, bs), (t, p) in obs.items():
        if p > problem.power_budget:
            continue
        if not sustainable(bs, problem.arrival_rate, t):
            continue
        lam = peak_latency(bs, problem.arrival_rate, t)
        if lam > problem.latency_budget:
            continue
        if best is None or lam < best.time:
            best = Solution(pm=pm, bs=bs, time=lam, power=p)
    return best


def solve_concurrent(problem: ConcurrentProblem, train_obs: dict,
                     infer_obs: dict) -> Optional[Solution]:
    """Primary: arg max theta_tr s.t. lambda <= budget and max(p) <= budget.
    Secondary: arg min lambda_in."""
    best = None
    for (pm, bs), (t_in, p_in) in infer_obs.items():
        if pm not in train_obs:
            continue
        t_tr, p_tr = train_obs[pm]
        p = max(p_in, p_tr)
        if p > problem.power_budget:
            continue
        if not sustainable(bs, problem.arrival_rate, t_in):
            continue
        lam = peak_latency(bs, problem.arrival_rate, t_in)
        if lam > problem.latency_budget:
            continue
        tau = interleave_tau(bs, problem.arrival_rate, t_in, t_tr)
        theta = tau / (bs / problem.arrival_rate)
        cand = Solution(pm=pm, bs=bs, tau_tr=tau, time=lam, power=p, throughput=theta)
        if best is None or (cand.throughput, -cand.time) > (best.throughput, -best.time):
            best = cand
    return best
