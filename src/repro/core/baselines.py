"""Baseline strategies (paper §6): RND-k random sampling with observed-Pareto
lookup, and the NN-k prediction-based baseline (PowerTrain-style) whose
*predicted* Pareto answers queries — and can therefore violate budgets.

Query answering runs on the vectorized grid engine: after fitting, the
observed (or predicted) profiles are flattened into an `ObservationGrid`
once, and `solve`/`solve_batch` are masked reductions over it — a whole
problem sweep is one array program instead of a per-problem Python scan.
Profiling itself still goes through the scalar `Profiler`, point by point.
"""
from __future__ import annotations

import random
from typing import Optional, Sequence

import numpy as np

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core.device_model import Profiler
from repro.core.gmd import ConcurrentProfiler
from repro.core.nn_model import NNPredictor, mode_features
from repro.core.powermode import PowerModeSpace


class RNDTrain:
    """RND-k: profile k random modes, answer from the observed profiles."""

    def __init__(self, profiler: Profiler, k: int, space=None, seed: int = 0):
        self.profiler, self.k = profiler, k
        self.space = space or PowerModeSpace()
        self.seed = seed
        self._fitted = False

    def fit(self):
        rng = random.Random(self.seed)
        for pm in rng.sample(self.space.all_modes(), self.k):
            self.profiler.profile(pm)
        self._fitted = True

    def solve(self, prob: P.TrainProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.TrainProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if not self._fitted:
            self.fit()
        grid = G.cached_grid(self, "_grid", self.profiler.observed_modes(),
                             "train")
        return G.solve_train_batch(probs, grid, backend)


class RNDInfer:
    """RND-150/250: k//5 random modes, each profiled at all 5 batch sizes."""

    def __init__(self, profiler: Profiler, k: int, space=None, seed: int = 0,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.profiler, self.k = profiler, k
        self.space = space or PowerModeSpace()
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.profiler.profile(pm, bs)
        self._fitted = True

    def solve(self, prob: P.InferProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.InferProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if not self._fitted:
            self.fit()
        grid = G.cached_grid(self, "_grid", self.profiler.observed(), "infer")
        return G.solve_infer_batch(probs, grid, backend)


class RNDMultiTenant:
    """RND-k for N streams: k//5 random modes, every stream profiled at all
    batch sizes per visit; answers ride the batched multi-tenant solver."""

    def __init__(self, mtprofiler, k: int, space=None, seed: int = 0,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.mp, self.k = mtprofiler, k
        self.space = space or PowerModeSpace()
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.mp.profile(pm, [bs] * self.mp.n_streams)
        self._fitted = True

    def solve(self, prob: P.MultiTenantProblem) -> Optional[P.MultiTenantSolution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.MultiTenantProblem],
                    backend: str = "numpy") -> list:
        if not self._fitted:
            self.fit()
        tgrid = G.cached_grid(self, "_tgrid", self.mp.train.observed_modes(),
                              "train") if self.mp.train else None
        igrids = [G.cached_grid(self, f"_igrid{j}", prof.observed(), "infer")
                  for j, prof in enumerate(self.mp.streams)]
        return G.solve_multi_tenant_batch(probs, tgrid, igrids, backend)


class RNDConcurrent:
    def __init__(self, cprofiler: ConcurrentProfiler, k: int, space=None,
                 seed: int = 0, batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.cp, self.k = cprofiler, k
        self.space = space or PowerModeSpace()
        self.seed = seed
        self.batch_sizes = list(batch_sizes)
        self._fitted = False

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.cp.profile(pm, bs)
        self._fitted = True

    def solve(self, prob: P.ConcurrentProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.ConcurrentProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if not self._fitted:
            self.fit()
        return G.solve_concurrent_batch(
            probs,
            G.cached_grid(self, "_tgrid", self.cp.train.observed_modes(), "train"),
            G.cached_grid(self, "_igrid", self.cp.infer.observed(), "infer"),
            backend)


# ---------------------------------------------------------------------------
# NN-k: prediction-based (the paper's cautionary baseline)
# ---------------------------------------------------------------------------

class NNTrainBaseline:
    def __init__(self, profiler: Profiler, k: int = 250, space=None,
                 seed: int = 0, nn_epochs: int = 1000):
        self.profiler, self.k = profiler, k
        self.space = space or PowerModeSpace()
        self.seed, self.nn_epochs = seed, nn_epochs
        self._pred = None

    def fit(self):
        rng = random.Random(self.seed)
        for pm in rng.sample(self.space.all_modes(), self.k):
            self.profiler.profile(pm)
        obs = self.profiler.observed()
        feats = np.array([mode_features(pm) for (pm, _) in obs])
        nn_t = NNPredictor.fit(feats, np.array([t for t, _ in obs.values()]),
                               epochs=self.nn_epochs)
        nn_p = NNPredictor.fit(feats, np.array([p for _, p in obs.values()]),
                               epochs=self.nn_epochs, seed=1)
        modes = self.space.all_modes()
        mf = np.array([mode_features(pm) for pm in modes])
        self._pred = {pm: (float(t), float(p))
                      for pm, t, p in zip(modes, nn_t.predict(mf), nn_p.predict(mf))}
        self._grid = None           # refit replaces predictions wholesale

    def solve(self, prob: P.TrainProblem) -> Optional[P.Solution]:
        """Answers from *predicted* values; the returned solution's true
        time/power may violate the budget (evaluated by the benchmark)."""
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.TrainProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if self._pred is None:
            self.fit()
        return G.solve_train_batch(
            probs, G.cached_grid(self, "_grid", self._pred, "train"), backend)


class NNInferBaseline:
    def __init__(self, profiler: Profiler, k: int = 250, space=None,
                 seed: int = 0, nn_epochs: int = 1000,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.profiler, self.k = profiler, k
        self.space = space or PowerModeSpace()
        self.seed, self.nn_epochs = seed, nn_epochs
        self.batch_sizes = list(batch_sizes)
        self._pred = None

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.profiler.profile(pm, bs)
        obs = self.profiler.observed()
        feats = np.array([mode_features(pm, bs) for (pm, bs) in obs])
        nn_t = NNPredictor.fit(feats, np.array([t for t, _ in obs.values()]),
                               epochs=self.nn_epochs)
        nn_p = NNPredictor.fit(feats, np.array([p for _, p in obs.values()]),
                               epochs=self.nn_epochs, seed=1)
        keys = [(pm, bs) for pm in self.space.all_modes() for bs in self.batch_sizes]
        mf = np.array([mode_features(pm, bs) for pm, bs in keys])
        self._pred = {k: (float(t), float(p))
                      for k, t, p in zip(keys, nn_t.predict(mf), nn_p.predict(mf))}
        self._grid = None           # refit replaces predictions wholesale

    def solve(self, prob: P.InferProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.InferProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if self._pred is None:
            self.fit()
        return G.solve_infer_batch(
            probs, G.cached_grid(self, "_grid", self._pred, "infer"), backend)


class NNConcurrentBaseline:
    def __init__(self, cprofiler: ConcurrentProfiler, k: int = 250, space=None,
                 seed: int = 0, nn_epochs: int = 1000,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.cp, self.k = cprofiler, k
        self.space = space or PowerModeSpace()
        self.seed, self.nn_epochs = seed, nn_epochs
        self.batch_sizes = list(batch_sizes)
        self._pred = None

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.cp.profile(pm, bs)
        iobs = self.cp.infer.observed()
        tobs = self.cp.train.observed()
        ifeats = np.array([mode_features(pm, bs) for (pm, bs) in iobs])
        nn_ti = NNPredictor.fit(ifeats, np.array([t for t, _ in iobs.values()]),
                                epochs=self.nn_epochs)
        nn_pi = NNPredictor.fit(ifeats, np.array([p for _, p in iobs.values()]),
                                epochs=self.nn_epochs, seed=1)
        tfeats = np.array([mode_features(pm) for (pm, _) in tobs])
        nn_tt = NNPredictor.fit(tfeats, np.array([t for t, _ in tobs.values()]),
                                epochs=self.nn_epochs, seed=2)
        nn_pt = NNPredictor.fit(tfeats, np.array([p for _, p in tobs.values()]),
                                epochs=self.nn_epochs, seed=3)
        modes = self.space.all_modes()
        keys = [(pm, bs) for pm in modes for bs in self.batch_sizes]
        imf = np.array([mode_features(pm, bs) for pm, bs in keys])
        tmf = np.array([mode_features(pm) for pm in modes])
        self._ipred = {k: (float(t), float(p)) for k, t, p in
                       zip(keys, nn_ti.predict(imf), nn_pi.predict(imf))}
        self._tpred = {pm: (float(t), float(p)) for pm, t, p in
                       zip(modes, nn_tt.predict(tmf), nn_pt.predict(tmf))}
        self._tgrid = self._igrid = None   # refit replaces predictions
        self._pred = True

    def solve(self, prob: P.ConcurrentProblem) -> Optional[P.Solution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.ConcurrentProblem],
                    backend: str = "numpy") -> list[Optional[P.Solution]]:
        if self._pred is None:
            self.fit()
        return G.solve_concurrent_batch(
            probs, G.cached_grid(self, "_tgrid", self._tpred, "train"),
            G.cached_grid(self, "_igrid", self._ipred, "infer"), backend)


class NNMultiTenantBaseline:
    """NN-k for N streams: per-stream time/power predictors answer from the
    *predicted* dense grids (so, as in the pair case, the chosen plan can
    violate budgets — the benchmark checks against ground truth)."""

    def __init__(self, mtprofiler, k: int = 250, space=None, seed: int = 0,
                 nn_epochs: int = 1000,
                 batch_sizes=tuple(P.INFER_BATCH_SIZES)):
        self.mp, self.k = mtprofiler, k
        self.space = space or PowerModeSpace()
        self.seed, self.nn_epochs = seed, nn_epochs
        self.batch_sizes = list(batch_sizes)
        self._pred = None

    def fit(self):
        rng = random.Random(self.seed)
        n_modes = max(1, self.k // len(self.batch_sizes))
        for pm in rng.sample(self.space.all_modes(), n_modes):
            for bs in self.batch_sizes:
                self.mp.profile(pm, [bs] * self.mp.n_streams)
        modes = self.space.all_modes()
        keys = [(pm, bs) for pm in modes for bs in self.batch_sizes]
        imf = np.array([mode_features(pm, bs) for pm, bs in keys])
        self._ipreds = []
        for j, prof in enumerate(self.mp.streams):
            obs = prof.observed()
            feats = np.array([mode_features(pm, bs) for (pm, bs) in obs])
            nn_t = NNPredictor.fit(feats,
                                   np.array([t for t, _ in obs.values()]),
                                   epochs=self.nn_epochs, seed=2 * j)
            nn_p = NNPredictor.fit(feats,
                                   np.array([p for _, p in obs.values()]),
                                   epochs=self.nn_epochs, seed=2 * j + 1)
            self._ipreds.append(
                {k: (float(t), float(p)) for k, t, p in
                 zip(keys, nn_t.predict(imf), nn_p.predict(imf))})
        self._tpred = None
        if self.mp.train:
            tobs = self.mp.train.observed()
            tfeats = np.array([mode_features(pm) for (pm, _) in tobs])
            nn_tt = NNPredictor.fit(tfeats,
                                    np.array([t for t, _ in tobs.values()]),
                                    epochs=self.nn_epochs, seed=100)
            nn_pt = NNPredictor.fit(tfeats,
                                    np.array([p for _, p in tobs.values()]),
                                    epochs=self.nn_epochs, seed=101)
            tmf = np.array([mode_features(pm) for pm in modes])
            self._tpred = {pm: (float(t), float(p)) for pm, t, p in
                           zip(modes, nn_tt.predict(tmf), nn_pt.predict(tmf))}
        self._tgrid = None                 # refit replaces predictions
        for j in range(self.mp.n_streams):
            setattr(self, f"_igrid{j}", None)
        self._pred = True

    def solve(self, prob: P.MultiTenantProblem) -> Optional[P.MultiTenantSolution]:
        return self.solve_batch([prob])[0]

    def solve_batch(self, probs: Sequence[P.MultiTenantProblem],
                    backend: str = "numpy") -> list:
        if self._pred is None:
            self.fit()
        tgrid = G.cached_grid(self, "_tgrid", self._tpred, "train") \
            if self._tpred is not None else None
        igrids = [G.cached_grid(self, f"_igrid{j}", pred, "infer")
                  for j, pred in enumerate(self._ipreds)]
        return G.solve_multi_tenant_batch(probs, tgrid, igrids, backend)
