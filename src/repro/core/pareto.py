"""Pareto-front utilities over (power, time) trade-off points."""
from __future__ import annotations

from typing import Callable, Hashable, Iterable, Sequence


def pareto_front(points: dict, lower_is_better: bool = True) -> dict:
    """points: {key: (power, objective)}. Returns the subset on the Pareto
    front: least objective for any power (and vice versa). For objectives
    where higher is better (throughput), pass lower_is_better=False."""
    sign = 1.0 if lower_is_better else -1.0
    items = sorted(points.items(), key=lambda kv: (kv[1][0], sign * kv[1][1]))
    front: dict = {}
    best = float("inf")
    for key, (p, obj) in items:
        o = sign * obj
        if o < best:
            front[key] = (p, obj)
            best = o
    return front


def on_front(points: dict, key: Hashable, lower_is_better: bool = True) -> bool:
    return key in pareto_front(points, lower_is_better)


def front_lookup(front: dict, power_budget: float,
                 lower_is_better: bool = True):
    """Best front entry with power <= budget. Returns (key, (p, obj)) or None."""
    sign = 1.0 if lower_is_better else -1.0
    best = None
    for key, (p, obj) in front.items():
        if p <= power_budget and (best is None or sign * obj < sign * best[1][1]):
            best = (key, (p, obj))
    return best
