"""Pareto-front utilities over (power, time) trade-off points.

Vectorized on the grid-evaluation engine's conventions: stable lexsort +
cumulative-min instead of a Python scan. Semantics are identical to the
scalar reference — stable sort by (power, signed objective), keep entries
that strictly improve the running best, first occurrence wins on ties.
NaN objectives never win (``front_lookup`` prefers a finite-objective
feasible entry over a NaN one).
"""
from __future__ import annotations

from typing import Hashable

import numpy as np


def pareto_front(points: dict, lower_is_better: bool = True) -> dict:
    """points: {key: (power, objective)}. Returns the subset on the Pareto
    front: least objective for any power (and vice versa). For objectives
    where higher is better (throughput), pass lower_is_better=False."""
    if not points:
        return {}
    sign = 1.0 if lower_is_better else -1.0
    keys = list(points)
    pw = np.fromiter((points[k][0] for k in keys), np.float64, len(keys))
    obj = sign * np.fromiter((points[k][1] for k in keys), np.float64, len(keys))
    order = np.lexsort((obj, pw))        # stable: by power, then signed obj
    o = obj[order]
    # NaN objectives never enter the front (NaN < x is False, as in the
    # scalar loop) and must not poison the running minimum for later points
    o_min = np.where(np.isnan(o), np.inf, o)
    prev_best = np.concatenate(([np.inf], np.minimum.accumulate(o_min)[:-1]))
    front: dict = {}
    for i in order[o < prev_best]:       # strict improvement only
        k = keys[i]
        front[k] = points[k]
    return front


def on_front(points: dict, key: Hashable, lower_is_better: bool = True) -> bool:
    return key in pareto_front(points, lower_is_better)


def front_lookup(front: dict, power_budget: float,
                 lower_is_better: bool = True):
    """Best front entry with power <= budget. Returns (key, (p, obj)) or None."""
    if not front:
        return None
    sign = 1.0 if lower_is_better else -1.0
    keys = list(front)
    pw = np.fromiter((front[k][0] for k in keys), np.float64, len(keys))
    obj = sign * np.fromiter((front[k][1] for k in keys), np.float64, len(keys))
    feas = pw <= power_budget
    if not feas.any():
        return None
    masked = np.where(feas & ~np.isnan(obj), obj, np.inf)
    i = int(np.argmin(masked))
    if not np.isfinite(masked[i]):  # every feasible objective is inf/NaN:
        i = int(np.argmax(feas))    # keep the first feasible entry
    k = keys[i]
    return (k, front[k])
