"""Vectorized grid-evaluation engine (the 273k-config sweep substrate).

The paper's evaluation solves every (power budget, latency budget, arrival
rate) triple against the observed 441-mode x 5-batch-size profile grid. The
scalar reference (`problem.solve_*`) re-scans all observations per problem in
pure Python, and `DeviceModel.time_power` re-hashes its deterministic
perturbations on every call; at paper scale that is hours of interpreter time.

This module replaces both hot paths with dense array programs:

 * ``materialize`` builds the device model as dense ``(cores, cpuf, gpuf,
   memf[, bs])`` time/power tensors per workload — perturbations are computed
   once per axis value (and once per mode for the power term), never in the
   evaluation loop;
 * ``ObservationGrid`` is a flat columnar view of an observation set (dense
   grid or any ``{pm: (t, p)}`` / ``{(pm, bs): (t, p)}`` dict, e.g. a partial
   RND sample or an NN-predicted surface);
 * ``solve_train_batch`` / ``solve_infer_batch`` / ``solve_concurrent_batch``
   solve a whole *batch* of problem configurations as masked argmin/argmax
   reductions, chunked to bound memory, with a NumPy baseline and an optional
   ``backend="jax"`` path (jit + vmap over the problem axis) that runs the
   reduction on-accelerator.

Exactness contract: the NumPy path is **bitwise identical** to the scalar
reference — it is the authoritative backend this module is judged against.
The tensors replay the exact IEEE-754 expression tree of
``DeviceModel.time_power`` elementwise, flattening in observation-dict
iteration order, and the reductions reproduce the scalar loops'
first-strict-improvement rule (NumPy's argmin/argmax return the first
occurrence of the extremum). ``tests/test_grid_eval.py`` enforces this
against randomized grids and the full 441 x 5 sweep. The jax backend runs
the same reductions under ``enable_x64`` (masked argmin/argmax are
reassociation-free, so it stays bitwise-equal too — unlike the execution
engine's scan, see ``docs/exactness.md``). Backend names are validated by
the shared ``core.backend`` plumbing, also used by ``core.simulate`` — the
solvers accept only the "numpy"/"jax" tiers (the "pallas" tier is an
execution-engine backend; there is no Pallas solver kernel, so asking for
it here is a ``ValueError`` rather than a silent NumPy fallback). Ragged
final problem chunks are padded to power-of-two row buckets before hitting
the jit kernels, so sweeping many batch sizes reuses a handful of
compilations — ``solver_trace_count()`` exposes the retrace counter.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from repro.core import problem as P
from repro.core.backend import check_backend, record_dispatch, require_jax
from repro.core.device_model import (MAX_CORES, MAX_CPUF, MAX_GPUF, MAX_MEMF,
                                     DeviceModel, WorkloadProfile, _pert)
from repro.core.powermode import PowerMode, PowerModeSpace

# Cap on problems x observations elements held per solver chunk. Each chunk
# materializes a handful of float64 (K, N) temporaries, so 4M elements keeps
# peak memory in the low hundreds of MB even for the concurrent solver.
CHUNK_ELEMS = 4 << 20


# ---------------------------------------------------------------------------
# columnar observation sets
# ---------------------------------------------------------------------------

class ObservationGrid:
    """Flat columnar view of an observation set, in iteration order.

    ``bs`` is None for training-style grids ({pm: (t, p)}) and an int array
    for inference-style grids ({(pm, bs): (t, p)}).
    """

    def __init__(self, modes: list, t: np.ndarray, p: np.ndarray,
                 bs: Optional[np.ndarray] = None):
        self.modes = modes
        self.t = np.ascontiguousarray(t, dtype=np.float64)
        self.p = np.ascontiguousarray(p, dtype=np.float64)
        self.bs = None if bs is None else np.ascontiguousarray(bs, np.int64)
        self._index: Optional[dict] = None
        self._stairs: dict = {}     # memoized Pareto staircases (per rate)

    def __len__(self) -> int:
        return len(self.modes)

    def key(self, i: int):
        if self.bs is None:
            return self.modes[i]
        return (self.modes[i], int(self.bs[i]))

    @property
    def index(self) -> dict:
        """{key: flat position}; first occurrence wins on duplicates."""
        if self._index is None:
            idx: dict = {}
            for i in range(len(self.modes)):
                idx.setdefault(self.key(i), i)
            self._index = idx
        return self._index

    def lookup(self, pm: PowerMode, bs: Optional[int] = None) -> tuple[float, float]:
        i = self.index[pm if self.bs is None else (pm, bs)]
        return float(self.t[i]), float(self.p[i])

    def to_dict(self) -> dict:
        return {self.key(i): (float(self.t[i]), float(self.p[i]))
                for i in range(len(self.modes))}

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_train_dict(cls, obs: dict) -> "ObservationGrid":
        modes = list(obs)
        t = np.fromiter((obs[k][0] for k in modes), np.float64, len(modes))
        p = np.fromiter((obs[k][1] for k in modes), np.float64, len(modes))
        return cls(modes, t, p)

    @classmethod
    def from_infer_dict(cls, obs: dict) -> "ObservationGrid":
        keys = list(obs)
        modes = [pm for pm, _ in keys]
        bs = np.fromiter((b for _, b in keys), np.int64, len(keys))
        t = np.fromiter((obs[k][0] for k in keys), np.float64, len(keys))
        p = np.fromiter((obs[k][1] for k in keys), np.float64, len(keys))
        return cls(modes, t, p, bs)


def as_train_grid(obs: Union[dict, ObservationGrid]) -> ObservationGrid:
    return obs if isinstance(obs, ObservationGrid) else \
        ObservationGrid.from_train_dict(obs)


def cached_grid(owner, attr: str, obs: dict, kind: str) -> ObservationGrid:
    """Memoize the columnar view of ``obs`` on ``owner.<attr>`` so repeated
    queries against a fitted strategy reuse the flattening and the grid's
    staircase memos. Invalidated when the observation count changes —
    sufficient for profiler-backed strategies, whose caches only grow; a
    strategy that *replaces* observations wholesale (the NN baselines'
    predicted surfaces) must also reset ``owner.<attr>`` to None on refit."""
    cache = getattr(owner, attr, None)
    if cache is None or cache[0] != len(obs):
        grid = (ObservationGrid.from_train_dict(obs) if kind == "train"
                else ObservationGrid.from_infer_dict(obs))
        cache = (len(obs), grid)
        setattr(owner, attr, cache)
    return cache[1]


def as_infer_grid(obs: Union[dict, ObservationGrid]) -> ObservationGrid:
    return obs if isinstance(obs, ObservationGrid) else \
        ObservationGrid.from_infer_dict(obs)


# ---------------------------------------------------------------------------
# dense device-model tensors
# ---------------------------------------------------------------------------

def _axis_pert(name: str, dim: str, values: Sequence[int],
               scale: float = 0.05) -> np.ndarray:
    return np.array([_pert(name, dim, v, scale) for v in values])


def _dense_closed_form(w: WorkloadProfile, space: PowerModeSpace,
                       bs_eff: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Replay DeviceModel.time_power's expression tree over the full
    (cores, cpuf, gpuf, memf, bs) grid. Elementwise ops on float64 are the
    same IEEE-754 operations the scalar path performs, so the result is
    bitwise identical per grid point."""
    cores_i = space.values["cores"]
    cpuf_i = space.values["cpuf"]
    gpuf_i = space.values["gpuf"]
    memf_i = space.values["memf"]
    gpuf = np.asarray(gpuf_i, np.float64)[None, None, :, None]
    memf = np.asarray(memf_i, np.float64)[None, None, None, :]

    pert_gpuf = _axis_pert(w.name, "gpuf", gpuf_i)[None, None, :, None]
    pert_cpuf = _axis_pert(w.name, "cpuf", cpuf_i)[None, :, None, None]
    pert_cores = _axis_pert(w.name, "cores", cores_i)[:, None, None, None]
    pert_memf = _axis_pert(w.name, "memf", memf_i)[None, None, None, :]
    # power perturbation keys mix (gpuf, cpuf, memf): one hash per combination
    pert_power = np.empty((1, len(cpuf_i), len(gpuf_i), len(memf_i)))
    for j, cf in enumerate(cpuf_i):
        for k, gf in enumerate(gpuf_i):
            for m, mf in enumerate(memf_i):
                pert_power[0, j, k, m] = _pert(
                    w.name, "power", gf * 31 + cf * 7 + mf, 0.015)

    # pow() per axis value with Python scalar math: NumPy's SIMD pow can
    # differ from libm by 1 ulp, which would break bitwise identity with the
    # scalar path. The remaining +,*,/ are correctly rounded either way.
    cpuf_pow = np.array([(v / MAX_CPUF) ** 0.9 for v in cpuf_i])[None, :, None, None]
    cores_pow = np.array([(min(c, w.cpu_parallelism) / w.cpu_parallelism) ** 0.7
                          for c in cores_i])[:, None, None, None]
    gpu_s = (gpuf / MAX_GPUF) * pert_gpuf
    cpu_s = cpuf_pow * cores_pow * pert_cpuf * pert_cores
    mem_s = (memf / MAX_MEMF) * pert_memf

    # trailing bs axis
    t_gpu = (w.gpu_fixed + w.gpu_per_sample * bs_eff) / gpu_s[..., None]
    t_cpu = (w.cpu_fixed + w.cpu_per_sample * bs_eff) / cpu_s[..., None]
    t_mem = (w.mem_fixed + w.mem_per_sample * bs_eff) / mem_s[..., None]
    t = t_gpu + t_cpu + t_mem

    util = bs_eff / (bs_eff + w.util_half_bs)
    f_gpu, f_cpu, f_mem = t_gpu / t, t_cpu / t, t_mem / t
    f_gpu_power = np.array([(v / MAX_GPUF) ** 1.3
                            for v in gpuf_i])[None, None, :, None]
    f_cpu_power = (np.array([(c / MAX_CORES) ** 0.8
                             for c in cores_i])[:, None, None, None]
                   * np.array([(v / MAX_CPUF) ** 1.3
                               for v in cpuf_i])[None, :, None, None])
    mem_power = np.array([(v / MAX_MEMF) ** 1.1
                          for v in memf_i])[None, None, None, :]
    p = (w.p_idle
         + w.p_gpu * (0.35 + 0.65 * util) * f_gpu_power[..., None] * (0.4 + 0.6 * f_gpu)
         + w.p_cpu * f_cpu_power[..., None] * (0.5 + 0.5 * f_cpu)
         + w.p_mem * mem_power[..., None] * (0.5 + 0.5 * f_mem))
    p = p * pert_power[..., None]
    return t, p


def materialize(device: DeviceModel, w: WorkloadProfile, space: PowerModeSpace,
                batch_sizes: Optional[Sequence[int]] = None) -> ObservationGrid:
    """Dense ground-truth grid for one workload: every mode in ``space``
    (x every batch size, for inference grids). Flattening follows
    ``space.all_modes()`` mode-major / bs-minor order — exactly the insertion
    order of the scalar oracle's observation dicts."""
    modes = space.all_modes()
    if type(device) is DeviceModel and isinstance(modes[0], PowerMode):
        if batch_sizes is None:
            bs_eff = np.array([float(w.train_bs)])
        else:
            bs_eff = np.array([float(b) for b in batch_sizes])
        t, p = _dense_closed_form(w, space, bs_eff)
        t = t.reshape(len(modes), -1)
        p = p.reshape(len(modes), -1)
    else:
        # exotic device model (subclass / TPU adapter): fall back to one
        # scalar call per grid point — still a one-off, amortized over every
        # problem configuration solved against the grid.
        bss = [None] if batch_sizes is None else list(batch_sizes)
        t = np.empty((len(modes), len(bss)))
        p = np.empty((len(modes), len(bss)))
        for i, pm in enumerate(modes):
            for j, b in enumerate(bss):
                t[i, j], p[i, j] = device.time_power(w, pm, b)
    if batch_sizes is None:
        return ObservationGrid(modes, t[:, 0], p[:, 0])
    B = t.shape[1]
    flat_modes = [pm for pm in modes for _ in range(B)]
    bs = np.tile(np.asarray(batch_sizes, np.int64), len(modes))
    return ObservationGrid(flat_modes, t.reshape(-1), p.reshape(-1), bs)


# ---------------------------------------------------------------------------
# batched solvers (NumPy baseline)
# ---------------------------------------------------------------------------

def _chunks(n_problems: int, n_obs: int):
    step = max(1, CHUNK_ELEMS // max(n_obs, 1))
    for s in range(0, n_problems, step):
        yield s, min(n_problems, s + step)


def _pad_problems(*arrs: np.ndarray) -> tuple[np.ndarray, ...]:
    """Pad problem-axis arrays to a power-of-two row count (floor 8) by
    repeating the last row. Full chunks share one jit compilation already;
    this buckets the ragged *final* chunk of each sweep too, so the jax
    kernels compile O(log) distinct shapes instead of one per sweep size.
    Padded rows are duplicated real problems — callers slice kernel outputs
    back to the true row count and never read the padding's answers."""
    m = arrs[0].shape[0]
    m_pad = max(8, 1 << max(0, m - 1).bit_length())
    if m_pad == m:
        return arrs
    return tuple(np.concatenate([a, np.repeat(a[-1:], m_pad - m, axis=0)])
                 for a in arrs)


def _problem_cols(problems, *fields) -> list[np.ndarray]:
    return [np.fromiter((getattr(pr, f) for pr in problems),
                        np.float64, len(problems)) for f in fields]


def _staircase(obj: np.ndarray, p: np.ndarray,
               subset: Optional[np.ndarray] = None):
    """Pareto staircase of (objective, power): entries sorted by (obj,
    original index) whose power strictly improves the running minimum.

    The scalar solvers pick the min-objective entry among those with
    p <= budget, first occurrence on ties; any such entry is on the
    staircase (a dominated entry has an earlier-ordered entry with power
    <= its own, hence also feasible with smaller-or-equal objective). Since
    staircase power is strictly decreasing, the answer for a budget is the
    *first* staircase entry with p <= budget — one binary search.
    Returns (flat indices, staircase powers, staircase objectives)."""
    idx = np.arange(len(obj)) if subset is None else subset
    if idx.size == 0:
        return idx, np.empty(0), np.empty(0)
    order = idx[np.argsort(obj[idx], kind="stable")]
    ps = p[order]
    keep = np.empty(ps.size, dtype=bool)
    keep[0] = True
    keep[1:] = ps[1:] < np.minimum.accumulate(ps)[:-1]
    sidx = order[keep]
    return sidx, ps[keep], obj[sidx]


def solve_train_batch(problems: Sequence[P.TrainProblem],
                      obs: Union[dict, ObservationGrid],
                      backend: str = "numpy") -> list[Optional[P.Solution]]:
    """Batched ``problem.solve_train``: argmax theta_tr s.t. p <= p-hat for
    every problem at once. Returns one Optional[Solution] per problem,
    bitwise identical to the scalar loop."""
    check_backend(backend, ("numpy", "jax"))
    grid = as_train_grid(obs)
    out: list[Optional[P.Solution]] = [None] * len(problems)
    if not len(grid) or not len(problems):
        return out
    budgets, = _problem_cols(problems, "power_budget")
    if backend == "jax":
        kern = _jax_kernels()["train"]
        for s, e in _chunks(len(problems), len(grid)):
            bud, = _pad_problems(budgets[s:e])
            idx, ok = kern(grid.t, grid.p, bud)
            for k in np.flatnonzero(ok[:e - s]):
                i = int(idx[k])
                t = float(grid.t[i])
                out[s + k] = P.Solution(pm=grid.modes[i], time=t,
                                        power=float(grid.p[i]),
                                        throughput=1.0 / t)
        return out
    if "train" not in grid._stairs:
        sidx, sp, _ = _staircase(grid.t, grid.p)
        grid._stairs["train"] = (sidx, sp)
    sidx, sp = grid._stairs["train"]
    pos = np.searchsorted(-sp, -budgets, side="left")
    for k in np.flatnonzero(pos < sidx.size):
        i = int(sidx[pos[k]])
        t = float(grid.t[i])
        out[k] = P.Solution(pm=grid.modes[i], time=t, power=float(grid.p[i]),
                            throughput=1.0 / t)
    return out


def solve_infer_batch(problems: Sequence[P.InferProblem],
                      obs: Union[dict, ObservationGrid],
                      backend: str = "numpy") -> list[Optional[P.Solution]]:
    """Batched ``problem.solve_infer``: argmin peak latency s.t. power,
    latency, and sustainability constraints, over a batch of problems."""
    check_backend(backend, ("numpy", "jax"))
    grid = as_infer_grid(obs)
    out: list[Optional[P.Solution]] = [None] * len(problems)
    if not len(grid) or not len(problems):
        return out
    pb, lb, ar = _problem_cols(problems, "power_budget", "latency_budget",
                               "arrival_rate")
    bsf = grid.bs.astype(np.float64)
    if backend == "jax":
        kern = _jax_kernels()["infer"]
        for s, e in _chunks(len(problems), len(grid)):
            pbc, lbc, arc = _pad_problems(pb[s:e], lb[s:e], ar[s:e])
            idx, ok, lam_sel = kern(grid.t, grid.p, bsf, pbc, lbc, arc)
            for k in np.flatnonzero(ok[:e - s]):
                i = int(idx[k])
                out[s + k] = P.Solution(pm=grid.modes[i], bs=int(grid.bs[i]),
                                        time=float(lam_sel[k, i]),
                                        power=float(grid.p[i]))
        return out
    # group problems by arrival rate: peak latency and sustainability depend
    # on the rate alone, so each distinct rate needs one staircase over the
    # sustainable entries and each problem one binary search.
    rates, inverse = np.unique(ar, return_inverse=True)
    for ri in range(rates.size):
        rate = rates[ri]
        sel = np.flatnonzero(inverse == ri)
        key = ("infer", float(rate))
        if key not in grid._stairs:
            if len(grid._stairs) > 256:     # bound memoization growth
                grid._stairs.clear()
            lam_all = (bsf - 1.0) / rate + grid.t
            sustainable = np.flatnonzero(grid.t <= bsf / rate)
            grid._stairs[key] = (*_staircase(lam_all, grid.p, sustainable),
                                 lam_all)
        sidx, sp, slam, lam = grid._stairs[key]
        if not sidx.size:
            continue
        pos = np.searchsorted(-sp, -pb[sel], side="left")
        safe = np.minimum(pos, sidx.size - 1)
        ok = (pos < sidx.size) & (slam[safe] <= lb[sel])
        for j in np.flatnonzero(ok):
            i = int(sidx[pos[j]])
            out[sel[j]] = P.Solution(pm=grid.modes[i], bs=int(grid.bs[i]),
                                     time=float(lam[i]),
                                     power=float(grid.p[i]))
    return out


def solve_infer_fleet_batch(problems: Sequence[P.InferProblem],
                            rate_his: Sequence[float],
                            obs: Union[dict, ObservationGrid],
                            time_scales: Sequence[float],
                            power_scales: Sequence[float],
                            backend: str = "numpy"
                            ) -> list[Optional[P.Solution]]:
    """Batched ``problem.solve_infer_interval`` across K heterogeneous
    devices sharing one *base* observation grid: device k's grid is the base
    grid scaled elementwise by its ``(time_scales[k], power_scales[k])``
    (the ``PerturbedDeviceModel`` law — same IEEE multiply as profiling the
    device point by point, so results are bitwise equal to the scalar solve
    over each device's own dict). Row k solves ``problems[k]`` against
    device k: sustainability at ``max(rate_his[k], arrival_rate)``, latency
    budget and objective at the problem's (low-end) rate. Every problem
    column — including ``power_budget`` — is per-row, which is how the
    fleet's shared power cap threads through: ``FleetSpec.fleet_power_budget``
    water-fills one cap into per-device budgets and each device's grant
    lands in its problem row. The fleet planner solves all K per-device
    windows with one call per window."""
    check_backend(backend, ("numpy", "jax"))
    grid = as_infer_grid(obs)
    out: list[Optional[P.Solution]] = [None] * len(problems)
    if not len(grid) or not len(problems):
        return out
    n = len(problems)
    if not (len(rate_his) == len(time_scales) == len(power_scales) == n):
        raise ValueError("rate_his / time_scales / power_scales must align "
                         "with the problems")
    pb, lb, ar = _problem_cols(problems, "power_budget", "latency_budget",
                               "arrival_rate")
    hi = np.maximum(np.asarray(rate_his, np.float64), ar)
    ts = np.asarray(time_scales, np.float64)
    ps = np.asarray(power_scales, np.float64)
    bsf = grid.bs.astype(np.float64)
    if backend == "jax":
        kern = _jax_kernels()["fleet"]
        t_dev, p_dev, bsf_dev = device_grid_arrays(grid)
        for s, e in _chunks(n, len(grid)):
            pbc, lbc, arc, hic, tsc, psc = _pad_problems(
                pb[s:e], lb[s:e], ar[s:e], hi[s:e], ts[s:e], ps[s:e])
            idx, ok, lam_sel = kern(t_dev, p_dev, bsf_dev, pbc, lbc, arc,
                                    hic, tsc, psc)
            for k in np.flatnonzero(ok[:e - s]):
                i = int(idx[k])
                out[s + k] = P.Solution(pm=grid.modes[i], bs=int(grid.bs[i]),
                                        time=float(lam_sel[k, i]),
                                        power=float(grid.p[i] * ps[s + k]))
        return out
    # the rate-grouped staircase trick does not survive per-device time
    # scales (each device reorders the Pareto set); a chunked dense masked
    # argmin is still one array program per window for the whole fleet.
    for s, e in _chunks(n, len(grid)):
        t_k = grid.t[None, :] * ts[s:e, None]
        p_k = grid.p[None, :] * ps[s:e, None]
        lam = (bsf[None, :] - 1.0) / ar[s:e, None] + t_k
        feas = ((p_k <= pb[s:e, None])
                & (t_k <= bsf[None, :] / hi[s:e, None])
                & (lam <= lb[s:e, None]))
        lam_sel = np.where(feas, lam, np.inf)
        idx = np.argmin(lam_sel, axis=1)
        for k in np.flatnonzero(feas.any(axis=1)):
            i = int(idx[k])
            out[s + k] = P.Solution(pm=grid.modes[i], bs=int(grid.bs[i]),
                                    time=float(lam[k, i]),
                                    power=float(p_k[k, i]))
    return out


def _align_train(infer_grid: ObservationGrid, train_grid: ObservationGrid):
    """Per-infer-entry train observations; entries whose mode is absent from
    the train grid are masked out (the scalar loop skips them)."""
    tindex = train_grid.index
    pos = np.fromiter((tindex.get(pm, -1) for pm in infer_grid.modes),
                      np.int64, len(infer_grid))
    valid = pos >= 0
    safe = np.maximum(pos, 0)
    t_tr = np.where(valid, train_grid.t[safe], np.nan)
    p_tr = np.where(valid, train_grid.p[safe], np.nan)
    return t_tr, p_tr, valid


def solve_concurrent_batch(problems: Sequence[P.ConcurrentProblem],
                           train_obs: Union[dict, ObservationGrid],
                           infer_obs: Union[dict, ObservationGrid],
                           backend: str = "numpy") -> list[Optional[P.Solution]]:
    """Batched ``problem.solve_concurrent``: lexicographic argmax of
    (training throughput, -peak latency) under the interleaving feasibility
    mask, for every problem at once."""
    check_backend(backend, ("numpy", "jax"))
    tg = as_train_grid(train_obs)
    ig = as_infer_grid(infer_obs)
    out: list[Optional[P.Solution]] = [None] * len(problems)
    if not len(tg) or not len(ig) or not len(problems):
        return out
    pb, lb, ar = _problem_cols(problems, "power_budget", "latency_budget",
                               "arrival_rate")
    t_tr, p_tr, valid = _align_train(ig, tg)
    with np.errstate(invalid="ignore"):
        pmax = np.maximum(ig.p, p_tr)
    bsf = ig.bs.astype(np.float64)
    if backend == "jax":
        kern = _jax_kernels()["concurrent"]
        for s, e in _chunks(len(problems), len(ig)):
            pbc, lbc, arc = _pad_problems(pb[s:e], lb[s:e], ar[s:e])
            idx, ok, tau_c, theta_c, lam_c = kern(
                ig.t, bsf, t_tr, pmax, valid, pbc, lbc, arc)
            for k in np.flatnonzero(ok[:e - s]):
                i = int(idx[k])
                out[s + k] = P.Solution(
                    pm=ig.modes[i], bs=int(ig.bs[i]), tau_tr=int(tau_c[k, i]),
                    time=float(lam_c[k, i]), power=float(pmax[i]),
                    throughput=float(theta_c[k, i]))
        return out
    # group by arrival rate: tau/theta/lam and sustainability depend only on
    # the rate, so compute them once per distinct rate over the (compressed)
    # sustainable candidate set; only the power/latency mask is per problem.
    rates, inverse = np.unique(ar, return_inverse=True)
    for ri in range(rates.size):
        rate = rates[ri]
        sel = np.flatnonzero(inverse == ri)
        cycle = bsf / rate
        cand = np.flatnonzero(valid & (ig.t <= cycle))  # original order kept
        if not cand.size:
            continue
        cyc = cycle[cand]
        lam = (bsf[cand] - 1.0) / rate + ig.t[cand]
        tau = np.maximum(np.floor((cyc - ig.t[cand]) / t_tr[cand]), 0.0)
        theta = tau / cyc
        pm_c = pmax[cand]
        for s, e in _chunks(sel.size, cand.size):
            rows = sel[s:e]
            feas = ((pm_c[None, :] <= pb[rows, None])
                    & (lam[None, :] <= lb[rows, None]))
            th = np.where(feas, theta[None, :], -np.inf)
            best = th.max(axis=1)
            lam_masked = np.where(feas & (th >= best[:, None]), lam, np.inf)
            idx = np.argmin(lam_masked, axis=1)
            for k in np.flatnonzero(feas.any(axis=1)):
                j = int(idx[k])
                i = int(cand[j])
                out[rows[k]] = P.Solution(
                    pm=ig.modes[i], bs=int(ig.bs[i]), tau_tr=int(tau[j]),
                    time=float(lam[j]), power=float(pmax[i]),
                    throughput=float(theta[j]))
    return out


# ---------------------------------------------------------------------------
# multi-tenant: N inference streams + optional training fill (problem.
# solve_multi_tenant batched). Candidates are the cross-product of per-stream
# (pm, bs) grid entries sharing one mode, enumerated stream-0-major in grid
# order — the scalar reference's exact scan (and tie-break) order.
# ---------------------------------------------------------------------------

_MISS = object()


class _MultiCandidates:
    """Columnar joint candidate set for one (stream grids, specs) tuple."""

    def __init__(self, grids: Sequence[ObservationGrid],
                 train_grid: Optional[ObservationGrid],
                 specs: Sequence) -> None:
        n = len(grids)
        masks = []
        for g, spec in zip(grids, specs):
            if spec.batch_sizes is None:
                masks.append(None)
            else:
                allowed = set(int(b) for b in spec.batch_sizes)
                masks.append(np.fromiter((int(b) in allowed for b in g.bs),
                                         bool, len(g)))
        # streams 1..n-1: {pm: [flat indices]} in grid order
        by_pm: list[dict] = []
        for g, m in zip(grids[1:], masks[1:]):
            d: dict = {}
            for i in range(len(g)):
                if m is None or m[i]:
                    d.setdefault(g.modes[i], []).append(i)
            by_pm.append(d)
        tindex = None if train_grid is None else train_grid.index
        inner_cache: dict = {}
        cols: list[list] = [[] for _ in range(n)]
        g0, m0 = grids[0], masks[0]
        for i in range(len(g0)):
            if m0 is not None and not m0[i]:
                continue
            pm = g0.modes[i]
            if tindex is not None and pm not in tindex:
                continue
            blk = inner_cache.get(pm, _MISS)
            if blk is _MISS:
                lists = [d.get(pm) for d in by_pm]
                if any(ls is None for ls in lists):
                    blk = None
                else:
                    mesh = np.meshgrid(*[np.asarray(ls, np.int64)
                                         for ls in lists], indexing="ij") \
                        if lists else []
                    blk = [mg.ravel() for mg in mesh]
                inner_cache[pm] = blk
            if blk is None:
                continue
            width = blk[0].size if blk else 1
            cols[0].append(np.full(width, i, np.int64))
            for j, b in enumerate(blk):
                cols[j + 1].append(b)
        if cols[0]:
            self.idx = [np.concatenate(c) for c in cols]
        else:
            self.idx = [np.empty(0, np.int64) for _ in range(n)]
        K = self.idx[0].size
        self.K, self.n = K, n
        self.modes = [grids[0].modes[int(i)] for i in self.idx[0]]
        self.t_in = np.empty((K, n))
        self.bsf = np.empty((K, n))
        self.bss = np.empty((K, n), np.int64)
        pmax = np.full(K, -np.inf)
        for j, g in enumerate(grids):
            ix = self.idx[j]
            self.t_in[:, j] = g.t[ix]
            self.bss[:, j] = g.bs[ix]
            self.bsf[:, j] = self.bss[:, j].astype(np.float64)
            pmax = np.maximum(pmax, g.p[ix])
        if train_grid is not None:
            tpos = np.fromiter((tindex[pm] for pm in self.modes), np.int64, K)
            self.t_tr = train_grid.t[tpos]
            pmax = np.maximum(pmax, train_grid.p[tpos])
        else:
            self.t_tr = None
        self.pmax = pmax


def _multi_spec_key(specs) -> tuple:
    """The per-stream structure that must be uniform across a problem batch:
    the observation sets are shared, so workloads and allowed batch sizes
    must match (rates and budgets may vary)."""
    return tuple((getattr(s.workload, "name", s.workload),
                  None if s.batch_sizes is None else tuple(s.batch_sizes))
                 for s in specs)


def _multi_rate_arrays(cand: "_MultiCandidates", rates: np.ndarray):
    """(sustainable candidate subset, per-candidate lam/tau/theta) for one
    per-stream rate vector — the rate-independent part of the reduction.
    Replays problem.multi_* op-for-op (single stream = the pair exprs)."""
    t_in, bsf, n = cand.t_in, cand.bsf, cand.n
    cycle = bsf / rates[None, :]
    sus = (t_in <= cycle).all(axis=1)
    if n == 1:
        base = cycle[:, 0]
        slack = base - t_in[:, 0]
        lam = (bsf - 1.0) / rates[None, :] + t_in
    else:
        base = cycle.min(axis=1)
        busy = np.zeros(cand.K)
        for j in range(n):
            busy += t_in[:, j] * (base * rates[j] / bsf[:, j])
        slack = base - busy
        sus &= slack >= 0.0
        total = np.zeros(cand.K)
        for j in range(n):
            total += t_in[:, j]
        lam = (bsf - 1.0) / rates[None, :] + t_in
        lam = lam + (total[:, None] - t_in)
    keep = np.flatnonzero(sus)
    if cand.t_tr is not None:
        tau = np.maximum(np.floor(slack[keep] / cand.t_tr[keep]), 0.0)
        theta = tau / base[keep]
    else:
        tau = theta = None
    return keep, lam[keep], tau, theta


def solve_multi_tenant_batch(problems: Sequence["P.MultiTenantProblem"],
                             train_obs: Optional[Union[dict, ObservationGrid]],
                             infer_obs: Sequence[Union[dict, ObservationGrid]],
                             backend: str = "numpy"
                             ) -> list[Optional["P.MultiTenantSolution"]]:
    """Batched ``problem.solve_multi_tenant``: every problem must share the
    stream count, train flag, and per-stream batch-size restrictions; rates,
    latency budgets, and power budgets vary per problem."""
    check_backend(backend, ("numpy", "jax"))
    out: list[Optional[P.MultiTenantSolution]] = [None] * len(problems)
    if not len(problems):
        return out
    p0 = problems[0]
    n = p0.n_streams
    if len(infer_obs) != n:
        raise ValueError(f"expected {n} observation sets, got {len(infer_obs)}")
    skey = _multi_spec_key(p0.streams)
    for pr in problems:
        if pr.n_streams != n or pr.train != p0.train \
                or _multi_spec_key(pr.streams) != skey \
                or pr.priorities != p0.priorities:
            raise ValueError("solve_multi_tenant_batch needs a uniform "
                             "stream shape (count, train flag, workloads, "
                             "batch sizes, priorities) across the problem "
                             "batch")
    weights = p0.priority_weights()
    grids = [as_infer_grid(o) for o in infer_obs]
    tg = as_train_grid(train_obs) if p0.train else None
    if any(not len(g) for g in grids) or (tg is not None and not len(tg)):
        return out
    cand = _MultiCandidates(grids, tg, p0.streams)
    if not cand.K:
        return out
    pb = np.fromiter((pr.power_budget for pr in problems), np.float64,
                     len(problems))
    ar = np.array([[s.arrival_rate for s in pr.streams] for pr in problems])
    lb = np.array([[s.latency_budget for s in pr.streams] for pr in problems])
    if backend == "jax":
        return _solve_multi_jax(problems, cand, pb, ar, lb, out, weights)
    rates, inverse = np.unique(ar, axis=0, return_inverse=True)
    inverse = inverse.reshape(-1)
    for ri in range(rates.shape[0]):
        sel = np.flatnonzero(inverse == ri)
        keep, lam, tau, theta = _multi_rate_arrays(cand, rates[ri])
        if not keep.size:
            continue
        pm_c = cand.pmax[keep]
        # the priority-weighted worst-latency secondary objective (scalar:
        # max_j(w_j * lam_j)); unset priorities apply no multiplication at
        # all — the bitwise-default contract
        worst = lam.max(axis=1) if weights is None \
            else (lam * np.asarray(weights, np.float64)[None, :]).max(axis=1)
        for s, e in _chunks(sel.size, keep.size * n):
            rows = sel[s:e]
            feas = ((pm_c[None, :] <= pb[rows, None])
                    & (lam[None, :, :] <= lb[rows, None, :]).all(axis=2))
            if theta is not None:
                th = np.where(feas, theta[None, :], -np.inf)
                best = th.max(axis=1)
                masked = np.where(feas & (th >= best[:, None]), worst, np.inf)
            else:
                masked = np.where(feas, worst, np.inf)
            idx = np.argmin(masked, axis=1)
            for k in np.flatnonzero(feas.any(axis=1)):
                j = int(idx[k])
                i = int(keep[j])
                out[rows[k]] = P.MultiTenantSolution(
                    pm=cand.modes[i], bss=tuple(int(b) for b in cand.bss[i]),
                    tau_tr=None if tau is None else int(tau[j]),
                    times=tuple(float(x) for x in lam[j]),
                    power=float(cand.pmax[i]),
                    throughput=0.0 if theta is None else float(theta[j]))
    return out


def _solve_multi_jax(problems, cand: "_MultiCandidates", pb, ar, lb, out,
                     weights=None):
    kern = _jax_kernels()["multi_train" if cand.t_tr is not None
                         else "multi_infer"]
    # unit weights reproduce the unweighted objective bitwise (1.0 * x == x
    # in IEEE-754), so the kernel always takes a weight vector
    wts = np.ones(cand.n) if weights is None \
        else np.asarray(weights, np.float64)
    args = (cand.t_in, cand.bsf, cand.pmax) + (
        (cand.t_tr,) if cand.t_tr is not None else ()) + (wts,)
    for s, e in _chunks(len(problems), cand.K * cand.n):
        pbc, arc, lbc = _pad_problems(pb[s:e], ar[s:e], lb[s:e])
        idx, ok, tau_s, theta_s, lam_s = kern(*args, pbc, arc, lbc)
        for k in np.flatnonzero(ok[:e - s]):
            i = int(idx[k])
            out[s + k] = P.MultiTenantSolution(
                pm=cand.modes[i], bss=tuple(int(b) for b in cand.bss[i]),
                tau_tr=None if cand.t_tr is None else int(tau_s[k]),
                times=tuple(float(x) for x in lam_s[k]),
                power=float(cand.pmax[i]),
                throughput=float(theta_s[k]))
    return out


# ---------------------------------------------------------------------------
# jax backend: jit + vmap over the problem axis, float64 via enable_x64 so
# the on-accelerator reduction keeps the NumPy path's exactness
# ---------------------------------------------------------------------------

_JAX_CACHE: dict = {}

# retrace counter, bumped inside the traced kernel bodies (fires at
# compile time only). Mirrors simulate.engine_trace_count().
_TRACE_COUNTS = {"solver": 0}


def solver_trace_count() -> int:
    """Number of solver-kernel (re)traces since import (all six kernels)."""
    return _TRACE_COUNTS["solver"]


def device_grid_arrays(grid: ObservationGrid) -> tuple:
    """Device-resident copies of a grid's ``(t, p, bs-as-float64)`` columns,
    uploaded once per grid instance and memoized on it (the cache dies with
    the grid, like ``_stairs``). Before this, every jax fleet-solver call —
    four per fleet window — re-transferred the same NumPy columns through
    ``jnp.asarray``; passing these committed arrays makes that a no-op, and
    the fused fleet-window program keys its per-window launches on them."""
    cache = grid.__dict__.get("_device_cols")
    if cache is None:
        _jax, jnp, enable_x64 = require_jax()
        with enable_x64():
            cache = (jnp.asarray(grid.t), jnp.asarray(grid.p),
                     jnp.asarray(grid.bs.astype(np.float64)))
        grid.__dict__["_device_cols"] = cache
    return cache


def _jax_kernels() -> dict:
    if _JAX_CACHE:
        return _JAX_CACHE
    jax, jnp, enable_x64 = require_jax()

    @jax.jit
    def train_kernel(t, p, budgets):
        _TRACE_COUNTS["solver"] += 1           # fires at trace time only
        def one(b):
            feas = p <= b
            masked = jnp.where(feas, t, jnp.inf)
            return jnp.argmin(masked), feas.any()
        return jax.vmap(one)(budgets)

    @jax.jit
    def infer_kernel(t, p, bsf, pb, lb, ar):
        _TRACE_COUNTS["solver"] += 1
        def one(b_p, b_l, b_a):
            lam = (bsf - 1.0) / b_a + t
            feas = (p <= b_p) & (t <= bsf / b_a) & (lam <= b_l)
            lam_sel = jnp.where(feas, lam, jnp.inf)
            return jnp.argmin(lam_sel), feas.any(), lam_sel
        return jax.vmap(one)(pb, lb, ar)

    @jax.jit
    def concurrent_kernel(t_in, bsf, t_tr, pmax, valid, pb, lb, ar):
        _TRACE_COUNTS["solver"] += 1
        def one(b_p, b_l, b_a):
            cycle = bsf / b_a
            lam = (bsf - 1.0) / b_a + t_in
            feas = (valid & (pmax <= b_p) & (t_in <= cycle) & (lam <= b_l))
            tau = jnp.where(
                feas, jnp.maximum(jnp.floor((cycle - t_in) / t_tr), 0.0), 0.0)
            theta = jnp.where(feas, tau / cycle, -jnp.inf)
            best = theta.max()
            lam_masked = jnp.where(feas & (theta >= best), lam, jnp.inf)
            return jnp.argmin(lam_masked), feas.any(), tau, theta, lam
        return jax.vmap(one)(pb, lb, ar)

    @jax.jit
    def fleet_kernel(t, p, bsf, pb, lb, ar, hi, ts, ps):
        _TRACE_COUNTS["solver"] += 1
        def one(b_p, b_l, b_a, b_h, k_t, k_p):
            # device row: the base grid scaled by this device's (time,
            # power) factors — the PerturbedDeviceModel law
            tk = t * k_t
            pk = p * k_p
            lam = (bsf - 1.0) / b_a + tk
            feas = (pk <= b_p) & (tk <= bsf / b_h) & (lam <= b_l)
            lam_sel = jnp.where(feas, lam, jnp.inf)
            return jnp.argmin(lam_sel), feas.any(), lam_sel
        return jax.vmap(one)(pb, lb, ar, hi, ts, ps)

    def _multi_one(t_in, bsf, pmax, t_tr, wts, b_p, b_a, b_l):
        n = t_in.shape[1]
        cycle = bsf / b_a[None, :]
        sus = (t_in <= cycle).all(axis=1)
        if n == 1:
            base = cycle[:, 0]
            slack = base - t_in[:, 0]
            lam = (bsf - 1.0) / b_a[None, :] + t_in
        else:
            base = cycle.min(axis=1)
            busy = jnp.zeros(t_in.shape[0])
            total = jnp.zeros(t_in.shape[0])
            for j in range(n):        # stream order, as the scalar reference
                busy = busy + t_in[:, j] * (base * b_a[j] / bsf[:, j])
                total = total + t_in[:, j]
            slack = base - busy
            sus = sus & (slack >= 0.0)
            lam = (bsf - 1.0) / b_a[None, :] + t_in
            lam = lam + (total[:, None] - t_in)
        feas = sus & (pmax <= b_p) & (lam <= b_l[None, :]).all(axis=1)
        worst = (lam * wts[None, :]).max(axis=1)
        if t_tr is None:
            tau = jnp.zeros(t_in.shape[0])
            theta = jnp.zeros(t_in.shape[0])
            masked = jnp.where(feas, worst, jnp.inf)
        else:
            tau = jnp.where(
                feas, jnp.maximum(jnp.floor(slack / t_tr), 0.0), 0.0)
            theta = jnp.where(feas, tau / base, -jnp.inf)
            best = theta.max()
            masked = jnp.where(feas & (theta >= best), worst, jnp.inf)
        i = jnp.argmin(masked)
        return i, feas.any(), tau[i], theta[i], lam[i]

    @jax.jit
    def multi_train_kernel(t_in, bsf, pmax, t_tr, wts, pb, ar, lb):
        _TRACE_COUNTS["solver"] += 1
        return jax.vmap(lambda p, a, l: _multi_one(
            t_in, bsf, pmax, t_tr, wts, p, a, l))(pb, ar, lb)

    @jax.jit
    def multi_infer_kernel(t_in, bsf, pmax, wts, pb, ar, lb):
        _TRACE_COUNTS["solver"] += 1
        return jax.vmap(lambda p, a, l: _multi_one(
            t_in, bsf, pmax, None, wts, p, a, l))(pb, ar, lb)

    def x64(fn):
        def wrapped(*args):
            record_dispatch("solver")
            with enable_x64():
                res = fn(*[jnp.asarray(a) for a in args])
            return tuple(np.asarray(r) for r in res)
        return wrapped

    _JAX_CACHE.update({"train": x64(train_kernel),
                       "infer": x64(infer_kernel),
                       "fleet": x64(fleet_kernel),
                       "concurrent": x64(concurrent_kernel),
                       "multi_train": x64(multi_train_kernel),
                       "multi_infer": x64(multi_infer_kernel)})
    return _JAX_CACHE
