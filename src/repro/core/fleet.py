"""Fleet-scale serving (``Scenario.FLEET``): K devices as one batched program.

PR 7 scaled the *engine* to 10^5 lanes; this tier scales the whole serving
loop. A fleet is K heterogeneous edge devices (``fleet_device`` — the base
Orin model with deterministic per-device time/power multipliers) fed by one
aggregate arrival stream. Each window:

 1. **dispatch** — the window's aggregate arrivals are split across devices
    by deterministic weighted round-robin: each arrival, in time order, goes
    to the device minimizing ``(n_d + 1) / w_d`` (ties to the lowest index),
    where ``n_d`` counts this window's assignments so far. ``"capacity"``
    starts every window's counts at zero with ``w_d = 1 / time_scale_d``
    (faster devices take proportionally more); ``"least-backlog"`` seeds the
    counts with each device's carried backlog (join-the-shortest-queue
    flavor). The dispatched window keeps provenance: the merged trace's
    ``stream_ids`` are device indices, so ``ArrivalTrace.split`` recovers
    exactly the per-device traces that ran.
 2. **plan** — the K per-device closed-loop controller windows run the PR-5/6
    ladder (EWMA rate estimate, feedback-scaled budget, burst quantile,
    interval solve -> high-rate fallback -> estimate -> nominal-budget retry),
    but each rung is ONE ``grid_eval.solve_infer_fleet_batch`` call over the
    still-unsolved devices: every device's observation grid is the shared
    base grid scaled by its (time, power) multipliers, so the K problems
    stack into one masked-argmin array program per rung.
 3. **execute** — all solved devices run as one ``simulate_batch`` call
    (devices ARE lanes; PR 7's chunked max-plus dispatch does the rest),
    each with its own carried ``QueueState``; reports fold back into the
    per-device controller states.

Correctness contract (enforced by ``tests/test_fleet.py``):
``serve_fleet`` is **bitwise identical on NumPy** (tolerance-identical on
jax, like the engine itself) to ``serve_fleet_sequential`` — K independent
single-device closed loops of the existing kind, run one after another over
the same split traces. The batched solver rungs replay the scalar solvers'
float ops over per-device scaled grids (``solve_infer_fleet_batch``'s
contract), ``FleetControllerState`` holds exactly the K scalar controller
states, and the batched engine's NumPy path runs the identical per-lane
kernel — so the fleet tier adds speed, never drift.

Single-device refinements that re-enter the controller mid-window
(admission trimming, backlog splits, ``degrade-bs``) are not fleet-batched;
configs requesting them are rejected rather than silently ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import problem as P
from repro.core.backend import resolve_backend
from repro.core.controller import (ControllerConfig, ControllerState,
                                   FleetControllerState)
from repro.core.device_model import (DeviceModel, PerturbedDeviceModel,
                                     WorkloadProfile, fleet_device)
from repro.core.grid_eval import materialize, solve_infer_fleet_batch
from repro.core.powermode import PowerModeSpace
from repro.core.simulate import ArrivalTrace, simulate, simulate_batch

_DISPATCHES = ("capacity", "least-backlog")


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """One fleet: how many devices, how they differ, and how arrivals are
    dispatched. Heterogeneity is sampled deterministically per (seed, index)
    via collision-free draws (``device_model._device_pert``), so a spec
    names the same fleet in every process."""
    n_devices: int
    seed: int = 0
    time_spread: float = 0.10     # per-device service-time spread (+-)
    power_spread: float = 0.05    # per-device power spread (+-)
    dispatch: str = "capacity"    # "capacity" | "least-backlog"

    def __post_init__(self):
        if self.n_devices <= 0:
            raise ValueError("a fleet needs at least one device")
        if not 0.0 <= self.time_spread < 1.0 \
                or not 0.0 <= self.power_spread < 1.0:
            raise ValueError("spreads must be in [0, 1)")
        if self.dispatch not in _DISPATCHES:
            raise ValueError(f"unknown dispatch policy {self.dispatch!r}; "
                             f"use {_DISPATCHES}")

    def devices(self) -> list[PerturbedDeviceModel]:
        return [fleet_device(d, self.seed, self.time_spread,
                             self.power_spread)
                for d in range(self.n_devices)]


@dataclasses.dataclass
class FleetWindowReport:
    """One fleet window: the per-device ``WindowReport``s (scheduler-shaped,
    index = device) plus the fleet-level dispatch and goodput account.
    ``trace`` is the dispatched aggregate window — ``trace.split(K)``
    recovers each device's arrivals (provenance round-trip)."""
    rate: float                       # aggregate announced rate
    devices: list                     # one WindowReport per device
    trace: ArrivalTrace               # merged; stream_ids = device indices
    dispatch_counts: np.ndarray       # arrivals dispatched per device
    offered_requests: int
    goodput: float                    # fleet-wide in-budget served / offered

    @property
    def attributed_power(self) -> float:
        """Summed per-device attributed power (satellite of the per-tenant
        attribution account): each executed report's time-weighted share —
        idle devices attribute 0, so this is the fleet's busy power."""
        return float(sum(wr.report.attributed_power or 0.0
                         for wr in self.devices if wr.report is not None))


def dispatch_arrivals(times: np.ndarray, weights: np.ndarray,
                      counts0: Optional[np.ndarray] = None) -> np.ndarray:
    """Deterministic weighted round-robin dispatch: arrival k (time order)
    goes to the device minimizing ``(counts0_d + n_d + 1) / w_d`` over the
    running assignment counts ``n_d``, ties to the lowest device index.
    Returns the per-arrival device index vector.

    Implemented as a merge, not a loop: device d's j-th assignment has key
    ``(counts0_d + j + 1) / w_d`` — strictly increasing per device — and the
    greedy order is exactly the first N keys in (key, device) order. Each
    device can own at most ``(N + C + K) * w_d / W - counts0_d`` of the
    first N keys (the N-th smallest key is at most ``(N + C + K) / W``
    with ``C = sum(counts0)``, ``W = sum(w)``), so only ~N + O(K) candidate
    keys are materialized however large K * N is."""
    weights = np.asarray(weights, np.float64)
    K = weights.size
    n = int(np.asarray(times).size)
    if K <= 0:
        raise ValueError("dispatch needs at least one device")
    if np.any(weights <= 0.0):
        raise ValueError("dispatch weights must be positive")
    c0 = np.zeros(K, np.int64) if counts0 is None \
        else np.asarray(counts0, np.int64)
    if c0.size != K:
        raise ValueError("counts0 must align with the weights")
    if n == 0:
        return np.empty(0, np.int64)
    W = float(weights.sum())
    C = int(c0.sum())
    caps = np.ceil((n + C + K) * weights / W).astype(np.int64) - c0 + 2
    caps = np.clip(caps, 0, n)
    keys, devs = [], []
    for d in range(K):
        m = int(caps[d])
        if m <= 0:
            continue
        keys.append((c0[d] + 1.0 + np.arange(m)) / weights[d])
        devs.append(np.full(m, d, np.int64))
    keys = np.concatenate(keys)
    devs = np.concatenate(devs)
    order = np.argsort(keys, kind="stable")   # stable: device-major input,
    return devs[order[:n]]                    # equal keys -> lowest index


def split_window(agg: ArrivalTrace, sid: np.ndarray, n_devices: int,
                 ) -> tuple[ArrivalTrace, list[ArrivalTrace]]:
    """The dispatched forms of one aggregate window: the merged trace with
    device provenance, and the per-device traces (absolute times, so the
    carryover replay contract applies per device)."""
    merged = ArrivalTrace(agg.times, agg.duration, agg.kind,
                          np.asarray(sid, np.int64), int(n_devices))
    return merged, merged.split(n_devices)


def _check_fleet_cfg(cfg: ControllerConfig) -> None:
    if cfg.admission != "none" or cfg.split_backlog is not None:
        raise ValueError(
            "fleet serving batches whole controller windows; admission "
            "trimming and mid-window splits are single-device refinements "
            "(serve them per device via Fulcrum.serve_dynamic)")


def _fleet_scales(spec: FleetSpec) -> tuple[list, np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]:
    """(devices, time_scales, power_scales, weights, shares): dispatch
    weight = 1 / time_scale (a device's service capacity), announced-rate
    share = normalized weight."""
    devs = spec.devices()
    ts = np.array([d.time_scale for d in devs])
    ps = np.array([d.power_scale for d in devs])
    wts = 1.0 / ts
    return devs, ts, ps, wts, wts / wts.sum()


def _window_trace(rate: float, i: int, window_duration: float,
                  arrivals: str, seed: int) -> ArrivalTrace:
    t0 = i * window_duration
    win = (ArrivalTrace.uniform(rate, window_duration)
           if arrivals == "uniform"
           else ArrivalTrace.poisson(rate, window_duration, seed + i))
    return win.shifted(t0)


def _backlog_counts(states: Sequence[ControllerState],
                    cfg: ControllerConfig) -> np.ndarray:
    """Per-device carried-backlog counts (0 with carryover off) — both the
    ``carried_requests`` account and the ``"least-backlog"`` dispatch seed."""
    return np.array([len(st.carry)
                     if cfg.carry_backlog and st.carry is not None else 0
                     for st in states], np.int64)


def _goodput(rep, latency_budget: float, offered: int) -> float:
    good = int(np.count_nonzero(
        np.asarray(rep.latencies, np.float64) <= latency_budget))
    return good / offered if offered else 1.0


def _fleet_report(rate, device_reports, merged, counts,
                  latency_budget) -> FleetWindowReport:
    offered = len(merged)
    good = sum(int(np.count_nonzero(
        np.asarray(wr.report.latencies, np.float64) <= latency_budget))
        for wr in device_reports if wr.report is not None)
    return FleetWindowReport(float(rate), device_reports, merged,
                             counts, offered,
                             good / offered if offered else 1.0)


def serve_fleet(w: WorkloadProfile, power_budget: float,
                latency_budget: float, rates: Sequence[float],
                spec: FleetSpec, window_duration: float = 30.0,
                arrivals: str = "uniform", seed: int = 0,
                backend: Optional[str] = None,
                controller: Optional[ControllerConfig] = None,
                space: Optional[PowerModeSpace] = None,
                ) -> list[FleetWindowReport]:
    """Serve a dynamic aggregate trace on a K-device fleet, stepping all K
    per-device closed-loop windows as one batched program per window: one
    dispatch pass, one batched solve per ladder rung, one ``simulate_batch``
    over the solved devices. Bitwise-identical on NumPy to
    ``serve_fleet_sequential`` (the K independent scalar loops)."""
    cfg = controller if controller is not None else ControllerConfig()
    _check_fleet_cfg(cfg)
    K = spec.n_devices
    devs, ts, ps, wts, shares = _fleet_scales(spec)
    grid = materialize(DeviceModel(), w, space or PowerModeSpace(),
                       P.INFER_BATCH_SIZES)
    eng_backend = resolve_backend(backend)
    sol_backend = "numpy" if eng_backend == "numpy" else "jax"
    state = FleetControllerState(cfg, K)
    prev_keys: list = [None] * K
    out: list[FleetWindowReport] = []
    from repro.core.scheduler import WindowReport
    for i, rate in enumerate(rates):
        t0 = i * window_duration
        agg = _window_trace(float(rate), i, window_duration, arrivals, seed)
        carried = _backlog_counts(state.devices, cfg)
        counts0 = carried if spec.dispatch == "least-backlog" else None
        sid = dispatch_arrivals(agg.times, wts, counts0)
        merged, dtr = split_window(agg, sid, K)
        counts = np.bincount(sid, minlength=K).astype(np.int64)
        announced = float(rate) * shares
        # the PR-5 ladder, vectorized over the device axis: every rung is
        # one batched fleet solve over the still-unsolved devices
        hi = state.plan_rates(announced, t0, window_duration)
        est = state.plan_rates(announced, t0, window_duration,
                               margin=1.0, pressure=False)
        if cfg.burst_quantile > 0.0:
            hi = np.maximum(hi, [P.burst_rate(e, window_duration,
                                              cfg.burst_quantile)
                                 for e in est])
        bud = state.plan_budgets([latency_budget] * K)
        sols: list[Optional[P.Solution]] = [None] * K
        live = est > 0.0            # a zero estimate has no rate to plan at
        unsolved = np.ones(K, bool)

        def rung(mask, rates_lo, budgets, rate_his):
            sel = np.flatnonzero(mask)
            if not sel.size:
                return
            probs = [P.InferProblem(power_budget, float(budgets[d]),
                                    float(rates_lo[d])) for d in sel]
            res = solve_infer_fleet_batch(probs, rate_his[sel], grid,
                                          ts[sel], ps[sel],
                                          backend=sol_backend)
            for d, s in zip(sel, res):
                sols[d] = s
                unsolved[d] = s is None

        # 1. margin headroom: sustainable up to hi, budget held at est
        rung(live & (hi > est), est, bud, hi)
        # 2. dead zone: prefer the high end (see _closed_loop_window)
        rung(live & (hi > est) & unsolved, hi, bud, hi)
        # 3. the point plan at the estimate
        rung(live & unsolved, est, bud, est)
        # 4. feedback tightened into infeasibility: retry at nominal
        nominal = np.full(K, float(latency_budget))
        rung(live & unsolved & (bud < nominal), est, nominal, est)
        lanes = []                  # (device, sol, switch_s)
        for d in range(K):
            if sols[d] is not None:
                switch_s = state.mode_switch(d, sols[d].pm)
                lanes.append((d, sols[d], switch_s))
            else:
                state.observe_unserved(d, dtr[d], window_duration)
        reps = simulate_batch(
            DeviceModel(), None, w,
            [sol.pm for _, sol, _ in lanes],
            [sol.bs for _, sol, _ in lanes],
            [dtr[d] for d, _, _ in lanes],
            tau_caps=[sol.tau_tr for _, sol, _ in lanes],
            backend=eng_backend,
            carry_ins=[state.window_carry_in(d, t0, s)
                       for d, _, s in lanes],
            devices=[devs[d] for d, _, _ in lanes])
        device_reports: list = [None] * K
        for (d, sol, switch_s), rep in zip(lanes, reps):
            offered = len(dtr[d])
            gp = _goodput(rep, latency_budget, offered)
            rep.goodput = gp
            state.observe(d, dtr[d], rep, latency_budget, window_duration,
                          rep.queue_state)
            key = (sol.pm, sol.bs, sol.tau_tr)
            device_reports[d] = WindowReport(
                float(announced[d]), sol, rep,
                estimated_rate=float(est[d]),
                replanned=key != prev_keys[d], mode_switch_s=switch_s,
                carried_requests=int(carried[d]), goodput=gp,
                offered_requests=offered)
            prev_keys[d] = key
        for d in range(K):
            if device_reports[d] is None:
                offered = len(dtr[d])
                device_reports[d] = WindowReport(
                    float(announced[d]), None, None,
                    estimated_rate=float(est[d]),
                    carried_requests=int(carried[d]),
                    goodput=0.0 if offered else 1.0,
                    offered_requests=offered)
        out.append(_fleet_report(rate, device_reports, merged, counts,
                                 latency_budget))
    return out


def serve_fleet_sequential(w: WorkloadProfile, power_budget: float,
                           latency_budget: float, rates: Sequence[float],
                           spec: FleetSpec, window_duration: float = 30.0,
                           arrivals: str = "uniform", seed: int = 0,
                           backend: Optional[str] = None,
                           controller: Optional[ControllerConfig] = None,
                           space: Optional[PowerModeSpace] = None,
                           ) -> list[FleetWindowReport]:
    """The reference: the SAME fleet served as K independent single-device
    closed loops run sequentially — scalar solvers over each device's own
    observation dict, one single-lane engine call per device per window.
    ``serve_fleet`` must match this bitwise on NumPy; benchmarks measure the
    batched speedup against it."""
    cfg = controller if controller is not None else ControllerConfig()
    _check_fleet_cfg(cfg)
    K = spec.n_devices
    devs, ts, ps, wts, shares = _fleet_scales(spec)
    base = materialize(DeviceModel(), w, space or PowerModeSpace(),
                       P.INFER_BATCH_SIZES).to_dict()
    # device d's observation dict: the base grid rescaled entrywise — the
    # same floats a per-device profile of PerturbedDeviceModel would yield
    obs = [{k: (t * ts[d], p * ps[d]) for k, (t, p) in base.items()}
           for d in range(K)]
    states = [ControllerState(cfg, 1) for _ in range(K)]
    prev_keys: list = [None] * K
    out: list[FleetWindowReport] = []
    from repro.core.scheduler import WindowReport
    for i, rate in enumerate(rates):
        t0 = i * window_duration
        agg = _window_trace(float(rate), i, window_duration, arrivals, seed)
        carried = _backlog_counts(states, cfg)
        counts0 = carried if spec.dispatch == "least-backlog" else None
        sid = dispatch_arrivals(agg.times, wts, counts0)
        merged, dtr = split_window(agg, sid, K)
        counts = np.bincount(sid, minlength=K).astype(np.int64)
        announced = float(rate) * shares
        device_reports: list = []
        for d in range(K):
            st = states[d]
            hi = st.plan_rates([announced[d]], t0, window_duration)[0]
            est = st.plan_rates([announced[d]], t0, window_duration,
                                margin=1.0, pressure=False)[0]
            if cfg.burst_quantile > 0.0:
                hi = max(hi, P.burst_rate(est, window_duration,
                                          cfg.burst_quantile))
            bud = st.plan_budgets([latency_budget])[0]
            sol = None
            if est > 0.0:
                if hi > est:
                    sol = P.solve_infer_interval(
                        P.InferProblem(power_budget, bud, est), hi, obs[d])
                    if sol is None:
                        sol = P.solve_infer(
                            P.InferProblem(power_budget, bud, hi), obs[d])
                if sol is None:
                    sol = P.solve_infer(
                        P.InferProblem(power_budget, bud, est), obs[d])
                if sol is None and bud < latency_budget:
                    sol = P.solve_infer(
                        P.InferProblem(power_budget, float(latency_budget),
                                       est), obs[d])
            offered = len(dtr[d])
            if sol is None:
                st.observe_unserved([dtr[d]], window_duration)
                device_reports.append(WindowReport(
                    float(announced[d]), None, None,
                    estimated_rate=float(est),
                    carried_requests=int(carried[d]),
                    goodput=0.0 if offered else 1.0,
                    offered_requests=offered))
                continue
            switch_s = st.mode_switch(sol.pm)
            carry_in = st.window_carry_in(t0, switch_s)
            rep = simulate(devs[d], None, w, sol.pm, sol.bs, dtr[d],
                           "managed", tau_cap=sol.tau_tr, backend=backend,
                           carry_in=carry_in)
            gp = _goodput(rep, latency_budget, offered)
            rep.goodput = gp
            st.observe([dtr[d]], [rep], [latency_budget], window_duration,
                       rep.queue_state)
            key = (sol.pm, sol.bs, sol.tau_tr)
            device_reports.append(WindowReport(
                float(announced[d]), sol, rep, estimated_rate=float(est),
                replanned=key != prev_keys[d], mode_switch_s=switch_s,
                carried_requests=int(carried[d]), goodput=gp,
                offered_requests=offered))
            prev_keys[d] = key
        out.append(_fleet_report(rate, device_reports, merged, counts,
                                 latency_budget))
    return out
