"""Fleet-scale serving (``Scenario.FLEET``): K devices as one batched program.

PR 7 scaled the *engine* to 10^5 lanes; this tier scales the whole serving
loop. A fleet is K heterogeneous edge devices (``fleet_device`` — the base
Orin model with deterministic per-device time/power multipliers) fed by one
aggregate arrival stream. Each window:

 1. **dispatch** — the window's aggregate arrivals are split across devices
    by deterministic weighted round-robin: each arrival, in time order, goes
    to the device minimizing ``(n_d + 1) / w_d`` (ties to the lowest index),
    where ``n_d`` counts this window's assignments so far. ``"capacity"``
    starts every window's counts at zero with ``w_d = 1 / time_scale_d``
    (faster devices take proportionally more); ``"least-backlog"`` seeds the
    counts with each device's carried backlog (join-the-shortest-queue
    flavor). The dispatched window keeps provenance: the merged trace's
    ``stream_ids`` are device indices, so ``ArrivalTrace.split`` recovers
    exactly the per-device traces that ran.
 2. **plan** — the K per-device closed-loop controller windows run the PR-5/6
    ladder (EWMA rate estimate, feedback-scaled budget, burst quantile,
    interval solve -> high-rate fallback -> estimate -> nominal-budget retry),
    but each rung is ONE ``grid_eval.solve_infer_fleet_batch`` call over the
    still-unsolved devices: every device's observation grid is the shared
    base grid scaled by its (time, power) multipliers, so the K problems
    stack into one masked-argmin array program per rung.
 3. **execute** — all solved devices run as one ``simulate_batch`` call
    (devices ARE lanes; PR 7's chunked max-plus dispatch does the rest),
    each with its own carried ``QueueState``; reports fold back into the
    per-device controller states.

Fleet-wide resource control rides on top of the same three passes (all
opt-in; with the knobs at their defaults every step below is skipped and the
loop is byte-identical to the PR-8 form — pinned by the fingerprint test in
``tests/test_fleet_admission.py``):

 * **global admission** (``ControllerConfig.admission``) — each solved
    device runs the PR-6 exact deadline-drop mask (``AdmissionPolicy.admit``
    over ``[carried pending, dispatched arrivals]`` with the *device's own*
    ``t_in``, so the admitted subsequence replays through that device's
    engine with zero nominal-budget violations by construction). ``"shed"``
    drops rejections; ``"defer"`` pushes them into a single fleet-level
    re-offer queue — at the next window start they re-enter the
    *dispatcher*, re-timestamped, and may land on any device, not the one
    they bounced off (``FleetControllerState.push_fleet_deferred`` /
    ``pop_fleet_deferred``, ``defer_cap`` overflow shed); ``"degrade-bs"``
    swaps a non-drainable device's plan for its max-service-rate plan
    (``problem.solve_infer_capacity``), trimming nothing.
 * **backlog migration** (``FleetSpec.migrate_backlog``) — between windows,
    every device's carried ``QueueState`` backlog is pooled and re-dispatched
    by the same capped key-merge as arrivals (``dispatch_arrivals`` with no
    seed counts == least-backlog equalization after pooling). A request that
    stays keeps its timestamp and replays bitwise; a request that moves is
    re-timestamped at the window start (re-submission semantics, the defer
    contract) so the receiving device's trace is still a valid nondecreasing
    replay. Device clocks never migrate — a busy device stays busy.
 * **shared power budget** (``FleetSpec.fleet_power_budget``) — one fleet
    cap allocated per window by water-filling (``problem.water_fill``) over
    the previous window's per-device ``attributed_power`` (the PR-8
    measurement side), floored so idle devices can re-enter and capped at
    the per-device ``power_budget``. The per-device grants thread into
    ``solve_infer_fleet_batch`` as its per-problem power-budget column.

Correctness contract (enforced by ``tests/test_fleet.py`` and
``tests/test_fleet_admission.py``): ``serve_fleet`` is **bitwise identical
on NumPy** (tolerance-identical on jax, like the engine itself) to
``serve_fleet_sequential`` — K independent single-device closed loops run
one after another over the same split traces — for every combination of
admission mode, migration, and shared budget. The cross-device decisions
(dispatch, deferral, migration, water-filling, admission masks) are shared
helper functions called identically by both drivers, so their floats cannot
diverge; the batched solver rungs replay the scalar solvers' float ops over
per-device scaled grids (``solve_infer_fleet_batch``'s contract), and the
batched engine's NumPy path runs the identical per-lane kernel — the fleet
tier adds speed, never drift.

The one remaining single-device refinement is mid-window re-entry
(``split_backlog``): configs requesting it are rejected rather than
silently ignored.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import problem as P
from repro.core.backend import resolve_backend
from repro.core.controller import (AdmissionPolicy, ControllerConfig,
                                   ControllerState, FleetControllerState)
from repro.core.device_model import (DeviceModel, PerturbedDeviceModel,
                                     WorkloadProfile, fleet_device)
from repro.core.fused_window import fused_fleet_window
from repro.core.grid_eval import materialize, solve_infer_fleet_batch
from repro.core.powermode import PowerModeSpace
from repro.core.simulate import (ArrivalTrace, ExecutionReport, QueueState,
                                 _presort_reports, simulate, simulate_batch)

_DISPATCHES = ("capacity", "least-backlog")


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """One fleet: how many devices, how they differ, how arrivals are
    dispatched, and which fleet-wide resource controls are on. Heterogeneity
    is sampled deterministically per (seed, index) via collision-free draws
    (``device_model._device_pert``), so a spec names the same fleet in every
    process. ``migrate_backlog`` and ``fleet_power_budget`` default off —
    the default spec reproduces the PR-8 K-isolated-loops behavior
    byte-for-byte."""
    n_devices: int
    seed: int = 0
    time_spread: float = 0.10     # per-device service-time spread (+-)
    power_spread: float = 0.05    # per-device power spread (+-)
    dispatch: str = "capacity"    # "capacity" | "least-backlog"
    migrate_backlog: bool = False  # re-dispatch carried backlog each window
    fleet_power_budget: Optional[float] = None   # shared cap, water-filled
    #   across devices per window (None = one per-device cap each)

    def __post_init__(self):
        if self.n_devices <= 0:
            raise ValueError("a fleet needs at least one device")
        if not 0.0 <= self.time_spread < 1.0 \
                or not 0.0 <= self.power_spread < 1.0:
            raise ValueError("spreads must be in [0, 1)")
        if self.dispatch not in _DISPATCHES:
            raise ValueError(f"unknown dispatch policy {self.dispatch!r}; "
                             f"use {_DISPATCHES}")
        if self.fleet_power_budget is not None \
                and self.fleet_power_budget <= 0.0:
            raise ValueError("fleet_power_budget must be positive (or None)")

    def devices(self) -> list[PerturbedDeviceModel]:
        return [fleet_device(d, self.seed, self.time_spread,
                             self.power_spread)
                for d in range(self.n_devices)]


@dataclasses.dataclass
class FleetWindowReport:
    """One fleet window: the per-device ``WindowReport``s (scheduler-shaped,
    index = device) plus the fleet-level dispatch and goodput account.
    ``trace`` is the dispatched aggregate window — ``trace.split(K)``
    recovers each device's arrivals (provenance round-trip). With admission
    ``"defer"`` the dispatched trace also carries the re-offered requests
    (re-timestamped at the window start), so ``len(trace)`` can exceed
    ``offered_requests`` — the window's own arrivals."""
    rate: float                       # aggregate announced rate
    devices: list                     # one WindowReport per device
    trace: ArrivalTrace               # merged; stream_ids = device indices
    dispatch_counts: np.ndarray       # arrivals dispatched per device
    offered_requests: int
    goodput: float                    # fleet-wide in-budget served / offered
    shed_requests: int = 0            # admission-rejected, dropped
    deferred_requests: int = 0        # admission-rejected, re-offered
    migrated_requests: int = 0        # backlog moved between devices
    power_budgets: Optional[np.ndarray] = None   # per-device water-filled
    #   grants (None unless FleetSpec.fleet_power_budget is set)

    @property
    def attributed_power(self) -> float:
        """Summed per-device attributed power (satellite of the per-tenant
        attribution account): each executed report's time-weighted share —
        idle devices attribute 0, so this is the fleet's busy power."""
        return float(sum(wr.report.attributed_power or 0.0
                         for wr in self.devices if wr.report is not None))


def dispatch_arrivals(times: np.ndarray, weights: np.ndarray,
                      counts0: Optional[np.ndarray] = None) -> np.ndarray:
    """Deterministic weighted round-robin dispatch: arrival k (time order)
    goes to the device minimizing ``(counts0_d + n_d + 1) / w_d`` over the
    running assignment counts ``n_d``, ties to the lowest device index.
    Returns the per-arrival device index vector.

    Implemented as a merge, not a loop: device d's j-th assignment has key
    ``(counts0_d + j + 1) / w_d`` — strictly increasing per device — and the
    greedy order is exactly the first N keys in (key, device) order. Each
    device can own at most ``(N + C + K) * w_d / W - counts0_d`` of the
    first N keys (the N-th smallest key is at most ``(N + C + K) / W``
    with ``C = sum(counts0)``, ``W = sum(w)``), so only ~N + O(K) candidate
    keys are materialized however large K * N is."""
    weights = np.asarray(weights, np.float64)
    K = weights.size
    n = int(np.asarray(times).size)
    if K <= 0:
        raise ValueError("dispatch needs at least one device")
    if np.any(weights <= 0.0):
        raise ValueError("dispatch weights must be positive")
    c0 = np.zeros(K, np.int64) if counts0 is None \
        else np.asarray(counts0, np.int64)
    if c0.size != K:
        raise ValueError("counts0 must align with the weights")
    if n == 0:
        return np.empty(0, np.int64)
    W = float(weights.sum())
    C = int(c0.sum())
    caps = np.ceil((n + C + K) * weights / W).astype(np.int64) - c0 + 2
    caps = np.clip(caps, 0, n)
    keys, devs = [], []
    for d in range(K):
        m = int(caps[d])
        if m <= 0:
            continue
        keys.append((c0[d] + 1.0 + np.arange(m)) / weights[d])
        devs.append(np.full(m, d, np.int64))
    keys = np.concatenate(keys)
    devs = np.concatenate(devs)
    order = np.argsort(keys, kind="stable")   # stable: device-major input,
    return devs[order[:n]]                    # equal keys -> lowest index


def split_window(agg: ArrivalTrace, sid: np.ndarray, n_devices: int,
                 ) -> tuple[ArrivalTrace, list[ArrivalTrace]]:
    """The dispatched forms of one aggregate window: the merged trace with
    device provenance, and the per-device traces (absolute times, so the
    carryover replay contract applies per device)."""
    merged = ArrivalTrace(agg.times, agg.duration, agg.kind,
                          np.asarray(sid, np.int64), int(n_devices))
    return merged, merged.split(n_devices)


def _check_fleet_features(spec: FleetSpec, cfg: ControllerConfig) -> None:
    """Per-feature capability checks (PR-8's blanket admission rejection is
    gone — shed / defer / degrade-bs are fleet-batched now)."""
    if cfg.split_backlog is not None:
        raise ValueError(
            "fleet serving batches whole controller windows; mid-window "
            "backlog splits (split_backlog) are a single-device refinement "
            "(serve them per device via Fulcrum.serve_dynamic)")
    if spec.migrate_backlog and not cfg.carry_backlog:
        raise ValueError(
            "backlog migration re-dispatches carried QueueState backlog "
            "between windows; it needs controller carry_backlog=True "
            "(or turn FleetSpec.migrate_backlog off)")


def _fleet_scales(spec: FleetSpec) -> tuple[list, np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]:
    """(devices, time_scales, power_scales, weights, shares): dispatch
    weight = 1 / time_scale (a device's service capacity), announced-rate
    share = normalized weight."""
    devs = spec.devices()
    ts = np.array([d.time_scale for d in devs])
    ps = np.array([d.power_scale for d in devs])
    wts = 1.0 / ts
    return devs, ts, ps, wts, wts / wts.sum()


def _window_trace(rate: float, i: int, window_duration: float,
                  arrivals: str, seed: int) -> ArrivalTrace:
    t0 = i * window_duration
    win = (ArrivalTrace.uniform(rate, window_duration)
           if arrivals == "uniform"
           else ArrivalTrace.poisson(rate, window_duration, seed + i))
    return win.shifted(t0)


def _backlog_counts(states: Sequence[ControllerState],
                    cfg: ControllerConfig) -> np.ndarray:
    """Per-device carried-backlog counts (0 with carryover off) — both the
    ``carried_requests`` account and the ``"least-backlog"`` dispatch seed."""
    return np.array([len(st.carry)
                     if cfg.carry_backlog and st.carry is not None else 0
                     for st in states], np.int64)


def _dispatch_fleet_window(agg: ArrivalTrace, n_deferred: int, t0: float,
                           weights: np.ndarray,
                           counts0: Optional[np.ndarray], K: int):
    """One window's dispatch pass, deferred re-offers included: the
    ``n_deferred`` fleet-level re-offers are re-timestamped at the window
    start and prepended to the aggregate arrivals (they sort first — the
    defer contract says they re-enter at the start), then the whole vector
    is dispatched by the capped key-merge. Returns ``(merged, dtr, own_dtr,
    deferred_counts, counts)``: the provenance-tagged merged trace, the
    per-device traces that run, the per-device *own-arrival* traces (the
    window's arrivals minus re-offers — what estimators observe and what
    ``offered_requests`` counts), how many re-offers each device drew, and
    the full dispatch counts."""
    if n_deferred:
        eff = ArrivalTrace(
            np.concatenate([np.full(n_deferred, float(t0)), agg.times]),
            agg.duration, agg.kind)
    else:
        eff = agg
    sid = dispatch_arrivals(eff.times, weights, counts0)
    merged, dtr = split_window(eff, sid, K)
    counts = np.bincount(sid, minlength=K).astype(np.int64)
    def_counts = np.bincount(sid[:n_deferred], minlength=K).astype(np.int64)
    if n_deferred:
        own = ArrivalTrace(agg.times, agg.duration, agg.kind,
                           np.asarray(sid[n_deferred:], np.int64), K)
        own_dtr = own.split(K)
    else:
        own_dtr = dtr
    return merged, dtr, own_dtr, def_counts, counts


def _migrate_backlog(states: Sequence[ControllerState], weights: np.ndarray,
                     t0: float) -> int:
    """Between-window backlog migration: pool every device's carried pending
    requests (time order, home-device-major on ties) and re-dispatch the
    pool through the same capped key-merge as arrivals — with no seed
    counts, the greedy ``(j + 1) / w_d`` keys equalize the queues, i.e.
    least-backlog placement over the pooled backlog. A request that stays on
    its home device keeps its original timestamp (its replay is bitwise the
    no-migration one); a request that moves is re-timestamped at the window
    start ``t0`` — re-submission semantics, exactly the defer contract — so
    the receiving device's ``[pending, window arrivals]`` vector stays
    nondecreasing and replays exactly. Device clocks never move: a busy
    device stays busy until its own clock. Returns how many requests moved
    (0 leaves every ``QueueState`` untouched)."""
    pend, home = [], []
    for d, st in enumerate(states):
        if st.carry is not None and len(st.carry):
            pend.append(np.asarray(st.carry.pending, np.float64))
            home.append(np.full(len(st.carry), d, np.int64))
    if not pend:
        return 0
    times = np.concatenate(pend)
    homes = np.concatenate(home)
    order = np.argsort(times, kind="stable")
    times, homes = times[order], homes[order]
    sid = dispatch_arrivals(times, weights)
    moved = sid != homes
    n_moved = int(np.count_nonzero(moved))
    if n_moved == 0:
        return 0
    new_times = np.where(moved, float(t0), times)
    for d, st in enumerate(states):
        pend_d = np.sort(new_times[sid == d], kind="stable")
        if st.carry is None and pend_d.size == 0:
            continue
        clock = float(st.carry.clock) if st.carry is not None else float(t0)
        st.carry = QueueState(pend_d, clock)
    return n_moved


def _fleet_power_budgets(spec: FleetSpec, power_budget: float,
                         prev_attr: np.ndarray, K: int) -> np.ndarray:
    """Per-device power budgets for one window. Without a fleet budget,
    every device keeps the per-device cap. With one, the shared cap is
    water-filled (``problem.water_fill``) over demand = the previous
    window's per-device attributed power — the PR-8 measurement side —
    floored at ``fleet_budget / 4K`` (an idle device must keep enough budget
    to serve again, or a zero-demand fixed point would starve it forever)
    and capped at the per-device ``power_budget`` (a grant the device's own
    envelope cannot use is forfeited, never redistributed — keeps the grant
    sum <= the fleet budget)."""
    if spec.fleet_power_budget is None:
        return np.full(K, float(power_budget))
    total = float(spec.fleet_power_budget)
    demands = np.maximum(np.asarray(prev_attr, np.float64),
                         total / (4.0 * K))
    return np.minimum(P.water_fill(demands, total), float(power_budget))


def _attributed_by_device(device_reports: Sequence) -> np.ndarray:
    """The per-device attributed power of one executed window — next
    window's water-filling demand vector (0 for unserved devices)."""
    return np.array([(wr.report.attributed_power or 0.0)
                     if wr is not None and wr.report is not None else 0.0
                     for wr in device_reports], np.float64)


def _admit_fleet_device(adm: AdmissionPolicy, latency_budget: float, sol,
                        t_in: float, carry_in: QueueState,
                        trace: ArrivalTrace,
                        ) -> tuple[ArrivalTrace, QueueState, int]:
    """One device's admission pass, exactly the single-device
    ``_closed_loop_window`` sequence: the deadline-drop mask runs over
    ``[carried pending, dispatched arrivals]`` from the carried clock with
    the device's own ``t_in`` (the engine's own recurrence — the admitted
    subsequence replays with zero nominal-budget violations by
    construction). Returns the trimmed ``(trace, carry_in, n_rejected)``;
    untouched inputs when everything admits."""
    k0 = len(carry_in)
    all_times = np.concatenate([np.asarray(carry_in.pending, np.float64),
                                trace.times])
    mask = adm.admit(all_times, latency_budget, sol.bs, t_in,
                     carry_in.clock)
    if mask.all():
        return trace, carry_in, 0
    run_carry = QueueState(carry_in.pending[mask[:k0]], carry_in.clock)
    run_trace = ArrivalTrace(trace.times[mask[k0:]], trace.duration,
                             trace.kind)
    return run_trace, run_carry, int(np.count_nonzero(~mask))


def _degrade_fleet_plan(sol, est: float, n_waiting: int, duration: float,
                        power_budget: float, obs: dict):
    """The ``degrade-bs`` admission mode per device (the fleet form of the
    scheduler's ``_degrade_plan``): when the device's demand — carried
    backlog + re-offers dispatched to it + estimated arrivals — is not
    drainable under the committed plan, swap in its max-service-rate plan
    under its (possibly water-filled) power budget and accept the
    violations."""
    t_in = obs[(sol.pm, sol.bs)][0]
    if P.drainable(n_waiting, est, sol.bs, t_in, duration):
        return sol
    cand = P.solve_infer_capacity(float(power_budget), obs)
    if cand is None:
        return sol
    c_t = obs[(cand.pm, cand.bs)][0]
    return cand if cand.bs / c_t > sol.bs / t_in else sol


def _goodput(rep, latency_budget: float, offered: int) -> float:
    good = int(np.count_nonzero(
        np.asarray(rep.latencies, np.float64) <= latency_budget))
    return good / offered if offered else 1.0


def _fleet_report(rate, device_reports, merged, counts, latency_budget,
                  offered, shed, deferred, migrated,
                  power_budgets) -> FleetWindowReport:
    good = sum(int(np.count_nonzero(
        np.asarray(wr.report.latencies, np.float64) <= latency_budget))
        for wr in device_reports if wr.report is not None)
    return FleetWindowReport(float(rate), device_reports, merged,
                             counts, int(offered),
                             good / offered if offered else 1.0,
                             shed_requests=int(shed),
                             deferred_requests=int(deferred),
                             migrated_requests=int(migrated),
                             power_budgets=power_budgets)


def serve_fleet(w: WorkloadProfile, power_budget: float,
                latency_budget: float, rates: Sequence[float],
                spec: FleetSpec, window_duration: float = 30.0,
                arrivals: str = "uniform", seed: int = 0,
                backend: Optional[str] = None,
                controller: Optional[ControllerConfig] = None,
                space: Optional[PowerModeSpace] = None,
                fused: Optional[bool] = None,
                ) -> list[FleetWindowReport]:
    """Serve a dynamic aggregate trace on a K-device fleet, stepping all K
    per-device closed-loop windows as one batched program per window: one
    dispatch pass (deferred re-offers re-entering first), one batched solve
    per ladder rung (per-device water-filled power budgets when the spec
    sets a fleet cap), one admission pass over the solved lanes, one
    ``simulate_batch`` over the admitted traces. Bitwise-identical on NumPy
    to ``serve_fleet_sequential`` (the K independent scalar loops).

    ``fused=True`` (jax/pallas backends only) runs each window through the
    fused solve+simulate program instead — ONE compiled launch per window
    (``core.fused_window``), tolerance-identical to this per-rung path.
    The default (``None``/False) keeps the unfused loop, so the NumPy
    reference path stays byte-identical."""
    cfg = controller if controller is not None else ControllerConfig()
    _check_fleet_features(spec, cfg)
    adm = cfg.admission_policy()
    if fused:
        eng = resolve_backend(backend)
        if eng == "numpy":
            raise ValueError(
                "the fused fleet window is a jax program; request "
                "backend='jax' (or 'pallas'), or leave fused off for the "
                "NumPy reference path")
        if adm.mode == "degrade-bs":
            raise ValueError(
                "admission mode 'degrade-bs' re-plans on the host between "
                "solve and simulate (problem.solve_infer_capacity over the "
                "device dict); serve it unfused — the fused window supports "
                "admission none/shed/defer")
        return _serve_fleet_fused(w, power_budget, latency_budget, rates,
                                  spec, window_duration, arrivals, seed,
                                  cfg, adm, space)
    K = spec.n_devices
    devs, ts, ps, wts, shares = _fleet_scales(spec)
    grid = materialize(DeviceModel(), w, space or PowerModeSpace(),
                       P.INFER_BATCH_SIZES)
    eng_backend = resolve_backend(backend)
    sol_backend = "numpy" if eng_backend == "numpy" else "jax"
    state = FleetControllerState(cfg, K)
    obs_cache: dict[int, dict] = {}     # degrade-bs only: per-device grids
    base_obs: list = []                 # the shared base dict, converted at
    #   most once per serve_fleet call (not once per device)

    def device_obs(d: int) -> dict:
        if d not in obs_cache:
            if not base_obs:
                base_obs.append(grid.to_dict())
            obs_cache[d] = {k: (t * ts[d], p * ps[d])
                            for k, (t, p) in base_obs[0].items()}
        return obs_cache[d]

    prev_keys: list = [None] * K
    prev_attr = np.full(K, float(power_budget))
    out: list[FleetWindowReport] = []
    from repro.core.scheduler import WindowReport
    for i, rate in enumerate(rates):
        t0 = i * window_duration
        agg = _window_trace(float(rate), i, window_duration, arrivals, seed)
        n_mig = _migrate_backlog(state.devices, wts, t0) \
            if spec.migrate_backlog else 0
        n_def = state.pop_fleet_deferred() if adm.active else 0
        carried = _backlog_counts(state.devices, cfg)
        counts0 = carried if spec.dispatch == "least-backlog" else None
        merged, dtr, own_dtr, def_counts, counts = _dispatch_fleet_window(
            agg, n_def, t0, wts, counts0, K)
        announced = float(rate) * shares
        pbud = _fleet_power_budgets(spec, power_budget, prev_attr, K)
        # the PR-5 ladder, vectorized over the device axis: every rung is
        # one batched fleet solve over the still-unsolved devices
        hi = state.plan_rates(announced, t0, window_duration)
        est = state.plan_rates(announced, t0, window_duration,
                               margin=1.0, pressure=False)
        if cfg.burst_quantile > 0.0:
            hi = np.maximum(hi, [P.burst_rate(e, window_duration,
                                              cfg.burst_quantile)
                                 for e in est])
        bud = state.plan_budgets([latency_budget] * K)
        sols: list[Optional[P.Solution]] = [None] * K
        live = est > 0.0            # a zero estimate has no rate to plan at
        unsolved = np.ones(K, bool)

        def rung(mask, rates_lo, budgets, rate_his):
            sel = np.flatnonzero(mask)
            if not sel.size:
                return
            probs = [P.InferProblem(float(pbud[d]), float(budgets[d]),
                                    float(rates_lo[d])) for d in sel]
            res = solve_infer_fleet_batch(probs, rate_his[sel], grid,
                                          ts[sel], ps[sel],
                                          backend=sol_backend)
            for d, s in zip(sel, res):
                sols[d] = s
                unsolved[d] = s is None

        # 1. margin headroom: sustainable up to hi, budget held at est
        rung(live & (hi > est), est, bud, hi)
        # 2. dead zone: prefer the high end (see _closed_loop_window)
        rung(live & (hi > est) & unsolved, hi, bud, hi)
        # 3. the point plan at the estimate
        rung(live & unsolved, est, bud, est)
        # 4. feedback tightened into infeasibility: retry at nominal
        nominal = np.full(K, float(latency_budget))
        rung(live & unsolved & (bud < nominal), est, nominal, est)
        lanes = []              # (device, sol, switch_s, run_trace, carry)
        shed_d = np.zeros(K, np.int64)
        def_out_d = np.zeros(K, np.int64)
        for d in range(K):
            sol = sols[d]
            if sol is not None and adm.mode == "degrade-bs":
                sol = _degrade_fleet_plan(
                    sol, float(est[d]), int(carried[d] + def_counts[d]),
                    window_duration, float(pbud[d]), device_obs(d))
                sols[d] = sol
            if sol is None:
                if def_counts[d]:
                    # nothing serves here: re-defer this device's re-offers
                    shed_d[d] += state.push_fleet_deferred(
                        int(def_counts[d]))
                state.observe_unserved(d, own_dtr[d], window_duration)
                continue
            switch_s = state.mode_switch(d, sol.pm)
            carry_in = state.window_carry_in(d, t0, switch_s)
            run_trace, run_carry = dtr[d], carry_in
            if adm.trims:
                t_in = devs[d].time_power(w, sol.pm, sol.bs)[0]
                run_trace, run_carry, n_rej = _admit_fleet_device(
                    adm, latency_budget, sol, t_in, carry_in, dtr[d])
                if n_rej:
                    if adm.mode == "defer":
                        dropped = state.push_fleet_deferred(n_rej)
                        def_out_d[d] = n_rej - dropped
                        shed_d[d] = dropped
                    else:
                        shed_d[d] = n_rej
            lanes.append((d, sol, switch_s, run_trace, run_carry))
        reps = simulate_batch(
            DeviceModel(), None, w,
            [sol.pm for _, sol, _, _, _ in lanes],
            [sol.bs for _, sol, _, _, _ in lanes],
            [rt for _, _, _, rt, _ in lanes],
            tau_caps=[sol.tau_tr for _, sol, _, _, _ in lanes],
            backend=eng_backend,
            carry_ins=[rc for _, _, _, _, rc in lanes],
            devices=[devs[d] for d, _, _, _, _ in lanes])
        device_reports: list = [None] * K
        for (d, sol, switch_s, _, _), rep in zip(lanes, reps):
            offered = len(own_dtr[d])
            gp = _goodput(rep, latency_budget, offered)
            rep.goodput = gp
            rep.shed_requests = int(shed_d[d])
            rep.deferred_requests = int(def_out_d[d])
            state.observe(d, own_dtr[d], rep, latency_budget,
                          window_duration, rep.queue_state)
            key = (sol.pm, sol.bs, sol.tau_tr)
            device_reports[d] = WindowReport(
                float(announced[d]), sol, rep,
                estimated_rate=float(est[d]),
                replanned=key != prev_keys[d], mode_switch_s=switch_s,
                carried_requests=int(carried[d]),
                shed_requests=int(shed_d[d]),
                deferred_requests=int(def_out_d[d]), goodput=gp,
                offered_requests=offered)
            prev_keys[d] = key
        for d in range(K):
            if device_reports[d] is None:
                offered = len(own_dtr[d])
                device_reports[d] = WindowReport(
                    float(announced[d]), None, None,
                    estimated_rate=float(est[d]),
                    carried_requests=int(carried[d]),
                    shed_requests=int(shed_d[d]),
                    goodput=0.0 if offered else 1.0,
                    offered_requests=offered)
        out.append(_fleet_report(
            rate, device_reports, merged, counts, latency_budget,
            offered=len(agg), shed=int(shed_d.sum()),
            deferred=int(def_out_d.sum()), migrated=n_mig,
            power_budgets=pbud.copy()
            if spec.fleet_power_budget is not None else None))
        prev_attr = _attributed_by_device(device_reports)
    return out


def _serve_fleet_fused(w: WorkloadProfile, power_budget: float,
                       latency_budget: float, rates: Sequence[float],
                       spec: FleetSpec, window_duration: float,
                       arrivals: str, seed: int, cfg: ControllerConfig,
                       adm: AdmissionPolicy,
                       space: Optional[PowerModeSpace],
                       ) -> list[FleetWindowReport]:
    """The fused driver behind ``serve_fleet(fused=True)``: identical
    host-side bookkeeping (dispatch, deferral, migration, water-filling,
    controller states) to the unfused loop, but the per-window plan ladder,
    admission recurrence, and engine run as ONE compiled launch
    (``core.fused_window.fused_fleet_window``) instead of up to four solver
    rungs + a host admission pass + an engine launch. Reports are
    reconstructed from the fetched arrays with the same float ops
    ``simulate_batch`` would apply, so results match the unfused jax path
    within the associative-scan tolerance (the padded tree shape is the
    only difference) and the unfused NumPy reference within the ladder's
    documented jax tolerance."""
    K = spec.n_devices
    devs, ts, ps, wts, shares = _fleet_scales(spec)
    grid = materialize(DeviceModel(), w, space or PowerModeSpace(),
                       P.INFER_BATCH_SIZES)
    state = FleetControllerState(cfg, K)
    prev_keys: list = [None] * K
    prev_mode = np.full(K, -1, np.int32)    # committed mode ids; -1 = none
    prev_attr = np.full(K, float(power_budget))
    adm_budget = adm.headroom * float(latency_budget)
    out: list[FleetWindowReport] = []
    from repro.core.scheduler import WindowReport
    for i, rate in enumerate(rates):
        t0 = i * window_duration
        agg = _window_trace(float(rate), i, window_duration, arrivals, seed)
        n_mig = _migrate_backlog(state.devices, wts, t0) \
            if spec.migrate_backlog else 0
        n_def = state.pop_fleet_deferred() if adm.active else 0
        carried = _backlog_counts(state.devices, cfg)
        counts0 = carried if spec.dispatch == "least-backlog" else None
        merged, dtr, own_dtr, def_counts, counts = _dispatch_fleet_window(
            agg, n_def, t0, wts, counts0, K)
        announced = float(rate) * shares
        pbud = _fleet_power_budgets(spec, power_budget, prev_attr, K)
        hi = state.plan_rates(announced, t0, window_duration)
        est = state.plan_rates(announced, t0, window_duration,
                               margin=1.0, pressure=False)
        if cfg.burst_quantile > 0.0:
            hi = np.maximum(hi, [P.burst_rate(e, window_duration,
                                              cfg.burst_quantile)
                                 for e in est])
        bud = state.plan_budgets([latency_budget] * K)
        nominal = np.full(K, float(latency_budget))
        live = est > 0.0
        # the engine-side carry-in, flattened: device d's effective arrival
        # vector [carried pending, dispatched arrivals] and its pre-switch
        # clock max(carried clock, t0) — window_carry_in minus the switch
        # cost, which the program charges in-line from prev_mode
        eff: list[np.ndarray] = []
        n_carry = np.zeros(K, np.int64)
        clock0 = np.full(K, float(t0))
        for d in range(K):
            st = state.devices[d]
            if cfg.carry_backlog and st.carry is not None:
                pend = np.asarray(st.carry.pending, np.float64)
                clock0[d] = max(float(st.carry.clock), float(t0))
                n_carry[d] = pend.size
                eff.append(np.concatenate([pend, dtr[d].times])
                           if pend.size else dtr[d].times)
            else:
                eff.append(dtr[d].times)
        res = fused_fleet_window(grid, ts, ps, pbud, bud, nominal, est, hi,
                                 live, prev_mode, eff, n_carry, clock0,
                                 float(cfg.mode_switch_s), adm_budget,
                                 adm.trims)
        shed_d = np.zeros(K, np.int64)
        def_out_d = np.zeros(K, np.int64)
        sols: list = [None] * K
        switches = np.zeros(K)
        reps: list = [None] * K
        for d in range(K):
            if not res["solved"][d]:
                if def_counts[d]:
                    shed_d[d] += state.push_fleet_deferred(
                        int(def_counts[d]))
                state.observe_unserved(d, own_dtr[d], window_duration)
                continue
            sel = int(res["sel"][d])
            sol = P.Solution(pm=grid.modes[sel], bs=int(grid.bs[sel]),
                             time=float(res["lam"][d]),
                             power=float(res["power"][d]))
            sols[d] = sol
            switches[d] = state.mode_switch(d, sol.pm)   # == res["switch"]
            n_rej = int(res["n_rej"][d])
            if n_rej:
                if adm.mode == "defer":
                    dropped = state.push_fleet_deferred(n_rej)
                    def_out_d[d] = n_rej - dropped
                    shed_d[d] = dropped
                else:
                    shed_d[d] = n_rej
            bs = sol.bs
            n_adm = int(res["n_adm"][d])
            nb = int(res["n_batches"][d])
            ctv = np.asarray(res["adm_times"][d][:n_adm], np.float64)
            if adm.trims and n_rej:
                # rebuilt exactly as _admit_fleet_device does: the admitted
                # window arrivals follow the admitted carry prefix
                nca = int(n_carry[d]) - int(res["n_carry_rej"][d])
                run_tr = ArrivalTrace(ctv[nca:].copy(), dtr[d].duration,
                                      dtr[d].kind)
            else:
                run_tr = dtr[d]
            power = float(res["power"][d])
            reps[d] = ExecutionReport(
                "managed",
                np.asarray(res["latencies"][d][:nb * bs], np.float64).copy(),
                0, run_tr.duration, power, run_tr,
                queue_state=QueueState(ctv[nb * bs:].copy(),
                                       float(res["clock_out"][d])),
                attributed_power=power if nb else 0.0)
            prev_mode[d] = int(res["mode_id"][d])
        _presort_reports([r for r in reps if r is not None])
        device_reports: list = [None] * K
        for d in range(K):
            rep = reps[d]
            offered = len(own_dtr[d])
            if rep is None:
                device_reports[d] = WindowReport(
                    float(announced[d]), None, None,
                    estimated_rate=float(est[d]),
                    carried_requests=int(carried[d]),
                    shed_requests=int(shed_d[d]),
                    goodput=0.0 if offered else 1.0,
                    offered_requests=offered)
                continue
            sol = sols[d]
            gp = _goodput(rep, latency_budget, offered)
            rep.goodput = gp
            rep.shed_requests = int(shed_d[d])
            rep.deferred_requests = int(def_out_d[d])
            state.observe(d, own_dtr[d], rep, latency_budget,
                          window_duration, rep.queue_state)
            key = (sol.pm, sol.bs, sol.tau_tr)
            device_reports[d] = WindowReport(
                float(announced[d]), sol, rep,
                estimated_rate=float(est[d]),
                replanned=key != prev_keys[d],
                mode_switch_s=float(switches[d]),
                carried_requests=int(carried[d]),
                shed_requests=int(shed_d[d]),
                deferred_requests=int(def_out_d[d]), goodput=gp,
                offered_requests=offered)
            prev_keys[d] = key
        out.append(_fleet_report(
            rate, device_reports, merged, counts, latency_budget,
            offered=len(agg), shed=int(shed_d.sum()),
            deferred=int(def_out_d.sum()), migrated=n_mig,
            power_budgets=pbud.copy()
            if spec.fleet_power_budget is not None else None))
        prev_attr = _attributed_by_device(device_reports)
    return out


def serve_fleet_sequential(w: WorkloadProfile, power_budget: float,
                           latency_budget: float, rates: Sequence[float],
                           spec: FleetSpec, window_duration: float = 30.0,
                           arrivals: str = "uniform", seed: int = 0,
                           backend: Optional[str] = None,
                           controller: Optional[ControllerConfig] = None,
                           space: Optional[PowerModeSpace] = None,
                           ) -> list[FleetWindowReport]:
    """The reference: the SAME fleet served as K independent single-device
    closed loops run sequentially — scalar solvers over each device's own
    observation dict, one single-lane engine call per device per window.
    The cross-device steps (dispatch, fleet deferral, migration,
    water-filling, admission) are the same shared helpers ``serve_fleet``
    calls, in the same device order, so the contract extends to every
    admission/migration/shared-budget combination: ``serve_fleet`` must
    match this bitwise on NumPy; benchmarks measure the batched speedup
    against it."""
    cfg = controller if controller is not None else ControllerConfig()
    _check_fleet_features(spec, cfg)
    adm = cfg.admission_policy()
    K = spec.n_devices
    devs, ts, ps, wts, shares = _fleet_scales(spec)
    base = materialize(DeviceModel(), w, space or PowerModeSpace(),
                       P.INFER_BATCH_SIZES).to_dict()
    # device d's observation dict: the base grid rescaled entrywise — the
    # same floats a per-device profile of PerturbedDeviceModel would yield
    obs = [{k: (t * ts[d], p * ps[d]) for k, (t, p) in base.items()}
           for d in range(K)]
    fstate = FleetControllerState(cfg, K)
    states = fstate.devices
    prev_keys: list = [None] * K
    prev_attr = np.full(K, float(power_budget))
    out: list[FleetWindowReport] = []
    from repro.core.scheduler import WindowReport
    for i, rate in enumerate(rates):
        t0 = i * window_duration
        agg = _window_trace(float(rate), i, window_duration, arrivals, seed)
        n_mig = _migrate_backlog(states, wts, t0) \
            if spec.migrate_backlog else 0
        n_def = fstate.pop_fleet_deferred() if adm.active else 0
        carried = _backlog_counts(states, cfg)
        counts0 = carried if spec.dispatch == "least-backlog" else None
        merged, dtr, own_dtr, def_counts, counts = _dispatch_fleet_window(
            agg, n_def, t0, wts, counts0, K)
        announced = float(rate) * shares
        pbud = _fleet_power_budgets(spec, power_budget, prev_attr, K)
        shed_d = np.zeros(K, np.int64)
        def_out_d = np.zeros(K, np.int64)
        device_reports: list = []
        for d in range(K):
            st = states[d]
            hi = st.plan_rates([announced[d]], t0, window_duration)[0]
            est = st.plan_rates([announced[d]], t0, window_duration,
                                margin=1.0, pressure=False)[0]
            if cfg.burst_quantile > 0.0:
                hi = max(hi, P.burst_rate(est, window_duration,
                                          cfg.burst_quantile))
            bud = st.plan_budgets([latency_budget])[0]
            pb = float(pbud[d])
            sol = None
            if est > 0.0:
                if hi > est:
                    sol = P.solve_infer_interval(
                        P.InferProblem(pb, bud, est), hi, obs[d])
                    if sol is None:
                        sol = P.solve_infer(
                            P.InferProblem(pb, bud, hi), obs[d])
                if sol is None:
                    sol = P.solve_infer(
                        P.InferProblem(pb, bud, est), obs[d])
                if sol is None and bud < latency_budget:
                    sol = P.solve_infer(
                        P.InferProblem(pb, float(latency_budget), est),
                        obs[d])
            if sol is not None and adm.mode == "degrade-bs":
                sol = _degrade_fleet_plan(
                    sol, float(est), int(carried[d] + def_counts[d]),
                    window_duration, pb, obs[d])
            offered = len(own_dtr[d])
            if sol is None:
                if def_counts[d]:
                    shed_d[d] += fstate.push_fleet_deferred(
                        int(def_counts[d]))
                st.observe_unserved([own_dtr[d]], window_duration)
                device_reports.append(WindowReport(
                    float(announced[d]), None, None,
                    estimated_rate=float(est),
                    carried_requests=int(carried[d]),
                    shed_requests=int(shed_d[d]),
                    goodput=0.0 if offered else 1.0,
                    offered_requests=offered))
                continue
            switch_s = st.mode_switch(sol.pm)
            carry_in = st.window_carry_in(t0, switch_s)
            run_trace, run_carry = dtr[d], carry_in
            if adm.trims:
                t_in = devs[d].time_power(w, sol.pm, sol.bs)[0]
                run_trace, run_carry, n_rej = _admit_fleet_device(
                    adm, latency_budget, sol, t_in, carry_in, dtr[d])
                if n_rej:
                    if adm.mode == "defer":
                        dropped = fstate.push_fleet_deferred(n_rej)
                        def_out_d[d] = n_rej - dropped
                        shed_d[d] = dropped
                    else:
                        shed_d[d] = n_rej
            rep = simulate(devs[d], None, w, sol.pm, sol.bs, run_trace,
                           "managed", tau_cap=sol.tau_tr, backend=backend,
                           carry_in=run_carry)
            gp = _goodput(rep, latency_budget, offered)
            rep.goodput = gp
            rep.shed_requests = int(shed_d[d])
            rep.deferred_requests = int(def_out_d[d])
            st.observe([own_dtr[d]], [rep], [latency_budget],
                       window_duration, rep.queue_state)
            key = (sol.pm, sol.bs, sol.tau_tr)
            device_reports.append(WindowReport(
                float(announced[d]), sol, rep, estimated_rate=float(est),
                replanned=key != prev_keys[d], mode_switch_s=switch_s,
                carried_requests=int(carried[d]),
                shed_requests=int(shed_d[d]),
                deferred_requests=int(def_out_d[d]), goodput=gp,
                offered_requests=offered))
            prev_keys[d] = key
        out.append(_fleet_report(
            rate, device_reports, merged, counts, latency_budget,
            offered=len(agg), shed=int(shed_d.sum()),
            deferred=int(def_out_d.sum()), migrated=n_mig,
            power_budgets=pbud.copy()
            if spec.fleet_power_budget is not None else None))
        prev_attr = _attributed_by_device(device_reports)
    return out
