"""AdamW + LR schedules, pure JAX (no optax dependency).

Optimizer state mirrors the param pytree, so the FSDP/ZeRO param
PartitionSpecs apply verbatim to m/v (ZeRO-1/3 falls out of the sharding
rules, not of the optimizer)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict:
    """m/v in fp32; when params are stored low-precision (bf16 forward
    weights), a sharded fp32 master copy lives here too (mixed precision)."""
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    state = {"m": zeros(params), "v": zeros(params),
             "step": jnp.zeros((), jnp.int32)}
    if any(x.dtype != jnp.float32 for x in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(
            lambda x: x.astype(jnp.float32), params)
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(grads: Any, opt_state: dict, params: Any,
                 cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        ref = master if master is not None else p.astype(jnp.float32)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + cfg.weight_decay * ref
        new_master = ref - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    flat_master = (treedef.flatten_up_to(opt_state["master"])
                   if "master" in opt_state else [None] * len(flat_p))
    new = [upd(g, m, v, p, mr) for g, m, v, p, mr in
           zip(flat_g, flat_m, flat_v, flat_p, flat_master)]
    new_p = treedef.unflatten([n[0] for n in new])
    new_state = {"m": treedef.unflatten([n[1] for n in new]),
                 "v": treedef.unflatten([n[2] for n in new]),
                 "step": step}
    if "master" in opt_state:
        new_state["master"] = treedef.unflatten([n[3] for n in new])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, new_state, stats
