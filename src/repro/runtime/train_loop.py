"""Training loop: jitted step + data pipeline + checkpointing + metrics."""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
from repro.data.pipeline import ShardedPrefetcher, SyntheticTokenSource
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.launch.steps import make_train_step
from repro.runtime.clock import Clock, WallClock


@dataclasses.dataclass
class TrainReport:
    steps: int
    losses: list[float]
    step_times: list[float]

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")

    @property
    def mean_step_time(self) -> float:
        xs = self.step_times[2:] or self.step_times   # skip compile steps
        return float(np.mean(xs)) if xs else float("nan")


class Trainer:
    """Single-process trainer (CPU smoke / examples). The production path is
    the same train_step jitted with mesh shardings via launch.steps."""

    def __init__(self, cfg: M.ModelConfig, batch: int, seq_len: int,
                 opt_cfg: AdamWConfig = AdamWConfig(), seed: int = 0,
                 ckpt_path: Optional[str] = None,
                 clock: Optional[Clock] = None):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self.opt_cfg = opt_cfg
        self.ckpt_path = ckpt_path
        self.clock = clock if clock is not None else WallClock()
        self.params = M.init_params(jax.random.key(seed), cfg)
        self.opt_state = init_opt_state(self.params)
        self.step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        self.data = iter(ShardedPrefetcher(
            SyntheticTokenSource(cfg, batch, seq_len, seed=seed + 1)))
        self.step = 0

    def restore(self):
        if self.ckpt_path and Path(self.ckpt_path).exists():
            (self.params, self.opt_state), self.step = restore_checkpoint(
                self.ckpt_path, (self.params, self.opt_state))

    def step_minibatch(self) -> None:
        """One optimizer step on the next data batch — the unit the managed
        interleave runtime schedules into inference slack."""
        batch = next(self.data)
        self.params, self.opt_state, _ = self.step_fn(
            self.params, self.opt_state, batch)
        self.step += 1

    def train(self, num_steps: int, log_every: int = 10,
              ckpt_every: int = 0) -> TrainReport:
        losses, times = [], []
        for _ in range(num_steps):
            batch = next(self.data)
            t0 = self.clock.now()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            times.append(self.clock.now() - t0)
            losses.append(loss)
            self.step += 1
            if log_every and self.step % log_every == 0:
                print(f"step {self.step:5d} loss {loss:.4f} "
                      f"({times[-1]*1e3:.0f} ms)", flush=True)
            if ckpt_every and self.ckpt_path and self.step % ckpt_every == 0:
                save_checkpoint(self.ckpt_path, (self.params, self.opt_state),
                                self.step)
        return TrainReport(self.step, losses, times)

    def train_minibatch_time(self, warmup: int = 2, iters: int = 3) -> float:
        """Profile one training minibatch (used by the real-mode Fulcrum)."""
        for _ in range(warmup):
            batch = next(self.data)
            self.params, self.opt_state, _ = self.step_fn(
                self.params, self.opt_state, batch)
        t0 = self.clock.now()
        for _ in range(iters):
            batch = next(self.data)
            self.params, self.opt_state, _ = self.step_fn(
                self.params, self.opt_state, batch)
        jax.block_until_ready(self.params)
        return (self.clock.now() - t0) / iters
