"""Real-mode managed interleaving: Fulcrum's executor over actual jitted JAX
steps (reduced models on CPU; identical control flow on a TPU host).

This is the wall-clock counterpart of core.interleave.simulate_managed: one
program owns the accelerator, alternating tau_tr jitted train minibatches
with one jitted inference minibatch, switching only at minibatch boundaries.
A training step is launched only if it is predicted (from its measured step
time) to finish before the next inference batch is ready, so inference never
queues behind training.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.interleave import ExecutionReport
from repro.configs.base import make_batch
from repro.runtime.serving import BatchInferenceServer
from repro.runtime.train_loop import Trainer


@dataclasses.dataclass
class InterleaveConfig:
    arrival_rate: float            # requests / s
    infer_bs: int
    latency_budget: float          # s
    duration: float = 20.0         # wall seconds


class ManagedInterleaveRuntime:
    def __init__(self, trainer: Optional[Trainer],
                 server: BatchInferenceServer, cfg: InterleaveConfig):
        self.trainer = trainer
        self.server = server
        self.cfg = cfg
        self.t_tr = trainer.train_minibatch_time() if trainer else float("inf")

    def run(self) -> ExecutionReport:
        cfg = self.cfg
        bs = cfg.infer_bs
        latencies: list[float] = []
        trained = 0
        start = time.time()
        next_arrival_idx = 0
        now = 0.0

        def arrival(i: int) -> float:
            return i / cfg.arrival_rate

        while now < cfg.duration:
            batch_ready = arrival(next_arrival_idx + bs - 1)
            if batch_ready > cfg.duration:
                break
            # fill slack with training minibatches that fit before the batch
            while self.trainer and (time.time() - start) + self.t_tr <= batch_ready:
                b = next(self.trainer.data)
                self.trainer.params, self.trainer.opt_state, _ = \
                    self.trainer.step_fn(self.trainer.params,
                                         self.trainer.opt_state, b)
                trained += 1
            # wait for the batch to accumulate, then run inference
            now = time.time() - start
            if now < batch_ready:
                time.sleep(batch_ready - now)
            self.server.infer().block_until_ready()
            done = time.time() - start
            latencies.extend(done - arrival(i) for i in
                             range(next_arrival_idx, next_arrival_idx + bs))
            next_arrival_idx += bs
            now = time.time() - start

        return ExecutionReport("managed-real", latencies, trained,
                               max(now, 1e-9), power=0.0)
