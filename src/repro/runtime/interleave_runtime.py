"""Real-mode managed interleaving: Fulcrum's executor over actual jitted JAX
steps (reduced models on CPU; identical control flow on a TPU host).

This is the wall-clock counterpart of the engine's managed kernel
(``core.simulate``): one program owns the accelerator, alternating jitted
train minibatches with jitted inference minibatches, switching only at
minibatch boundaries. A training step is launched only if it is predicted
(from its measured step time) to finish before the next inference batch is
ready, so inference never queues behind training.

Ported off the per-request wall-clock loop: the runtime now consumes an
``ArrivalTrace`` — including merged multi-tenant traces, served in the same
(ready time, stream) event order as ``core.simulate.simulate_multi_tenant``
— through an injectable ``Clock``, and emits the same ``ExecutionReport``
(or ``MultiTenantReport``) as the engine. Under a ``FakeClock`` with
fixed-duration step stubs the control flow replays the engine's scalar
reference bitwise, so sim-vs-real drift is measurable: ``attach_drift``
records the max |Δlatency| against an engine report for the shared trace on
the runtime report's ``drift_s``. The §5.4 controller
(``core.controller``) can therefore drive either backend — both consume
traces and emit reports.

Duck-typed dependencies (so tests stub them without building models):
``trainer`` needs ``train_minibatch_time()`` and ``step_minibatch()``;
each server needs ``infer()`` (the result's ``block_until_ready`` is
awaited when present).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.simulate import ArrivalTrace, ExecutionReport, \
    MultiTenantReport, batch_ready_events
from repro.runtime.clock import Clock, WallClock


@dataclasses.dataclass
class InterleaveConfig:
    arrival_rate: float            # requests / s (default uniform trace)
    infer_bs: int
    latency_budget: float          # s
    duration: float = 20.0         # horizon of the default uniform trace


class ManagedInterleaveRuntime:
    def __init__(self, trainer, server, cfg: InterleaveConfig,
                 trace: Optional[ArrivalTrace] = None,
                 clock: Optional[Clock] = None,
                 servers: Optional[Sequence] = None,
                 bss: Optional[Sequence[int]] = None,
                 admission=None):
        """``trace`` defaults to the config's uniform-rate arrivals. For a
        merged multi-tenant trace pass ``servers`` (one per stream, in
        stream-id order) and optionally per-stream ``bss``; ``run`` then
        returns one report per tenant. ``admission`` is an optional
        trace-trimming gate (``AdmissionPolicy.gate(...)``) applied to a
        single-stream trace before serving: ``gate(trace) ->
        (admitted_trace, n_shed)``, the shed count landing on the report's
        ``shed_requests`` — so a FakeClock runtime run sheds the identical
        request set as the engine-side admission mask."""
        self.trainer = trainer
        self.servers = list(servers) if servers is not None else [server]
        self.cfg = cfg
        # None => a fresh WallClock anchored at run() entry, so setup work
        # (model building, the trainer's timing measurement) does not count
        # as elapsed serving time
        self.clock = clock
        self.trace = trace if trace is not None else \
            ArrivalTrace.uniform(cfg.arrival_rate, cfg.duration)
        self.bss = [int(b) for b in bss] if bss is not None \
            else [cfg.infer_bs] * len(self.servers)
        self.t_tr = trainer.train_minibatch_time() if trainer else float("inf")
        self.admission = admission
        self.shed_requests = 0
        if admission is not None:
            if self.trace.stream_ids is not None:
                raise ValueError("runtime admission gates single-stream "
                                 "traces only")
            self.trace, self.shed_requests = admission(self.trace)

    def _infer(self, j: int) -> None:
        out = self.servers[j].infer()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()

    def _stream_traces(self) -> list[ArrivalTrace]:
        if self.trace.stream_ids is not None:
            return self.trace.split()
        return [self.trace]

    def run(self):
        """Serve the trace: per-stream minibatch-ready events in
        (time, stream) order — exactly the engine's merge order — training
        filling the slack before each event. Returns an ``ExecutionReport``
        for a single-stream trace, a ``MultiTenantReport`` for a merged
        one."""
        traces = self._stream_traces()
        if len(traces) != len(self.servers):
            raise ValueError(f"{len(traces)} trace streams need "
                             f"{len(traces)} servers, got "
                             f"{len(self.servers)}")
        clock = self.clock if self.clock is not None else WallClock()
        arrivals = [tr.times.tolist() for tr in traces]
        events = batch_ready_events(arrivals, self.bss)
        latencies: list[list[float]] = [[] for _ in traces]
        trained = 0
        for ready, j, start in events:
            # fill slack with training minibatches predicted to finish
            # before the batch is ready (inference never queues)
            while self.trainer and clock.now() + self.t_tr <= ready:
                self.trainer.step_minibatch()
                trained += 1
            clock.sleep_until(ready)           # wait for the batch to form
            self._infer(j)
            done = clock.now()
            latencies[j].extend(done - arrivals[j][i]
                                for i in range(start, start + self.bss[j]))
        duration = max(self.trace.duration, 1e-9)
        reports = [ExecutionReport("managed-real", lat, 0, duration,
                                   power=0.0, trace=tr)
                   for lat, tr in zip(latencies, traces)]
        if len(reports) == 1:
            reports[0].train_minibatches = trained
            reports[0].shed_requests = self.shed_requests
            return reports[0]
        return MultiTenantReport(reports, trained, duration, power=0.0,
                                 trace=self.trace)


def attach_drift(report: ExecutionReport,
                 reference: ExecutionReport) -> float:
    """Record sim-vs-real drift: the max |Δlatency| between a runtime report
    and the engine's report for the same trace and plan, stored on the
    runtime report (``drift_s``) and returned. The reports must cover the
    same requests."""
    a = np.asarray(report.latencies, np.float64)
    b = np.asarray(reference.latencies, np.float64)
    if a.size != b.size:
        raise ValueError(f"reports serve different request counts "
                         f"({a.size} vs {b.size}); drift needs a shared "
                         f"trace and plan")
    report.drift_s = float(np.max(np.abs(a - b))) if a.size else 0.0
    return report.drift_s
