"""Injectable clocks for the runtime layer.

Every wall-time read in ``repro.runtime`` goes through a ``Clock`` so the
same control flow runs against real time (``WallClock``) or deterministic
simulated time (``FakeClock``). That is what makes sim-vs-real drift
measurable: the managed interleave runtime under a ``FakeClock`` with
fixed step times replays the *identical* float operations as the engine's
scalar reference loop — ``sleep_until`` is a ``max`` (not an add of a
computed remainder, which would round differently), and ``advance`` is the
same repeated addition the engine's slack-fill uses — so the runtime
reproduces ``core.simulate`` completion times bitwise
(``tests/test_controller.py``), and runtime tests run seeded and fast
instead of sleeping through wall seconds.
"""
from __future__ import annotations

import time


class Clock:
    """Interface: ``now()`` in seconds since the clock's epoch, and
    ``sleep_until(t)`` which never moves time backwards."""

    def now(self) -> float:
        raise NotImplementedError

    def sleep_until(self, t: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    """Real time, relative to construction (epoch 0 at creation)."""

    def __init__(self):
        self._t0 = time.time()

    def now(self) -> float:
        return time.time() - self._t0

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class FakeClock(Clock):
    """Deterministic manual time. ``sleep_until`` jumps exactly to the
    target (a float ``max`` — no drift from adding a computed remainder);
    ``advance`` charges simulated work, e.g. a stub inference step adding
    its modeled duration."""

    def __init__(self, t: float = 0.0):
        self._t = float(t)

    def now(self) -> float:
        return self._t

    def sleep_until(self, t: float) -> None:
        self._t = max(self._t, float(t))

    def advance(self, dt: float) -> None:
        self._t += float(dt)
