"""Serving engine: request queue, minibatch batcher, and two server kinds:

 * BatchInferenceServer — the paper's inference semantics: independent
   requests batched into one forward pass (vision/classification style).
 * GenerationServer — LLM-style prefill + decode against the ring-buffer
   KV/SSM caches (exercises model.prefill / model.decode_step end-to-end).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import make_batch
from repro.models import model as M
from repro.runtime.clock import Clock, WallClock


@dataclasses.dataclass
class Request:
    arrival: float
    payload: dict
    done: float = -1.0

    @property
    def latency(self) -> float:
        return self.done - self.arrival


class RequestQueue:
    """Arrival-stamped FIFO; supports synthetic constant/trace-driven feeds.
    Arrival stamps come from the injectable ``clock`` (deterministic under a
    ``FakeClock``) unless an explicit ``now`` is given."""

    def __init__(self, clock: Optional[Clock] = None):
        self.q: deque[Request] = deque()
        self.clock = clock if clock is not None else WallClock()

    def push(self, payload: dict, now: Optional[float] = None):
        self.q.append(Request(now if now is not None else self.clock.now(),
                              payload))

    def ready(self, bs: int) -> bool:
        return len(self.q) >= bs

    def pop_batch(self, bs: int) -> list[Request]:
        return [self.q.popleft() for _ in range(bs)]

    def __len__(self):
        return len(self.q)


class BatchInferenceServer:
    """One jitted forward per minibatch of bs requests."""

    def __init__(self, cfg: M.ModelConfig, seq_len: int, bs: int,
                 seed: int = 0, clock: Optional[Clock] = None):
        self.cfg, self.seq_len, self.bs = cfg, seq_len, bs
        self.clock = clock if clock is not None else WallClock()
        self.params = M.init_params(jax.random.key(seed), cfg)
        self._fwd = jax.jit(lambda p, b: M.forward(p, b, cfg)[0])
        # warm the compile cache
        self._fwd(self.params, make_batch(cfg, seq_len, bs, "prefill")).block_until_ready()

    def infer(self, batch: Optional[dict] = None) -> jax.Array:
        batch = batch or make_batch(self.cfg, self.seq_len, self.bs, "prefill")
        return self._fwd(self.params, batch)

    def minibatch_time(self, iters: int = 3) -> float:
        t0 = self.clock.now()
        for _ in range(iters):
            self.infer().block_until_ready()
        return (self.clock.now() - t0) / iters


class GenerationServer:
    """Prefill + token-by-token decode using the model's serving caches."""

    def __init__(self, cfg: M.ModelConfig, max_seq: int, bs: int, seed: int = 0):
        self.cfg, self.max_seq, self.bs = cfg, max_seq, bs
        self.params = M.init_params(jax.random.key(seed), cfg)
        self._prefill = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_seq))
        self._decode = jax.jit(lambda p, c, b, pos: M.decode_step(p, c, b, pos, cfg))

    def generate(self, prompt: dict, steps: int, prompt_len: int) -> np.ndarray:
        logits, cache = self._prefill(self.params, prompt)
        tokens = []
        pos = jnp.full((self.bs,), prompt_len, jnp.int32)
        for _ in range(steps):
            nxt = jnp.argmax(logits[:, -1:] if logits.ndim == 3 else
                             logits[:, -1:, 0], axis=-1).astype(jnp.int32)
            if self.cfg.arch_type == "audio":
                nxt = jnp.broadcast_to(nxt[..., None],
                                       (self.bs, 1, self.cfg.n_codebooks))
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": nxt.reshape(self.bs, 1, -1)
                                          if self.cfg.arch_type == "audio"
                                          else nxt.reshape(self.bs, 1)}, pos)
            pos = pos + 1
            tokens.append(np.asarray(nxt).reshape(self.bs, -1)[:, 0])
        return np.stack(tokens, axis=1)
