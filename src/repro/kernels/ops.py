"""Jit'd public wrappers around the Pallas kernels.

On a TPU backend the kernels run compiled; elsewhere (this CPU container)
they execute via ``interpret=True``, which runs the kernel body in Python —
bit-correct for validation against the ref.py oracles, not for speed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention as _flash
from repro.kernels.ssd_scan.ssd_scan import ssd_chunk as _ssd_chunk
from repro.kernels.tiled_matmul.tiled_matmul import tiled_matmul as _mm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k"))
def flash_attention(q, k, v, window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    """Causal (windowed) attention, (B, H, S, D) layout."""
    return _flash(q, k, v, window=window, block_q=block_q, block_k=block_k,
                  interpret=not _on_tpu())


@jax.jit
def ssd_scan(x, dt, A, B, C):
    """Full SSD scan via the Pallas intra-chunk kernel + jnp inter-chunk
    recurrence. x: (b, nc, l, h, p); dt: (b, nc, l, h); A: (h,);
    B, C: (b, nc, l, n). Returns (y: (b, nc, l, h, p), final_state)."""
    b, nc, l, h, p = x.shape
    dA = dt * A[None, None, None, :]
    y_diag, states = _ssd_chunk(x, dA, dt, B, C, interpret=not _on_tpu())
    # inter-chunk recurrence (O(nc) tiny work) in jnp
    dA_cs = jnp.cumsum(dA, axis=2)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])              # (b, nc, h)
    init = jnp.zeros((b, h, states.shape[3], p), jnp.float32)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry

    final, prev = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     chunk_decay.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                   # (b, nc, h, n, p)
    state_decay = jnp.exp(dA_cs)                           # (b, nc, l, h)
    Ch = jnp.repeat(C[:, :, :, None], h, axis=3)
    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp", Ch, prev, state_decay)
    return (y_diag.astype(jnp.float32) + y_off).astype(x.dtype), final


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def tiled_matmul(a, b, block_m: int = 128, block_n: int = 128, block_k: int = 128):
    return _mm(a, b, block_m=block_m, block_n=block_n, block_k=block_k,
               interpret=not _on_tpu())
