"""Pure-jnp oracles for the Fulcrum engine kernels.

``maxplus_scan_ref`` is the ``jax.lax.associative_scan`` formulation the
engine shipped in PR 4 (``core.simulate._jax_engine``), restated here as the
kernel contract: the managed recurrence ``c_k = max(c_{k-1}, ready_k) + e_k``
is the composition of affine max-plus maps ``f_k(x) = max(x + a_k, b_k)``
with ``a_k = e_k``, ``b_k = ready_k + e_k``; composition keeps that form via
``(f_r . f_l) -> (a_l + a_r, max(b_l + a_r, b_r))``, and applying the prefix
compositions to ``c_0 = clock`` gives ``c_k = max(clock + A_k, B_k)``.

Padding convention (shared with the kernels and ``simulate._pad_lanes``):
trailing events carry ``ready = +inf, exec = 0`` — absorbing for both ops —
and idle/padding lanes are all-padding. Fill counts mask padded events via
``isfinite(ready)``; ``t_tr = +inf`` (no training) yields zero fills.

``lane_sort_ref`` / ``lane_violations_ref`` mirror the report-builder sort:
ascending per-lane sort of a +inf-padded (lane, request) matrix (real
latencies stay the leading prefix) and the per-lane count of *finite*
entries strictly above a per-lane latency budget.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_scan_ref(ready: jax.Array, exec_t: jax.Array, t_tr: jax.Array,
                     tau_cap: jax.Array, clock: jax.Array):
    """Managed completions + slack-fill sums via lax.associative_scan.

    ready, exec_t: (lanes, K); t_tr, tau_cap, clock: (lanes,).
    Returns (completions (lanes, K), fills_sum (lanes,)).
    """
    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l + a_r, jnp.maximum(b_l + a_r, b_r)

    def one_lane(r, e, ttr, cap, clk):
        a, b = jax.lax.associative_scan(combine, (e, r + e))
        c = jnp.maximum(clk + a, b)
        start = jnp.concatenate([jnp.full(1, clk, c.dtype), c[:-1]])
        fills = jnp.clip(jnp.floor((r - start) / ttr), 0.0, cap)
        fills = jnp.where(jnp.isfinite(r), fills, 0.0)
        return c, fills.sum()

    return jax.vmap(one_lane)(ready, exec_t, t_tr, tau_cap, clock)


def lane_sort_ref(mat: jax.Array) -> jax.Array:
    """Ascending per-lane sort of a +inf-padded (lanes, R) matrix."""
    return jnp.sort(mat, axis=-1)


def lane_violations_ref(mat: jax.Array, budgets: jax.Array) -> jax.Array:
    """Per-lane count of finite entries strictly above the lane's budget."""
    over = jnp.isfinite(mat) & (mat > budgets[:, None])
    return over.sum(axis=-1).astype(jnp.int32)
