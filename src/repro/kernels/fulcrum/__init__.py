"""Hand-written Pallas kernels for the Fulcrum execution engine.

Two hot paths of ``core.simulate`` get a kernel each, following the in-tree
``kernels/ssd_scan`` pattern (kernel module + pure-jnp ``ref.py`` oracle,
``interpret=True`` so CPU CI runs the exact kernel code path):

 * ``maxplus_scan`` — the managed-interleaving recurrence
   ``c_k = max(c_{k-1}, ready_k) + e_k`` as a lane-blocked Hillis-Steele
   doubling scan over max-plus affine maps, fused with the training
   slack-fill count.
 * ``lane_sort`` — the per-lane padded quantile sort behind the batched
   report builder (``simulate._presort_reports``), a bitonic network over
   +inf-padded lanes, with per-lane budget-violation counts.

Backend selection (pallas → jax → numpy) lives in ``core.backend``;
tolerance contracts in ``docs/exactness.md``.
"""
from repro.kernels.fulcrum.lane_sort import lane_sort
from repro.kernels.fulcrum.maxplus_scan import maxplus_scan

__all__ = ["maxplus_scan", "lane_sort"]
