"""Pallas kernel for the managed-interleaving max-plus scan (engine hot path).

One grid cell owns a block of lanes and runs the whole event axis in
registers/VMEM: a Hillis-Steele doubling scan over the max-plus affine maps
``f_k(x) = max(x + a_k, b_k)`` (``a = exec``, ``b = ready + exec``), whose
composition rule is ``(a, b) <- (a_shift + a, max(b_shift + a, b))`` — after
``log2 K`` rounds ``(a, b)`` holds every prefix composition, so the batch
completions are ``c = max(clock + a, b)`` applied to the carried window
clock. The training slack-fill count (floor estimate, the jax tier's
documented tolerance contract — no scalar boundary replay on-accelerator)
is fused into the same cell, one memory pass over the block.

Padding convention (``simulate._pad_lanes``): trailing events carry
``ready = +inf, exec = 0`` — absorbing for max and + — and whole padding
lanes are all-padding with ``clock = 0``; fills mask padded events via
``isfinite(ready)``.

Runs under ``enable_x64`` (float64 lanes, the engine's working precision).
``interpret=True`` (the default off-TPU) executes the identical kernel body
on CPU, so CI exercises this exact code path — see ``tests/test_kernels.py``
and the in-tree ``kernels/ssd_scan`` exemplar this module follows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxplus_kernel(ready_ref, exec_ref, ttr_ref, cap_ref, clk_ref,
                    c_ref, fills_ref):
    r = ready_ref[...]                                  # (bl, K)
    a = exec_ref[...]                                   # (bl, K)
    ttr = ttr_ref[...]                                  # (bl, 1)
    cap = cap_ref[...]
    clk = clk_ref[...]
    bl, K = r.shape
    b = r + a
    d = 1
    while d < K:                                        # Hillis-Steele: the
        b_s = jnp.concatenate(                          # shifted-in prefix
            [jnp.full((bl, d), -jnp.inf, b.dtype), b[:, :-d]], axis=1)
        a_s = jnp.concatenate(
            [jnp.zeros((bl, d), a.dtype), a[:, :-d]], axis=1)
        b = jnp.maximum(b_s + a, b)                     # b first: uses the
        a = a_s + a                                     # round's current a
        d *= 2
    c = jnp.maximum(clk + a, b)
    start = jnp.concatenate([clk, c[:, :-1]], axis=1)
    fills = jnp.clip(jnp.floor((r - start) / ttr), 0.0, cap)
    fills = jnp.where(jnp.isfinite(r), fills, 0.0)
    c_ref[...] = c
    fills_ref[...] = fills.sum(axis=1, keepdims=True)


def maxplus_scan(ready: jax.Array, exec_t: jax.Array, t_tr: jax.Array,
                 tau_cap: jax.Array, clock: jax.Array,
                 block_lanes: int | None = None,
                 interpret: bool | None = None):
    """Managed completions + slack-fill sums, lane-blocked.

    ready, exec_t: (lanes, K) padded event matrices; t_tr, tau_cap, clock:
    (lanes,) per-lane scalars (+inf t_tr/tau_cap = no training / no cap).
    Returns (completions (lanes, K), fills_sum (lanes,)) — the contract of
    ``ref.maxplus_scan_ref``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L, K = ready.shape
    if L == 0 or K == 0:
        return (jnp.zeros((L, K), ready.dtype),
                jnp.zeros((L,), ready.dtype))
    # interpret mode pays Python per grid cell: big blocks. TPU: sublane tile.
    bl = block_lanes if block_lanes is not None else (256 if interpret else 8)
    bl = min(bl, L)
    pad = (-L) % bl
    if pad:                     # absorbing padding lanes (all-+inf events)
        ready = jnp.pad(ready, ((0, pad), (0, 0)), constant_values=jnp.inf)
        exec_t = jnp.pad(exec_t, ((0, pad), (0, 0)))
        t_tr = jnp.pad(t_tr, (0, pad), constant_values=jnp.inf)
        tau_cap = jnp.pad(tau_cap, (0, pad))
        clock = jnp.pad(clock, (0, pad))
    Lp = L + pad
    lane_spec = pl.BlockSpec((bl, K), lambda i: (i, 0))
    col_spec = pl.BlockSpec((bl, 1), lambda i: (i, 0))
    c, fills = pl.pallas_call(
        _maxplus_kernel,
        grid=(Lp // bl,),
        in_specs=[lane_spec, lane_spec, col_spec, col_spec, col_spec],
        out_specs=[lane_spec, col_spec],
        out_shape=[jax.ShapeDtypeStruct((Lp, K), ready.dtype),
                   jax.ShapeDtypeStruct((Lp, 1), ready.dtype)],
        interpret=interpret,
    )(ready, exec_t, t_tr.reshape(-1, 1), tau_cap.reshape(-1, 1),
      clock.reshape(-1, 1))
    return c[:L], fills[:L, 0]
