"""Pallas kernel for the per-lane padded quantile sort (report builder).

The batched report builder (``simulate._presort_reports``) fills every
lane's quantile/violation cache from one ascending sort of a +inf-padded
(lane, request) latency matrix. This kernel runs that sort as a bitonic
network, one grid cell per lane block, entirely in VMEM: compare-exchange
partners at distance ``j`` are materialized by the reshape-flip trick
(``(bl, R/2j, 2, j)`` with the size-2 axis swapped — no gather), and the
stage direction/role masks come from ``broadcasted_iota`` bit tests. The
per-lane count of finite entries above a per-lane latency budget (the
violation-rate numerator) is fused into the same pass.

Sorting permutes values without arithmetic, so the sorted output is the
same float64 multiset whatever sorts it — NumPy's sort stays the bitwise
reference and this kernel is checked for *equality*, not tolerance
(latencies are strictly positive; no -0.0/+0.0 tie ambiguity).

R is padded to a power of two with +inf by the wrapper (the network needs
it); real latencies stay the leading prefix. ``interpret=True`` (default
off-TPU) runs the identical body on CPU for CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lane_sort_kernel(x_ref, bud_ref, o_ref, v_ref):
    x = x_ref[...]                                      # (bl, R), R pow2
    bl, R = x.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (bl, R), 1)
    k = 2
    while k <= R:                                       # bitonic network
        up = (idx & k) == 0                             # stage direction
        j = k // 2
        while j >= 1:
            y = x.reshape(bl, R // (2 * j), 2, j)
            part = jnp.concatenate([y[:, :, 1:2], y[:, :, 0:1]],
                                   axis=2).reshape(bl, R)
            lo = (idx & j) == 0                         # lower of the pair
            mn = jnp.minimum(x, part)
            mx = jnp.maximum(x, part)
            x = jnp.where(lo == up, mn, mx)
            j //= 2
        k *= 2
    o_ref[...] = x
    over = jnp.isfinite(x) & (x > bud_ref[...])
    v_ref[...] = over.sum(axis=1, keepdims=True).astype(jnp.int32)


def lane_sort(mat: jax.Array, budgets: jax.Array | None = None,
              block_lanes: int | None = None,
              interpret: bool | None = None):
    """Ascending per-lane sort of a +inf-padded (lanes, R) matrix.

    Returns the sorted matrix, or ``(sorted, violations)`` when per-lane
    latency ``budgets`` (lanes,) are given — ``violations[i]`` counts finite
    entries of lane ``i`` strictly above ``budgets[i]``. Contract of
    ``ref.lane_sort_ref`` / ``ref.lane_violations_ref``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    L, R = mat.shape
    want_viol = budgets is not None
    if L == 0 or R == 0:
        out = jnp.zeros((L, R), mat.dtype)
        return (out, jnp.zeros((L,), jnp.int32)) if want_viol else out
    if budgets is None:
        budgets = jnp.zeros((L,), mat.dtype)
    r_pad = (-R) % max(1, 1 << (R - 1).bit_length())    # next pow2
    if r_pad:
        mat = jnp.pad(mat, ((0, 0), (0, r_pad)), constant_values=jnp.inf)
    bl = block_lanes if block_lanes is not None else (256 if interpret else 8)
    bl = min(bl, L)
    l_pad = (-L) % bl
    if l_pad:
        mat = jnp.pad(mat, ((0, l_pad), (0, 0)), constant_values=jnp.inf)
        budgets = jnp.pad(budgets, (0, l_pad))
    Lp, Rp = mat.shape
    lane_spec = pl.BlockSpec((bl, Rp), lambda i: (i, 0))
    col_spec = pl.BlockSpec((bl, 1), lambda i: (i, 0))
    srt, viol = pl.pallas_call(
        _lane_sort_kernel,
        grid=(Lp // bl,),
        in_specs=[lane_spec, col_spec],
        out_specs=[lane_spec, col_spec],
        out_shape=[jax.ShapeDtypeStruct((Lp, Rp), mat.dtype),
                   jax.ShapeDtypeStruct((Lp, 1), jnp.int32)],
        interpret=interpret,
    )(mat, budgets.astype(mat.dtype).reshape(-1, 1))
    srt = srt[:L, :R]
    return (srt, viol[:L, 0]) if want_viol else srt
