"""Pallas TPU tiled matmul with fp32 VMEM accumulator.

Grid = (M/bm, N/bn, K/bk) with K innermost so the (bm, bn) accumulator stays
resident in VMEM across the contraction. Tiles default to 128x128x128 (MXU
native); the working set 3 * 128*128*4 B = 192 KiB fits VMEM with headroom
for double-buffered HBM->VMEM prefetch of the next K tile.

Used as the expert-FFN GEMM building block in the MoE path (per-expert
(capacity, d_model) x (d_model, d_ff) batches).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr, *, num_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...].astype(jnp.float32), b_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == num_k - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def tiled_matmul(a: jax.Array, b: jax.Array,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    nk = k // block_k
    return pl.pallas_call(
        functools.partial(_mm_kernel, num_k=nk),
        grid=(m // block_m, n // block_n, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
