"""Pure-jnp oracle for the SSD kernels: the chunked scan from
repro.models.layers (itself validated against step-by-step recurrence in the
test suite) restricted to the intra-chunk pieces the kernel computes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import _segsum, ssd_chunked


def ssd_chunk_ref(x, dA, dt, B, C):
    """Same contract as ssd_scan.ssd_chunk (single group).

    x: (b, nc, l, h, p); dA, dt: (b, nc, l, h); B, C: (b, nc, l, n).
    Returns (y_diag, states) with states (b, nc, h, n, p).
    """
    h = x.shape[3]
    Bh = jnp.repeat(B[:, :, :, None], h, axis=3)       # (b,nc,l,h,n)
    Ch = jnp.repeat(C[:, :, :, None], h, axis=3)
    dA_cs = jnp.cumsum(dA, axis=2)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))     # (b,nc,h,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)
    y_diag = jnp.einsum("bchls,bchls,bcshp,bcsh->bclhp", scores, L,
                        x.astype(jnp.float32), dt)
    decay = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)       # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchnp", Bh, decay, dt,
                        x.astype(jnp.float32))
    return y_diag.astype(x.dtype), states


def ssd_full_ref(x, dt, A, B, C, chunk):
    """Full SSD (intra + inter chunk), via the model-layer implementation."""
    return ssd_chunked(x, dt, A, B, C, chunk)
