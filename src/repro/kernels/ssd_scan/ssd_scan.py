"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

The SSD algorithm (arXiv:2405.21060) splits the sequence into chunks; within
a chunk the recurrence is expressed as a small attention-like quadratic form
(MXU work), while chunk-to-chunk state passing is a cheap recurrence. This
kernel computes, per (batch, chunk, head) grid cell, entirely in VMEM:

  y_diag = (C B^T  *  L  *  dt_row) x          (l, p)   intra-chunk output
  states = (B * decay * dt)^T x                (n, p)   chunk-final state

where L = exp(segsum(dt*A)) is the causal decay matrix. The inter-chunk
recurrence + off-diagonal correction stay in jnp (they are O(l) work and
bandwidth-trivial) — see ops.ssd_scan.

Block sizes: the chunk length l (default 128/256) and head dim p (64) are the
MXU dims; VMEM working set per cell = l*(p + 2n + l) * 4 bytes (< 1 MiB for
l=256, n=128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dA_ref, dt_ref, b_ref, c_ref,
                      y_ref, st_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)      # (l, p)
    dA = dA_ref[0, 0, :, 0].astype(jnp.float32)       # (l,)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)       # (l,)
    B = b_ref[0, 0].astype(jnp.float32)               # (l, n)
    C = c_ref[0, 0].astype(jnp.float32)               # (l, n)

    l = x.shape[0]
    dA_cs = jnp.cumsum(dA)
    seg = dA_cs[:, None] - dA_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    L = jnp.where(jj <= ii, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * L * dt[None, :], x,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay = jnp.exp(dA_cs[-1] - dA_cs)                 # (l,)
    wb = B * (decay * dt)[:, None]                     # (l, n)
    st = jax.lax.dot_general(wb, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (n, p)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


def ssd_chunk(x: jax.Array, dA: jax.Array, dt: jax.Array,
              B: jax.Array, C: jax.Array, interpret: bool = False):
    """Intra-chunk SSD.

    x: (b, nc, l, h, p); dA, dt: (b, nc, l, h); B, C: (b, nc, l, n)
    (single SSM group broadcast over heads).
    Returns (y_diag: (b, nc, l, h, p), states: (b, nc, h, n, p)).
    """
    b, nc, l, h, p = x.shape
    n = B.shape[-1]
    out = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, l, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, l, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, l, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, l, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, 1, p), lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, dA, dt, B, C)
    return out
