"""Pure-jnp oracle for the flash attention kernel: causal (optionally
sliding-window) multi-head attention, fp32 softmax."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  window: Optional[int] = None) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D). Causal; optional window."""
    s = q.shape[2]
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)
