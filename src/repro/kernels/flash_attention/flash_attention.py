"""Pallas TPU flash attention (causal, optional sliding window).

Blocking: grid = (B*H, S/BQ, S/BK); the KV loop is the innermost grid axis so
the (BQ, D) accumulator, running max m and normalizer l live in VMEM scratch
across KV iterations (online softmax). Block sizes default to 128x128 —
MXU-aligned on the (8, 128)/(128, 128) tiling of TPU v5e. Causality is
exploited structurally: KV blocks strictly above the diagonal are skipped via
@pl.when, and sliding-window masking zeroes within-block entries.

HBM->VMEM traffic per (q-block, kv-block): BQ*D + 2*BK*D + outputs; the
working set (q, k, v blocks + accumulator) is ~4*128*D*4 bytes, well inside
the ~16 MiB v5e VMEM for D <= 256.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, scale: float, block_q: int, block_k: int,
                  window: Optional[int], num_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip blocks strictly above the causal diagonal
    @pl.when(ki * block_k <= qi * block_q + block_q - 1)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (BQ, D)
        k = k_ref[0].astype(jnp.float32)                 # (BK, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D), causal flash attention."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        window=window, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # normalizer l
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
