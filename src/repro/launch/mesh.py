"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any JAX
initialization, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 (256 chips) per pod; 2 pods = 512."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever devices exist (CPU smoke / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (lumped per-chip figure)
HBM_BYTES = 16 * 2**30            # 16 GiB HBM per v5e chip
