import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: JAX locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes and extract roofline inputs from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single,multi --out benchmarks/results/dryrun.json

Results are flushed after every pair (resumable; pass --force to redo).
No arrays are ever allocated: inputs are ShapeDtypeStructs and only
.lower()/.compile() run.
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import dataclasses

import jax

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.steps import jitted_step
from repro.models import sharding as S

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective family, from compiled HLO.

    Sums *operand* sizes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute ops (post-SPMD shapes, i.e. per device).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        for op in _COLLECTIVES:
            tok = f" {op}(" if f" {op}(" in rhs else (f" {op}-start(" if f" {op}-start(" in rhs else None)
            if tok is None:
                continue
            pre, _, args = rhs.partition(tok)
            # operand shapes are printed inline in post-opt HLO; if absent,
            # fall back to the output shape (exact for all-reduce/permute).
            arg_str = args.split("),", 1)[0]
            shapes = _SHAPE_RE.findall(arg_str)
            if not shapes:
                shapes = _SHAPE_RE.findall(pre)
            out[op] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            counts[op] += 1
            break
    out["counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def analytic_model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for fwd-only (N = active params,
    D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _compile_once(cfg, shape, mesh, **kw) -> tuple[dict, object]:
    t0 = time.time()
    fn, args = jitted_step(cfg, shape, mesh, **kw)
    lowered = fn.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    rec = {"lower_s": round(t1 - t0, 1), "compile_s": round(time.time() - t1, 1)}
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {k: int(getattr(mem, k)) for k in
                         ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes")
                         if hasattr(mem, k)}
    except Exception as e:
        rec["memory"] = {"error": repr(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jax returns [dict]
            cost = cost[0] if cost else {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and k in
                       ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:
        rec["cost"] = {"error": repr(e)}
    rec["collectives"] = collective_bytes(compiled.as_text())
    return rec, compiled


def _probe_depths(cfg) -> tuple[int, int]:
    """Two small depths for per-layer cost extraction; hybrid archs need
    multiples of attn_every so shared-attention sites scale linearly."""
    if cfg.arch_type == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    return 2, 4


def _lin_extrapolate(v1: float, v2: float, l1: int, l2: int, L: int) -> float:
    per_layer = (v2 - v1) / (l2 - l1)
    base = v1 - l1 * per_layer
    return base + L * per_layer


def run_pair(arch: str, shape_name: str, multi_pod: bool,
             variant: dict | None = None) -> dict:
    """Three compiles per pair:
      1. FULL config, production scan-over-layers — proves the real
         (arch x shape x mesh) lowers + compiles; memory_analysis of the
         production artifact.
      2./3. small unrolled depths L1 < L2 — XLA cost analysis counts
         while-loop bodies once, so scanned stacks under-report flops/bytes/
         collectives by ~num_layers; unrolled probes give exact per-layer
         terms which we extrapolate linearly to the full depth.
    """
    variant = variant or {}
    cfg = get_config(arch)
    if variant.get("param_dtype") == "bf16":
        import jax.numpy as jnp
        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
    if variant.get("kv_quant"):
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    if variant.get("pad_heads"):
        cfg = dataclasses.replace(cfg, n_heads=variant["pad_heads"],
                                  head_dim=cfg.resolved_head_dim)
    kw = {"microbatches": variant.get("microbatches", 1),
          "fsdp_params": variant.get("fsdp_params", True)}
    shape = INPUT_SHAPES[shape_name]
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "devices": mesh.size, "variant": variant}
    with mesh:
        full, _ = _compile_once(cfg, shape, mesh, **kw)
        rec["full"] = full

        l1, l2 = _probe_depths(cfg)
        probes = {}
        for li in (l1, l2):
            pcfg = dataclasses.replace(cfg, num_layers=li, unroll=True)
            probes[li], _ = _compile_once(pcfg, shape, mesh, **kw)
        rec["probes"] = {str(k): v for k, v in probes.items()}

        L = cfg.num_layers
        extr: dict = {}
        for key in ("flops", "bytes accessed"):
            try:
                extr[key] = _lin_extrapolate(probes[l1]["cost"][key],
                                             probes[l2]["cost"][key], l1, l2, L)
            except Exception:
                pass
        try:
            extr["collective_bytes"] = _lin_extrapolate(
                probes[l1]["collectives"]["total"],
                probes[l2]["collectives"]["total"], l1, l2, L)
            extr["collectives_by_kind"] = {
                k: _lin_extrapolate(probes[l1]["collectives"][k],
                                    probes[l2]["collectives"][k], l1, l2, L)
                for k in _COLLECTIVES}
        except Exception:
            pass
        rec["extrapolated"] = extr

    rec["model_flops"] = analytic_model_flops(cfg, shape)
    rec["param_count"] = cfg.param_count()
    rec["active_param_count"] = cfg.active_param_count()
    rec["tokens"] = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    rec["ok"] = True
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="", help="variant key suffix")
    ap.add_argument("--param-dtype", default="", choices=["", "bf16"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp-params", action="store_true")
    ap.add_argument("--kv-quant", action="store_true")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="pad n_heads to this count (TP head padding)")
    args = ap.parse_args()
    variant = {}
    if args.kv_quant:
        variant["kv_quant"] = True
    if args.pad_heads:
        variant["pad_heads"] = args.pad_heads
    if args.param_dtype:
        variant["param_dtype"] = args.param_dtype
    if args.microbatches != 1:
        variant["microbatches"] = args.microbatches
    if args.no_fsdp_params:
        variant["fsdp_params"] = False

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = json.loads(out_path.read_text()) if out_path.exists() else {}

    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                key = f"{arch}|{shape_name}|{mesh_kind}"
                if args.tag:
                    key += f"|{args.tag}"
                if key in results and results[key].get("ok") and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = run_pair(arch, shape_name, mesh_kind == "multi",
                                   variant)
                except Exception:
                    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                           "ok": False, "error": traceback.format_exc()[-2000:]}
                    print(rec["error"], flush=True)
                results[key] = rec
                out_path.write_text(json.dumps(results, indent=1))
                status = "OK" if rec.get("ok") else "FAIL"
                full = rec.get("full", {})
                print(f"[dryrun] {key} {status} "
                      f"compile={full.get('compile_s')}s "
                      f"coll={rec.get('extrapolated', {}).get('collective_bytes')}",
                      flush=True)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"done: {n_ok}/{len(results)} pairs OK")


if __name__ == "__main__":
    main()
