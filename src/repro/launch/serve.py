"""Serving launcher: batched prefill+decode requests against one arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
      --requests 8 --prompt-len 32 --gen 16 [--reduced]
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_config, make_batch, reduced as reduce_cfg
from repro.runtime.serving import GenerationServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--bs", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    max_seq = args.prompt_len + args.gen
    server = GenerationServer(cfg, max_seq=max_seq, bs=args.bs)
    print(f"serving {cfg.name}: bs={args.bs}, prompt {args.prompt_len}, "
          f"gen {args.gen}")
    batches = (args.requests + args.bs - 1) // args.bs
    for i in range(batches):
        prompt = make_batch(cfg, args.prompt_len, args.bs, "prefill", seed=i)
        t0 = time.time()
        tokens = server.generate(prompt, steps=args.gen,
                                 prompt_len=args.prompt_len)
        dt = time.time() - t0
        print(f"batch {i}: {tokens.shape[0]}x{tokens.shape[1]} tokens in "
              f"{dt*1e3:.0f} ms ({tokens.shape[0]*tokens.shape[1]/dt:.1f} tok/s) "
              f"first seq: {tokens[0][:8].tolist()}")


if __name__ == "__main__":
    main()
