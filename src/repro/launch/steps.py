"""Jit-able step functions (train / prefill / decode) with their shardings.

These are the units the launcher jits, the dry-run lowers, and the Fulcrum
interleave runtime alternates between.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, batch_struct, input_specs
from repro.models import model as M
from repro.models import sharding as S
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: M.ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    microbatches: int = 1) -> Callable:
    """One optimizer step. microbatches > 1 = gradient accumulation via
    lax.scan: activation memory shrinks ~1/microbatches at the cost of one
    fp32 grad buffer (params-shaped, FSDP-sharded like params)."""
    if microbatches == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                M.train_loss, has_aux=True)(params, batch, cfg)
            new_params, new_opt, stats = adamw_update(grads, opt_state, params, opt_cfg)
            return new_params, new_opt, {**metrics, **stats}
        return train_step

    def train_step(params, opt_state, batch):
        mb_batch = jax.tree.map(
            lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                *x.shape[1:]), batch)

        def mb(acc, one):
            (loss, metrics), grads = jax.value_and_grad(
                M.train_loss, has_aux=True)(params, one, cfg)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                acc, grads)
            return acc, metrics

        zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(mb, zero, mb_batch, unroll=cfg.unroll)
        metrics = jax.tree.map(lambda x: jnp.mean(x), metrics)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, {**metrics, **stats}
    return train_step


def make_prefill_step(cfg: M.ModelConfig, max_seq_len: int) -> Callable:
    def prefill_step(params, batch):
        return M.prefill(params, batch, cfg, max_seq_len)
    return prefill_step


def make_decode_step(cfg: M.ModelConfig) -> Callable:
    def decode_step(params, cache, batch, pos):
        return M.decode_step(params, cache, batch, pos, cfg)
    return decode_step


# ---------------------------------------------------------------------------
# sharded jit assembly for a (cfg, shape, mesh) triple
# ---------------------------------------------------------------------------

def _ns(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _logits_spec(cfg: M.ModelConfig, mesh: Mesh, batch: int) -> P:
    bax = S.batch_axes(mesh, batch)
    v = "model" if cfg.padded_vocab % S.axis_size(mesh, "model") == 0 else None
    if cfg.arch_type == "audio":
        return P(bax, None, None, v)
    return P(bax, None, v)


def _act_constraint(mesh: Mesh, batch: int):
    """Pin activations to batch-over-data at layer boundaries so GSPMD
    all-gathers (small, per-layer) weights rather than activations. Rank-4
    (B, S, H, D) attention internals are pinned too (head axis replicated on
    model when indivisible), forcing the reshard to happen once in bf16."""
    sh3 = NamedSharding(mesh, S.activation_spec(mesh, batch))
    bax = S.batch_axes(mesh, batch)

    def fn(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, sh3)
        if x.ndim == 4:
            h_ax = "model" if x.shape[2] % S.axis_size(mesh, "model") == 0 else None
            sh4 = NamedSharding(mesh, P(bax, None, h_ax, None))
            return jax.lax.with_sharding_constraint(x, sh4)
        return x
    return fn


def jitted_step(cfg: M.ModelConfig, shape: InputShape, mesh: Mesh,
                opt_cfg: AdamWConfig = AdamWConfig(), donate: bool = True,
                microbatches: int = 1, fsdp_params: bool = True):
    """Returns (jitted_fn, abstract_args) for the given workload shape.

    abstract_args are ShapeDtypeStructs suitable for .lower(*abstract_args).
    Perf variants: microbatches (gradient accumulation), fsdp_params=False
    (TP-only param storage for serving).
    """
    pspec = S.param_specs(cfg, mesh, fsdp_on=fsdp_params)
    pshard = _ns(mesh, pspec)
    specs = input_specs(cfg, shape)
    bshard = _ns(mesh, S.batch_specs(specs["batch"], mesh))
    params_abs = jax.eval_shape(lambda k: M.init_params(k, cfg), jax.random.key(0))

    act = M.activation_sharding
    constraint = _act_constraint(mesh, shape.global_batch)

    def wrap(step_fn):
        def wrapped(*a):
            with act(constraint):
                return step_fn(*a)
        return wrapped

    if shape.kind == "train":
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        opt_shard = {"m": pshard, "v": pshard,
                     "step": NamedSharding(mesh, P())}
        if "master" in opt_abs:
            opt_shard["master"] = pshard
        fn = jax.jit(
            wrap(make_train_step(cfg, opt_cfg, microbatches=microbatches)),
            in_shardings=(pshard, opt_shard, bshard),
            out_shardings=(pshard, opt_shard, None),
            donate_argnums=(0, 1) if donate else (),
        )
        return fn, (params_abs, opt_abs, specs["batch"])

    if shape.kind == "prefill":
        cshard = _ns(mesh, S.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len))
        lshard = NamedSharding(mesh, _logits_spec(cfg, mesh, shape.global_batch))
        fn = jax.jit(
            wrap(make_prefill_step(cfg, shape.seq_len)),
            in_shardings=(pshard, bshard),
            out_shardings=(lshard, cshard),
        )
        return fn, (params_abs, specs["batch"])

    if shape.kind == "decode":
        cspec = S.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        cshard = _ns(mesh, cspec)
        bax = S.batch_axes(mesh, shape.global_batch)
        pos_shard = NamedSharding(mesh, P(bax))
        lshard = NamedSharding(mesh, _logits_spec(cfg, mesh, shape.global_batch))
        cache_abs = specs["cache"]
        fn = jax.jit(
            wrap(make_decode_step(cfg)),
            in_shardings=(pshard, cshard, bshard, pos_shard),
            out_shardings=(lshard, cshard),
            donate_argnums=(1,) if donate else (),
        )
        return fn, (params_abs, cache_abs, specs["batch"], specs["pos"])

    raise ValueError(shape.kind)
