"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --steps 50 [--reduced] [--batch 8 --seq 128] [--ckpt /tmp/ck.npz]

On this CPU container only --reduced configs execute; the full-size configs
are exercised through repro.launch.dryrun (lower+compile, no allocation).
On a TPU fleet the same jitted step runs under make_production_mesh().
"""
from __future__ import annotations

import argparse

from repro.configs import get_config, reduced as reduce_cfg
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    print(f"training {cfg.name} ({cfg.arch_type}), {cfg.num_layers}L "
          f"d={cfg.d_model}, batch={args.batch} seq={args.seq}")
    trainer = Trainer(cfg, args.batch, args.seq,
                      AdamWConfig(lr=args.lr, total_steps=args.steps),
                      ckpt_path=args.ckpt)
    trainer.restore()
    report = trainer.train(args.steps, log_every=10,
                           ckpt_every=args.ckpt_every)
    print(f"done: loss {report.losses[0]:.4f} -> {report.final_loss:.4f}, "
          f"{report.mean_step_time*1e3:.0f} ms/step")


if __name__ == "__main__":
    main()
