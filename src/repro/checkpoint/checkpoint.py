"""Sharding-aware npz checkpointing for param/optimizer pytrees.

Leaves are saved under '/'-joined path keys; restore re-places each leaf with
the provided shardings (so a checkpoint written on one mesh restores onto
another — resharding happens at device_put).
"""
from __future__ import annotations

import io
import os
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | Path, tree: Any, step: int | None = None) -> None:
    """Atomic write (tmp + rename)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore_checkpoint(path: str | Path, like: Any,
                       shardings: Optional[Any] = None) -> tuple[Any, int]:
    """Restore into the structure of `like`; returns (tree, step)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    step = int(flat.pop("__step__", np.asarray(0)))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)
    paths, treedef = leaves_with_path[0], leaves_with_path[1]
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step
