"""Neural-net building blocks for the repro model zoo.

Pure-functional JAX: every layer is an ``init(key, cfg) -> params`` plus an
``apply(params, x, ...) -> y`` pair, params are plain nested dicts so they
pjit/shard_map cleanly and checkpoint as flat npz.

Blocks provided: RMS/LayerNorm, rotary embeddings, GQA attention (optional
QKV bias, sliding window, KV cache with ring buffer), SwiGLU/GELU MLP,
top-k MoE with capacity-factor dispatch (einsum form so GSPMD shards the
expert axis), and the Mamba2 SSD mixer (chunked scan for train/prefill,
O(1) recurrence for decode).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

Params = dict
DEFAULT_ROPE_THETA = 10_000.0


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_apply(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * p["scale"]).astype(dtype)


def layernorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm_apply(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * lax.rsqrt(var + eps)
    return (x * p["scale"] + p["bias"]).astype(dtype)


def norm_init(kind: str, d: int) -> Params:
    return layernorm_init(d) if kind == "layernorm" else rmsnorm_init(d)


def norm_apply(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return layernorm_apply(p, x) if kind == "layernorm" else rmsnorm_apply(p, x)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = DEFAULT_ROPE_THETA) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = DEFAULT_ROPE_THETA) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense projections
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_apply(p: Params, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...i,io->...o", x, p["w"].astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = DEFAULT_ROPE_THETA
    unroll: bool = False


def attention_init(key, spec: AttnSpec) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, spec.d_model, spec.n_heads * spec.head_dim, bias=spec.qkv_bias),
        "wk": dense_init(kk, spec.d_model, spec.n_kv_heads * spec.head_dim, bias=spec.qkv_bias),
        "wv": dense_init(kv, spec.d_model, spec.n_kv_heads * spec.head_dim, bias=spec.qkv_bias),
        "wo": dense_init(ko, spec.n_heads * spec.head_dim, spec.d_model),
    }


def init_kv_cache(batch: int, spec: AttnSpec, cache_len: int,
                  dtype=jnp.bfloat16) -> Params:
    """Ring-buffer KV cache, laid out (B, cache_len, Hkv, D): the ring slot is
    the leading in-cache axis so the per-token scatter is contiguous and
    layout-transpose-free (#Perf hillclimb A, iteration 2).

    dtype=jnp.int8 selects the quantized cache (#Perf A, iteration 3):
    per-(slot, head) symmetric scales in bf16, halving cache HBM."""
    shape = (batch, cache_len, spec.n_kv_heads, spec.head_dim)
    cache = {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }
    if dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros(shape[:3] + (1,), jnp.bfloat16)
        cache["v_scale"] = jnp.zeros(shape[:3] + (1,), jnp.bfloat16)
    return cache


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., D) -> (int8 values, bf16 scale (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,Hq,D), k: (B,Hkv,Sk,D) -> (B,Hq,Sq,Sk) with grouped heads."""
    b, sq, hq, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    scores = jnp.einsum("bskgd,bktd->bkgst", qg, k)
    return scores.reshape(b, hq, sq, k.shape[2])


def _gqa_values(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (B,Hq,Sq,Sk), v: (B,Hkv,Sk,D) -> (B,Sq,Hq,D)."""
    b, hq, sq, sk = probs.shape
    hkv = v.shape[1]
    group = hq // hkv
    pg = probs.reshape(b, hkv, group, sq, sk)
    out = jnp.einsum("bkgst,bktd->bskgd", pg, v)
    return out.reshape(b, sq, hq, v.shape[3])


_Q_CHUNK = 1024  # flash-style query blocking beyond this sequence length


def _chunked_causal_attention(q, kt, vt, positions, scale, window, unroll=False):
    """Flash-style attention: scan over query blocks so live score memory is
    O(block x S) instead of O(S x S). Each block is rematerialized in the
    backward pass (same trade the Pallas kernel makes in VMEM)."""
    b, s, hq, d = q.shape
    nb = s // _Q_CHUNK
    assert s % _Q_CHUNK == 0, f"seq {s} not divisible by q-chunk {_Q_CHUNK}"
    qb = q.reshape(b, nb, _Q_CHUNK, hq, d).transpose(1, 0, 2, 3, 4)
    pb = positions.reshape(b, nb, _Q_CHUNK).transpose(1, 0, 2)

    @jax.checkpoint
    def block(q_blk, pos_blk):
        scores = _gqa_scores(q_blk, kt).astype(jnp.float32) * scale
        qpos = pos_blk[:, None, :, None]
        kpos = positions[:, None, None, :]
        mask = kpos <= qpos
        if window is not None:
            mask = mask & (kpos > qpos - window)
        scores = jnp.where(mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(q_blk.dtype)
        return _gqa_values(probs, vt)

    def body(_, xs):
        q_blk, pos_blk = xs
        return None, block(q_blk, pos_blk)

    _, out = lax.scan(body, None, (qb, pb), unroll=unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d)


def attention_apply(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    spec: AttnSpec,
    cache: Optional[Params] = None,
    cache_positions: Optional[jax.Array] = None,
    return_kv: bool = False,
) -> tuple[jax.Array, Any]:
    """Causal (optionally sliding-window) self-attention.

    Prefill/train path (cache None): full-sequence causal attention.
    Decode path (cache given): x is (B, 1, d); ``positions`` (B,1) is the
    absolute position of the new token; ``cache_positions`` (B, cache_len)
    holds the absolute position stored in each ring-buffer slot (-1 = empty).
    Returns (y, new_cache) where new_cache includes updated k/v/positions.
    """
    from repro.models.model import constrain   # activation-sharding hook
    b, s, _ = x.shape
    q = constrain(dense_apply(p["wq"], x).reshape(b, s, spec.n_heads, spec.head_dim))
    k = constrain(dense_apply(p["wk"], x).reshape(b, s, spec.n_kv_heads, spec.head_dim))
    v = constrain(dense_apply(p["wv"], x).reshape(b, s, spec.n_kv_heads, spec.head_dim))
    q = apply_rope(q, positions, spec.rope_theta)
    k = apply_rope(k, positions, spec.rope_theta)
    scale = 1.0 / math.sqrt(spec.head_dim)

    if cache is None:
        kt = k.transpose(0, 2, 1, 3)  # (B, Hkv, S, D)
        vt = v.transpose(0, 2, 1, 3)
        if s > _Q_CHUNK:
            out = _chunked_causal_attention(q, kt, vt, positions, scale,
                                            spec.sliding_window, spec.unroll)
        else:
            scores = _gqa_scores(q, kt).astype(jnp.float32) * scale
            qpos = positions[:, None, :, None]   # (B,1,Sq,1)
            kpos = positions[:, None, None, :]   # (B,1,1,Sk)
            mask = kpos <= qpos
            if spec.sliding_window is not None:
                mask = mask & (kpos > qpos - spec.sliding_window)
            scores = jnp.where(mask, scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = _gqa_values(probs, vt)
        y = dense_apply(p["wo"], out.reshape(b, s, spec.n_heads * spec.head_dim))
        return y, ((k, v) if return_kv else None)   # (B, S, Hkv, D) layout

    # --- decode: single new token against ring-buffer cache -------------
    # Scatter-based update in the cache's native (B, slot, H, D) layout:
    # touches O(B*Hkv*D) entries, no layout transposes. (The naive one-hot
    # masked arithmetic update rewrote the ENTIRE cache every token and
    # dominated the decode memory roofline; see EXPERIMENTS.md #Perf A.)
    cache_len = cache["k"].shape[1]
    quantized = cache["k"].dtype == jnp.int8
    b_idx = jnp.arange(b)
    pos = positions[:, 0]                                   # (B,)
    slot = (pos % cache_len).astype(jnp.int32)              # ring-buffer slot
    new_cache = {}
    if quantized:
        kq, ks = quantize_kv(k[:, 0])
        vq, vs = quantize_kv(v[:, 0])
        k_cache = cache["k"].at[b_idx, slot].set(kq)
        v_cache = cache["v"].at[b_idx, slot].set(vq)
        k_scale = cache["k_scale"].at[b_idx, slot].set(ks)
        v_scale = cache["v_scale"].at[b_idx, slot].set(vs)
        new_cache.update(k_scale=k_scale, v_scale=v_scale)
    else:
        knew = k[:, 0].astype(cache["k"].dtype)             # (B, Hkv, D)
        vnew = v[:, 0].astype(cache["v"].dtype)
        k_cache = cache["k"].at[b_idx, slot].set(knew)
        v_cache = cache["v"].at[b_idx, slot].set(vnew)
    new_cpos = cache_positions.at[b_idx, slot].set(
        pos.astype(cache_positions.dtype))

    # scores directly against the (B, T, Hkv, D) layout
    hkv = spec.n_kv_heads
    group = spec.n_heads // hkv
    qg = q.reshape(b, s, hkv, group, spec.head_dim)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg,
                        k_cache.astype(q.dtype)).astype(jnp.float32) * scale
    if quantized:   # fold the per-(slot, head) scale into the logits
        scores = scores * k_scale[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    scores = scores.reshape(b, spec.n_heads, s, cache_len)
    valid = new_cpos >= 0
    visible = new_cpos <= pos[:, None]
    if spec.sliding_window is not None:
        visible = visible & (new_cpos > (pos[:, None] - spec.sliding_window))
    mask = (valid & visible)[:, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    pg = probs.reshape(b, hkv, group, s, cache_len)
    if quantized:   # fold the v scale into the probabilities
        pg = pg * v_scale[..., 0].transpose(0, 2, 1)[:, :, None, None, :].astype(pg.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", pg, v_cache.astype(x.dtype))
    out = out.reshape(b, s, spec.n_heads, spec.head_dim)
    y = dense_apply(p["wo"], out.reshape(b, s, spec.n_heads * spec.head_dim))
    new_cache.update(k=k_cache, v=v_cache)
    return y, (new_cache, new_cpos)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, activation: str = "swiglu") -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": dense_init(k1, d_model, d_ff),
        "w2": dense_init(k2, d_ff, d_model),
    }
    if activation == "swiglu":
        p["w3"] = dense_init(k3, d_model, d_ff)
    return p


def mlp_apply(p: Params, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    h = dense_apply(p["w1"], x)
    if activation == "swiglu":
        h = jax.nn.silu(h) * dense_apply(p["w3"], x)
    else:
        h = jax.nn.gelu(h)
    return dense_apply(p["w2"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-factor dispatch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoeSpec:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 1024        # tokens per dispatch group (memory control)
    dense_residual: bool = False  # Arctic-style always-on dense branch
    dense_residual_ff: int = 0


def moe_init(key, spec: MoeSpec) -> Params:
    kr, ke1, ke2, ke3, kd = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(spec.d_model)
    p = {
        "router": jax.random.normal(kr, (spec.d_model, spec.n_experts), jnp.float32) * scale,
        "w1": jax.random.normal(ke1, (spec.n_experts, spec.d_model, spec.d_ff), jnp.float32) * scale,
        "w3": jax.random.normal(ke3, (spec.n_experts, spec.d_model, spec.d_ff), jnp.float32) * scale,
        "w2": jax.random.normal(ke2, (spec.n_experts, spec.d_ff, spec.d_model), jnp.float32)
        * (1.0 / math.sqrt(spec.d_ff)),
    }
    if spec.dense_residual:
        p["dense"] = mlp_init(kd, spec.d_model, spec.dense_residual_ff or spec.d_ff)
    return p


def moe_apply(p: Params, x: jax.Array, spec: MoeSpec) -> tuple[jax.Array, jax.Array]:
    """Returns (y, aux_loss). x: (B, S, d)."""
    b, s, d = x.shape
    # Dispatch groups never span batch rows: capacity-queue positions come
    # from a cumsum over the group, so mixing rows would make one sequence's
    # drops depend on another's tokens (and break prefill/decode parity,
    # where row lengths shift between calls).
    g_row = max(1, s // spec.group_size) if s >= spec.group_size else 1
    while s % g_row:                  # largest divisor of s, so the reshape
        g_row -= 1                    # is exact for any sequence length
    g = b * g_row
    t = s // g_row
    xg = x.reshape(g, t, d)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (G, T, E)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(probs, axis=1)                                   # (G, E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), spec.n_experts)
    usage = jnp.mean(top1, axis=1)                                      # (G, E)
    aux = jnp.mean(jnp.sum(density * usage, axis=-1)) * spec.n_experts

    capacity = int(math.ceil(t * spec.top_k / spec.n_experts * spec.capacity_factor))
    capacity = max(capacity, spec.top_k)

    gate_vals, gate_idx = lax.top_k(probs, spec.top_k)                  # (G, T, K)
    gate_vals = gate_vals / jnp.clip(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # position of each (token, k) routing choice within its expert queue
    sel = jax.nn.one_hot(gate_idx, spec.n_experts, dtype=jnp.float32)   # (G,T,K,E)
    flat = sel.reshape(g, t * spec.top_k, spec.n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat                     # (G,T*K,E)
    pos_in_expert = jnp.sum(pos_in_expert * flat, axis=-1).reshape(g, t, spec.top_k)
    keep = pos_in_expert < capacity
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    # dispatch / combine tensors: (G, T, E, C)
    slot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), capacity, dtype=jnp.float32)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_vals, sel, slot)
    dispatch = (combine > 0).astype(xg.dtype)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg)                     # (G,E,C,d)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"].astype(xg.dtype))
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"].astype(xg.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(xg.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(xg.dtype), ye)

    if spec.dense_residual:
        y = y + mlp_apply(p["dense"], xg)
    return y.reshape(b, s, d), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Mamba2 / SSD mixer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, spec: SSMSpec) -> Params:
    ki, ko, kc, ka, kdt = jax.random.split(key, 5)
    din = spec.d_inner
    d_in_proj = 2 * din + 2 * spec.n_groups * spec.d_state + spec.n_heads
    conv_dim = din + 2 * spec.n_groups * spec.d_state
    scale = 1.0 / math.sqrt(spec.d_model)
    a = jax.random.uniform(ka, (spec.n_heads,), jnp.float32, 1.0, 16.0)
    dt = jnp.exp(jax.random.uniform(kdt, (spec.n_heads,), jnp.float32) * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    return {
        "in_proj": jax.random.normal(ki, (spec.d_model, d_in_proj), jnp.float32) * scale,
        "conv_w": jax.random.normal(kc, (spec.d_conv, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(a),
        "D": jnp.ones((spec.n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.clip(dt, 1e-4))),
        "norm": rmsnorm_init(din),
        "out_proj": jax.random.normal(ko, (din, spec.d_model), jnp.float32) * (1.0 / math.sqrt(din)),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan (Mamba2, state-space duality).

    x: (b, s, h, p); dt: (b, s, h); A: (h,) (negative); B, C: (b, s, g, n).
    Returns (y: (b, s, h, p), final_state: (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    rep = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)  # (b,nc,l,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]            # (b,nc,l,h)
    dA_cs = jnp.cumsum(dA, axis=2)               # within-chunk cumsum

    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))               # (b,nc,h,l,l)
    scores = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)            # (b,nc,h,l,l)
    y_diag = jnp.einsum("bchls,bchls,bcshp,bcsh->bclhp",
                        scores, L, xc, dtc)

    # chunk-final states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)          # (b,nc,l,h)
    states = jnp.einsum("bclhn,bclh,bclh,bclhp->bchpn",
                        Bh, decay_states, dtc, xc)               # (b,nc,h,p,n)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                    # (b,nc,h)
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), x.dtype)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state *entering* the chunk

    final, prev_states = lax.scan(
        step,
        initial_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,nc,h,p,n)

    state_decay = jnp.exp(dA_cs)                                 # (b,nc,l,h)
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssm_apply(p: Params, x: jax.Array, spec: SSMSpec,
              cache: Optional[Params] = None,
              return_state: bool = False) -> tuple[jax.Array, Optional[Params]]:
    """Mamba2 block. Train/prefill when cache is None; else one-token decode.

    cache = {"conv": (B, d_conv-1, conv_dim), "ssm": (B, H, P, N)}.
    """
    b, s, _ = x.shape
    din = spec.d_inner
    gn = spec.n_groups * spec.d_state
    proj = dense_apply({"w": p["in_proj"]}, x)
    # split: z (din) | xbc (din + 2*gn) | dt (n_heads)
    z = proj[..., :din]
    xbc = proj[..., din:2 * din + 2 * gn]
    dt = proj[..., 2 * din + 2 * gn:]

    conv_w = p["conv_w"].astype(x.dtype)  # (d_conv, conv_dim)
    if cache is None:
        pad = jnp.zeros((b, spec.d_conv - 1, xbc.shape[-1]), xbc.dtype)
        xin = jnp.concatenate([pad, xbc], axis=1)
        new_conv = xin[:, -(spec.d_conv - 1):, :] if return_state else None
    else:
        xin = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv = xin[:, 1:, :]
    # depthwise causal conv1d
    idx = jnp.arange(s)[:, None] + jnp.arange(spec.d_conv)[None, :]
    windows = xin[:, idx, :]                                  # (B, S, d_conv, C)
    xbc = jax.nn.silu(jnp.einsum("bskc,kc->bsc", windows, conv_w) + p["conv_b"].astype(x.dtype))

    xi = xbc[..., :din].reshape(b, s, spec.n_heads, spec.head_dim)
    Bm = xbc[..., din:din + gn].reshape(b, s, spec.n_groups, spec.d_state)
    Cm = xbc[..., din + gn:].reshape(b, s, spec.n_groups, spec.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)

    if cache is None:
        # pad seq to a chunk multiple; dt=0 on pad => state unaffected
        pad_s = (-s) % spec.chunk
        if pad_s:
            padf = lambda a: jnp.pad(a, [(0, 0), (0, pad_s)] + [(0, 0)] * (a.ndim - 2))
            xi_p, dt_p, B_p, C_p = padf(xi), padf(dt), padf(Bm), padf(Cm)
        else:
            xi_p, dt_p, B_p, C_p = xi, dt, Bm, Cm
        y, final_state = ssd_chunked(
            xi_p.astype(jnp.float32), dt_p, A,
            B_p.astype(jnp.float32), C_p.astype(jnp.float32), spec.chunk)
        y = y[:, :s]
        new_cache = ({"conv": new_conv, "ssm": final_state} if return_state else None)
    else:
        # one-step recurrence: h' = h * exp(dt A) + dt * B x ; y = C h'
        rep = spec.n_heads // spec.n_groups
        B1 = jnp.repeat(Bm[:, 0], rep, axis=1)                   # (B,H,N)
        C1 = jnp.repeat(Cm[:, 0], rep, axis=1)
        dt1 = dt[:, 0]                                           # (B,H)
        xv = xi[:, 0].astype(jnp.float32)                        # (B,H,P)
        decay = jnp.exp(dt1 * A[None, :])[..., None, None]       # (B,H,1,1)
        upd = dt1[..., None, None] * xv[..., None] * B1[:, :, None, :].astype(jnp.float32)
        h_new = cache["ssm"].astype(jnp.float32) * decay + upd   # (B,H,P,N)
        y = jnp.einsum("bhpn,bhn->bhp", h_new, C1.astype(jnp.float32))[:, None]
        final_state = h_new
        new_cache = {"conv": new_conv, "ssm": h_new.astype(cache["ssm"].dtype)}

    y = y + xi.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rmsnorm_apply(p["norm"], y * jax.nn.silu(z))
    out = dense_apply({"w": p["out_proj"]}, y)
    return out, new_cache


def init_ssm_cache(batch: int, spec: SSMSpec, dtype=jnp.float32) -> Params:
    conv_dim = spec.d_inner + 2 * spec.n_groups * spec.d_state
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state), dtype),
    }


# ---------------------------------------------------------------------------
# Embeddings / output head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02}


def embedding_apply(p: Params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed_apply(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype))
