from repro.models.model import (ModelConfig, decode_step, forward, init_cache,
                                init_params, train_loss)
