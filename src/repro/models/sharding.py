"""GSPMD sharding rules for the model zoo.

Megatron-style tensor parallelism on the ``model`` mesh axis + FSDP/ZeRO-3
storage sharding of params and optimizer state over the data axes
(``('data',)`` per pod, ``('pod', 'data')`` multi-pod). Rules are applied by
pattern-matching parameter paths against an ``eval_shape`` of the param tree,
with divisibility fallbacks (a dim that does not divide the axis size is left
unsharded rather than failing to lower).

Expert axis: sharded on ``model`` when n_experts % model == 0 (expert
parallelism, e.g. Arctic 128e); otherwise the per-expert FFN dim shards
(tensor parallelism inside each expert, e.g. Mixtral 8e on a 16-way axis).
GQA KV heads replicate over ``model`` when n_kv < model.
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import ModelConfig, init_params, init_cache


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _maybe(mesh: Mesh, axes, dim: int):
    """Return `axes` if dim is divisible by their total size, else None."""
    if axes is None:
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_axes(mesh: Mesh, batch: int):
    """Largest prefix of (pod, data) whose product divides `batch`."""
    cand = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen: list[str] = []
    prod = 1
    for a in cand:
        if batch % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    return tuple(chosen) or None


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _leaf_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig, mesh: Mesh,
               fsdp_on: bool = True) -> P:
    fsdp = fsdp_axes(mesh) if fsdp_on else None
    M = "model"
    stacked = 0
    if path.startswith("layers/"):
        stacked = 1            # leading num_layers axis from the scan stack
    if path.startswith("embed_cb/"):
        stacked = 1            # leading codebook axis
    core = shape[stacked:]

    def done(*spec):
        return P(*([None] * stacked), *spec)

    # --- norms / scalars -------------------------------------------------
    last = path.rsplit("/", 1)[-1]
    if last in ("scale", "bias", "A_log", "D", "dt_bias", "conv_b") or len(core) == 0:
        return done(*([None] * len(core)))
    if last == "b":            # projection bias (fused head dim)
        return done(_maybe(mesh, M, core[0]))

    # --- embeddings ------------------------------------------------------
    if "embed" in path and last == "table":
        return done(_maybe(mesh, M, core[0]), _maybe(mesh, fsdp, core[1]))

    # --- MoE -------------------------------------------------------------
    if "/moe/" in path or path.endswith("router"):
        if last == "router":
            return done(_maybe(mesh, fsdp, core[0]), None)
        if last in ("w1", "w3") and len(core) == 3:     # (E, d, f)
            if core[0] % axis_size(mesh, M) == 0:
                return done(M, _maybe(mesh, fsdp, core[1]), None)
            return done(None, _maybe(mesh, fsdp, core[1]), _maybe(mesh, M, core[2]))
        if last == "w2" and len(core) == 3:             # (E, f, d)
            if core[0] % axis_size(mesh, M) == 0:
                return done(M, None, _maybe(mesh, fsdp, core[2]))
            return done(None, _maybe(mesh, M, core[1]), _maybe(mesh, fsdp, core[2]))
        # dense-residual branch falls through to MLP rules below

    # --- SSM -------------------------------------------------------------
    if last == "in_proj":
        return done(_maybe(mesh, fsdp, core[0]), _maybe(mesh, M, core[1]))
    if last == "conv_w":
        return done(None, _maybe(mesh, M, core[1]))
    if last == "out_proj":
        return done(_maybe(mesh, M, core[0]), _maybe(mesh, fsdp, core[1]))

    # --- attention / MLP 2-D weights --------------------------------------
    if len(core) == 2:
        d_in, d_out = core
        if "/attn/wo" in path or last == "w2":
            return done(_maybe(mesh, M, d_in), _maybe(mesh, fsdp, d_out))
        # wq/wk/wv, mlp w1/w3, vision_proj: shard output dim on model
        return done(_maybe(mesh, fsdp, d_in), _maybe(mesh, M, d_out))

    return done(*([None] * len(core)))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ModelConfig, mesh: Mesh, fsdp_on: bool = True):
    """fsdp_on=False -> tensor-parallel-only storage (serving variants that
    fit per-chip HBM skip the per-layer weight all-gathers)."""
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(_path_str(path), leaf.shape, cfg, mesh,
                                      fsdp_on), shapes)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), param_specs(cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(batch_shapes: dict, mesh: Mesh):
    """PartitionSpec per batch leaf: shard dim-0 (global batch) on data axes."""
    def spec(leaf):
        b = leaf.shape[0]
        ax = batch_axes(mesh, b)
        return P(ax, *([None] * (len(leaf.shape) - 1)))
    return jax.tree.map(spec, batch_shapes)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, seq_len: int):
    shapes = jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))
    bax = batch_axes(mesh, batch)
    M = "model"

    def spec(path, leaf):
        p = _path_str(path)
        sh = leaf.shape
        if p.startswith("kv_pos"):                       # (L|sites, B, clen)
            return P(None, bax, _maybe(mesh, M, sh[2]) if sh[2] else None)
        if p.startswith("kv"):                           # (L, B, clen, Hkv, hd)
            h_ax = _maybe(mesh, M, sh[3])
            c_ax = None if h_ax else _maybe(mesh, M, sh[2])
            return P(None, bax, c_ax, h_ax, None)
        if "conv" in p:                                  # (L, B, k, conv_dim)
            return P(None, bax, None, _maybe(mesh, M, sh[3]))
        if "ssm" in p:                                   # (L, B, H, P, N)
            return P(None, bax, _maybe(mesh, M, sh[2]), None, None)
        return P(*([None] * len(sh)))

    return jax.tree_util.tree_map_with_path(spec, shapes)


def activation_spec(mesh: Mesh, batch: int) -> P:
    return P(batch_axes(mesh, batch), None, None)
