"""Decoder-LM skeleton covering the six assigned architecture families.

One config-driven model: dense / MoE / SSM (Mamba2-SSD) / hybrid (Zamba2) /
VLM backbone / audio backbone. Homogeneous layer stacks are parameterised as
leading-axis-stacked pytrees and executed with ``jax.lax.scan`` so HLO size is
O(1) in depth (essential for 56-layer full-size dry-run compiles).

Entry points:
  init_params(key, cfg)                      -> params
  forward(params, batch, cfg)                -> logits (train / prefill)
  train_loss(params, batch, cfg)             -> (loss, metrics)
  init_cache(cfg, batch, cache_len)          -> decode cache
  decode_step(params, cache, batch, pos, cfg)-> (logits, new_cache)
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# activation-sharding hook
#
# With FSDP/ZeRO param sharding, GSPMD would otherwise propagate the *weight*
# sharding into activations (replicating the batch on every device). The
# launcher installs a with_sharding_constraint here that re-pins (B, S, d)
# activations to (batch->data axes, None, None) at every layer boundary, so
# the compiler all-gathers weights (small, per layer) instead of activations.
# ---------------------------------------------------------------------------

_ACT_CONSTRAINT = None


@contextlib.contextmanager
def activation_sharding(fn):
    """fn: jax.Array -> jax.Array (typically a with_sharding_constraint).
    Applied to rank-3 (B, S, d) tensors at layer boundaries and, when heads
    don't divide the model axis (e.g. Qwen's 40 heads on 16-way TP), to
    rank-4 attention internals so the unavoidable reshard happens once, in
    bf16, at an explicit point (#Perf hillclimb B)."""
    global _ACT_CONSTRAINT
    prev = _ACT_CONSTRAINT
    _ACT_CONSTRAINT = fn
    try:
        yield
    finally:
        _ACT_CONSTRAINT = prev


def constrain(x: jax.Array) -> jax.Array:
    if _ACT_CONSTRAINT is not None:
        return _ACT_CONSTRAINT(x)
    return x


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    n_heads: int                        # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    norm: str = "rmsnorm"
    activation: str = "swiglu"
    qkv_bias: bool = False
    sliding_window: Optional[int] = None      # training-time SWA (Mixtral)
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    moe_dense_residual: bool = False
    moe_aux_weight: float = 0.01
    # SSM
    ssm_state: int = 0
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # hybrid (Zamba2): shared attention block every `attn_every` SSM layers
    attn_every: int = 6
    # VLM stub frontend
    n_patches: int = 256
    d_vision: int = 1024
    # audio stub frontend (EnCodec codebooks)
    n_codebooks: int = 4
    # serving
    kv_cache_quant: bool = False        # int8 KV cache with bf16 scales
    long_context_mode: str = "native"   # native | swa (ring-buffer window)
    serve_window: int = 8192
    swa_activation_len: int = 65536     # swa mode kicks in beyond this context
    # numerics / memory
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    unroll: bool = False               # unroll layer scans (dry-run cost analysis)
    vocab_pad_multiple: int = 2048
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def attn_spec(self) -> L.AttnSpec:
        return L.AttnSpec(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            qkv_bias=self.qkv_bias, sliding_window=self.sliding_window,
            rope_theta=self.rope_theta, unroll=self.unroll)

    @property
    def moe_spec(self) -> L.MoeSpec:
        return L.MoeSpec(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.top_k, capacity_factor=self.capacity_factor,
            group_size=self.moe_group_size,
            dense_residual=self.moe_dense_residual,
            dense_residual_ff=self.d_ff)

    @property
    def ssm_spec(self) -> L.SSMSpec:
        return L.SSMSpec(
            d_model=self.d_model, d_state=self.ssm_state,
            expand=self.ssm_expand, head_dim=self.ssm_head_dim,
            n_groups=self.ssm_groups, chunk=self.ssm_chunk)

    @property
    def n_attn_sites(self) -> int:
        """Number of shared-attention applications in a hybrid stack."""
        if self.arch_type != "hybrid":
            return 0
        return len([i for i in range(self.num_layers) if i % self.attn_every == 0])

    def param_count(self) -> int:
        """Analytic parameter count (embedding + stack + head)."""
        d, f, v = self.d_model, self.d_ff, self.padded_vocab
        hd = self.resolved_head_dim
        per_layer = 0
        if self.arch_type in ("dense", "vlm", "audio"):
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            mlp = d * f * (3 if self.activation == "swiglu" else 2)
            per_layer = attn + mlp + 2 * d
        elif self.arch_type == "moe":
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            moe = self.n_experts * 3 * d * f + d * self.n_experts
            if self.moe_dense_residual:
                moe += 3 * d * f
            per_layer = attn + moe + 2 * d
        elif self.arch_type in ("ssm", "hybrid"):
            s = self.ssm_spec
            din = s.d_inner
            gn = s.n_groups * s.d_state
            per_layer = d * (2 * din + 2 * gn + s.n_heads) + din * d + s.d_conv * (din + 2 * gn) + 2 * din
        total = self.num_layers * per_layer + v * d
        if self.arch_type == "hybrid":
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            total += attn + 3 * d * f + 4 * d   # one shared block
        if self.arch_type == "vlm":
            total += self.d_vision * d
        if self.arch_type == "audio":
            total += (self.n_codebooks - 1) * v * d
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.arch_type != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_like = self.param_count() - self.num_layers * self.n_experts * 3 * d * f
        active = self.num_layers * (self.top_k + (1 if self.moe_dense_residual else 0)) * 3 * d * f
        return dense_like + active


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig) -> dict:
    """Params of ONE layer (unstacked)."""
    d = cfg.d_model
    if cfg.arch_type in ("dense", "vlm", "audio"):
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_init(cfg.norm, d),
            "attn": L.attention_init(k1, cfg.attn_spec),
            "ln2": L.norm_init(cfg.norm, d),
            "mlp": L.mlp_init(k2, d, cfg.d_ff, activation=cfg.activation),
        }
    if cfg.arch_type == "moe":
        k1, k2 = jax.random.split(key)
        return {
            "ln1": L.norm_init(cfg.norm, d),
            "attn": L.attention_init(k1, cfg.attn_spec),
            "ln2": L.norm_init(cfg.norm, d),
            "moe": L.moe_init(k2, cfg.moe_spec),
        }
    if cfg.arch_type in ("ssm", "hybrid"):
        return {
            "ln": L.norm_init(cfg.norm, d),
            "ssm": L.ssm_init(key, cfg.ssm_spec),
        }
    raise ValueError(cfg.arch_type)


def _apply_param_dtype(params: dict, cfg: ModelConfig) -> dict:
    """Cast weight matrices to cfg.param_dtype; keep 1-D params (norms,
    biases, A_log/D/dt_bias) in fp32 for stability."""
    if cfg.param_dtype == jnp.float32:
        return params
    return jax.tree.map(
        lambda x: x.astype(cfg.param_dtype) if x.ndim >= 2 else x, params)


def init_params(key, cfg: ModelConfig) -> dict:
    ke, kl, kx = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.num_layers)
    stack = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    params = {
        "embed": L.embedding_init(ke, cfg.padded_vocab, cfg.d_model),
        "layers": stack,
        "final_norm": L.norm_init(cfg.norm, cfg.d_model),
    }
    if cfg.arch_type == "hybrid":
        k1, k2 = jax.random.split(kx)
        params["shared_attn"] = {
            "ln1": L.norm_init(cfg.norm, cfg.d_model),
            "attn": L.attention_init(k1, cfg.attn_spec),
            "ln2": L.norm_init(cfg.norm, cfg.d_model),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, activation=cfg.activation),
        }
    if cfg.arch_type == "vlm":
        params["vision_proj"] = L.dense_init(kx, cfg.d_vision, cfg.d_model)
    if cfg.arch_type == "audio":
        keys = jax.random.split(kx, cfg.n_codebooks - 1)
        params["embed_cb"] = jax.vmap(
            lambda k: L.embedding_init(k, cfg.padded_vocab, cfg.d_model))(keys)
    return _apply_param_dtype(params, cfg)


# ---------------------------------------------------------------------------
# embedding frontends
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jax.Array:
    """Map a batch to (B, S, d_model) in compute dtype."""
    dt = cfg.compute_dtype
    if cfg.arch_type == "audio":
        toks = batch["tokens"]                                     # (B, S, CB)
        x = L.embedding_apply(params["embed"], toks[..., 0], dt)
        for i in range(cfg.n_codebooks - 1):
            tab = jax.tree.map(lambda t: t[i], params["embed_cb"])
            x = x + L.embedding_apply(tab, toks[..., i + 1], dt)
        return x
    if cfg.arch_type == "vlm":
        txt = L.embedding_apply(params["embed"], batch["tokens"], dt)   # (B, St, d)
        if "vision" not in batch:          # decode: text tokens only
            return txt
        vis = L.dense_apply(params["vision_proj"], batch["vision"].astype(dt))
        return jnp.concatenate([vis, txt], axis=1)
    return L.embedding_apply(params["embed"], batch["tokens"], dt)


def output_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = L.norm_apply(cfg.norm, params["final_norm"], x)
    if cfg.arch_type == "audio":
        outs = [L.unembed_apply(params["embed"], x)]
        for i in range(cfg.n_codebooks - 1):
            tab = jax.tree.map(lambda t: t[i], params["embed_cb"])
            outs.append(L.unembed_apply(tab, x))
        return jnp.stack(outs, axis=-2)                            # (B,S,CB,V)
    return L.unembed_apply(params["embed"], x)


# ---------------------------------------------------------------------------
# transformer stack (train / prefill)
# ---------------------------------------------------------------------------

def _dense_block(lp, x, positions, cfg: ModelConfig, cache=None, cpos=None):
    h, kv = L.attention_apply(lp["attn"], L.norm_apply(cfg.norm, lp["ln1"], x),
                              positions, cfg.attn_spec, cache, cpos)
    x = x + h
    mixer = lp.get("moe")
    aux = jnp.zeros((), jnp.float32)
    if mixer is not None:
        h, aux = L.moe_apply(mixer, L.norm_apply(cfg.norm, lp["ln2"], x), cfg.moe_spec)
    else:
        h = L.mlp_apply(lp["mlp"], L.norm_apply(cfg.norm, lp["ln2"], x), cfg.activation)
    return x + h, aux, kv


def _hybrid_shared(params, x, positions, cfg: ModelConfig, cache=None, cpos=None):
    sp = params["shared_attn"]
    spec = cfg.attn_spec
    h, kv = L.attention_apply(sp["attn"], L.norm_apply(cfg.norm, sp["ln1"], x),
                              positions, spec, cache, cpos)
    x = x + h
    x = x + L.mlp_apply(sp["mlp"], L.norm_apply(cfg.norm, sp["ln2"], x), cfg.activation)
    return x, kv


def forward(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / prefill). Returns (logits, moe_aux)."""
    x = constrain(embed_inputs(params, batch, cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(x, lp):
            x = constrain(x)
            x, aux, _ = _dense_block(lp, x, positions, cfg)
            return x, aux
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, auxs = lax.scan(body, x, params["layers"], unroll=cfg.unroll)
        return output_logits(params, x, cfg), jnp.mean(auxs)

    if cfg.arch_type == "ssm":
        def body(x, lp):
            x = constrain(x)
            h, _ = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x), cfg.ssm_spec)
            return x + h, jnp.zeros((), jnp.float32)
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, params["layers"], unroll=cfg.unroll)
        return output_logits(params, x, cfg), jnp.zeros((), jnp.float32)

    if cfg.arch_type == "hybrid":
        shared = params["shared_attn"]
        is_attn = jnp.array([i % cfg.attn_every == 0 for i in range(cfg.num_layers)])

        def body(x, inp):
            x = constrain(x)
            lp, attn_here = inp

            def with_attn(x):
                h, _ = L.attention_apply(
                    shared["attn"], L.norm_apply(cfg.norm, shared["ln1"], x),
                    positions, cfg.attn_spec)
                x = x + h
                return x + L.mlp_apply(shared["mlp"],
                                       L.norm_apply(cfg.norm, shared["ln2"], x),
                                       cfg.activation)

            x = lax.cond(attn_here, with_attn, lambda x: x, x)
            h, _ = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x), cfg.ssm_spec)
            return x + h, jnp.zeros((), jnp.float32)

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = lax.scan(body, x, (params["layers"], is_attn), unroll=cfg.unroll)
        return output_logits(params, x, cfg), jnp.zeros((), jnp.float32)

    raise ValueError(cfg.arch_type)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    # one-hot contraction (not take_along_axis) so a vocab-sharded logits
    # tensor reduces to partial sums + a tiny all-reduce under GSPMD instead
    # of an all-gather of the full logits.
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    return logz - gold


def train_loss(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":
        logits = logits[:, cfg.n_patches:]          # loss over text positions only
    xent = softmax_xent(logits, labels)
    loss = jnp.mean(xent) + cfg.moe_aux_weight * aux
    return loss, {"loss": loss, "xent": jnp.mean(xent), "moe_aux": aux}


# ---------------------------------------------------------------------------
# prefill (serving: process prompt, fill cache, emit last-token logits)
# ---------------------------------------------------------------------------

def _ring_fill(k_full: jax.Array, v_full: jax.Array, clen: int):
    """Scatter full-sequence KV (L,B,S,H,hd) into a ring buffer of length
    clen laid out (L,B,clen,H,hd). Slot i holds the *latest* position p < S
    with p % clen == i. Returns (k_cache, v_cache, slot_positions (clen,)),
    -1 for never-written slots."""
    s = k_full.shape[2]
    i = jnp.arange(clen)
    src = (s - 1) - ((s - 1 - i) % clen)
    valid = src >= 0
    srcc = jnp.clip(src, 0)
    k_cache = jnp.take(k_full, srcc, axis=2)
    v_cache = jnp.take(v_full, srcc, axis=2)
    slot_pos = jnp.where(valid, src, -1).astype(jnp.int32)
    zero = jnp.zeros((), k_cache.dtype)
    k_cache = jnp.where(valid[None, None, :, None, None], k_cache, zero)
    v_cache = jnp.where(valid[None, None, :, None, None], v_cache, zero)
    return k_cache, v_cache, slot_pos


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            max_seq_len: int, cache_dtype=jnp.bfloat16) -> tuple[jax.Array, dict]:
    """Process a full prompt; return (last-token logits, decode cache sized
    for a total context of max_seq_len)."""
    if cfg.kv_cache_quant:
        cache_dtype = jnp.int8
    x = constrain(embed_inputs(params, batch, cfg))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    clen = cache_len_for(cfg, max_seq_len)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(x, lp):
            x = constrain(x)
            h, kv = L.attention_apply(lp["attn"], L.norm_apply(cfg.norm, lp["ln1"], x),
                                      positions, cfg.attn_spec, return_kv=True)
            x = x + h
            mixer = lp.get("moe")
            if mixer is not None:
                h, _ = L.moe_apply(mixer, L.norm_apply(cfg.norm, lp["ln2"], x), cfg.moe_spec)
            else:
                h = L.mlp_apply(lp["mlp"], L.norm_apply(cfg.norm, lp["ln2"], x), cfg.activation)
            return x + h, kv
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (k_full, v_full) = lax.scan(body, x, params["layers"], unroll=cfg.unroll)
        if cache_dtype == jnp.int8:
            from repro.models.layers import quantize_kv
            kq, ks = quantize_kv(k_full)
            vq, vs = quantize_kv(v_full)
            kc, vc, slot_pos = _ring_fill(kq, vq, clen)
            ksc, vsc, _ = _ring_fill(ks, vs, clen)
            kv = {"k": kc, "v": vc, "k_scale": ksc, "v_scale": vsc}
        else:
            kc, vc, slot_pos = _ring_fill(k_full.astype(cache_dtype),
                                          v_full.astype(cache_dtype), clen)
            kv = {"k": kc, "v": vc}
        cache = {
            "kv": kv,
            "kv_pos": jnp.broadcast_to(slot_pos[None, None], (cfg.num_layers, b, clen)),
        }
        logits = output_logits(params, x[:, -1:], cfg)
        return logits, cache

    if cfg.arch_type == "ssm":
        def body(x, lp):
            x = constrain(x)
            h, st = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x),
                                cfg.ssm_spec, return_state=True)
            return x + h, st
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, states = lax.scan(body, x, params["layers"], unroll=cfg.unroll)
        ssm_cache = {"conv": states["conv"],
                     "ssm": states["ssm"].astype(jnp.float32)}
        logits = output_logits(params, x[:, -1:], cfg)
        return logits, {"ssm": ssm_cache}

    if cfg.arch_type == "hybrid":
        shared = params["shared_attn"]
        is_attn = jnp.array([i % cfg.attn_every == 0 for i in range(cfg.num_layers)])
        spec = cfg.attn_spec
        hd = cfg.resolved_head_dim

        def body(x, inp):
            x = constrain(x)
            lp, attn_here = inp

            def with_attn(x):
                h, (kt, vt) = L.attention_apply(
                    shared["attn"], L.norm_apply(cfg.norm, shared["ln1"], x),
                    positions, spec, return_kv=True)
                x = x + h
                x = x + L.mlp_apply(shared["mlp"],
                                    L.norm_apply(cfg.norm, shared["ln2"], x),
                                    cfg.activation)
                return x, (kt, vt)

            def without(x):
                z = jnp.zeros((b, s, cfg.n_kv_heads, hd), x.dtype)
                return x, (z, z)

            x, kv = lax.cond(attn_here, with_attn, without, x)
            h, st = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x),
                                cfg.ssm_spec, return_state=True)
            return x + h, (kv, st)
        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, ((k_full, v_full), states) = lax.scan(body, x, (params["layers"], is_attn), unroll=cfg.unroll)
        sites = [i for i in range(cfg.num_layers) if i % cfg.attn_every == 0]
        k_sites = k_full[jnp.array(sites)].astype(cache_dtype)
        v_sites = v_full[jnp.array(sites)].astype(cache_dtype)
        kc, vc, slot_pos = _ring_fill(k_sites, v_sites, clen)
        cache = {
            "ssm": {"conv": states["conv"], "ssm": states["ssm"].astype(jnp.float32)},
            "kv": {"k": kc, "v": vc},
            "kv_pos": jnp.broadcast_to(slot_pos[None, None], (len(sites), b, clen)),
        }
        logits = output_logits(params, x[:, -1:], cfg)
        return logits, cache

    raise ValueError(cfg.arch_type)


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    """KV ring-buffer length for a max context of seq_len.

    The ring buffer *is* the sliding window: when cache_len < seq_len old
    entries are overwritten, which enforces the window without extra masking.
    """
    if cfg.arch_type in ("ssm",):
        return 0
    if cfg.sliding_window is not None:                  # native SWA (Mixtral)
        return min(seq_len, cfg.sliding_window)
    if cfg.long_context_mode == "swa" and seq_len > cfg.swa_activation_len:
        return min(seq_len, cfg.serve_window)           # serving-only window
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> dict:
    """Decode cache for a maximum context of `seq_len` tokens."""
    if cfg.kv_cache_quant:
        dtype = jnp.int8
    clen = cache_len_for(cfg, seq_len)
    spec = cfg.attn_spec
    cache: dict = {}
    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        kv = jax.vmap(lambda _: L.init_kv_cache(batch, spec, clen, dtype))(
            jnp.arange(cfg.num_layers))
        cache["kv"] = kv
        cache["kv_pos"] = -jnp.ones((cfg.num_layers, batch, clen), jnp.int32)
    elif cfg.arch_type == "ssm":
        cache["ssm"] = jax.vmap(lambda _: L.init_ssm_cache(batch, cfg.ssm_spec))(
            jnp.arange(cfg.num_layers))
    elif cfg.arch_type == "hybrid":
        cache["ssm"] = jax.vmap(lambda _: L.init_ssm_cache(batch, cfg.ssm_spec))(
            jnp.arange(cfg.num_layers))
        n_sites = cfg.n_attn_sites
        cache["kv"] = jax.vmap(lambda _: L.init_kv_cache(batch, spec, clen, dtype))(
            jnp.arange(n_sites))
        cache["kv_pos"] = -jnp.ones((n_sites, batch, clen), jnp.int32)
    return cache


def _effective_decode_spec(cfg: ModelConfig) -> L.AttnSpec:
    # Ring-buffer overwrite already enforces the window during decode
    # (cache_len == window), so the decode mask needs no window term.
    return dataclasses.replace(cfg.attn_spec, sliding_window=None)


def decode_step(params: dict, cache: dict, batch: dict, pos: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """One-token decode. batch['tokens']: (B,1) (or (B,1,CB) audio);
    pos: (B,) absolute positions. Returns (logits, new_cache)."""
    x = constrain(embed_inputs(params, batch, cfg))    # (B, 1, d)
    positions = pos[:, None].astype(jnp.int32)
    spec = _effective_decode_spec(cfg)

    if cfg.arch_type in ("dense", "moe", "vlm", "audio"):
        def body(x, inp):
            x = constrain(x)
            lp, kv, cpos = inp
            x, _, kvout = _dense_block_decode(lp, x, positions, cfg, spec, kv, cpos)
            return x, kvout
        x, kvs = lax.scan(body, x, (params["layers"], cache["kv"], cache["kv_pos"]), unroll=cfg.unroll)
        new_cache = {"kv": kvs[0], "kv_pos": kvs[1]}
        return output_logits(params, x, cfg), new_cache

    if cfg.arch_type == "ssm":
        def body(x, inp):
            x = constrain(x)
            lp, sc = inp
            h, new_sc = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x),
                                    cfg.ssm_spec, sc)
            return x + h, new_sc
        x, new_ssm = lax.scan(body, x, (params["layers"], cache["ssm"]), unroll=cfg.unroll)
        return output_logits(params, x, cfg), {"ssm": new_ssm}

    if cfg.arch_type == "hybrid":
        shared = params["shared_attn"]
        is_attn = jnp.array([i % cfg.attn_every == 0 for i in range(cfg.num_layers)])
        site_idx = jnp.cumsum(is_attn.astype(jnp.int32)) - is_attn.astype(jnp.int32)

        # scan over layers; ssm caches are xs/ys, shared kv cache is carry
        def body2(carry, inp):
            x, kv, kv_pos = carry
            x = constrain(x)
            lp, sc, attn_here, site = inp

            def with_attn(operand):
                x, kv, kv_pos = operand
                kv_l = jax.tree.map(lambda t: t[site], kv)
                cpos_l = kv_pos[site]
                h, upd = L.attention_apply(
                    shared["attn"], L.norm_apply(cfg.norm, shared["ln1"], x),
                    positions, spec, kv_l, cpos_l)
                new_kv_l, new_cpos = upd
                x = x + h
                x = x + L.mlp_apply(shared["mlp"],
                                    L.norm_apply(cfg.norm, shared["ln2"], x),
                                    cfg.activation)
                kv = jax.tree.map(
                    lambda full, new: lax.dynamic_update_index_in_dim(
                        full, new.astype(full.dtype), site, 0), kv, new_kv_l)
                kv_pos = lax.dynamic_update_index_in_dim(kv_pos, new_cpos, site, 0)
                return x, kv, kv_pos

            x, kv, kv_pos = lax.cond(attn_here, with_attn, lambda o: o, (x, kv, kv_pos))
            h, new_sc = L.ssm_apply(lp["ssm"], L.norm_apply(cfg.norm, lp["ln"], x),
                                    cfg.ssm_spec, sc)
            return (x + h, kv, kv_pos), new_sc

        (x, kv, kv_pos), new_ssm = lax.scan(body2, (x, cache["kv"], cache["kv_pos"]),
            (params["layers"], cache["ssm"], is_attn, site_idx),
            unroll=cfg.unroll)
        new_cache = {"ssm": new_ssm, "kv": kv, "kv_pos": kv_pos}
        return output_logits(params, x, cfg), new_cache

    raise ValueError(cfg.arch_type)


def _dense_block_decode(lp, x, positions, cfg: ModelConfig, spec, kv, cpos):
    h, upd = L.attention_apply(lp["attn"], L.norm_apply(cfg.norm, lp["ln1"], x),
                               positions, spec, kv, cpos)
    new_kv, new_cpos = upd
    x = x + h
    mixer = lp.get("moe")
    aux = jnp.zeros((), jnp.float32)
    if mixer is not None:
        h, aux = L.moe_apply(mixer, L.norm_apply(cfg.norm, lp["ln2"], x), cfg.moe_spec)
    else:
        h = L.mlp_apply(lp["mlp"], L.norm_apply(cfg.norm, lp["ln2"], x), cfg.activation)
    return x + h, aux, (new_kv, new_cpos)
