"""Deterministic synthetic token pipeline with host-side prefetch and
sharding-aware device placement.

Real deployments swap `SyntheticTokenSource` for a tokenized corpus reader;
everything downstream (batching, sharding, prefetch) is source-agnostic.
"""
from __future__ import annotations

import dataclasses
import threading
import queue as queue_mod
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models.model import ModelConfig


class SyntheticTokenSource:
    """Seeded stream of token batches shaped for the given architecture.

    Generates Zipf-distributed token ids (more realistic unembedding gradients
    than uniform) with next-token labels.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int, seed: int = 0):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self._rng = np.random.default_rng(seed)
        zipf = 1.0 / np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = zipf / zipf.sum()

    def _tokens(self, shape) -> np.ndarray:
        flat = self._rng.choice(self.cfg.vocab_size, size=int(np.prod(shape)),
                                p=self._probs)
        return flat.reshape(shape).astype(np.int32)

    def __iter__(self) -> Iterator[dict]:
        cfg = self.cfg
        while True:
            if cfg.arch_type == "audio":
                toks = self._tokens((self.batch, self.seq_len + 1, cfg.n_codebooks))
                yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            elif cfg.arch_type == "vlm":
                s_txt = self.seq_len - cfg.n_patches
                toks = self._tokens((self.batch, s_txt + 1))
                vis = self._rng.standard_normal(
                    (self.batch, cfg.n_patches, cfg.d_vision)).astype(np.float32)
                yield {"tokens": toks[:, :-1], "labels": toks[:, 1:], "vision": vis}
            else:
                toks = self._tokens((self.batch, self.seq_len + 1))
                yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class ShardedPrefetcher:
    """Host-thread prefetch + device_put with the batch sharding, so input
    H2D transfer overlaps the previous step's compute."""

    def __init__(self, source, mesh: Optional[Mesh] = None,
                 shardings: Optional[dict] = None, depth: int = 2):
        self.source = iter(source)
        self.shardings = shardings
        self.q: queue_mod.Queue = queue_mod.Queue(maxsize=depth)
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _place(self, batch: dict) -> dict:
        if self.shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.shardings[k]) for k, v in batch.items()}

    def _worker(self):
        for batch in self.source:
            self.q.put(self._place(batch))

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()
