"""Fulcrum-on-JAX: concurrent DNN training + inferencing scheduler (CS.DC
2025 reproduction) inside a multi-pod JAX training/serving framework."""
__version__ = "1.0.0"
