"""Fleet-scale serving (``Scenario.FLEET``): one aggregate request stream
served on K heterogeneous edge devices, every window stepped as ONE batched
program — one weighted round-robin dispatch pass, one batched grid solve
per planning-ladder rung, one multi-lane engine call (devices are lanes).

Each device is the base Orin model with deterministic per-device time/power
multipliers (``fleet_device``), governed by its own closed-loop controller
state (EWMA rate estimate, latency feedback, backlog carryover). The
batched step is bitwise-identical on NumPy to serving the K devices one by
one with the existing single-device loop — ``--sequential`` runs that
reference instead so the two can be diffed. ``--fused`` (jax/pallas only)
collapses each window further: the whole plan ladder + admission + engine
runs as ONE compiled launch per window (``core.fused_window``), and the
per-window host-dispatch count is printed from the backend counters.

Run: PYTHONPATH=src python examples/fleet_serving.py [--devices 8]
     [--dispatch least-backlog] [--backend jax] [--sequential] [--fused]
"""
import argparse

import numpy as np

from repro.core import fleet as F
from repro.core.controller import ControllerConfig
from repro.core.device_model import INFER_WORKLOADS

POWER, LATENCY = 30.0, 0.1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--dnn", default="mobilenet",
                    choices=sorted(INFER_WORKLOADS))
    ap.add_argument("--dispatch", default="capacity",
                    choices=["capacity", "least-backlog"])
    ap.add_argument("--backend", default=None,
                    help="engine backend (numpy/jax/pallas; default env)")
    ap.add_argument("--sequential", action="store_true",
                    help="run the K-sequential-loops reference instead of "
                         "the batched fleet step")
    ap.add_argument("--fused", action="store_true",
                    help="run each window as ONE compiled solve+simulate "
                         "launch (jax/pallas backends only)")
    args = ap.parse_args()
    if args.fused and args.sequential:
        ap.error("--fused fuses the batched step; drop --sequential")

    spec = F.FleetSpec(args.devices, seed=3, dispatch=args.dispatch)
    cfg = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                           feedback=True, carry_backlog=True,
                           mode_switch_s=0.25)
    # aggregate offered rate per window: cruise, surge, recover
    rates = [30.0 * args.devices * m for m in (0.9, 1.5, 0.8, 1.1)]
    serve = F.serve_fleet_sequential if args.sequential else F.serve_fleet
    kw = {"fused": True} if args.fused else {}
    from repro.core.backend import dispatch_count
    d0 = dispatch_count()
    wins = serve(INFER_WORKLOADS[args.dnn], POWER, LATENCY, rates, spec,
                 window_duration=5.0, arrivals="poisson", seed=11,
                 backend=args.backend, controller=cfg, **kw)
    d1 = dispatch_count()

    kind = ("fused" if args.fused
            else "sequential" if args.sequential else "batched")
    print(f"{kind} fleet of "
          f"{args.devices} devices, dispatch={args.dispatch}")
    if d1 > d0:
        print(f"compiled-program launches: {d1 - d0} "
              f"({(d1 - d0) / len(rates):.1f} per window)")
    ts = [d.time_scale for d in spec.devices()]
    print(f"device time scales: min={min(ts):.3f} max={max(ts):.3f}")
    print(f"{'win':>3} {'rate':>7} {'offered':>8} {'goodput':>8} "
          f"{'power_w':>8} {'served_devs':>11}  dispatch")
    for i, wr in enumerate(wins):
        served = sum(d.solution is not None for d in wr.devices)
        counts = np.asarray(wr.dispatch_counts)
        print(f"{i:>3} {wr.rate:>7.1f} {wr.offered_requests:>8} "
              f"{wr.goodput:>8.3f} {wr.attributed_power:>8.1f} "
              f"{served:>4}/{len(wr.devices):<4}  "
              f"min={counts.min()} max={counts.max()}")


if __name__ == "__main__":
    main()
