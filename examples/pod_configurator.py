"""Beyond-paper example: Fulcrum's GMD as a TPU-pod auto-configurator.

For each assigned architecture, search (tensor-parallel width, microbatches,
remat) for the train_4k shape with ~11 roofline "profiles" — the TPU analogue
of profiling ~11 power modes on a Jetson — and compare against the exhaustive
oracle over the knob grid. The HBM budget (16 GiB/chip) plays the paper's
power budget; step time plays minibatch time.

Run: PYTHONPATH=src python examples/pod_configurator.py [--chips 256]
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.tpu_adapter import (GMDForTPU, RooflineTPUModel, TPUKnobSpace,
                                    exhaustive_best)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()

    print(f"{'arch':16s} {'GMD config':>20s} {'t_step':>8s} {'HBM':>7s} "
          f"{'probes':>6s} {'vs oracle':>9s}")
    for arch in ARCH_IDS:
        model = RooflineTPUModel(get_config(arch), args.seq, args.batch,
                                 "train", chips=args.chips)
        space = TPUKnobSpace(args.chips)
        gmd = GMDForTPU(model, space)
        sol = gmd.solve()
        opt = exhaustive_best(model, space)
        if sol is None:
            note = ("does not fit 16 GiB/chip at any config — needs "
                    "multi-pod (--chips 512) or 8-bit optimizer state"
                    if opt is None else "search failed")
            print(f"{arch:16s} {'-':>20s} {'-':>8s} {'-':>7s} "
                  f"{gmd.num_profiles:6d} {note}")
            continue
        exc = 100 * (sol.time - opt[1]) / opt[1]
        print(f"{arch:16s} {str(sol.pm):>20s} {sol.time*1e3:7.0f}m "
              f"{sol.power/2**30:6.1f}G {gmd.num_profiles:6d} {exc:+8.1f}%")


if __name__ == "__main__":
    main()
