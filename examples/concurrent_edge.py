"""End-to-end driver: concurrent DNN training + inference serving under
Fulcrum, with REAL JAX execution (the paper's headline scenario).

 1. GMD solves the concurrent problem on the edge-device model -> plan
    (power mode, inference minibatch size bs, interleave factor).
 2. The plan's bs drives the real managed-interleave runtime: one process
    owns the accelerator, alternating jitted train minibatches of one
    reduced model with jitted inference minibatches of another, switching
    only at minibatch boundaries; requests arrive at a constant rate and
    per-request latency is measured wall-clock.

Run: PYTHONPATH=src python examples/concurrent_edge.py \
         [--train-arch stablelm-1.6b --infer-arch internvl2-1b --duration 15]
"""
import argparse

from repro.configs import get_config, reduced
from repro.core import problem as P
from repro.core.device_model import DeviceModel, workload_from_model_config
from repro.core.scheduler import Fulcrum
from repro.runtime.interleave_runtime import (InterleaveConfig,
                                              ManagedInterleaveRuntime)
from repro.runtime.serving import BatchInferenceServer
from repro.runtime.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-arch", default="stablelm-1.6b")
    ap.add_argument("--infer-arch", default="internvl2-1b")
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--latency-budget", type=float, default=4.0)
    ap.add_argument("--power-budget", type=float, default=35.0)
    args = ap.parse_args()

    # 1. plan on the device model
    dev = DeviceModel()
    w_tr = workload_from_model_config(get_config(args.train_arch), "train")
    w_in = workload_from_model_config(get_config(args.infer_arch), "infer")
    prob = P.ConcurrentProblem(args.power_budget, args.latency_budget, args.rate)
    plan = Fulcrum(dev).solve_concurrent(w_tr, w_in, prob, strategy="gmd")
    if plan is None:
        print("Fulcrum: no feasible plan under the budgets"); return
    s = plan.solution
    print(f"Fulcrum plan: pm={s.pm} bs={s.bs} tau_tr={s.tau_tr} "
          f"(predicted latency {s.time*1e3:.0f} ms, power {s.power:.1f} W, "
          f"{plan.profiling_runs} modes profiled)")

    # 2. execute for real on CPU with reduced models
    print("building models + compiling steps ...")
    trainer = Trainer(reduced(get_config(args.train_arch)), batch=4, seq_len=64)
    server = BatchInferenceServer(reduced(get_config(args.infer_arch)),
                                  seq_len=64, bs=s.bs or 4)
    runtime = ManagedInterleaveRuntime(
        trainer, server,
        InterleaveConfig(arrival_rate=args.rate, infer_bs=s.bs or 4,
                         latency_budget=args.latency_budget,
                         duration=args.duration))
    print(f"running managed interleaving for {args.duration:.0f} s wall ...")
    rep = runtime.run()
    print(f"requests served: {len(rep.latencies)}  "
          f"median latency {rep.latency_quantile(0.5)*1e3:.0f} ms  "
          f"p95 {rep.latency_quantile(0.95)*1e3:.0f} ms  "
          f"violations {100*rep.violation_rate(args.latency_budget):.1f}%")
    print(f"training minibatches completed concurrently: {rep.train_minibatches} "
          f"({rep.train_throughput:.2f}/s)")


if __name__ == "__main__":
    main()
