"""Dynamic arrival rates (paper §5.4/§7.4): serve an inference workload whose
request rate changes every window, replanning with GMD only when the current
plan stops satisfying the new rate — profiled modes are reused across
windows. Each window is then *executed* by the trace-driven engine
(core.simulate) over a uniform or seeded-Poisson arrival trace.

``--closed-loop`` flips the serving loop from open loop (oracle rates, each
window independent) to the feedback controller (core.controller): the rate
is *estimated* from the observed arrivals (EWMA over inter-arrival gaps,
1.5x planning margin), the previous window's executed violation rate scales
the next effective latency budget, backlogged requests carry across window
boundaries, and power-mode switches cost 0.5 wall seconds.

``--admission`` (implies --closed-loop) adds burst survival: requests the
committed plan provably cannot serve within budget are shed (dropped) or
deferred (re-offered at the next window start), the plan's service headroom
is sized at the window's p95 Poisson arrival-count quantile, and the report
gains goodput / shed / deferred columns.

Run: PYTHONPATH=src:. python examples/dynamic_serving.py [--trace azure]
     [--arrivals poisson] [--strategy rnd150] [--closed-loop]
     [--admission shed]
"""
import argparse

from benchmarks.bench_dynamic import make_traces
from repro.core.controller import ControllerConfig
from repro.core.device_model import DeviceModel, INFER_WORKLOADS
from repro.core.scheduler import Fulcrum

POWER, LATENCY = 40.0, 0.1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="azure",
                    choices=["azure", "alibaba", "poisson"])
    ap.add_argument("--dnn", default="resnet50")
    ap.add_argument("--strategy", default="gmd")
    ap.add_argument("--arrivals", default="uniform",
                    choices=["uniform", "poisson"])
    ap.add_argument("--closed-loop", action="store_true",
                    help="EWMA-estimated rates + executed-latency feedback "
                         "+ backlog carryover + mode-switch cost")
    ap.add_argument("--admission", default="none",
                    choices=["none", "shed", "defer"],
                    help="SLO-aware admission control (implies "
                         "--closed-loop): shed drops requests the plan "
                         "cannot serve in budget, defer re-offers them at "
                         "the next window start")
    args = ap.parse_args()
    closed = args.closed_loop or args.admission != "none"

    rates = make_traces()[args.trace]
    dev = DeviceModel()
    w = INFER_WORKLOADS[args.dnn]
    f = Fulcrum(dev)
    controller = ControllerConfig(
        rate_estimator="ewma", rate_margin=1.5, feedback=True,
        carry_backlog=True, mode_switch_s=0.5,
        admission=args.admission,
        burst_quantile=0.95 if args.admission != "none" else 0.0,
        defer_cap=1000 if args.admission == "defer" else None) \
        if closed else None
    windows = f.serve_dynamic(w, POWER, LATENCY, rates,
                              strategy=args.strategy, window_duration=30.0,
                              arrivals=args.arrivals, controller=controller)

    loop = "closed loop" if closed else "open loop"
    if args.admission != "none":
        loop += f", admission={args.admission}"
    print(f"{args.dnn} on {args.trace} trace ({args.arrivals} arrivals, "
          f"{args.strategy}, {loop}): {len(rates)} x 5-min windows, "
          f"power<={POWER:.0f} W, latency<={LATENCY*1e3:.0f} ms")
    print(f"{'win':>3} {'rate':>6} {'est':>6} {'pm':>18} {'bs':>3} "
          f"{'p95_ms':>7} {'viol%':>5} {'pow_W':>6} {'sw_s':>4} {'carry':>5} "
          f"{'good%':>5} {'shed':>5} {'defer':>5}")
    found = 0
    for i, wr in enumerate(windows):
        est = f"{wr.estimated_rate:6.1f}" if wr.estimated_rate is not None \
            else " " * 6
        if wr.solution is None:
            print(f"{i:3d} {wr.rate:6.1f} {est} {'(no solution)':>18}")
            continue
        found += 1
        sol, rep = wr.solution, wr.report
        gp = f"{100*wr.goodput:5.1f}" if wr.goodput is not None else " " * 5
        print(f"{i:3d} {wr.rate:6.1f} {est} {str(sol.pm):>18} {sol.bs:3d} "
              f"{rep.latency_quantile(0.95)*1e3:7.1f} "
              f"{100*rep.violation_rate(LATENCY):5.1f} {sol.power:6.1f} "
              f"{wr.mode_switch_s:4.1f} {wr.carried_requests:5d} "
              f"{gp} {wr.shed_requests:5d} {wr.deferred_requests:5d}")
    print(f"solutions found: {found}/{len(rates)}")
    if closed:
        sat = sum(wr.report is not None
                  and wr.report.violation_rate(LATENCY) <= 0.05
                  for wr in windows)
        print(f"windows meeting the budget (p95 <= {LATENCY*1e3:.0f} ms): "
              f"{sat}/{len(windows)}")
    if args.admission != "none":
        gps = [wr.goodput for wr in windows if wr.goodput is not None]
        print(f"mean goodput {100*sum(gps)/max(1, len(gps)):.1f}% | "
              f"shed {sum(wr.shed_requests for wr in windows)} | "
              f"deferred {sum(wr.deferred_requests for wr in windows)}")


if __name__ == "__main__":
    main()
