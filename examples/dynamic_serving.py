"""Dynamic arrival rates (paper §5.4/§7.4): serve an inference workload whose
request rate changes every window, replanning with GMD only when the current
plan stops satisfying the new rate — profiled modes are reused across
windows. Each window is then *executed* by the trace-driven engine
(core.simulate) over a uniform or seeded-Poisson arrival trace.

Run: PYTHONPATH=src:. python examples/dynamic_serving.py [--trace azure]
     [--arrivals poisson] [--strategy rnd150]
"""
import argparse

from benchmarks.bench_dynamic import make_traces
from repro.core.device_model import DeviceModel, INFER_WORKLOADS
from repro.core.scheduler import Fulcrum

POWER, LATENCY = 40.0, 0.1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="azure",
                    choices=["azure", "alibaba", "poisson"])
    ap.add_argument("--dnn", default="resnet50")
    ap.add_argument("--strategy", default="gmd")
    ap.add_argument("--arrivals", default="uniform",
                    choices=["uniform", "poisson"])
    args = ap.parse_args()

    rates = make_traces()[args.trace]
    dev = DeviceModel()
    w = INFER_WORKLOADS[args.dnn]
    f = Fulcrum(dev)
    windows = f.serve_dynamic(w, POWER, LATENCY, rates,
                              strategy=args.strategy, window_duration=30.0,
                              arrivals=args.arrivals)

    print(f"{args.dnn} on {args.trace} trace ({args.arrivals} arrivals, "
          f"{args.strategy}): {len(rates)} x 5-min windows, "
          f"power<={POWER:.0f} W, latency<={LATENCY*1e3:.0f} ms")
    print(f"{'win':>3} {'rate':>6} {'pm':>18} {'bs':>3} {'p95_ms':>7} "
          f"{'viol%':>5} {'pow_W':>6}")
    found = 0
    for i, wr in enumerate(windows):
        if wr.solution is None:
            print(f"{i:3d} {wr.rate:6.1f} {'(no solution)':>18}")
            continue
        found += 1
        sol, rep = wr.solution, wr.report
        print(f"{i:3d} {wr.rate:6.1f} {str(sol.pm):>18} {sol.bs:3d} "
              f"{rep.latency_quantile(0.95)*1e3:7.1f} "
              f"{100*rep.violation_rate(LATENCY):5.1f} {sol.power:6.1f}")
    print(f"solutions found: {found}/{len(rates)}")


if __name__ == "__main__":
    main()
