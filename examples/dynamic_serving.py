"""Dynamic arrival rates (paper §5.4/§7.4): serve an inference workload whose
request rate changes every window, replanning with GMD only when the current
plan stops satisfying the new rate — profiled modes are reused across windows.

Run: PYTHONPATH=src:. python examples/dynamic_serving.py [--trace azure]
"""
import argparse

from benchmarks.bench_dynamic import make_traces
from repro.core import problem as P
from repro.core.device_model import DeviceModel, INFER_WORKLOADS
from repro.core.interleave import simulate_managed
from repro.core.scheduler import Fulcrum

POWER, LATENCY = 40.0, 0.1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", default="azure", choices=["azure", "alibaba", "poisson"])
    ap.add_argument("--dnn", default="resnet50")
    args = ap.parse_args()

    rates = make_traces()[args.trace]
    dev = DeviceModel()
    w = INFER_WORKLOADS[args.dnn]
    f = Fulcrum(dev)
    sols = f.solve_dynamic(w, POWER, LATENCY, rates, strategy="gmd")

    print(f"{args.dnn} on {args.trace} trace: {len(rates)} x 5-min windows, "
          f"power<={POWER:.0f} W, latency<={LATENCY*1e3:.0f} ms")
    print(f"{'win':>3} {'rate':>6} {'pm':>18} {'bs':>3} {'lat_ms':>7} {'pow_W':>6}")
    found = 0
    for i, (rate, sol) in enumerate(zip(rates, sols)):
        if sol is None:
            print(f"{i:3d} {rate:6.1f} {'(no solution)':>18}")
            continue
        found += 1
        rep = simulate_managed(dev, None, w, sol.pm, sol.bs, rate, duration=30.0)
        print(f"{i:3d} {rate:6.1f} {str(sol.pm):>18} {sol.bs:3d} "
              f"{rep.latency_quantile(0.95)*1e3:7.1f} {sol.power:6.1f}")
    print(f"solutions found: {found}/{len(rates)}")


if __name__ == "__main__":
    main()
