"""Multi-tenant serving demo: 3 heterogeneous inference streams share one
edge accelerator with a training job under a single power budget.

 1. Fulcrum (GMD) solves the N-stream problem: one power mode, one minibatch
    size per tenant, and the training interleave factor.
 2. The N-stream managed engine executes the plan over per-tenant Poisson
    arrival traces (merged with stream provenance) and reports per-tenant
    latency quantiles and violation rates plus the realized training
    throughput.

Run: PYTHONPATH=src python examples/multi_tenant.py \
         [--power-budget 45 --duration 60 --arrivals poisson --backend jax]

The ``--backend`` flag picks the execution-engine implementation (NumPy
reference or the jax max-plus scan), so this example doubles as a smoke test
for the on-accelerator engine path; the backend that actually ran is printed
with the execution report.
"""
import argparse

from repro.core import problem as P
from repro.core.backend import resolve_backend
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.scheduler import Fulcrum

TENANTS = [
    # (infer workload, arrival rate req/s, peak-latency budget s)
    ("mobilenet", 40.0, 0.8),     # camera feed classifier
    ("lstm", 60.0, 0.5),          # sensor-stream scorer
    ("resnet50", 20.0, 1.5),      # periodic quality inspection
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", default="resnet18",
                    choices=sorted(TRAIN_WORKLOADS))
    ap.add_argument("--power-budget", type=float, default=45.0)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--arrivals", default="poisson",
                    choices=["uniform", "poisson"])
    ap.add_argument("--strategy", default="gmd")
    ap.add_argument("--backend", default=None, choices=["numpy", "jax"],
                    help="execution-engine backend (default: resolve via "
                         "FULCRUM_ENGINE_BACKEND, falling back to numpy)")
    args = ap.parse_args()
    backend = resolve_backend(args.backend)

    dev = DeviceModel()
    w_tr = TRAIN_WORKLOADS[args.train]
    specs = tuple(P.StreamSpec(rate, lat, INFER_WORKLOADS[name])
                  for name, rate, lat in TENANTS)
    prob = P.MultiTenantProblem(args.power_budget, specs)

    f = Fulcrum(dev)
    plan = f.solve_multi_tenant(w_tr, prob, args.strategy)
    if plan is None:
        print("Fulcrum: no feasible multi-tenant plan under the budgets")
        return
    s = plan.solution
    print(f"plan ({args.strategy}): pm={s.pm}  power={s.power:.1f} W "
          f"(budget {args.power_budget:.0f} W), {plan.profiling_runs} "
          f"modes profiled")
    for (name, rate, lat), bs, lam in zip(TENANTS, s.bss, s.times):
        print(f"  {name:<10} rate={rate:>5.1f}/s  bs={bs:<3} "
              f"planned peak latency {lam*1e3:6.0f} ms (budget {lat*1e3:.0f})")
    print(f"  train      tau_tr={s.tau_tr}/cycle -> "
          f"{s.throughput:.2f} minibatches/s planned")

    rep = f.execute_multi_tenant(plan, prob, w_tr, duration=args.duration,
                                 arrivals=args.arrivals, backend=backend)
    print(f"\nexecuted {args.duration:.0f} s of {args.arrivals} arrivals "
          f"({len(rep.trace)} requests merged across {len(specs)} tenants) "
          f"on the {backend} engine backend:")
    viols = rep.violation_rates([sp.latency_budget for sp in specs])
    for (name, _, lat), r, v in zip(TENANTS, rep.streams, viols):
        print(f"  {name:<10} served {len(r.latencies):>5} reqs  "
              f"q50 {r.latency_quantile(0.5)*1e3:6.0f} ms  "
              f"q95 {r.latency_quantile(0.95)*1e3:6.0f} ms  "
              f"violations {100*v:4.1f} %")
    print(f"  train      {rep.train_minibatches} minibatches "
          f"({rep.train_throughput:.2f}/s) at {rep.power:.1f} W")


if __name__ == "__main__":
    main()
