"""Quickstart: the three layers of the repro in one script.

 1. Train a reduced assigned-architecture model for a few steps (real JAX).
 2. Serve it with batched requests (prefill + decode, real JAX).
 3. Ask Fulcrum (GMD) for a power-mode plan for the same workload on the
    edge-device model, under power + latency budgets.

Run: PYTHONPATH=src python examples/quickstart.py [--arch stablelm-1.6b]
"""
import argparse

from repro.configs import get_config, make_batch, reduced
from repro.core import problem as P
from repro.core.device_model import DeviceModel, workload_from_model_config
from repro.core.scheduler import Fulcrum
from repro.runtime.serving import GenerationServer
from repro.runtime.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"== 1. training reduced {cfg.name} ({cfg.arch_type}) ==")
    trainer = Trainer(cfg, batch=4, seq_len=64)
    report = trainer.train(args.steps, log_every=5)
    print(f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f} "
          f"({report.mean_step_time*1e3:.0f} ms/step)")

    print("== 2. serving with batched requests ==")
    server = GenerationServer(cfg, max_seq=96, bs=2)
    prompt = make_batch(cfg, 32, 2, "prefill")
    tokens = server.generate(prompt, steps=8, prompt_len=32)
    print(f"generated {tokens.shape[1]} tokens/seq: {tokens[0].tolist()}")

    print("== 3. Fulcrum plan for this workload on the edge device ==")
    dev = DeviceModel()
    w = workload_from_model_config(get_config(args.arch), "infer")
    fulcrum = Fulcrum(dev)
    prob = P.InferProblem(power_budget=30.0, latency_budget=5.0, arrival_rate=2.0)
    plan = fulcrum.solve_infer(w, prob, strategy="gmd")
    if plan is None:
        print("no feasible power mode under the budgets")
    else:
        s = plan.solution
        print(f"power mode {s.pm}  bs={s.bs}  latency {s.time*1e3:.0f} ms "
              f"power {s.power:.1f} W  ({plan.profiling_runs} modes profiled, "
              f"{plan.profiling_cost_s/60:.1f} simulated-min profiling)")


if __name__ == "__main__":
    main()
