"""Scenario registry + strategy caching + dynamic re-planning controller."""
import pytest

from repro.core import problem as P
from repro.core import scheduler as sched
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.scheduler import (Fulcrum, Scenario, as_nonurgent,
                                  available_strategies)
from repro.core.simulate import ArrivalTrace

DEV = DeviceModel()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_scenarios_share_canonical_solver_families():
    assert Scenario.CONCURRENT_INFERENCE.canonical is Scenario.CONCURRENT
    assert Scenario.DYNAMIC.canonical is Scenario.INFER
    assert Scenario.TRAIN.canonical is Scenario.TRAIN
    assert available_strategies(Scenario.CONCURRENT_INFERENCE) == \
        available_strategies(Scenario.CONCURRENT)
    for sc in Scenario:
        assert "gmd" in available_strategies(sc)


def test_unknown_strategy_raises_with_choices():
    f = Fulcrum(DEV)
    with pytest.raises(KeyError, match="als145"):
        f.strategy_for(Scenario.INFER, "als9000", INFER_WORKLOADS["lstm"])


def test_strategy_accepts_scenario_by_value():
    f = Fulcrum(DEV)
    s = f.strategy_for("infer", "rnd150", INFER_WORKLOADS["lstm"])
    assert s is f.strategy_for(Scenario.INFER, "rnd150",
                               INFER_WORKLOADS["lstm"])


# ---------------------------------------------------------------------------
# fitted-strategy caching (satellite: same workload+strategy reuses the
# fitted object; GMD never caches)
# ---------------------------------------------------------------------------

def test_fitted_strategy_cached_per_workload():
    f = Fulcrum(DEV)
    w1, w2 = INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]
    a = f.strategy_for(Scenario.INFER, "rnd150", w1)
    assert f.strategy_for(Scenario.INFER, "rnd150", w1) is a
    assert f.strategy_for(Scenario.INFER, "rnd150", w2) is not a
    assert f.strategy_for(Scenario.INFER, "rnd250", w1) is not a
    # the dynamic scenario resolves to the same fitted infer object
    assert f.strategy_for(Scenario.DYNAMIC, "rnd150", w1) is a


def test_gmd_is_never_cached():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    a = f.strategy_for(Scenario.INFER, "gmd", w)
    b = f.strategy_for(Scenario.INFER, "gmd", w)
    assert a is not b
    assert not f._fitted                      # nothing was cached


def test_solve_reuses_fitted_across_calls():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    p1 = f.solve_infer(w, P.InferProblem(40.0, 0.5, 60.0), "rnd150")
    runs_after_first = p1.profiling_runs
    p2 = f.solve_infer(w, P.InferProblem(35.0, 0.4, 50.0), "rnd150")
    # same fitted object answers the second problem: no new profiling
    assert p2.profiling_runs == runs_after_first
    assert len(f._fitted) == 1


# ---------------------------------------------------------------------------
# dynamic re-planning controller (satellite: profiler-cache reuse)
# ---------------------------------------------------------------------------

def test_solve_dynamic_gmd_reuses_profiler_cache(monkeypatch):
    """GMD re-searches only when cached observations stop satisfying the
    new rate: repeated/easier windows must not trigger new GMD searches."""
    searches = []
    real = sched.GMDInfer

    class Counting(real):
        def __init__(self, *a, **k):
            searches.append(1)
            super().__init__(*a, **k)

    monkeypatch.setattr(sched, "GMDInfer", Counting)
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [60.0, 60.0, 30.0, 45.0, 60.0]
    sols = f.solve_dynamic(w, 40.0, 0.5, rates, "gmd")
    assert all(s is not None for s in sols)
    assert len(searches) == 1     # only the first window really searched
    for s in sols:
        assert s.time <= 0.5 + 1e-9


def test_solve_dynamic_fitted_strategy_reuses_model():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [40.0, 60.0, 80.0]
    a = f.solve_dynamic(w, 40.0, 0.5, rates, "rnd150")
    b = f.solve_dynamic(w, 40.0, 0.5, rates, "rnd150")
    assert len(f._fitted) == 1                # one fitted model, reused
    assert [s and (s.pm, s.bs) for s in a] == [s and (s.pm, s.bs) for s in b]


def test_serve_dynamic_emits_per_window_reports():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [40.0, 70.0, 55.0]
    windows = f.serve_dynamic(w, 40.0, 0.5, rates, "gmd",
                              window_duration=10.0)
    assert len(windows) == len(rates)
    for wr in windows:
        assert wr.solution is not None
        assert wr.report is not None
        assert wr.report.trace.kind == "uniform"
        # the plan's guarantee holds exactly under the planned uniform rate
        assert wr.report.violation_rate(0.5) == 0.0
        assert len(wr.report.latencies) > 0
    bursty = f.serve_dynamic(w, 40.0, 0.5, rates, "gmd",
                             window_duration=10.0, arrivals="poisson")
    for wr in bursty:
        assert wr.report.trace.kind == "poisson"
        # bursts may exceed the uniform-rate bound, but only in the tail
        assert wr.report.violation_rate(0.5) < 0.05


# ---------------------------------------------------------------------------
# execute threads the plan through the engine (satellite)
# ---------------------------------------------------------------------------

def test_execute_threads_plan_and_returns_trace():
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    prob = P.ConcurrentProblem(35.0, 1.0, 60.0)
    plan = f.solve_concurrent(w_tr, w_in, prob, "gmd")
    assert plan is not None and plan.scenario is Scenario.CONCURRENT
    trace = ArrivalTrace.poisson(60.0, 20.0, seed=5)
    rep = f.execute(plan, w_in, w_tr, trace=trace)
    assert rep.trace is trace                 # the trace used is returned
    n_batches = len(trace) // plan.solution.bs
    # slack-fill is capped at the plan's committed tau_tr per cycle
    assert rep.train_minibatches <= plan.solution.tau_tr * n_batches
    assert rep.power <= prob.power_budget + 1e-9


def test_execute_requires_inference_batch_size():
    f = Fulcrum(DEV)
    w = TRAIN_WORKLOADS["lstm"]
    plan = f.solve_train(w, P.TrainProblem(30.0), "gmd")
    with pytest.raises(ValueError, match="minibatch size"):
        f.execute(plan, INFER_WORKLOADS["lstm"], arrival_rate=10.0)


def test_execute_requires_rate_or_trace():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    plan = f.solve_infer(w, P.InferProblem(40.0, 0.5, 60.0), "gmd")
    with pytest.raises(ValueError, match="arrival_rate or a trace"):
        f.execute(plan, w)


# ---------------------------------------------------------------------------
# multi-tenant scenario (N streams + training under one budget)
# ---------------------------------------------------------------------------

def _specs(n=2):
    pool = [("mobilenet", 40.0, 1.0), ("lstm", 50.0, 0.6),
            ("resnet50", 25.0, 1.2), ("yolov8n", 20.0, 1.5)]
    return tuple(P.StreamSpec(r, l, INFER_WORKLOADS[w])
                 for w, r, l in pool[:n])


def test_multi_tenant_scenario_registered():
    assert Scenario.MULTI_TENANT.canonical is Scenario.MULTI_TENANT
    assert "gmd" in available_strategies(Scenario.MULTI_TENANT)
    assert "rnd150" in available_strategies(Scenario.MULTI_TENANT)


def test_solve_multi_tenant_gmd_plan_respects_budgets():
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["resnet18"]
    prob = P.MultiTenantProblem(45.0, _specs(3))
    plan = f.solve_multi_tenant(w_tr, prob, "gmd")
    assert plan is not None and plan.scenario is Scenario.MULTI_TENANT
    sol = plan.solution
    assert len(sol.bss) == 3 and len(sol.times) == 3
    assert sol.power <= prob.power_budget + 1e-9
    for lam, spec in zip(sol.times, prob.streams):
        assert lam <= spec.latency_budget + 1e-9
    rep = f.execute_multi_tenant(plan, prob, w_tr, duration=20.0)
    assert len(rep.streams) == 3
    assert rep.power <= prob.power_budget + 1e-9
    # the plan's per-tenant guarantee holds under the planned uniform rates
    for v in rep.violation_rates([s.latency_budget for s in prob.streams]):
        assert v == 0.0


def test_solve_multi_tenant_requires_workloads():
    f = Fulcrum(DEV)
    prob = P.MultiTenantProblem(40.0, (P.StreamSpec(40.0, 1.0),))
    with pytest.raises(ValueError, match="workload"):
        f.solve_multi_tenant(TRAIN_WORKLOADS["lstm"], prob, "gmd")


def test_multi_tenant_fitted_strategy_cached():
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    specs = _specs(2)
    prob = P.MultiTenantProblem(40.0, specs)
    p1 = f.solve_multi_tenant(w_tr, prob, "rnd150")
    p2 = f.solve_multi_tenant(w_tr, P.MultiTenantProblem(30.0, specs),
                              "rnd150")
    assert p1 is not None and p2 is not None
    assert p2.profiling_runs == p1.profiling_runs   # no re-profiling
    assert len(f._fitted) == 1


def test_serve_dynamic_per_stream_rate_windows():
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    specs = _specs(2)
    windows = [(40.0, 50.0), (60.0, 30.0), (20.0, 70.0)]
    reports = f.serve_dynamic(specs, 40.0, None, windows, "gmd",
                              window_duration=10.0, w_tr=w_tr)
    assert len(reports) == len(windows)
    for wr, rvec in zip(reports, windows):
        assert wr.rate == rvec
        assert wr.solution is not None and wr.report is not None
        assert len(wr.report.streams) == 2
        for v in wr.report.violation_rates(
                [s.latency_budget for s in specs]):
            assert v == 0.0
        assert wr.report.trace.kind == "merged"


def test_execute_multi_tenant_rejects_pair_plan():
    f = Fulcrum(DEV)
    w_in = INFER_WORKLOADS["mobilenet"]
    plan = f.solve_infer(w_in, P.InferProblem(40.0, 0.5, 60.0), "gmd")
    prob = P.MultiTenantProblem(40.0, _specs(1))
    with pytest.raises(ValueError, match="not multi-tenant"):
        f.execute_multi_tenant(plan, prob, TRAIN_WORKLOADS["mobilenet"])


def test_execute_multi_tenant_requires_train_workload():
    """A train=True plan executed without w_tr would silently drop the
    training fill (zero minibatches, under-reported power) — must raise."""
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    prob = P.MultiTenantProblem(40.0, _specs(2))
    plan = f.solve_multi_tenant(w_tr, prob, "gmd")
    with pytest.raises(ValueError, match="train workload"):
        f.execute_multi_tenant(plan, prob)


def test_concurrent_inference_scenario_and_nonurgent_cast():
    f = Fulcrum(DEV)
    urgent = INFER_WORKLOADS["mobilenet"]
    nonurgent = INFER_WORKLOADS["resnet50"]
    w = as_nonurgent(nonurgent, 32)
    assert w.train_bs == 32 and w.name.endswith("-nonurgent")
    assert as_nonurgent(w) is w               # idempotent
    prob = P.ConcurrentProblem(38.0, 1.0, 60.0)
    plan = f.solve_concurrent_inference(nonurgent, urgent, prob, "gmd")
    assert plan is not None
    assert plan.scenario is Scenario.CONCURRENT_INFERENCE
    assert plan.solution.power <= 38.0 + 1e-9
    # the generic entry point applies the same cast — identical problem
    generic = f.solve(Scenario.CONCURRENT_INFERENCE, (nonurgent, urgent),
                      prob, "gmd")
    assert generic.solution == plan.solution
    # strategy_for applies it too: raw and pre-cast workloads share a model
    s1 = f.strategy_for(Scenario.CONCURRENT_INFERENCE, "rnd150",
                        nonurgent, urgent)
    s2 = f.strategy_for(Scenario.CONCURRENT_INFERENCE, "rnd150",
                        as_nonurgent(nonurgent), urgent)
    assert s1 is s2 and len(f._fitted) == 1
