"""Property-based (hypothesis) + invariant tests for the Fulcrum core."""
import math

import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.core import problem as P
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS, Profiler,
                                     TRAIN_WORKLOADS)
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent, GMDInfer, GMDTrain
from repro.core.oracle import Oracle
from repro.core.pareto import front_lookup, pareto_front
from repro.core.powermode import DIMS, PowerMode, PowerModeSpace

DEV = DeviceModel()
SPACE = PowerModeSpace()
ORACLE = Oracle(DEV)

mode_st = st.builds(
    PowerMode,
    cores=st.sampled_from(SPACE.values["cores"]),
    cpuf=st.sampled_from(SPACE.values["cpuf"]),
    gpuf=st.sampled_from(SPACE.values["gpuf"]),
    memf=st.sampled_from(SPACE.values["memf"]),
)
workload_st = st.sampled_from(list(TRAIN_WORKLOADS.values())
                              + list(INFER_WORKLOADS.values()))


@given(mode_st, workload_st, st.sampled_from(DIMS))
@settings(max_examples=200, deadline=None)
def test_power_monotone_time_antitone_along_every_dimension(pm, w, dim):
    """The property GMD's half-line pruning relies on (§5.1.2): power rises
    and minibatch time falls along every dimension — up to measurement noise
    (~1.5%), which the real board also exhibits and which GMD's slope
    thresholding (POWER_SLOPE_EPS, §5.1.2 "thresholding logic") absorbs."""
    vals = SPACE.values[dim]
    idx = vals.index(pm.value(dim))
    if idx + 1 >= len(vals):
        return
    hi = pm.replace(**{dim: vals[idx + 1]})
    t_lo, p_lo = DEV.time_power(w, pm, 16 if w.kind == "infer" else None)
    t_hi, p_hi = DEV.time_power(w, hi, 16 if w.kind == "infer" else None)
    assert p_hi >= p_lo - 0.015 * p_lo - 1e-9
    # time noise is +-5% per (workload, dim-value): adjacent flat segments
    # (e.g. cores beyond the dataloader parallelism) can swing ~10%
    assert t_hi <= t_lo + 0.10 * t_lo + 1e-9


@given(workload_st, mode_st, st.sampled_from([1, 4, 16, 32, 64]))
@settings(max_examples=100, deadline=None)
def test_inference_time_increases_sublinearly_with_bs(w, pm, bs):
    t1, _ = DEV.time_power(w, pm, 1)
    tb, _ = DEV.time_power(w, pm, bs)
    assert tb >= t1 - 1e-12            # more samples never faster
    assert tb <= t1 * bs + 1e-9        # sublinear growth (paper §2)


@given(st.dictionaries(st.integers(0, 1000),
                       st.tuples(st.floats(1, 100), st.floats(0.001, 10)),
                       min_size=1, max_size=40))
@settings(max_examples=100, deadline=None)
def test_pareto_front_invariants(points):
    front = pareto_front(points)
    assert front                                  # never empty
    assert set(front) <= set(points)
    # no front point dominates another front point
    items = list(front.values())
    for i, (p1, t1) in enumerate(items):
        for j, (p2, t2) in enumerate(items):
            if i != j:
                assert not (p2 <= p1 and t2 < t1)
    # every non-front point is dominated by some front point
    for key, (p, t) in points.items():
        if key not in front:
            assert any(p2 <= p and t2 <= t for (p2, t2) in items)


@given(st.floats(10, 60), st.floats(1, 100), st.floats(0.001, 10))
@settings(max_examples=100, deadline=None)
def test_front_lookup_matches_exhaustive(budget, pw, tm):
    points = {0: (pw, tm), 1: (pw * 0.5, tm * 2), 2: (pw * 1.5, tm * 0.7)}
    front = pareto_front(points)
    hit = front_lookup(front, budget)
    feas = [(t, k) for k, (p, t) in points.items() if p <= budget]
    if hit is None:
        # no front point fits; then no point at all can beat the front ones
        assert not feas or min(f[0] for f in feas) >= min(
            t for (p, t) in points.values())
    else:
        assert hit[1][0] <= budget
        assert math.isclose(hit[1][1], min(f[0] for f in feas), rel_tol=1e-9)


@given(st.integers(1, 64), st.floats(1, 120), st.floats(0.001, 2),
       st.floats(0.001, 2))
@settings(max_examples=200, deadline=None)
def test_interleaving_math(bs, rate, t_in, t_tr):
    lam = P.peak_latency(bs, rate, t_in)
    assert lam >= t_in                      # queueing only adds latency
    tau = P.interleave_tau(bs, rate, t_in, t_tr)
    assert tau >= 0
    # tau training steps + the inference step must fit in the cycle
    if P.sustainable(bs, rate, t_in):
        assert tau * t_tr + t_in <= bs / rate + 1e-6
    theta = P.train_throughput(bs, rate, t_in, t_tr)
    assert theta * t_tr <= 1.0 + 1e-9       # can't train more than wall time


# ---------------------------------------------------------------------------
# strategy invariants (paper: profiling-based strategies NEVER violate
# budgets; oracle dominates every strategy)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("budget", [12.0, 20.0, 30.0, 45.0])
def test_gmd_train_never_violates_and_oracle_dominates(budget):
    w = TRAIN_WORKLOADS["mobilenet"]
    prob = P.TrainProblem(budget)
    sol = GMDTrain(Profiler(DEV, w)).solve(prob)
    opt = ORACLE.solve_train(w, prob)
    if sol is not None:
        assert sol.power <= budget + 1e-9
        t_true, p_true = DEV.time_power(w, sol.pm)
        assert abs(t_true - sol.time) < 1e-9     # observed == ground truth
        if opt is not None:
            assert opt.time <= sol.time + 1e-9   # oracle dominates


@pytest.mark.parametrize("budget,lat,rate", [(20, 0.5, 30), (35, 0.2, 60),
                                             (45, 1.0, 90), (15, 0.3, 50)])
def test_gmd_infer_never_violates(budget, lat, rate):
    w = INFER_WORKLOADS["mobilenet"]
    prob = P.InferProblem(float(budget), lat, float(rate))
    sol = GMDInfer(Profiler(DEV, w)).solve(prob)
    if sol is not None:
        assert sol.power <= budget + 1e-9
        assert sol.time <= lat + 1e-9
        t_true, _ = DEV.time_power(w, sol.pm, sol.bs)
        assert P.sustainable(sol.bs, rate, t_true)


@pytest.mark.parametrize("budget,lat,rate", [(30, 1.0, 60), (45, 2.0, 120)])
def test_gmd_concurrent_never_violates(budget, lat, rate):
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    prob = P.ConcurrentProblem(float(budget), lat, float(rate))
    cp = ConcurrentProfiler(Profiler(DEV, w_tr), Profiler(DEV, w_in))
    sol = GMDConcurrent(cp).solve(prob)
    if sol is not None:
        assert sol.power <= budget + 1e-9
        assert sol.time <= lat + 1e-9
        assert cp.num_runs <= 15 + 5   # branch&bound probes + search budget


def test_solver_agrees_with_bruteforce_on_tiny_space():
    """solve_train == brute force over an exhaustive observation set."""
    w = TRAIN_WORKLOADS["lstm"]
    small = PowerModeSpace(cores=[4, 12], cpuf=[422, 2201],
                           gpuf=[115, 1300], memf=[665, 3199])
    obs = {pm: DEV.time_power(w, pm) for pm in small.all_modes()}
    for budget in (15.0, 25.0, 40.0):
        sol = P.solve_train(P.TrainProblem(budget), obs)
        feas = [(t, pm) for pm, (t, p) in obs.items() if p <= budget]
        if not feas:
            assert sol is None
        else:
            assert sol is not None
            assert math.isclose(sol.time, min(feas)[0], rel_tol=1e-12)


def test_managed_interleaving_latency_within_budget():
    """Fig. 2 claim: managed interleaving keeps every request within the
    latency bound predicted by the formulation."""
    from repro.core.interleave import simulate_managed
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    bs, rate = 16, 60.0
    rep = simulate_managed(DEV, w_tr, w_in, pm, bs, rate, duration=30.0)
    t_in, _ = DEV.time_power(w_in, pm, bs)
    lam = P.peak_latency(bs, rate, t_in)
    assert len(rep.latencies) > 0
    assert max(rep.latencies) <= lam + 1e-6
    assert rep.train_minibatches > 0
