"""Vectorized grid-evaluation engine: bitwise identity against the scalar
reference (device model, solvers, Pareto utilities) on randomized observation
grids and problem batches, plus the dense 441-mode x 5-bs oracle sweep."""
import math
import random

import numpy as np
import pytest

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.oracle import Oracle
from repro.core.pareto import front_lookup, pareto_front
from repro.core.powermode import PowerModeSpace

DEV = DeviceModel()
SPACE = PowerModeSpace()
BSS = list(P.INFER_BATCH_SIZES)


# ---------------------------------------------------------------------------
# dense device-model tensors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["resnet18", "bert"])
def test_dense_train_grid_bitwise_identical(name):
    w = TRAIN_WORKLOADS[name]
    grid = G.materialize(DEV, w, SPACE)
    for i, pm in enumerate(SPACE.all_modes()):
        t, p = DEV.time_power(w, pm)
        assert t == grid.t[i] and p == grid.p[i], pm


@pytest.mark.parametrize("name", ["mobilenet", "bert"])
def test_dense_infer_grid_bitwise_identical(name):
    w = INFER_WORKLOADS[name]
    grid = G.materialize(DEV, w, SPACE, BSS)
    i = 0
    for pm in SPACE.all_modes():
        for bs in BSS:
            t, p = DEV.time_power(w, pm, bs)
            assert t == grid.t[i] and p == grid.p[i], (pm, bs)
            assert grid.key(i) == (pm, bs)
            i += 1


def test_grid_lookup_and_dict_roundtrip():
    w = TRAIN_WORKLOADS["lstm"]
    grid = G.materialize(DEV, w, SPACE)
    d = grid.to_dict()
    assert list(d) == SPACE.all_modes()          # insertion order preserved
    pm = SPACE.midpoint()
    assert grid.lookup(pm) == d[pm] == DEV.time_power(w, pm)


# ---------------------------------------------------------------------------
# randomized observation grids: batched solvers == scalar loops, bitwise
# ---------------------------------------------------------------------------

def _rand_train_obs(rng, modes):
    sub = rng.sample(modes, rng.randrange(1, 50))
    # coarse value pools force ties so first-occurrence tie-breaking is hit
    return {pm: (rng.choice([0.1, 0.25, round(rng.uniform(0.01, 1.0), 3)]),
                 rng.choice([12.0, 30.0, round(rng.uniform(5.0, 60.0), 2)]))
            for pm in sub}


def _rand_infer_obs(rng, modes):
    sub = rng.sample(modes, rng.randrange(1, 50))
    return {(pm, rng.choice(BSS)):
            (rng.choice([0.05, 0.2, round(rng.uniform(0.005, 2.0), 3)]),
             rng.choice([15.0, round(rng.uniform(5.0, 60.0), 2)]))
            for pm in sub for _ in range(2)}


def test_solve_train_batch_identical_randomized():
    rng = random.Random(7)
    modes = SPACE.all_modes()
    for _ in range(40):
        obs = _rand_train_obs(rng, modes)
        probs = [P.TrainProblem(rng.choice([0.0, 11.0, rng.uniform(1, 70)]))
                 for _ in range(15)]
        batched = G.solve_train_batch(probs, obs)
        scalar = [P.solve_train(pr, obs) for pr in probs]
        assert batched == scalar
    # budget below every observed power: all None
    obs = _rand_train_obs(rng, modes)
    assert G.solve_train_batch([P.TrainProblem(0.0)], obs) == [None]


def test_solve_infer_batch_identical_randomized():
    rng = random.Random(8)
    modes = SPACE.all_modes()
    for _ in range(40):
        obs = _rand_infer_obs(rng, modes)
        probs = [P.InferProblem(rng.uniform(1, 70),
                                rng.choice([0.01, 0.3, 2.0]),
                                rng.choice([5.0, 30.0, 60.0, 200.0]))
                 for _ in range(15)]
        batched = G.solve_infer_batch(probs, obs)
        scalar = [P.solve_infer(pr, obs) for pr in probs]
        assert batched == scalar


def test_solve_concurrent_batch_identical_randomized():
    rng = random.Random(9)
    modes = SPACE.all_modes()
    for _ in range(40):
        iobs = _rand_infer_obs(rng, modes)
        # train obs cover only part of the inference modes (the scalar loop
        # skips uncovered modes; the batched mask must too)
        imodes = list({pm for pm, _ in iobs})
        tobs = {pm: (round(rng.uniform(0.01, 1.0), 3),
                     round(rng.uniform(5.0, 60.0), 2))
                for pm in rng.sample(imodes, max(1, len(imodes) // 2))}
        probs = [P.ConcurrentProblem(rng.uniform(1, 70),
                                     rng.choice([0.05, 0.5, 2.0]),
                                     rng.choice([10.0, 30.0, 60.0]))
                 for _ in range(15)]
        batched = G.solve_concurrent_batch(probs, tobs, iobs)
        scalar = [P.solve_concurrent(pr, tobs, iobs) for pr in probs]
        assert batched == scalar


def test_empty_observations_and_problems():
    assert G.solve_train_batch([P.TrainProblem(30.0)], {}) == [None]
    assert G.solve_infer_batch([], {}) == []
    assert G.solve_concurrent_batch([P.ConcurrentProblem(30.0, 1.0, 60.0)],
                                    {}, {}) == [None]


def test_chunked_path_matches_unchunked():
    """Force multi-chunk execution and compare against one-shot solving."""
    pytest.importorskip("jax")
    rng = random.Random(10)
    modes = SPACE.all_modes()
    obs = {pm: (rng.uniform(0.01, 1.0), rng.uniform(5.0, 60.0))
           for pm in modes}
    probs = [P.TrainProblem(rng.uniform(1, 70)) for _ in range(64)]
    old = G.CHUNK_ELEMS
    try:
        G.CHUNK_ELEMS = len(modes) * 4       # ~16 problems per chunk
        chunked = G.solve_train_batch(probs, obs, backend="jax")
    finally:
        G.CHUNK_ELEMS = old
    assert chunked == G.solve_train_batch(probs, obs)


# ---------------------------------------------------------------------------
# oracle: vectorized path on the dense 441 x 5 sweep == scalar reference
# ---------------------------------------------------------------------------

def test_oracle_batch_matches_scalar_on_dense_grid():
    oracle = Oracle(DEV, SPACE)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    tobs = oracle.train_observations(w_tr)
    iobs = oracle.infer_observations(w_in)
    assert len(tobs) == 441 and len(iobs) == 441 * 5

    tprobs = [P.TrainProblem(float(b)) for b in range(8, 61, 4)]
    assert oracle.solve_train_batch(w_tr, tprobs) == \
        [P.solve_train(pr, tobs) for pr in tprobs]

    iprobs = [P.InferProblem(float(b), lat, rate)
              for b in (12, 25, 40, 55) for lat in (0.05, 0.3, 1.0)
              for rate in (30.0, 60.0, 90.0)]
    assert oracle.solve_infer_batch(w_in, iprobs) == \
        [P.solve_infer(pr, iobs) for pr in iprobs]

    cprobs = [P.ConcurrentProblem(float(b), lat, rate)
              for b in (15, 30, 45) for lat in (0.5, 1.0, 2.0)
              for rate in (30.0, 60.0, 120.0)]
    assert oracle.solve_concurrent_batch(w_tr, w_in, cprobs) == \
        [P.solve_concurrent(pr, tobs, iobs) for pr in cprobs]


def test_oracle_true_lookups_match_device():
    oracle = Oracle(DEV, SPACE)
    w = INFER_WORKLOADS["resnet50"]
    pm = SPACE.midpoint()
    assert oracle.true_infer(w, pm, 16) == DEV.time_power(w, pm, 16)
    w_tr = TRAIN_WORKLOADS["yolov8n"]
    assert oracle.true_train(w_tr, pm) == DEV.time_power(w_tr, pm)
    # off-grid fallback goes straight to the device model
    off = pm.replace(cpuf=123)
    assert oracle.true_train(w_tr, off) == DEV.time_power(w_tr, off)


# ---------------------------------------------------------------------------
# jax backend: jit+vmap reduction agrees with the NumPy reference
# ---------------------------------------------------------------------------

def test_jax_backend_matches_numpy():
    pytest.importorskip("jax")
    oracle = Oracle(DEV, SPACE)
    w_tr = TRAIN_WORKLOADS["resnet18"]
    w_in = INFER_WORKLOADS["lstm"]
    tprobs = [P.TrainProblem(float(b)) for b in range(10, 55, 9)]
    iprobs = [P.InferProblem(float(b), 0.3, 60.0) for b in range(10, 55, 9)]
    cprobs = [P.ConcurrentProblem(float(b), 1.0, 60.0)
              for b in range(10, 55, 9)]
    assert oracle.solve_train_batch(w_tr, tprobs, backend="jax") == \
        oracle.solve_train_batch(w_tr, tprobs)
    assert oracle.solve_infer_batch(w_in, iprobs, backend="jax") == \
        oracle.solve_infer_batch(w_in, iprobs)
    assert oracle.solve_concurrent_batch(w_tr, w_in, cprobs, backend="jax") \
        == oracle.solve_concurrent_batch(w_tr, w_in, cprobs)


# ---------------------------------------------------------------------------
# fitted strategies: batch answering == per-problem answering
# ---------------------------------------------------------------------------

def test_rnd_solve_batch_matches_scalar_solve():
    from repro.core.baselines import RNDInfer, RNDTrain
    from repro.core.device_model import Profiler
    w = TRAIN_WORKLOADS["lstm"]
    strat = RNDTrain(Profiler(DEV, w), 50, SPACE)
    probs = [P.TrainProblem(float(b)) for b in range(10, 55, 5)]
    assert strat.solve_batch(probs) == [strat.solve(pr) for pr in probs]

    wi = INFER_WORKLOADS["mobilenet"]
    istrat = RNDInfer(Profiler(DEV, wi), 150, SPACE)
    iprobs = [P.InferProblem(float(b), 0.4, 60.0) for b in range(10, 55, 5)]
    assert istrat.solve_batch(iprobs) == [istrat.solve(pr) for pr in iprobs]


# ---------------------------------------------------------------------------
# pareto: vectorized front/front_lookup == scalar reference semantics
# ---------------------------------------------------------------------------

def _ref_pareto_front(points, lower_is_better=True):
    sign = 1.0 if lower_is_better else -1.0
    items = sorted(points.items(), key=lambda kv: (kv[1][0], sign * kv[1][1]))
    front, best = {}, float("inf")
    for key, (p, obj) in items:
        o = sign * obj
        if o < best:
            front[key] = (p, obj)
            best = o
    return front


def _ref_front_lookup(front, power_budget, lower_is_better=True):
    sign = 1.0 if lower_is_better else -1.0
    best = None
    for key, (p, obj) in front.items():
        if p <= power_budget and (best is None or sign * obj < sign * best[1][1]):
            best = (key, (p, obj))
    return best


@pytest.mark.parametrize("lower", [True, False])
def test_pareto_matches_reference_randomized(lower):
    rng = random.Random(11)
    for _ in range(50):
        n = rng.randrange(1, 40)
        points = {i: (rng.choice([1.0, 5.0, round(rng.uniform(0.0, 50.0), 2)]),
                      rng.choice([2.0, round(rng.uniform(0.001, 10.0), 3)]))
                  for i in range(n)}
        front = pareto_front(points, lower)
        ref = _ref_pareto_front(points, lower)
        assert front == ref and list(front) == list(ref)
        for budget in (0.0, 2.0, rng.uniform(0, 55)):
            assert front_lookup(front, budget, lower) == \
                _ref_front_lookup(front, budget, lower)
    assert pareto_front({}) == {}
    assert front_lookup({}, 10.0) is None


# ---------------------------------------------------------------------------
# jit-cache stability: ragged final problem chunks are padded to pow2 row
# buckets, so same-bucket batch sizes must not retrace the solver kernels
# ---------------------------------------------------------------------------

def test_solver_trace_count_stable_within_pow2_bucket():
    pytest.importorskip("jax")
    w = TRAIN_WORKLOADS["mobilenet"]
    grid = G.materialize(DEV, w, SPACE)
    probs = [P.TrainProblem(float(b)) for b in np.linspace(5.0, 30.0, 30)]
    G.solve_train_batch(probs[:29], grid, backend="jax")   # pads 29 -> 32
    n0 = G.solver_trace_count()
    G.solve_train_batch(probs[:30], grid, backend="jax")   # 30 -> 32: reuse
    G.solve_train_batch(probs[:17], grid, backend="jax")   # 17 -> 32: reuse
    assert G.solver_trace_count() == n0
    # padding duplicates the last problem; answers must be unaffected
    a = G.solve_train_batch(probs, grid, backend="numpy")
    b = G.solve_train_batch(probs, grid, backend="jax")
    assert a == b
