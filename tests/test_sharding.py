"""Sharding-rule validity on the production meshes, device-free via
AbstractMesh: every PartitionSpec axis must divide the dim it shards, for
all 10 archs x both meshes x params/batches/caches."""
import jax
import jax.numpy as jnp
import math
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, input_specs
from repro.models import sharding as S
from repro.models.model import init_cache, init_params


def _abstract_mesh(sizes, names):
    """jax >= 0.4.36 takes a single ((name, size), ...) shape tuple."""
    return AbstractMesh(tuple(zip(names, sizes)))


def _meshes():
    return [_abstract_mesh((16, 16), ("data", "model")),
            _abstract_mesh((2, 16, 16), ("pod", "data", "model"))]


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _check_spec_divides(mesh, spec: P, shape):
    assert len(spec) <= len(shape), (spec, shape)
    for dim, axes in zip(shape, spec):
        if axes is not None:
            assert dim % _axis_size(mesh, axes) == 0, (spec, shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", _meshes(), ids=["16x16", "2x16x16"])
def test_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    specs = S.param_specs(cfg, mesh)
    leaves_sh = jax.tree.leaves(shapes)
    leaves_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves_sh) == len(leaves_sp)
    for sh, sp in zip(leaves_sh, leaves_sp):
        _check_spec_divides(mesh, sp, sh.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", _meshes(), ids=["16x16", "2x16x16"])
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_batch_and_cache_specs_divide(arch, mesh, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    bspecs = S.batch_specs(specs["batch"], mesh)
    for sh, sp in zip(jax.tree.leaves(specs["batch"]),
                      jax.tree.leaves(bspecs, is_leaf=lambda x: isinstance(x, P))):
        _check_spec_divides(mesh, sp, sh.shape)
    if shape.kind == "decode":
        cshapes = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cspecs = S.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
        for sh, sp in zip(jax.tree.leaves(cshapes),
                          jax.tree.leaves(cspecs, is_leaf=lambda x: isinstance(x, P))):
            _check_spec_divides(mesh, sp, sh.shape)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "arctic-480b"])
def test_expert_sharding_strategy(arch):
    """Arctic (128e) must be expert-parallel on the model axis; Mixtral (8e)
    must fall back to per-expert FFN tensor parallelism."""
    cfg = get_config(arch)
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    specs = S.param_specs(cfg, mesh)
    w1_spec = specs["layers"]["moe"]["w1"]
    if cfg.n_experts % 16 == 0:
        assert w1_spec[1] == "model"      # (L, E->model, d, f)
    else:
        assert w1_spec[1] is None and w1_spec[3] == "model"


def test_vocab_padding_is_model_shardable():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % (16 * 128) == 0
        assert cfg.padded_vocab >= cfg.vocab_size


def test_batch_axes_fallback_for_batch_1():
    mesh = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert S.batch_axes(mesh, 1) is None            # long_500k: replicate
    assert S.batch_axes(mesh, 128) == ("pod", "data")
    assert S.batch_axes(mesh, 32) == ("pod", "data")
    mesh1 = _abstract_mesh((16, 16), ("data", "model"))
    assert S.batch_axes(mesh1, 256) == ("data",)
