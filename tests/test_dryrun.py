"""Multi-pod dry-run machinery: subprocess smoke (real 512-device lowering
for one pair) + collective-parser and extrapolation unit tests."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch.dryrun import _lin_extrapolate, collective_bytes

REPO = Path(__file__).resolve().parent.parent


def test_collective_parser_counts_operands():
    hlo = """
  %ag = bf16[32,1024]{1,0} all-gather(bf16[2,1024]{1,0} %x), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %y), to_apply=%sum
  %rs = f32[8,16]{1,0} reduce-scatter(f32[128,16]{1,0} %z), dimensions={0}
  %nothing = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 2 * 1024 * 2        # operand, not output
    assert out["all-reduce"] == 128 * 4
    assert out["reduce-scatter"] == 128 * 16 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total"] == out["all-gather"] + out["all-reduce"] + out["reduce-scatter"]


def test_linear_extrapolation():
    # f(L) = 10 + 3L sampled at L=2,4 must recover f(48)
    assert _lin_extrapolate(16.0, 22.0, 2, 4, 48) == pytest.approx(10 + 3 * 48)


@pytest.mark.slow
def test_dryrun_subprocess_one_pair(tmp_path):
    """Full 512-placeholder-device lowering for one (arch x shape x mesh):
    proves the production mesh machinery works end to end."""
    out = tmp_path / "dry.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "stablelm-1.6b", "--shape", "decode_32k",
         "--mesh", "multi", "--out", str(out)],
        cwd=REPO, env={**os.environ, "PYTHONPATH": str(REPO / "src")},
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    rec = json.loads(out.read_text())["stablelm-1.6b|decode_32k|multi"]
    assert rec["ok"], rec
    assert rec["devices"] == 512
    assert rec["extrapolated"]["flops"] > 0
    assert rec["extrapolated"]["collective_bytes"] > 0
