"""Perf-program features: int8 KV cache, bf16 params + fp32 master,
gradient-accumulation microbatching, TP-only serving shardings."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, make_batch, reduced
from repro.launch.steps import make_train_step
from repro.models import decode_step, forward, init_params
from repro.models import sharding as S
from repro.models.model import prefill
from repro.optim.adamw import init_opt_state


def test_int8_kv_cache_decode_close_to_forward():
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-14b")),
                              compute_dtype=jnp.float32, kv_cache_quant=True)
    params = init_params(jax.random.key(0), cfg)
    T = 64
    batch = make_batch(cfg, T, 2, "prefill")
    logits_full, _ = forward(params, batch, cfg)
    _, cache = prefill(params, {"tokens": batch["tokens"][:, :T - 1]}, cfg, T)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert cache["kv"]["k_scale"].dtype == jnp.bfloat16
    logits_dec, _ = decode_step(params, cache,
                                {"tokens": batch["tokens"][:, T - 1:T]},
                                jnp.full((2,), T - 1, jnp.int32), cfg)
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    rel = np.max(np.abs(a - b)) / np.abs(a).max()
    assert rel < 0.05, rel                      # ~1% quantization error


def test_int8_cache_is_half_the_bytes():
    cfg = reduced(get_config("stablelm-1.6b"))
    qcfg = dataclasses.replace(cfg, kv_cache_quant=True)
    from repro.models.model import init_cache
    base = init_cache(cfg, 2, 64)
    quant = init_cache(qcfg, 2, 64)
    nbytes = lambda c: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c))
    assert nbytes(quant) < 0.6 * nbytes(base)


def test_bf16_params_master_restores_precision():
    cfg = dataclasses.replace(reduced(get_config("stablelm-1.6b")),
                              param_dtype=jnp.bfloat16)
    params = init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params)
    assert "master" in opt
    # master mirrors params in fp32
    for p, m in zip(jax.tree.leaves(params), jax.tree.leaves(opt["master"])):
        assert m.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(p, np.float32), np.asarray(m),
                                   rtol=1e-2, atol=1e-2)
    batch = make_batch(cfg, 64, 2, "train")
    step = jax.jit(make_train_step(cfg))
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # master moved and params track it
    moved = any(float(jnp.max(jnp.abs(a - b))) > 0 for a, b in
                zip(jax.tree.leaves(opt["master"]), jax.tree.leaves(o2["master"])))
    assert moved


@pytest.mark.parametrize("mb", [2, 4])
def test_microbatched_step_matches_single(mb):
    cfg = reduced(get_config("minitron-4b"))
    params = init_params(jax.random.key(1), cfg)
    opt = init_opt_state(params)
    batch = make_batch(cfg, 32, 4, "train")
    p1, _, m1 = jax.jit(make_train_step(cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, microbatches=mb))(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


def test_tp_only_param_specs_drop_fsdp():
    cfg = get_config("stablelm-1.6b")
    mesh = AbstractMesh((("data", 16), ("model", 16)))
    fsdp = S.param_specs(cfg, mesh, fsdp_on=True)
    tponly = S.param_specs(cfg, mesh, fsdp_on=False)
    flat_f = jax.tree.leaves(fsdp, is_leaf=lambda x: isinstance(x, P))
    flat_t = jax.tree.leaves(tponly, is_leaf=lambda x: isinstance(x, P))
    assert any("data" in str(s) for s in flat_f)
    assert not any("data" in str(s) for s in flat_t)
    assert any("model" in str(s) for s in flat_t)   # TP survives


def test_head_padding_variant_lowers_shapes():
    cfg = get_config("qwen2.5-14b")
    padded = dataclasses.replace(cfg, n_heads=48, head_dim=cfg.resolved_head_dim)
    assert padded.resolved_head_dim == 128
    assert padded.n_heads % 16 == 0
    shapes = jax.eval_shape(lambda k: init_params(k, reduced(padded)),
                            jax.random.key(0))
    assert shapes is not None
