"""Fleet tier (``Scenario.FLEET``): the batched K-device serving loop must be
*bitwise* identical on NumPy to K sequential single-device closed loops over
the same split traces (the PR's correctness contract), the weighted
round-robin dispatch must match its greedy definition and round-trip
provenance, the per-device perturbation draws must be collision-free at
K=512, the batched fleet solver must replay the scalar solver over scaled
grids, and priority weighting must default (None / all-equal) to the
unweighted solver bitwise."""
import dataclasses

import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core import simulate as S
from repro.core.backend import jax_available
from repro.core.controller import ControllerConfig
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS, _device_pert,
                                     fleet_device)
from repro.core.powermode import PowerModeSpace
from repro.core.scheduler import Fulcrum, Scenario

DEV = DeviceModel()
SPACE = PowerModeSpace()
W_IN = INFER_WORKLOADS["mobilenet"]


def _fused_backend(backend):
    """Skip guard for the fused-window cases: the fused program is jax-tier
    (``pallas`` resolves to the same program; the engine tier is unused)."""
    if not jax_available():
        pytest.skip("jax unavailable")
    return backend


# ---------------------------------------------------------------------------
# (a) heterogeneity: collision-free deterministic perturbations
# ---------------------------------------------------------------------------

def test_device_perturbations_collision_free_at_k512():
    # the _poisson_seed trap: arithmetic seed mixing collides distinct
    # (index, field) pairs; the delimited-string key must not. 512 devices
    # x 2 fields = 1024 draws, all distinct.
    draws = [_device_pert(0, d, f, 0.10)
             for d in range(512) for f in ("time", "power")]
    assert len(set(draws)) == len(draws)
    assert all(0.90 <= x <= 1.10 for x in draws)
    # different seeds name different fleets; same seed is reproducible
    assert _device_pert(1, 7, "time", 0.1) != _device_pert(2, 7, "time", 0.1)
    assert _device_pert(3, 7, "time", 0.1) == _device_pert(3, 7, "time", 0.1)


def test_fleet_device_scales_grid_elementwise():
    d = fleet_device(5, seed=9)
    for pm in SPACE.all_modes()[:8]:
        for bs in (1, 32):
            t0, p0 = DEV.time_power(W_IN, pm, bs)
            t1, p1 = d.time_power(W_IN, pm, bs)
            assert t1 == t0 * d.time_scale and p1 == p0 * d.power_scale


def test_fleet_spec_validation():
    with pytest.raises(ValueError):
        F.FleetSpec(0)
    with pytest.raises(ValueError):
        F.FleetSpec(4, time_spread=1.5)
    with pytest.raises(ValueError):
        F.FleetSpec(4, dispatch="round-trip")
    assert len(F.FleetSpec(4).devices()) == 4


# ---------------------------------------------------------------------------
# (b) dispatch: greedy definition, vectorized merge, provenance round-trip
# ---------------------------------------------------------------------------

def _greedy_dispatch(n, weights, counts0=None):
    counts = (np.zeros(len(weights), np.int64) if counts0 is None
              else np.asarray(counts0, np.int64).copy())
    out = np.empty(n, np.int64)
    for k in range(n):
        out[k] = int(np.argmin((counts + 1.0) / weights))
        counts[out[k]] += 1
    return out


@pytest.mark.parametrize("seed", range(6))
def test_dispatch_matches_greedy_reference(seed):
    rng = np.random.default_rng(seed)
    K = int(rng.integers(1, 12))
    n = int(rng.integers(0, 400))
    wts = rng.uniform(0.5, 2.0, K)
    c0 = rng.integers(0, 30, K) if rng.random() < 0.5 else None
    got = F.dispatch_arrivals(np.zeros(n), wts, c0)
    assert np.array_equal(got, _greedy_dispatch(n, wts, c0))


def test_dispatch_proportional_to_capacity():
    wts = np.array([1.0, 1.0, 2.0])        # device 2 is twice as fast
    sid = F.dispatch_arrivals(np.zeros(400), wts)
    counts = np.bincount(sid, minlength=3)
    assert counts[2] == 200 and counts[0] == counts[1] == 100


def test_dispatch_provenance_round_trips():
    agg = S.ArrivalTrace.poisson(80.0, 5.0, seed=3)
    wts = np.array([1.0, 1.3, 0.8, 1.1])
    sid = F.dispatch_arrivals(agg.times, wts)
    merged, per_dev = F.split_window(agg, sid, 4)
    assert merged.n_streams == 4 and len(merged) == len(agg)
    # split(K) recovers exactly the per-device arrival times, in order
    re_split = merged.split(4)
    for tr, tr2, d in zip(per_dev, re_split, range(4)):
        assert np.array_equal(tr.times, agg.times[sid == d])
        assert np.array_equal(tr.times, tr2.times)
        assert tr.duration == agg.duration


# ---------------------------------------------------------------------------
# (c) the batched fleet solver == per-device scalar solves over scaled grids
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_fleet_solver_matches_scalar_over_scaled_grids(backend):
    if backend == "jax" and not jax_available():
        pytest.skip("jax unavailable")
    rng = np.random.default_rng(11)
    grid = G.materialize(DEV, W_IN, SPACE, P.INFER_BATCH_SIZES)
    base = grid.to_dict()
    n = 40
    ts = rng.uniform(0.9, 1.1, n)
    ps = rng.uniform(0.95, 1.05, n)
    probs = [P.InferProblem(float(rng.uniform(10, 55)),
                            float(rng.uniform(0.05, 1.5)),
                            float(rng.uniform(5, 150))) for _ in range(n)]
    his = np.array([p.arrival_rate * float(rng.uniform(1.0, 1.6))
                    for p in probs])
    got = G.solve_infer_fleet_batch(probs, his, grid, ts, ps,
                                    backend=backend)
    for k, (pr, sol) in enumerate(zip(probs, got)):
        obs = {key: (t * ts[k], p * ps[k]) for key, (t, p) in base.items()}
        ref = P.solve_infer_interval(pr, float(his[k]), obs)
        assert (sol is None) == (ref is None)
        if ref is not None:
            assert (sol.pm, sol.bs) == (ref.pm, ref.bs)
            if backend == "numpy":
                assert sol.time == ref.time and sol.power == ref.power
            else:
                np.testing.assert_allclose([sol.time, sol.power],
                                           [ref.time, ref.power],
                                           atol=1e-8, rtol=1e-9)


def test_fleet_solver_validates_alignment():
    grid = G.materialize(DEV, W_IN, SPACE, P.INFER_BATCH_SIZES)
    probs = [P.InferProblem(30.0, 0.5, 50.0)] * 2
    with pytest.raises(ValueError):
        G.solve_infer_fleet_batch(probs, [60.0], grid, [1.0, 1.0],
                                  [1.0, 1.0])


# ---------------------------------------------------------------------------
# (d) THE contract: batched fleet == K sequential single-device loops
# ---------------------------------------------------------------------------

def _assert_fleet_equal(a, b, exact=True):
    assert len(a) == len(b)
    for wa, wb in zip(a, b):
        assert np.array_equal(wa.dispatch_counts, wb.dispatch_counts)
        assert wa.offered_requests == wb.offered_requests
        assert np.array_equal(wa.trace.stream_ids, wb.trace.stream_ids)
        if exact:
            assert wa.goodput == wb.goodput
        for da, db in zip(wa.devices, wb.devices):
            assert (da.solution is None) == (db.solution is None)
            assert da.carried_requests == db.carried_requests
            assert da.replanned == db.replanned
            assert da.offered_requests == db.offered_requests
            if exact:
                assert da.rate == db.rate
                assert da.estimated_rate == db.estimated_rate
                assert da.goodput == db.goodput
            if da.solution is None:
                continue
            assert (da.solution.pm, da.solution.bs) \
                == (db.solution.pm, db.solution.bs)
            if exact:
                assert da.solution == db.solution
                assert da.report.latencies.tolist() \
                    == db.report.latencies.tolist()
                assert da.report.power == db.report.power
                assert da.report.attributed_power \
                    == db.report.attributed_power
                assert da.report.queue_state.pending.tolist() \
                    == db.report.queue_state.pending.tolist()
                assert da.report.queue_state.clock \
                    == db.report.queue_state.clock
            else:
                np.testing.assert_allclose(da.report.latencies,
                                           db.report.latencies,
                                           atol=1e-8, rtol=1e-9)


@pytest.mark.parametrize("seed", range(4))
def test_batched_fleet_bitwise_equals_sequential_numpy(seed):
    rng = np.random.default_rng(seed)
    spec = F.FleetSpec(int(rng.integers(2, 9)), seed=seed,
                       dispatch=("capacity", "least-backlog")[seed % 2])
    cfg = ControllerConfig(rate_estimator="ewma",
                           feedback=bool(seed % 2),
                           carry_backlog=True,
                           mode_switch_s=0.25 * (seed % 2),
                           burst_quantile=0.9 if seed == 1 else 0.0)
    rates = [float(r) for r in rng.uniform(20.0, 500.0, 4)]
    kw = dict(window_duration=3.0, arrivals="poisson", seed=seed + 100,
              backend="numpy", controller=cfg)
    a = F.serve_fleet(W_IN, 30.0, 0.2, rates, spec, **kw)
    b = F.serve_fleet_sequential(W_IN, 30.0, 0.2, rates, spec, **kw)
    _assert_fleet_equal(a, b, exact=True)


def test_batched_fleet_with_idle_devices_matches_sequential():
    # aggregate rate so low a window dispatches nothing to some devices —
    # idle lanes must still observe (rate estimate decays) and report
    # goodput 1.0 on zero offered
    spec = F.FleetSpec(8, seed=1)
    cfg = ControllerConfig(rate_estimator="ewma", carry_backlog=True)
    kw = dict(window_duration=2.0, arrivals="poisson", seed=5,
              backend="numpy", controller=cfg)
    a = F.serve_fleet(W_IN, 30.0, 0.2, [2.0, 1.0], spec, **kw)
    b = F.serve_fleet_sequential(W_IN, 30.0, 0.2, [2.0, 1.0], spec, **kw)
    _assert_fleet_equal(a, b, exact=True)
    idle = [d for d, c in enumerate(a[0].dispatch_counts) if c == 0]
    assert idle                              # the setup really idles devices
    for d in idle:
        assert a[0].devices[d].goodput == 1.0
        assert a[0].devices[d].offered_requests == 0


def test_batched_fleet_jax_matches_numpy_within_tolerance():
    if not jax_available():
        pytest.skip("jax unavailable")
    spec = F.FleetSpec(4, seed=2)
    cfg = ControllerConfig(rate_estimator="ewma", carry_backlog=True)
    kw = dict(window_duration=3.0, arrivals="poisson", seed=7,
              controller=cfg)
    a = F.serve_fleet(W_IN, 30.0, 0.2, [200.0, 400.0, 80.0], spec,
                      backend="jax", **kw)
    b = F.serve_fleet(W_IN, 30.0, 0.2, [200.0, 400.0, 80.0], spec,
                      backend="numpy", **kw)
    _assert_fleet_equal(a, b, exact=False)


def test_fleet_rejects_single_device_refinements():
    # admission is fleet-batched since the global-admission PR; the one
    # remaining single-device refinement is mid-window re-entry
    with pytest.raises(ValueError, match="split_backlog"):
        F.serve_fleet(W_IN, 30.0, 0.2, [50.0], F.FleetSpec(2),
                      controller=ControllerConfig(split_backlog=1))
    out = F.serve_fleet(W_IN, 30.0, 0.2, [50.0], F.FleetSpec(2),
                        controller=ControllerConfig(admission="shed"),
                        backend="numpy")
    assert len(out) == 1


def test_scenario_fleet_and_scheduler_facade():
    assert Scenario.FLEET.canonical is Scenario.INFER
    ful = Fulcrum(DEV, SPACE)
    out = ful.serve_fleet(W_IN, 30.0, 0.2, [100.0, 150.0], 4,
                          window_duration=2.0, backend="numpy")
    assert len(out) == 2 and len(out[0].devices) == 4
    assert out[0].attributed_power > 0.0
    # an int fleet arg names the default-spec fleet of that size
    spec = F.FleetSpec(4)
    ref = F.serve_fleet(W_IN, 30.0, 0.2, [100.0, 150.0], spec,
                        window_duration=2.0, backend="numpy",
                        space=SPACE)
    _assert_fleet_equal(out, ref, exact=True)


# ---------------------------------------------------------------------------
# (e) satellites: power attribution and priority-weighted objectives
# ---------------------------------------------------------------------------

def test_single_stream_attribution_equals_power():
    rep = S.simulate(DEV, None, W_IN, SPACE.maxn(), 16,
                     S.ArrivalTrace.uniform(50.0, 5.0))
    assert rep.attributed_power == rep.power   # sole busy share takes all
    idle = S.simulate(DEV, None, W_IN, SPACE.maxn(), 16,
                      S.ArrivalTrace.uniform(0.0, 5.0))
    assert idle.attributed_power == 0.0        # nothing ran, nothing billed


def test_multi_tenant_attribution_sums_to_device_power():
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    ws = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["resnet50"]]
    traces = [S.ArrivalTrace.uniform(40.0, 10.0),
              S.ArrivalTrace.uniform(15.0, 10.0)]
    rep = S.simulate_multi_tenant(DEV, w_tr, ws, SPACE.maxn(), [16, 4],
                                  traces)
    shares = [s.attributed_power for s in rep.streams] \
        + [rep.train_attributed_power]
    assert all(s >= 0.0 for s in shares)
    assert np.isclose(sum(shares), rep.power)
    # time-weighted: the busier stream is billed more per unit time served
    assert rep.streams[0].attributed_power > 0.0


def test_priorities_none_and_uniform_are_bitwise_default():
    rng = np.random.default_rng(4)
    sub = SPACE.all_modes()[::12]
    w_tr = TRAIN_WORKLOADS["resnet18"]
    tobs = {pm: DEV.time_power(w_tr, pm) for pm in sub}
    iobs = {(pm, bs): DEV.time_power(W_IN, pm, bs)
            for pm in sub for bs in P.INFER_BATCH_SIZES}
    for _ in range(25):
        streams = tuple(
            P.StreamSpec(float(rng.uniform(5, 60)),
                         float(rng.uniform(0.1, 1.0)), W_IN)
            for _ in range(2))
        prob = P.MultiTenantProblem(float(rng.uniform(15, 55)), streams,
                                    train=w_tr)
        ref = P.solve_multi_tenant(prob, tobs, [iobs, iobs])
        for pri in ((1.0, 1.0), (7.0, 7.0)):
            got = P.solve_multi_tenant(
                dataclasses.replace(prob, priorities=pri),
                tobs, [iobs, iobs])
            assert (ref is None) == (got is None)
            if ref is not None:
                assert ref.pm == got.pm and ref.bss == got.bss
                assert ref.times == got.times    # bitwise
                assert ref.power == got.power


def test_priorities_skew_the_latency_objective():
    # two identical streams; the solver breaks ties on the worst *weighted*
    # latency, so any skew must weakly improve the favored stream's latency
    sub = SPACE.all_modes()[::6]
    iobs = {(pm, bs): DEV.time_power(W_IN, pm, bs)
            for pm in sub for bs in P.INFER_BATCH_SIZES}
    streams = tuple(P.StreamSpec(40.0, 1.0, W_IN) for _ in range(2))
    base = P.MultiTenantProblem(40.0, streams, train=False)
    ref = P.solve_multi_tenant(base, None, [iobs, iobs])
    skew = P.solve_multi_tenant(
        dataclasses.replace(base, priorities=(100.0, 1.0)),
        None, [iobs, iobs])
    assert ref is not None and skew is not None
    assert skew.times[0] <= ref.times[0] + 1e-12
    # weights normalize to priority/max; validation rejects bad shapes
    assert base.priority_weights() is None
    w = dataclasses.replace(base, priorities=(2.0, 1.0)).priority_weights()
    assert w == (1.0, 0.5)
    with pytest.raises(ValueError):
        P.MultiTenantProblem(40.0, streams, train=False,
                             priorities=(1.0,))
    with pytest.raises(ValueError):
        P.MultiTenantProblem(40.0, streams, train=False,
                             priorities=(1.0, -2.0))


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_priority_batch_solver_matches_scalar(backend):
    if backend == "jax" and not jax_available():
        pytest.skip("jax unavailable")
    sub = SPACE.all_modes()[::8]
    iobs = {(pm, bs): DEV.time_power(W_IN, pm, bs)
            for pm in sub for bs in P.INFER_BATCH_SIZES}
    ig = G.ObservationGrid.from_infer_dict(iobs)
    streams = tuple(P.StreamSpec(30.0, 0.6, W_IN) for _ in range(2))
    # the batch solver requires uniform priorities per batch: one batch
    # call per priority vector, each checked against the scalar solver
    for pri in (None, (1.0, 1.0), (10.0, 1.0), (1.0, 10.0)):
        probs = [P.MultiTenantProblem(float(pb), streams, train=False,
                                      priorities=pri)
                 for pb in (20.0, 35.0, 55.0)]
        got = G.solve_multi_tenant_batch(probs, None, [ig, ig],
                                         backend=backend)
        for pr, sol in zip(probs, got):
            ref = P.solve_multi_tenant(pr, None, [iobs, iobs])
            assert (sol is None) == (ref is None)
            if ref is not None:
                assert sol.pm == ref.pm and sol.bss == ref.bss
                if backend == "numpy":
                    assert sol.times == ref.times and sol.power == ref.power


# ---------------------------------------------------------------------------
# (f) the fused window: solve + admit + simulate as ONE launch per window
# ---------------------------------------------------------------------------

_FUSED_MATRIX = {
    # name -> (FleetSpec kwargs, ControllerConfig kwargs): every fleet
    # feature the fused program claims to cover, including combinations
    "heterogeneous": (dict(time_spread=0.25, power_spread=0.15), dict()),
    "carried-backlog": (dict(), dict(rate_estimator="ewma",
                                     carry_backlog=True,
                                     mode_switch_s=0.25)),
    "shed": (dict(), dict(admission="shed", carry_backlog=True,
                          mode_switch_s=0.25)),
    "defer": (dict(dispatch="least-backlog"),
              dict(admission="defer", defer_cap=25, carry_backlog=True,
                   rate_estimator="ewma", rate_margin=1.5, feedback=True,
                   mode_switch_s=0.25)),
    "water-filled": (dict(migrate_backlog=True, fleet_power_budget=80.0),
                     dict(carry_backlog=True, feedback=True)),
}

# idle devices: rates so low whole windows dispatch nothing to some lanes
_FUSED_RATES = {"idle": [2.0, 0.0, 1.0],
                "default": [60.0, 110.0, 25.0, 80.0]}


@pytest.mark.parametrize("case", sorted(_FUSED_MATRIX))
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_fused_fleet_matches_unfused(case, backend):
    _fused_backend(backend)
    if backend == "pallas":
        from repro.core.backend import pallas_available
        if not pallas_available():
            pytest.skip("pallas unavailable")
    spec_kw, cfg_kw = _FUSED_MATRIX[case]
    spec = F.FleetSpec(6, seed=3, **spec_kw)
    cfg = ControllerConfig(**cfg_kw)
    rates = _FUSED_RATES["idle" if case == "heterogeneous" else "default"]
    kw = dict(window_duration=3.0, arrivals="poisson", seed=17,
              controller=cfg)
    fus = F.serve_fleet(W_IN, 30.0, 0.15, rates, spec, backend=backend,
                        fused=True, **kw)
    unf = F.serve_fleet(W_IN, 30.0, 0.15, rates, spec, backend=backend,
                        **kw)
    seq = F.serve_fleet_sequential(W_IN, 30.0, 0.15, rates, spec,
                                   backend="numpy", **kw)
    # same jax tier: only the associative scan's padded tree shape differs
    _assert_fleet_equal(fus, unf, exact=False)
    # and the exactness ladder back to the bitwise NumPy reference
    _assert_fleet_equal(fus, seq, exact=False)
    for wf, wu in zip(fus, unf):
        assert wf.shed_requests == wu.shed_requests
        assert wf.deferred_requests == wu.deferred_requests
        assert wf.migrated_requests == wu.migrated_requests
        for df, du in zip(wf.devices, wu.devices):
            assert df.shed_requests == du.shed_requests
            assert df.deferred_requests == du.deferred_requests
            assert df.mode_switch_s == du.mode_switch_s
            if df.report is not None:
                np.testing.assert_allclose(
                    df.report.queue_state.pending,
                    du.report.queue_state.pending, atol=1e-8, rtol=1e-9)
                np.testing.assert_allclose(
                    df.report.queue_state.clock,
                    du.report.queue_state.clock, atol=1e-8, rtol=1e-9)
                np.testing.assert_allclose(
                    df.report.attributed_power,
                    du.report.attributed_power, atol=1e-8, rtol=1e-9)


def test_fused_fleet_no_retrace_across_windows():
    _fused_backend("jax")
    from repro.core.fused_window import fleet_trace_count
    spec = F.FleetSpec(6, seed=3)
    cfg = ControllerConfig(rate_estimator="ewma", carry_backlog=True)
    kw = dict(window_duration=3.0, arrivals="poisson", seed=17,
              backend="jax", controller=cfg, fused=True)
    rates = [80.0] * 3
    F.serve_fleet(W_IN, 30.0, 0.15, rates, spec, **kw)   # warm the buckets
    before = fleet_trace_count()
    F.serve_fleet(W_IN, 30.0, 0.15, rates + [75.0, 85.0], spec, **kw)
    # steady state: same pow2 (K, event) buckets -> zero new compilations
    assert fleet_trace_count() == before


def test_fused_fleet_one_dispatch_per_window():
    _fused_backend("jax")
    from repro.core.backend import dispatch_count
    spec = F.FleetSpec(4, seed=3)
    kw = dict(window_duration=3.0, arrivals="poisson", seed=17,
              backend="jax", fused=True,
              controller=ControllerConfig(admission="shed"))
    rates = [60.0, 90.0, 40.0]
    F.serve_fleet(W_IN, 30.0, 0.15, rates, spec, **kw)   # warm compile
    before = dispatch_count()
    F.serve_fleet(W_IN, 30.0, 0.15, rates, spec, **kw)
    assert dispatch_count() - before == len(rates)       # ONE launch each


def test_fused_fleet_rejects_unfusable_configs():
    # the fused window is a jax program; the NumPy tier has no fused form
    with pytest.raises(ValueError, match="jax"):
        F.serve_fleet(W_IN, 30.0, 0.15, [50.0], F.FleetSpec(2),
                      backend="numpy", fused=True)
    # degrade-bs re-plans on the host mid-window: unfusable by design
    if jax_available():
        with pytest.raises(ValueError, match="degrade-bs"):
            F.serve_fleet(W_IN, 30.0, 0.15, [50.0], F.FleetSpec(2),
                          backend="jax", fused=True,
                          controller=ControllerConfig(
                              admission="degrade-bs", carry_backlog=True))


def test_grid_mode_ids_injective_and_memoized():
    from repro.core.fused_window import grid_mode_ids
    grid = G.materialize(DEV, W_IN, SPACE, P.INFER_BATCH_SIZES)
    ids = grid_mode_ids(grid)
    assert ids.shape == (len(grid),)
    # id equality must be PowerMode equality — the in-program mode-switch
    # charge depends on it
    by_id: dict = {}
    for pm, i in zip(grid.modes, ids):
        assert by_id.setdefault(int(i), pm) == pm
    n_modes = len({pm for pm in grid.modes})
    assert len(by_id) == n_modes
    assert grid_mode_ids(grid) is ids            # memoized on the grid
