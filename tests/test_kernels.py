"""Pallas kernels vs pure-jnp oracles, interpret=True shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_chunk_ref, ssd_full_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_chunk
from repro.kernels.tiled_matmul.ref import matmul_ref
from repro.kernels.tiled_matmul.tiled_matmul import tiled_matmul
from repro.kernels import ops


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 3, 256, 64),
                                     (1, 2, 384, 128), (2, 1, 256, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, h, s, d, dtype):
    ks = jax.random.split(jax.random.key(b * 100 + s), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32).astype(dtype)
               for kk in ks)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 2, 256, 64
    ks = jax.random.split(jax.random.key(window), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32) for kk in ks)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("block", [64, 128])
def test_flash_attention_block_invariance(block):
    """Output must not depend on the blocking."""
    b, h, s, d = 1, 1, 256, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32) for kk in ks)
    a = flash_attention(q, k, v, block_q=block, block_k=block, interpret=True)
    bfull = flash_attention(q, k, v, block_q=256, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bfull), atol=1e-5)


@pytest.mark.parametrize("bsz,nc,l,h,p,n", [(1, 2, 64, 2, 32, 16),
                                            (2, 4, 32, 4, 16, 8)])
def test_ssd_chunk_kernel(bsz, nc, l, h, p, n):
    ks = jax.random.split(jax.random.key(l + n), 5)
    x = jax.random.normal(ks[0], (bsz, nc, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, nc, l, n))
    C = jax.random.normal(ks[4], (bsz, nc, l, n))
    dA = dt * A[None, None, None, :]
    y, st = ssd_chunk(x, dA, dt, B, C, interpret=True)
    yr, str_ = ssd_chunk_ref(x, dA, dt, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), rtol=2e-4, atol=2e-4)


def test_ssd_full_scan_vs_model_layer():
    bsz, nc, l, h, p, n = 1, 4, 32, 2, 16, 8
    ks = jax.random.split(jax.random.key(7), 5)
    x = jax.random.normal(ks[0], (bsz, nc, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, nc, l, n))
    C = jax.random.normal(ks[4], (bsz, nc, l, n))
    y, final = ops.ssd_scan(x, dt, A, B, C)
    yr, fr = ssd_full_ref(x.reshape(bsz, nc * l, h, p),
                          dt.reshape(bsz, nc * l, h), A,
                          B.reshape(bsz, nc * l, 1, n),
                          C.reshape(bsz, nc * l, 1, n), l)
    np.testing.assert_allclose(np.asarray(y.reshape(bsz, nc * l, h, p)),
                               np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final.transpose(0, 1, 3, 2)),
                               np.asarray(fr), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul(m, k, n, dtype):
    a = jax.random.normal(jax.random.key(m + n), (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.key(k), (k, n), jnp.float32).astype(dtype)
    out = tiled_matmul(a, b, interpret=True)
    ref = matmul_ref(a, b)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_tiled_matmul_block_invariance():
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    o1 = tiled_matmul(a, b, block_m=64, block_n=64, block_k=64, interpret=True)
    o2 = tiled_matmul(a, b, block_m=128, block_n=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-4)
