"""Pallas kernels vs pure-jnp oracles, interpret=True shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ref import ssd_chunk_ref, ssd_full_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_chunk
from repro.kernels.tiled_matmul.ref import matmul_ref
from repro.kernels.tiled_matmul.tiled_matmul import tiled_matmul
from repro.kernels import ops


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 128, 64), (2, 3, 256, 64),
                                     (1, 2, 384, 128), (2, 1, 256, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(b, h, s, d, dtype):
    ks = jax.random.split(jax.random.key(b * 100 + s), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32).astype(dtype)
               for kk in ks)
    out = flash_attention(q, k, v, interpret=True)
    ref = attention_ref(q, k, v)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_sliding_window(window):
    b, h, s, d = 1, 2, 256, 64
    ks = jax.random.split(jax.random.key(window), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32) for kk in ks)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("block", [64, 128])
def test_flash_attention_block_invariance(block):
    """Output must not depend on the blocking."""
    b, h, s, d = 1, 1, 256, 64
    ks = jax.random.split(jax.random.key(9), 3)
    q, k, v = (jax.random.normal(kk, (b, h, s, d), jnp.float32) for kk in ks)
    a = flash_attention(q, k, v, block_q=block, block_k=block, interpret=True)
    bfull = flash_attention(q, k, v, block_q=256, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bfull), atol=1e-5)


@pytest.mark.parametrize("bsz,nc,l,h,p,n", [(1, 2, 64, 2, 32, 16),
                                            (2, 4, 32, 4, 16, 8)])
def test_ssd_chunk_kernel(bsz, nc, l, h, p, n):
    ks = jax.random.split(jax.random.key(l + n), 5)
    x = jax.random.normal(ks[0], (bsz, nc, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, nc, l, n))
    C = jax.random.normal(ks[4], (bsz, nc, l, n))
    dA = dt * A[None, None, None, :]
    y, st = ssd_chunk(x, dA, dt, B, C, interpret=True)
    yr, str_ = ssd_chunk_ref(x, dA, dt, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_), rtol=2e-4, atol=2e-4)


def test_ssd_full_scan_vs_model_layer():
    bsz, nc, l, h, p, n = 1, 4, 32, 2, 16, 8
    ks = jax.random.split(jax.random.key(7), 5)
    x = jax.random.normal(ks[0], (bsz, nc, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, nc, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bsz, nc, l, n))
    C = jax.random.normal(ks[4], (bsz, nc, l, n))
    y, final = ops.ssd_scan(x, dt, A, B, C)
    yr, fr = ssd_full_ref(x.reshape(bsz, nc * l, h, p),
                          dt.reshape(bsz, nc * l, h), A,
                          B.reshape(bsz, nc * l, 1, n),
                          C.reshape(bsz, nc * l, 1, n), l)
    np.testing.assert_allclose(np.asarray(y.reshape(bsz, nc * l, h, p)),
                               np.asarray(yr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final.transpose(0, 1, 3, 2)),
                               np.asarray(fr), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul(m, k, n, dtype):
    a = jax.random.normal(jax.random.key(m + n), (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.key(k), (k, n), jnp.float32).astype(dtype)
    out = tiled_matmul(a, b, interpret=True)
    ref = matmul_ref(a, b)
    tol = 1e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_tiled_matmul_block_invariance():
    a = jax.random.normal(jax.random.key(0), (256, 256), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (256, 256), jnp.float32)
    o1 = tiled_matmul(a, b, block_m=64, block_n=64, block_k=64, interpret=True)
    o2 = tiled_matmul(a, b, block_m=128, block_n=128, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# fulcrum engine kernels: max-plus scan + lane sort. These run in float64
# under enable_x64 (the engine's working precision) and are checked against
# BOTH the lax.associative_scan oracle (ref.py) and an independent scalar
# replay of the managed recurrence — tolerances per docs/exactness.md.
# ---------------------------------------------------------------------------

from repro.core.backend import require_jax
from repro.kernels.fulcrum.lane_sort import lane_sort
from repro.kernels.fulcrum.maxplus_scan import maxplus_scan
from repro.kernels.fulcrum.ref import (lane_sort_ref, lane_violations_ref,
                                       maxplus_scan_ref)

_, _, _enable_x64 = require_jax()
ENG_TOL = dict(rtol=1e-9, atol=1e-8)


def _maxplus_case(rng, lanes, kmax):
    """Ragged lanes padded the engine's way (+inf ready / 0 exec), with
    random nonzero clocks (backlog carryover) and +inf t_tr / tau_cap
    (no-training / uncapped lanes)."""
    sizes = rng.integers(0, kmax + 1, lanes)
    K = max(int(sizes.max(initial=0)), 1)
    ready = np.full((lanes, K), np.inf)
    exec_t = np.zeros((lanes, K))
    for i, nsz in enumerate(sizes):
        ready[i, :nsz] = np.sort(rng.uniform(0.0, 5.0, nsz))
        exec_t[i, :nsz] = rng.uniform(0.01, 0.5, nsz)
    t_tr = np.where(rng.random(lanes) < 0.3, np.inf,
                    rng.uniform(0.05, 0.5, lanes))
    cap = np.where(rng.random(lanes) < 0.5, np.inf,
                   rng.integers(0, 5, lanes).astype(np.float64))
    clock = np.where(rng.random(lanes) < 0.5, 0.0,
                     rng.uniform(0.0, 2.0, lanes))
    return ready, exec_t, t_tr, cap, clock, sizes


def _maxplus_scalar(ready, exec_t, t_tr, cap, clock):
    """Independent oracle: the managed recurrence replayed event-by-event in
    Python (completion c_k = max(c_{k-1}, ready_k) + e_k, fills clipped to
    the cap), skipping padded (+inf ready) events for the fill count."""
    lanes, K = ready.shape
    c = np.empty((lanes, K))
    fills = np.zeros(lanes)
    for i in range(lanes):
        t = clock[i]
        for k in range(K):
            if np.isfinite(ready[i, k]):
                gap = ready[i, k] - t
                fills[i] += min(max(np.floor(gap / t_tr[i]), 0.0), cap[i])
            t = max(t, ready[i, k]) + exec_t[i, k]
            c[i, k] = t
    return c, fills


@pytest.mark.parametrize("seed,lanes,kmax", [(0, 1, 16), (1, 7, 33),
                                             (2, 64, 5), (3, 17, 120)])
def test_maxplus_scan_matches_ref_and_scalar(seed, lanes, kmax):
    rng = np.random.default_rng(seed)
    ready, exec_t, t_tr, cap, clock, sizes = _maxplus_case(rng, lanes, kmax)
    with _enable_x64():
        c, fills = maxplus_scan(jnp.asarray(ready), jnp.asarray(exec_t),
                                jnp.asarray(t_tr), jnp.asarray(cap),
                                jnp.asarray(clock), interpret=True)
        cr, fr = maxplus_scan_ref(jnp.asarray(ready), jnp.asarray(exec_t),
                                  jnp.asarray(t_tr), jnp.asarray(cap),
                                  jnp.asarray(clock))
    c, fills = np.asarray(c), np.asarray(fills)
    cr, fr = np.asarray(cr), np.asarray(fr)
    cs, fs = _maxplus_scalar(ready, exec_t, t_tr, cap, clock)
    for i, nsz in enumerate(sizes):
        np.testing.assert_allclose(c[i, :nsz], cr[i, :nsz], **ENG_TOL)
        np.testing.assert_allclose(c[i, :nsz], cs[i, :nsz], **ENG_TOL)
    np.testing.assert_allclose(fills, fr, **ENG_TOL)
    assert np.all(np.abs(fills - fs) <= 2)     # floor-boundary slack


@pytest.mark.parametrize("bl", [1, 3, 8, 64])
def test_maxplus_scan_block_invariance(bl):
    """Per-lane arithmetic is independent of the lane blocking — results
    must be bitwise identical whatever block_lanes is."""
    rng = np.random.default_rng(42)
    ready, exec_t, t_tr, cap, clock, _ = _maxplus_case(rng, 13, 40)
    with _enable_x64():
        args = (jnp.asarray(ready), jnp.asarray(exec_t), jnp.asarray(t_tr),
                jnp.asarray(cap), jnp.asarray(clock))
        c_a, f_a = maxplus_scan(*args, block_lanes=bl, interpret=True)
        c_b, f_b = maxplus_scan(*args, block_lanes=256, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_a), np.asarray(c_b))
        np.testing.assert_array_equal(np.asarray(f_a), np.asarray(f_b))


def test_maxplus_scan_empty_edges():
    with _enable_x64():
        c, f = maxplus_scan(jnp.zeros((0, 4)), jnp.zeros((0, 4)),
                            jnp.zeros((0,)), jnp.zeros((0,)),
                            jnp.zeros((0,)), interpret=True)
    assert np.asarray(c).shape == (0, 4) and np.asarray(f).shape == (0,)


def _sort_case(rng, lanes, reqs):
    mat = np.full((lanes, reqs), np.inf)
    for i in range(lanes):
        nsz = int(rng.integers(0, reqs + 1))
        mat[i, :nsz] = rng.uniform(1e-4, 10.0, nsz)
    return mat


@pytest.mark.parametrize("seed,lanes,reqs", [(0, 1, 1), (1, 9, 17),
                                             (2, 33, 64), (3, 8, 100)])
def test_lane_sort_exact_vs_numpy(seed, lanes, reqs):
    """Sorting permutes values — the sorted matrix must be *equal* to
    NumPy's sort, not merely close (and to the jnp oracle)."""
    rng = np.random.default_rng(50 + seed)
    mat = _sort_case(rng, lanes, reqs)
    budgets = rng.uniform(0.1, 5.0, lanes)
    with _enable_x64():
        srt, viol = lane_sort(jnp.asarray(mat), jnp.asarray(budgets),
                              interpret=True)
        ref = lane_sort_ref(jnp.asarray(mat))
        vref = lane_violations_ref(jnp.asarray(mat), jnp.asarray(budgets))
    np.testing.assert_array_equal(np.asarray(srt), np.sort(mat, axis=1))
    np.testing.assert_array_equal(np.asarray(srt), np.asarray(ref))
    want = [(np.isfinite(mat[i]) & (mat[i] > budgets[i])).sum()
            for i in range(lanes)]
    np.testing.assert_array_equal(np.asarray(viol), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(viol), np.asarray(vref))


@pytest.mark.parametrize("bl", [1, 5, 256])
def test_lane_sort_block_invariance_and_sorted_only(bl):
    rng = np.random.default_rng(77)
    mat = _sort_case(rng, 11, 23)
    with _enable_x64():
        a = lane_sort(jnp.asarray(mat), block_lanes=bl, interpret=True)
        b = lane_sort(jnp.asarray(mat), block_lanes=8, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.sort(mat, axis=1))
