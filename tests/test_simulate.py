"""Trace-driven execution engine: the vectorized managed simulator must be
*identical* (latencies, minibatch counts, power) to the seed's scalar loop
across randomized (workload, pm, bs, rate) configs and every trace kind;
native/streams are seeded-deterministic with the same queueing skeleton.
The jax backend (max-plus associative scan) is cross-checked against the
NumPy reference within the tolerance documented in docs/exactness.md."""
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import problem as P
from repro.core import simulate as S
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.interleave import (simulate_managed, simulate_native,
                                   simulate_streams)
from repro.core.powermode import PowerModeSpace

DEV = DeviceModel()
SPACE = PowerModeSpace()
MODES = SPACE.all_modes()


def _random_config(rng):
    w_tr = (list(TRAIN_WORKLOADS.values())[rng.integers(5)]
            if rng.random() < 0.8 else None)
    w_in = list(INFER_WORKLOADS.values())[rng.integers(5)]
    pm = MODES[rng.integers(len(MODES))]
    bs = [1, 4, 16, 32, 64][rng.integers(5)]
    rate = float(rng.uniform(1.0, 120.0))
    duration = float(rng.uniform(5.0, 60.0))
    kind = int(rng.integers(3))
    if kind == 0:
        trace = S.ArrivalTrace.uniform(rate, duration)
    elif kind == 1:
        trace = S.ArrivalTrace.poisson(rate, duration,
                                       seed=int(rng.integers(1000)))
    else:
        trace = S.ArrivalTrace.piecewise(
            [float(rng.uniform(1.0, 100.0)) for _ in range(4)], duration / 4)
    tau_cap = None if rng.random() < 0.7 else int(rng.integers(0, 4))
    return w_tr, w_in, pm, bs, trace, tau_cap


# ---------------------------------------------------------------------------
# managed: vectorized kernel == scalar reference, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_managed_identical_to_scalar_randomized(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        w_tr, w_in, pm, bs, trace, tau_cap = _random_config(rng)
        vec = S.simulate(DEV, w_tr, w_in, pm, bs, trace, "managed",
                         tau_cap=tau_cap)
        ref = S.managed_scalar(DEV, w_tr, w_in, pm, bs, trace,
                               tau_cap=tau_cap)
        assert vec.latencies.tolist() == ref.latencies
        assert vec.train_minibatches == ref.train_minibatches
        assert vec.power == ref.power
        assert vec.duration == ref.duration


def test_managed_backlogged_identical_to_scalar():
    """Unsustainable config (t_in > bs/rate): the backlog-resolve path must
    still match the scalar recurrence exactly."""
    w_in = INFER_WORKLOADS["bert"]          # slow inference
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    pm = MODES[0]                           # slowest mode
    trace = S.ArrivalTrace.uniform(60.0, 20.0)
    vec = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed")
    ref = S.managed_scalar(DEV, w_tr, w_in, pm, 16, trace)
    t_in, _ = DEV.time_power(w_in, pm, 16)
    assert not P.sustainable(16, 60.0, t_in)     # backlog really happens
    assert vec.latencies.tolist() == ref.latencies
    assert vec.train_minibatches == ref.train_minibatches


def test_managed_wrapper_matches_seed_signature():
    """The interleave.simulate_managed wrapper over a uniform trace equals
    the scalar reference driven by the seed's arrival list."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    rep = simulate_managed(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    arrivals = [i / 60.0 for i in range(int(60.0 * 30.0))]   # seed loop
    assert rep.trace.times.tolist() == arrivals
    ref = S.managed_scalar(DEV, w_tr, w_in, pm, 16, rep.trace)
    assert rep.latencies.tolist() == ref.latencies
    assert rep.train_minibatches == ref.train_minibatches


def test_managed_tau_cap_bounds_training_only():
    """Threading the plan's tau_tr caps slack-fill without touching the
    latency trajectory (training never delays inference)."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    trace = S.ArrivalTrace.uniform(60.0, 30.0)
    free = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed")
    capped = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed", tau_cap=1)
    n_batches = len(trace) // 16
    assert capped.train_minibatches <= n_batches
    assert capped.train_minibatches <= free.train_minibatches
    assert capped.latencies.tolist() == free.latencies.tolist()


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def test_uniform_trace_bitwise_matches_seed_arrivals():
    for rate, duration in [(60.0, 30.0), (37.3, 17.9), (1.5, 120.0)]:
        trace = S.ArrivalTrace.uniform(rate, duration)
        assert trace.times.tolist() == \
            [i / rate for i in range(int(rate * duration))]


def test_poisson_trace_seeded_and_bounded():
    a = S.ArrivalTrace.poisson(60.0, 30.0, seed=3)
    b = S.ArrivalTrace.poisson(60.0, 30.0, seed=3)
    c = S.ArrivalTrace.poisson(60.0, 30.0, seed=4)
    assert np.array_equal(a.times, b.times)
    assert not np.array_equal(a.times, c.times)
    assert np.all(np.diff(a.times) > 0)
    assert a.times[-1] < 30.0 and a.times[0] > 0.0
    # ~rate*duration arrivals (Poisson concentration)
    assert 0.7 * 1800 < len(a) < 1.3 * 1800


def test_poisson_trace_idle_window_is_empty():
    trace = S.ArrivalTrace.poisson(0.0, 30.0, seed=1)
    assert len(trace) == 0 and trace.duration == 30.0
    rep = S.simulate(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(), 4,
                     trace, "managed")
    assert len(rep.latencies) == 0 and rep.train_minibatches == 0


def test_piecewise_trace_window_structure():
    rates = [10.0, 0.0, 40.0]
    trace = S.ArrivalTrace.piecewise(rates, 5.0)
    assert trace.duration == 15.0
    w0 = trace.times[trace.times < 5.0]
    w1 = trace.times[(trace.times >= 5.0) & (trace.times < 10.0)]
    w2 = trace.times[trace.times >= 10.0]
    assert len(w0) == 50 and len(w1) == 0 and len(w2) == 200
    assert np.all(np.diff(trace.times) >= 0)


# ---------------------------------------------------------------------------
# report statistics
# ---------------------------------------------------------------------------

def test_latency_quantile_nearest_rank():
    rep = S.ExecutionReport("managed", [4.0, 1.0, 3.0, 2.0], 0, 1.0, 0.0)
    assert rep.latency_quantile(0.75) == 3.0     # ceil(0.75*4)=3rd, not max
    assert rep.latency_quantile(0.5) == 2.0
    assert rep.latency_quantile(1.0) == 4.0
    assert rep.latency_quantile(0.01) == 1.0
    assert S.ExecutionReport("m", [], 0, 1.0, 0.0).latency_quantile(0.5) == 0.0


def test_violation_rate_matches_loop():
    xs = [0.1, 0.5, 0.2, 0.9]
    rep = S.ExecutionReport("managed", np.asarray(xs), 0, 1.0, 0.0)
    assert rep.violation_rate(0.3) == sum(1 for x in xs if x > 0.3) / len(xs)


# ---------------------------------------------------------------------------
# native / streams: seeded determinism + queueing skeleton
# ---------------------------------------------------------------------------

def test_native_streams_deterministic_per_seed():
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    for sim in (simulate_native, simulate_streams):
        a = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=1)
        b = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=1)
        c = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=2)
        assert a.latencies.tolist() == b.latencies.tolist()
        assert a.latencies.tolist() != c.latencies.tolist()


def test_queue_completions_matches_sequential_recurrence():
    rng = np.random.default_rng(0)
    for _ in range(20):
        K = int(rng.integers(1, 200))
        ready = np.sort(rng.uniform(0, 50, K))
        exec_t = rng.uniform(0.01, 2.0, K)
        got = S._queue_completions(ready, exec_t)
        now, want = 0.0, []
        for r, e in zip(ready, exec_t):
            now = max(now, r) + e
            want.append(now)
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-12)


def test_managed_dominates_native_and_streams_tails():
    """Fig. 2 shape is preserved by the vectorized engines."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    man = simulate_managed(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    nat = simulate_native(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    stc = simulate_streams(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    assert nat.latency_quantile(0.75) > man.latency_quantile(0.75)
    assert stc.latency_quantile(0.95) > man.latency_quantile(0.95)
    for rep in (man, nat, stc):
        assert rep.trace is not None and len(rep.trace) == 1800


def test_unknown_approach_raises():
    with pytest.raises(ValueError, match="unknown approach"):
        S.simulate(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(), 1,
                   S.ArrivalTrace.uniform(10.0, 1.0), approach="magic")


# ---------------------------------------------------------------------------
# jax backend: max-plus scan engine vs the NumPy reference, within the
# tolerance documented in docs/exactness.md (the scan reassociates adds and
# skips the fill-count boundary replay, so it is NOT bitwise)
# ---------------------------------------------------------------------------

needs_jax = pytest.mark.skipif(not B.jax_available(),
                               reason="jax unavailable")
TOL = dict(rtol=1e-9, atol=1e-8)
TRAIN_WS = list(TRAIN_WORKLOADS.values())
INFER_WS = list(INFER_WORKLOADS.values())


def _assert_engine_close(ref, got):
    np.testing.assert_allclose(np.asarray(got.latencies, np.float64),
                               np.asarray(ref.latencies, np.float64), **TOL)
    # fill counts may flip only on quotient-boundary cases (floor vs replay)
    assert abs(ref.train_minibatches - got.train_minibatches) <= 2
    if bool(ref.train_minibatches) == bool(got.train_minibatches):
        assert ref.power == got.power
    assert ref.duration == got.duration


@needs_jax
@pytest.mark.parametrize("seed", range(4))
def test_jax_engine_matches_numpy_randomized(seed):
    rng = np.random.default_rng(100 + seed)
    w_tr = TRAIN_WS[seed % len(TRAIN_WS)] if seed % 2 == 0 else None
    w_in = INFER_WS[seed % len(INFER_WS)]
    pms, bss, traces, caps = [], [], [], []
    for _ in range(8):
        _, _, pm, bs, trace, cap = _random_config(rng)
        pms.append(pm), bss.append(bs), traces.append(trace), caps.append(cap)
    ref = S.simulate_batch(DEV, w_tr, w_in, pms, bss, traces,
                           tau_caps=caps, backend="numpy")
    got = S.simulate_batch(DEV, w_tr, w_in, pms, bss, traces,
                           tau_caps=caps, backend="jax")
    for a, b in zip(ref, got):
        _assert_engine_close(a, b)


@needs_jax
def test_jax_single_simulate_matches_numpy():
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    trace = S.ArrivalTrace.poisson(60.0, 30.0, seed=7)
    ref = S.simulate(DEV, w_tr, w_in, SPACE.maxn(), 16, trace, "managed")
    got = S.simulate(DEV, w_tr, w_in, SPACE.maxn(), 16, trace, "managed",
                     backend="jax")
    _assert_engine_close(ref, got)


@needs_jax
def test_jax_engine_backlogged_within_tolerance():
    """Unsustainable config: the scan must track the queue buildup too."""
    trace = S.ArrivalTrace.uniform(60.0, 20.0)
    ref = S.simulate(DEV, TRAIN_WORKLOADS["mobilenet"],
                     INFER_WORKLOADS["bert"], MODES[0], 16, trace, "managed")
    got = S.simulate(DEV, TRAIN_WORKLOADS["mobilenet"],
                     INFER_WORKLOADS["bert"], MODES[0], 16, trace, "managed",
                     backend="jax")
    _assert_engine_close(ref, got)


@needs_jax
@pytest.mark.parametrize("seed", range(3))
def test_jax_multi_tenant_matches_numpy_randomized(seed):
    """Ragged tenant counts across lanes (padded stream axes), including
    idle tenants whose trace is empty."""
    rng = np.random.default_rng(200 + seed)
    w_tr = TRAIN_WS[seed % len(TRAIN_WS)] if seed != 1 else None
    wss, pms, bsss, tracess, caps = [], [], [], [], []
    for lane in range(4):
        n = int(rng.integers(1, 4))
        wss.append([INFER_WS[rng.integers(len(INFER_WS))] for _ in range(n)])
        pms.append(MODES[rng.integers(len(MODES))])
        bsss.append([int([1, 4, 16, 32][rng.integers(4)]) for _ in range(n)])
        duration = float(rng.uniform(5.0, 25.0))
        tracess.append([S.ArrivalTrace.poisson(
            0.0 if (lane == 0 and j == 0) or rng.random() < 0.15
            else float(rng.uniform(5.0, 60.0)),
            duration, seed=int(rng.integers(1000))) for j in range(n)])
        caps.append(None if rng.random() < 0.7 else int(rng.integers(0, 4)))
    ref = S.simulate_multi_tenant_batch(DEV, w_tr, wss, pms, bsss, tracess,
                                        tau_caps=caps, backend="numpy")
    got = S.simulate_multi_tenant_batch(DEV, w_tr, wss, pms, bsss, tracess,
                                        tau_caps=caps, backend="jax")
    assert tracess[0][0].times.size == 0           # an idle lane really ran
    for a, b in zip(ref, got):
        assert abs(a.train_minibatches - b.train_minibatches) <= 2
        assert len(a.streams) == len(b.streams)
        for ra, rb in zip(a.streams, b.streams):
            np.testing.assert_allclose(
                np.asarray(rb.latencies, np.float64),
                np.asarray(ra.latencies, np.float64), **TOL)


@needs_jax
def test_jax_multi_tenant_single_call_matches_numpy():
    ws = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]]
    traces = [S.ArrivalTrace.poisson(30.0, 20.0, seed=1),
              S.ArrivalTrace.uniform(50.0, 20.0)]
    ref = S.simulate_multi_tenant(DEV, TRAIN_WORKLOADS["resnet18"], ws,
                                  SPACE.maxn(), [4, 16], traces)
    got = S.simulate_multi_tenant(DEV, TRAIN_WORKLOADS["resnet18"], ws,
                                  SPACE.maxn(), [4, 16], traces,
                                  backend="jax")
    assert abs(ref.train_minibatches - got.train_minibatches) <= 2
    for ra, rb in zip(ref.streams, got.streams):
        np.testing.assert_allclose(np.asarray(rb.latencies, np.float64),
                                   np.asarray(ra.latencies, np.float64),
                                   **TOL)


# ---------------------------------------------------------------------------
# backend selection + batched report builder
# ---------------------------------------------------------------------------

def test_jax_backend_selection_defaults_to_numpy_when_unavailable(monkeypatch):
    """Regression: with jax absent the default path must degrade to the
    NumPy reference — env-var requests included — while an *explicit*
    backend='jax' argument raises."""
    monkeypatch.setattr(B, "_JAX_OK", False)
    monkeypatch.setenv(B.ENGINE_BACKEND_ENV, "jax")
    assert B.resolve_backend(None) == "numpy"
    monkeypatch.delenv(B.ENGINE_BACKEND_ENV)
    assert B.resolve_backend(None) == "numpy"
    with pytest.raises(RuntimeError, match="requires jax"):
        B.resolve_backend("jax")
    # the engine default still runs, on the reference backend
    trace = S.ArrivalTrace.uniform(20.0, 2.0)
    rep = S.simulate(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(), 4,
                     trace)
    ref = S.managed_scalar(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(),
                           4, trace)
    assert rep.latencies.tolist() == ref.latencies


def test_explicit_numpy_backend_wins_over_env_jax(monkeypatch):
    """Regression: backend='numpy' must run the reference engine even when
    FULCRUM_ENGINE_BACKEND=jax — the batch paths' per-lane delegation must
    not re-resolve the backend from the environment."""
    monkeypatch.setenv(B.ENGINE_BACKEND_ENV, "jax")
    monkeypatch.setitem(
        S._JAX_ENGINE_CACHE, "managed",
        lambda *a: pytest.fail("jax engine ran despite backend='numpy'"))
    w_in = INFER_WORKLOADS["mobilenet"]
    trace = S.ArrivalTrace.uniform(40.0, 5.0)
    S.simulate_batch(DEV, None, w_in, [SPACE.maxn()], [16], [trace],
                     backend="numpy")
    S.simulate_multi_tenant_batch(DEV, None, [[w_in]], [SPACE.maxn()],
                                  [[16]], [[trace]], backend="numpy")
    S.simulate(DEV, None, w_in, SPACE.maxn(), 16, trace, "managed",
               backend="numpy")


def test_backend_env_var_selects_jax(monkeypatch):
    if not B.jax_available():
        pytest.skip("jax unavailable")
    monkeypatch.setenv(B.ENGINE_BACKEND_ENV, "jax")
    assert B.resolve_backend(None) == "jax"
    with pytest.raises(ValueError, match="unknown backend"):
        B.resolve_backend("torch")


def test_batched_report_builder_matches_per_report_statistics():
    """The presorted quantile/violation caches must change nothing about
    the statistics themselves."""
    rng = np.random.default_rng(5)
    w_in = INFER_WORKLOADS["mobilenet"]
    pms = [MODES[int(rng.integers(len(MODES)))] for _ in range(4)]
    traces = [S.ArrivalTrace.poisson(float(rng.uniform(10, 60)), 15.0,
                                     seed=i) for i in range(4)]
    reps = S.simulate_batch(DEV, None, w_in, pms, [4, 16, 1, 32], traces)
    for rep in reps:
        assert rep._sorted is not None         # builder pre-filled the cache
        xs = np.asarray(rep.latencies, np.float64)
        for q in (0.01, 0.5, 0.75, 0.95, 1.0):
            fresh = S.ExecutionReport("managed", xs.tolist(), 0, 1.0, 0.0)
            assert rep.latency_quantile(q) == fresh.latency_quantile(q)
        for budget in (0.0, float(np.median(xs)) if xs.size else 0.5, 10.0):
            want = (float(np.count_nonzero(xs > budget)) / xs.size
                    if xs.size else 0.0)
            assert rep.violation_rate(budget) == want


# ---------------------------------------------------------------------------
# pallas backend tier: the hand-written kernels behind the same entry points
# ---------------------------------------------------------------------------

needs_pallas = pytest.mark.skipif(not B.pallas_available(),
                                  reason="pallas unavailable")


@needs_pallas
@pytest.mark.parametrize("seed", range(2))
def test_pallas_engine_matches_numpy_randomized(seed):
    rng = np.random.default_rng(300 + seed)
    w_tr = TRAIN_WS[seed % len(TRAIN_WS)] if seed % 2 == 0 else None
    w_in = INFER_WS[seed % len(INFER_WS)]
    pms, bss, traces, caps = [], [], [], []
    for _ in range(6):
        _, _, pm, bs, trace, cap = _random_config(rng)
        pms.append(pm), bss.append(bs), traces.append(trace), caps.append(cap)
    ref = S.simulate_batch(DEV, w_tr, w_in, pms, bss, traces,
                           tau_caps=caps, backend="numpy")
    got = S.simulate_batch(DEV, w_tr, w_in, pms, bss, traces,
                           tau_caps=caps, backend="pallas")
    for a, b in zip(ref, got):
        _assert_engine_close(a, b)
        # the pallas report builder sorts with the bitonic kernel: sorting
        # permutes values, so the cache must EQUAL sorting its own latencies
        assert b._sorted is not None
        np.testing.assert_array_equal(
            b._sorted, np.sort(np.asarray(b.latencies, np.float64)))


@needs_pallas
def test_pallas_single_simulate_matches_numpy():
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    trace = S.ArrivalTrace.poisson(60.0, 20.0, seed=7)
    ref = S.simulate(DEV, w_tr, w_in, SPACE.maxn(), 16, trace, "managed")
    got = S.simulate(DEV, w_tr, w_in, SPACE.maxn(), 16, trace, "managed",
                     backend="pallas")
    _assert_engine_close(ref, got)


@needs_pallas
def test_pallas_multi_tenant_matches_numpy():
    ws = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]]
    traces = [S.ArrivalTrace.poisson(30.0, 15.0, seed=1),
              S.ArrivalTrace.uniform(50.0, 15.0)]
    ref = S.simulate_multi_tenant(DEV, TRAIN_WORKLOADS["resnet18"], ws,
                                  SPACE.maxn(), [4, 16], traces)
    got = S.simulate_multi_tenant(DEV, TRAIN_WORKLOADS["resnet18"], ws,
                                  SPACE.maxn(), [4, 16], traces,
                                  backend="pallas")
    assert abs(ref.train_minibatches - got.train_minibatches) <= 2
    for ra, rb in zip(ref.streams, got.streams):
        np.testing.assert_allclose(np.asarray(rb.latencies, np.float64),
                                   np.asarray(ra.latencies, np.float64),
                                   **TOL)


def test_env_pallas_degrades_down_tiers(monkeypatch):
    """An environment-level 'pallas' request degrades pallas -> jax -> numpy
    as capabilities vanish; an *explicit* backend='pallas' argument raises."""
    monkeypatch.setenv(B.ENGINE_BACKEND_ENV, "pallas")
    if B.pallas_available():
        assert B.resolve_backend(None) == "pallas"
    monkeypatch.setattr(B, "_PALLAS_OK", False)
    if B.jax_available():
        assert B.resolve_backend(None) == "jax"
    monkeypatch.setattr(B, "_JAX_OK", False)
    assert B.resolve_backend(None) == "numpy"
    with pytest.raises(RuntimeError, match="pallas"):
        B.resolve_backend("pallas")


def test_grid_solvers_reject_pallas_backend():
    """The 'pallas' tier is engine-only: the grid solvers must refuse it
    loudly instead of silently falling back to the NumPy branch."""
    from repro.core import grid_eval as G
    with pytest.raises(ValueError, match="unknown backend"):
        G.solve_train_batch([], {}, backend="pallas")
    with pytest.raises(ValueError, match="unknown backend"):
        G.solve_infer_batch([], {}, backend="pallas")
    with pytest.raises(ValueError, match="unknown backend"):
        G.solve_concurrent_batch([], {}, {}, backend="pallas")


# ---------------------------------------------------------------------------
# jit-cache stability: shape bucketing must keep retraces flat across calls
# ---------------------------------------------------------------------------

@needs_jax
def test_engine_trace_count_stable_within_shape_bucket():
    """Lane counts inside one power-of-two bucket (and identical padded
    event counts) must reuse the compiled scan — no per-call retracing."""
    w_in = INFER_WORKLOADS["mobilenet"]
    trace = S.ArrivalTrace.poisson(30.0, 4.0, seed=3)

    def batch(n):
        S.simulate_batch(DEV, None, w_in, [SPACE.maxn()] * n, [8] * n,
                         [trace] * n, backend="jax")

    batch(5)                           # compile (or reuse a prior test's)
    n0 = S.engine_trace_count()
    batch(5)                           # identical shapes
    batch(6)                           # same pow2 lane bucket (8)
    batch(3)                           # floor bucket is 8 as well
    assert S.engine_trace_count() == n0


@needs_pallas
def test_pallas_trace_count_stable_within_shape_bucket():
    w_in = INFER_WORKLOADS["lstm"]
    trace = S.ArrivalTrace.poisson(25.0, 4.0, seed=5)

    def batch(n):
        S.simulate_batch(DEV, None, w_in, [SPACE.maxn()] * n, [4] * n,
                         [trace] * n, backend="pallas")

    batch(4)
    n0 = S.engine_trace_count()
    batch(4)
    batch(7)
    assert S.engine_trace_count() == n0


# ---------------------------------------------------------------------------
# chunked report builder: chunking must be invisible (bitwise)
# ---------------------------------------------------------------------------

def test_presort_chunking_bitwise_identical(monkeypatch):
    """Force tiny sort chunks: the per-report sorted caches must be bitwise
    identical to one unchunked NumPy sort per report."""
    rng = np.random.default_rng(9)
    reports = []
    for _ in range(13):
        xs = rng.uniform(0.0, 3.0, int(rng.integers(0, 40))).tolist()
        reports.append(S.ExecutionReport("managed", xs, 0, 1.0, 0.0))
    want = [np.sort(np.asarray(r.latencies, np.float64)) for r in reports]
    monkeypatch.setattr(S, "_SORT_CHUNK_ELEMS", 64)
    S._presort_reports(reports)
    for rep, w in zip(reports, want):
        assert rep._sorted is not None
        np.testing.assert_array_equal(rep._sorted, w)


@needs_pallas
def test_presort_pallas_backend_equals_numpy_sort(monkeypatch):
    rng = np.random.default_rng(11)
    reports = [S.ExecutionReport(
        "managed", rng.uniform(0.0, 3.0, int(rng.integers(1, 30))).tolist(),
        0, 1.0, 0.0) for _ in range(9)]
    want = [np.sort(np.asarray(r.latencies, np.float64)) for r in reports]
    monkeypatch.setattr(S, "_SORT_CHUNK_ELEMS", 128)   # exercise chunk loop
    S._presort_reports(reports, backend="pallas")
    for rep, w in zip(reports, want):
        np.testing.assert_array_equal(rep._sorted, w)
