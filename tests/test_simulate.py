"""Trace-driven execution engine: the vectorized managed simulator must be
*identical* (latencies, minibatch counts, power) to the seed's scalar loop
across randomized (workload, pm, bs, rate) configs and every trace kind;
native/streams are seeded-deterministic with the same queueing skeleton."""
import numpy as np
import pytest

from repro.core import problem as P
from repro.core import simulate as S
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.interleave import (simulate_managed, simulate_native,
                                   simulate_streams)
from repro.core.powermode import PowerModeSpace

DEV = DeviceModel()
SPACE = PowerModeSpace()
MODES = SPACE.all_modes()


def _random_config(rng):
    w_tr = (list(TRAIN_WORKLOADS.values())[rng.integers(5)]
            if rng.random() < 0.8 else None)
    w_in = list(INFER_WORKLOADS.values())[rng.integers(5)]
    pm = MODES[rng.integers(len(MODES))]
    bs = [1, 4, 16, 32, 64][rng.integers(5)]
    rate = float(rng.uniform(1.0, 120.0))
    duration = float(rng.uniform(5.0, 60.0))
    kind = int(rng.integers(3))
    if kind == 0:
        trace = S.ArrivalTrace.uniform(rate, duration)
    elif kind == 1:
        trace = S.ArrivalTrace.poisson(rate, duration,
                                       seed=int(rng.integers(1000)))
    else:
        trace = S.ArrivalTrace.piecewise(
            [float(rng.uniform(1.0, 100.0)) for _ in range(4)], duration / 4)
    tau_cap = None if rng.random() < 0.7 else int(rng.integers(0, 4))
    return w_tr, w_in, pm, bs, trace, tau_cap


# ---------------------------------------------------------------------------
# managed: vectorized kernel == scalar reference, exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_managed_identical_to_scalar_randomized(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        w_tr, w_in, pm, bs, trace, tau_cap = _random_config(rng)
        vec = S.simulate(DEV, w_tr, w_in, pm, bs, trace, "managed",
                         tau_cap=tau_cap)
        ref = S.managed_scalar(DEV, w_tr, w_in, pm, bs, trace,
                               tau_cap=tau_cap)
        assert vec.latencies.tolist() == ref.latencies
        assert vec.train_minibatches == ref.train_minibatches
        assert vec.power == ref.power
        assert vec.duration == ref.duration


def test_managed_backlogged_identical_to_scalar():
    """Unsustainable config (t_in > bs/rate): the backlog-resolve path must
    still match the scalar recurrence exactly."""
    w_in = INFER_WORKLOADS["bert"]          # slow inference
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    pm = MODES[0]                           # slowest mode
    trace = S.ArrivalTrace.uniform(60.0, 20.0)
    vec = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed")
    ref = S.managed_scalar(DEV, w_tr, w_in, pm, 16, trace)
    t_in, _ = DEV.time_power(w_in, pm, 16)
    assert not P.sustainable(16, 60.0, t_in)     # backlog really happens
    assert vec.latencies.tolist() == ref.latencies
    assert vec.train_minibatches == ref.train_minibatches


def test_managed_wrapper_matches_seed_signature():
    """The interleave.simulate_managed wrapper over a uniform trace equals
    the scalar reference driven by the seed's arrival list."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    rep = simulate_managed(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    arrivals = [i / 60.0 for i in range(int(60.0 * 30.0))]   # seed loop
    assert rep.trace.times.tolist() == arrivals
    ref = S.managed_scalar(DEV, w_tr, w_in, pm, 16, rep.trace)
    assert rep.latencies.tolist() == ref.latencies
    assert rep.train_minibatches == ref.train_minibatches


def test_managed_tau_cap_bounds_training_only():
    """Threading the plan's tau_tr caps slack-fill without touching the
    latency trajectory (training never delays inference)."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    trace = S.ArrivalTrace.uniform(60.0, 30.0)
    free = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed")
    capped = S.simulate(DEV, w_tr, w_in, pm, 16, trace, "managed", tau_cap=1)
    n_batches = len(trace) // 16
    assert capped.train_minibatches <= n_batches
    assert capped.train_minibatches <= free.train_minibatches
    assert capped.latencies.tolist() == free.latencies.tolist()


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

def test_uniform_trace_bitwise_matches_seed_arrivals():
    for rate, duration in [(60.0, 30.0), (37.3, 17.9), (1.5, 120.0)]:
        trace = S.ArrivalTrace.uniform(rate, duration)
        assert trace.times.tolist() == \
            [i / rate for i in range(int(rate * duration))]


def test_poisson_trace_seeded_and_bounded():
    a = S.ArrivalTrace.poisson(60.0, 30.0, seed=3)
    b = S.ArrivalTrace.poisson(60.0, 30.0, seed=3)
    c = S.ArrivalTrace.poisson(60.0, 30.0, seed=4)
    assert np.array_equal(a.times, b.times)
    assert not np.array_equal(a.times, c.times)
    assert np.all(np.diff(a.times) > 0)
    assert a.times[-1] < 30.0 and a.times[0] > 0.0
    # ~rate*duration arrivals (Poisson concentration)
    assert 0.7 * 1800 < len(a) < 1.3 * 1800


def test_poisson_trace_idle_window_is_empty():
    trace = S.ArrivalTrace.poisson(0.0, 30.0, seed=1)
    assert len(trace) == 0 and trace.duration == 30.0
    rep = S.simulate(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(), 4,
                     trace, "managed")
    assert len(rep.latencies) == 0 and rep.train_minibatches == 0


def test_piecewise_trace_window_structure():
    rates = [10.0, 0.0, 40.0]
    trace = S.ArrivalTrace.piecewise(rates, 5.0)
    assert trace.duration == 15.0
    w0 = trace.times[trace.times < 5.0]
    w1 = trace.times[(trace.times >= 5.0) & (trace.times < 10.0)]
    w2 = trace.times[trace.times >= 10.0]
    assert len(w0) == 50 and len(w1) == 0 and len(w2) == 200
    assert np.all(np.diff(trace.times) >= 0)


# ---------------------------------------------------------------------------
# report statistics
# ---------------------------------------------------------------------------

def test_latency_quantile_nearest_rank():
    rep = S.ExecutionReport("managed", [4.0, 1.0, 3.0, 2.0], 0, 1.0, 0.0)
    assert rep.latency_quantile(0.75) == 3.0     # ceil(0.75*4)=3rd, not max
    assert rep.latency_quantile(0.5) == 2.0
    assert rep.latency_quantile(1.0) == 4.0
    assert rep.latency_quantile(0.01) == 1.0
    assert S.ExecutionReport("m", [], 0, 1.0, 0.0).latency_quantile(0.5) == 0.0


def test_violation_rate_matches_loop():
    xs = [0.1, 0.5, 0.2, 0.9]
    rep = S.ExecutionReport("managed", np.asarray(xs), 0, 1.0, 0.0)
    assert rep.violation_rate(0.3) == sum(1 for x in xs if x > 0.3) / len(xs)


# ---------------------------------------------------------------------------
# native / streams: seeded determinism + queueing skeleton
# ---------------------------------------------------------------------------

def test_native_streams_deterministic_per_seed():
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    for sim in (simulate_native, simulate_streams):
        a = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=1)
        b = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=1)
        c = sim(DEV, w_tr, w_in, pm, 16, 60.0, duration=20.0, seed=2)
        assert a.latencies.tolist() == b.latencies.tolist()
        assert a.latencies.tolist() != c.latencies.tolist()


def test_queue_completions_matches_sequential_recurrence():
    rng = np.random.default_rng(0)
    for _ in range(20):
        K = int(rng.integers(1, 200))
        ready = np.sort(rng.uniform(0, 50, K))
        exec_t = rng.uniform(0.01, 2.0, K)
        got = S._queue_completions(ready, exec_t)
        now, want = 0.0, []
        for r, e in zip(ready, exec_t):
            now = max(now, r) + e
            want.append(now)
        np.testing.assert_allclose(got, np.asarray(want), rtol=1e-12)


def test_managed_dominates_native_and_streams_tails():
    """Fig. 2 shape is preserved by the vectorized engines."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    man = simulate_managed(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    nat = simulate_native(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    stc = simulate_streams(DEV, w_tr, w_in, pm, 16, 60.0, duration=30.0)
    assert nat.latency_quantile(0.75) > man.latency_quantile(0.75)
    assert stc.latency_quantile(0.95) > man.latency_quantile(0.95)
    for rep in (man, nat, stc):
        assert rep.trace is not None and len(rep.trace) == 1800


def test_unknown_approach_raises():
    with pytest.raises(ValueError, match="unknown approach"):
        S.simulate(DEV, None, INFER_WORKLOADS["lstm"], SPACE.maxn(), 1,
                   S.ArrivalTrace.uniform(10.0, 1.0), approach="magic")
