"""Substrate tests: optimizer, schedules, data pipeline, checkpointing,
serving engine, real-mode interleave runtime."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config, make_batch, reduced
from repro.data.pipeline import SyntheticTokenSource
from repro.optim.adamw import (AdamWConfig, adamw_update, init_opt_state,
                               schedule)
from repro.runtime.serving import BatchInferenceServer, GenerationServer, RequestQueue
from repro.runtime.train_loop import Trainer


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0, total_steps=200,
                      grad_clip=100.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss(params)) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert math.isclose(max(lrs), 1e-3, rel_tol=0.03)
    assert math.isclose(lrs[-1], 1e-4, rel_tol=0.05)
    assert all(b <= a + 1e-12 for a, b in zip(lrs[2:], lrs[3:]))  # decays


def test_grad_clip_bounds_update():
    params = {"w": jnp.ones((4,))}
    opt = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=0)
    g = {"w": jnp.full((4,), 1e6)}
    _, opt2, stats = adamw_update(g, opt, params, cfg)
    assert float(stats["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(opt2["m"]["w"]))) <= 0.2  # clipped before m


def test_data_pipeline_deterministic_and_shaped():
    cfg = reduced(get_config("internvl2-1b"))
    a = next(iter(SyntheticTokenSource(cfg, 2, 64, seed=7)))
    b = next(iter(SyntheticTokenSource(cfg, 2, 64, seed=7)))
    assert set(a) == {"tokens", "labels", "vision"}
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (2, 64 - cfg.n_patches)
    assert a["vision"].shape == (2, cfg.n_patches, cfg.d_vision)
    assert a["tokens"].max() < cfg.vocab_size


def test_checkpoint_roundtrip_and_missing_leaf():
    cfg = reduced(get_config("mamba2-780m"))
    tr = Trainer(cfg, batch=2, seq_len=32)
    tr.train(1, log_every=0)
    save_checkpoint("/tmp/test_ck.npz", (tr.params, tr.opt_state), tr.step)
    (p2, o2), step = restore_checkpoint("/tmp/test_ck.npz",
                                        (tr.params, tr.opt_state))
    assert step == 1
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(KeyError):
        restore_checkpoint("/tmp/test_ck.npz", {"nope": jnp.zeros(3)})


def test_training_reduces_loss():
    cfg = reduced(get_config("stablelm-1.6b"))
    tr = Trainer(cfg, batch=4, seq_len=64)
    rep = tr.train(8, log_every=0)
    assert rep.final_loss < rep.losses[0]
    assert np.isfinite(rep.final_loss)


def test_request_queue_batching():
    q = RequestQueue()
    for i in range(10):
        q.push({"i": i}, now=float(i))
    assert q.ready(4) and len(q) == 10
    batch = q.pop_batch(4)
    assert [r.payload["i"] for r in batch] == [0, 1, 2, 3]
    assert len(q) == 6


def test_generation_server_decodes():
    cfg = reduced(get_config("qwen2.5-14b"))
    gs = GenerationServer(cfg, max_seq=64, bs=2)
    prompt = make_batch(cfg, 16, 2, "prefill")
    toks = gs.generate(prompt, steps=4, prompt_len=16)
    assert toks.shape == (2, 4)
    assert (toks >= 0).all() and (toks < cfg.padded_vocab).all()


def test_generation_greedy_is_deterministic():
    cfg = reduced(get_config("stablelm-1.6b"))
    gs = GenerationServer(cfg, max_seq=48, bs=1)
    prompt = make_batch(cfg, 16, 1, "prefill")
    t1 = gs.generate(prompt, steps=4, prompt_len=16)
    t2 = gs.generate(prompt, steps=4, prompt_len=16)
    np.testing.assert_array_equal(t1, t2)
