"""Fleet-wide resource control (global admission, backlog migration, shared
power budget): the batched K-device loop must stay *bitwise* identical on
NumPy to K sequential single-device loops for every admission mode x
migration x shared-budget combination (tolerance-identical on jax), the
admitted subsequences must replay with zero nominal-budget violations at
fleet scale (the PR-6 exactness property, per device), migration must
conserve requests, water-filled grants must sum within the fleet cap, and
the default ``FleetSpec`` must reproduce the PR-8 loop byte-for-byte — the
features are provably opt-in."""
import numpy as np
import pytest

from repro.core import fleet as F
from repro.core import problem as P
from repro.core import simulate as S
from repro.core.backend import jax_available
from repro.core.controller import ControllerConfig, ControllerState
from repro.core.device_model import DeviceModel, INFER_WORKLOADS

try:                                   # hypothesis is optional: the random-
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # scenario property tests degrade to
    HAVE_HYPOTHESIS = False            # skips; seeded ones always run

DEV = DeviceModel()
W_IN = INFER_WORKLOADS["mobilenet"]

# the PR-6 closed-loop config the admission benches use, fleet-sized windows
_CL = dict(rate_estimator="ewma", rate_margin=1.5, feedback=True,
           carry_backlog=True, mode_switch_s=0.25, burst_quantile=0.95)


def _cfg(mode, **over):
    kw = dict(_CL, admission=mode)
    if mode == "defer":
        kw["defer_cap"] = 500
    kw.update(over)
    return ControllerConfig(**kw)


def _assert_fleet_equal(a, b, exact=True):
    """Field-by-field equality of two fleet runs, the new resource-control
    accounts included (extends test_fleet's checker)."""
    assert len(a) == len(b)
    for wa, wb in zip(a, b):
        assert np.array_equal(wa.dispatch_counts, wb.dispatch_counts)
        assert wa.offered_requests == wb.offered_requests
        assert np.array_equal(wa.trace.stream_ids, wb.trace.stream_ids)
        assert wa.shed_requests == wb.shed_requests
        assert wa.deferred_requests == wb.deferred_requests
        assert wa.migrated_requests == wb.migrated_requests
        assert (wa.power_budgets is None) == (wb.power_budgets is None)
        if wa.power_budgets is not None:
            assert wa.power_budgets.tolist() == wb.power_budgets.tolist()
        if exact:
            assert wa.goodput == wb.goodput
        for da, db in zip(wa.devices, wb.devices):
            assert (da.solution is None) == (db.solution is None)
            assert da.carried_requests == db.carried_requests
            assert da.offered_requests == db.offered_requests
            assert da.shed_requests == db.shed_requests
            assert da.deferred_requests == db.deferred_requests
            if exact:
                assert da.rate == db.rate
                assert da.estimated_rate == db.estimated_rate
                assert da.goodput == db.goodput
            if da.solution is None:
                continue
            assert (da.solution.pm, da.solution.bs) \
                == (db.solution.pm, db.solution.bs)
            if exact:
                assert da.solution == db.solution
                assert da.report.latencies.tolist() \
                    == db.report.latencies.tolist()
                assert da.report.queue_state.pending.tolist() \
                    == db.report.queue_state.pending.tolist()
                assert da.report.queue_state.clock \
                    == db.report.queue_state.clock
            else:
                np.testing.assert_allclose(da.report.latencies,
                                           db.report.latencies,
                                           atol=1e-8, rtol=1e-9)


def _run_pair(spec, cfg, rates, backend="numpy", latency=0.05, power=30.0,
              wd=2.0, seed=11):
    kw = dict(window_duration=wd, arrivals="poisson", seed=seed,
              backend=backend, controller=cfg)
    a = F.serve_fleet(W_IN, power, latency, rates, spec, **kw)
    b = F.serve_fleet_sequential(W_IN, power, latency, rates, spec, **kw)
    return a, b


# ---------------------------------------------------------------------------
# (a) THE contract, extended: batched == sequential for every feature combo
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["shed", "defer", "degrade-bs"])
@pytest.mark.parametrize("mig,fleet_budget", [(False, None), (True, None),
                                              (False, 130.0), (True, 130.0)])
def test_admission_fleet_bitwise_equals_sequential_numpy(mode, mig,
                                                         fleet_budget):
    spec = F.FleetSpec(5, seed=3, time_spread=0.3, dispatch="least-backlog",
                       migrate_backlog=mig, fleet_power_budget=fleet_budget)
    rates = [400.0, 800.0, 120.0, 600.0]     # overload: the gates must act
    a, b = _run_pair(spec, _cfg(mode), rates)
    _assert_fleet_equal(a, b, exact=True)
    if mode in ("shed", "defer") and fleet_budget is None:
        assert sum(w.shed_requests + w.deferred_requests for w in a) > 0
    if mig and fleet_budget is None:
        assert sum(w.migrated_requests for w in a) > 0


@pytest.mark.parametrize("mode", ["shed", "defer"])
def test_admission_fleet_jax_matches_sequential_within_tolerance(mode):
    if not jax_available():
        pytest.skip("jax unavailable")
    spec = F.FleetSpec(4, seed=2, time_spread=0.25, migrate_backlog=True,
                       fleet_power_budget=110.0)
    a, b = _run_pair(spec, _cfg(mode), [300.0, 700.0, 150.0], backend="jax")
    _assert_fleet_equal(a, b, exact=False)


@pytest.mark.parametrize("seed", range(4))
def test_admission_fleet_parity_random_scenarios(seed):
    """Seeded random K / heterogeneity / burst mixes — always runs, even
    without hypothesis installed."""
    rng = np.random.default_rng(seed)
    mode = ("shed", "defer", "degrade-bs")[seed % 3]
    spec = F.FleetSpec(int(rng.integers(1, 7)), seed=seed,
                       time_spread=float(rng.uniform(0.0, 0.4)),
                       dispatch=("capacity", "least-backlog")[seed % 2],
                       migrate_backlog=bool(seed % 2),
                       fleet_power_budget=(None, 80.0)[(seed // 2) % 2])
    rates = [float(r) for r in rng.uniform(20.0, 900.0, 4)]
    a, b = _run_pair(spec, _cfg(mode), rates, seed=seed + 50)
    _assert_fleet_equal(a, b, exact=True)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 10**6),
           k=st.integers(1, 6),
           spread=st.floats(0.0, 0.4),
           mode=st.sampled_from(["shed", "defer", "degrade-bs"]),
           mig=st.booleans(),
           budget=st.sampled_from([None, 60.0, 100.0]),
           dispatch=st.sampled_from(["capacity", "least-backlog"]),
           burst=st.floats(100.0, 1200.0))
    def test_admission_fleet_parity_property(seed, k, spread, mode, mig,
                                             budget, dispatch, burst):
        rng = np.random.default_rng(seed)
        spec = F.FleetSpec(k, seed=seed % 97, time_spread=spread,
                           dispatch=dispatch, migrate_backlog=mig,
                           fleet_power_budget=budget)
        rates = [float(r) for r in rng.uniform(10.0, burst, 3)]
        a, b = _run_pair(spec, _cfg(mode), rates, seed=seed % 1013)
        _assert_fleet_equal(a, b, exact=True)


# ---------------------------------------------------------------------------
# (b) flood admission at fleet scale: admitted subsequences replay clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["shed", "defer"])
def test_fleet_flood_admitted_requests_meet_budget(mode):
    """The PR-6 exactness property per device: the admission mask runs each
    device's own engine recurrence (its own t_in, its carried clock), so
    every admitted request — simulated for real through ``simulate_batch``
    — meets the nominal budget, while the flood guarantees rejections
    happened on several devices."""
    spec = F.FleetSpec(4, seed=3, time_spread=0.3)
    rates = [1200.0, 1200.0, 1200.0]            # ~3x sustainable per device
    wins = F.serve_fleet(W_IN, 40.0, 0.1, rates, spec, window_duration=2.0,
                         arrivals="poisson", seed=7, backend="numpy",
                         controller=_cfg(mode, defer_cap=200))
    trimmed_devices = set()
    for fw in wins:
        assert fw.shed_requests + fw.deferred_requests > 0
        for d, wr in enumerate(fw.devices):
            if wr.report is not None:
                assert wr.report.violation_rate(0.1) == 0.0
            if wr.shed_requests + wr.deferred_requests > 0:
                trimmed_devices.add(d)
    assert len(trimmed_devices) > 1             # fleet-wide, not one lane
    # dropping the gate makes the same flood violate: the overload is real
    raw = F.serve_fleet(W_IN, 40.0, 0.1, rates, spec, window_duration=2.0,
                        arrivals="poisson", seed=7, backend="numpy",
                        controller=ControllerConfig(**_CL))
    assert any(wr.report is not None and wr.report.violation_rate(0.1) > 0.0
               for fw in raw for wr in fw.devices)


def test_fleet_deferred_reenter_the_dispatcher():
    """Deferred requests re-enter the *dispatcher* at the next window start
    (re-timestamped at t0, sorted first), not the device that bounced them:
    the next window's merged trace carries exactly the previous window's
    deferral count as extra leading arrivals."""
    spec = F.FleetSpec(3, seed=3, time_spread=0.3)
    wins = F.serve_fleet(W_IN, 40.0, 0.1, [900.0, 300.0, 100.0], spec,
                         window_duration=2.0, arrivals="poisson", seed=7,
                         backend="numpy", controller=_cfg("defer"))
    assert wins[0].deferred_requests > 0
    for prev, cur, i in zip(wins, wins[1:], range(1, len(wins))):
        extra = len(cur.trace) - cur.offered_requests
        assert extra == prev.deferred_requests
        t0 = i * 2.0
        assert np.all(cur.trace.times[:extra] == t0)
        # the re-offers were dispatched across devices like any arrival
        assert int(cur.dispatch_counts.sum()) == len(cur.trace)


# ---------------------------------------------------------------------------
# (c) conservation: migration moves requests, never loses or mints them
# ---------------------------------------------------------------------------

def _states_with_backlog(pendings, clocks, cfg):
    states = []
    for pend, clock in zip(pendings, clocks):
        stt = ControllerState(cfg, 1)
        if pend is not None:
            stt.carry = S.QueueState(np.asarray(pend, np.float64),
                                     float(clock))
        states.append(stt)
    return states


def test_migrate_backlog_conserves_and_retimestamps():
    cfg = ControllerConfig(carry_backlog=True)
    # device 0 is flooded, 1 idle, 2 lightly loaded, 3 has no carry at all
    pendings = [np.linspace(0.0, 1.8, 40), np.empty(0), [1.0, 1.5], None]
    clocks = [2.4, 2.0, 2.1, 0.0]
    states = _states_with_backlog(pendings, clocks, cfg)
    before = sum(len(s.carry) for s in states if s.carry is not None)
    moved = F._migrate_backlog(states, np.ones(4), t0=2.0)
    assert moved > 0
    after = sum(len(s.carry) for s in states if s.carry is not None)
    assert after == before                       # nothing lost or minted
    sizes = [len(s.carry) for s in states]
    assert max(sizes) - min(sizes) <= 1          # equal-weight equalization
    for d, s in enumerate(states):
        pend = s.carry.pending
        assert np.all(np.diff(pend) >= 0.0)      # replayable: nondecreasing
        assert np.all(pend <= 2.0 + 1e-12)       # moved requests land at t0
        # clocks never migrate: a busy device stays busy
        expect = clocks[d] if pendings[d] is not None else 2.0
        assert s.carry.clock == expect
    # stayed requests keep their original timestamps (bitwise replay);
    # moved ones are re-timestamped at exactly t0
    orig = set(np.concatenate([np.asarray(p, np.float64)
                               for p in pendings if p is not None]))
    for s in states:
        for t in s.carry.pending:
            assert float(t) in orig or float(t) == 2.0


def test_migrate_noop_when_nothing_moves():
    cfg = ControllerConfig(carry_backlog=True)
    states = _states_with_backlog([[0.5], [0.6]], [1.0, 1.0], cfg)
    carries = [s.carry for s in states]
    assert F._migrate_backlog(states, np.ones(2), t0=1.0) == 0
    assert all(s.carry is c for s, c in zip(states, carries))


def test_migration_rebalances_toward_idle_devices():
    """End to end: with least-backlog dispatch off (capacity dispatch pins
    arrivals proportionally), migration drains a hot device's carry into
    idle ones between windows."""
    spec_off = F.FleetSpec(4, seed=5, time_spread=0.35)
    spec_on = F.FleetSpec(4, seed=5, time_spread=0.35, migrate_backlog=True)
    kw = dict(window_duration=2.0, arrivals="poisson", seed=9,
              backend="numpy", controller=ControllerConfig(**_CL))
    rates = [1000.0, 50.0, 50.0, 50.0]          # burst, then drain windows
    off = F.serve_fleet(W_IN, 30.0, 0.05, rates, spec_off, **kw)
    on = F.serve_fleet(W_IN, 30.0, 0.05, rates, spec_on, **kw)
    assert sum(w.migrated_requests for w in on) > 0
    assert all(w.migrated_requests == 0 for w in off)
    # post-burst carried backlog is spread flatter with migration on
    def worst_carry(wins):
        return max(max(wr.carried_requests for wr in w.devices)
                   for w in wins[1:])
    assert worst_carry(on) <= worst_carry(off)


# ---------------------------------------------------------------------------
# (d) shared power budget: water-filling grants within the fleet cap
# ---------------------------------------------------------------------------

def test_water_fill_closed_form():
    np.testing.assert_allclose(P.water_fill(np.array([1.0, 2.0, 3.0]), 9.0),
                               [2.0, 3.0, 4.0])          # slack split evenly
    got = P.water_fill(np.array([1.0, 5.0, 10.0]), 8.0)
    np.testing.assert_allclose(got, [1.0, 3.5, 3.5])     # level allocation
    assert float(got.sum()) == pytest.approx(8.0)
    np.testing.assert_allclose(P.water_fill(np.array([4.0]), 2.0), [2.0])
    with pytest.raises(ValueError):
        P.water_fill(np.array([-1.0, 2.0]), 5.0)
    with pytest.raises(ValueError):
        P.water_fill(np.empty(0), 5.0)


def test_fleet_power_budget_bounds_attributed_power():
    fb = 120.0
    spec = F.FleetSpec(5, seed=3, time_spread=0.3, fleet_power_budget=fb)
    wins = F.serve_fleet(W_IN, 30.0, 0.05, [400.0, 800.0, 300.0], spec,
                         window_duration=2.0, arrivals="poisson", seed=11,
                         backend="numpy", controller=_cfg("shed"))
    served = 0
    for fw in wins:
        assert fw.power_budgets is not None
        assert float(fw.power_budgets.sum()) <= fb + 1e-9
        assert np.all(fw.power_budgets > 0.0)
        assert np.all(fw.power_budgets <= 30.0 + 1e-12)  # per-device cap
        assert fw.attributed_power <= fb + 1e-9
        for d, wr in enumerate(fw.devices):
            if wr.report is None:
                continue
            served += 1
            # the committed plan respects the device's water-filled grant
            assert wr.solution.power <= fw.power_budgets[d] + 1e-12
    assert served > 0


def test_fleet_power_budget_follows_demand():
    """Water-filling is demand-driven: after a window where only some
    devices attribute power, the next grants skew toward them (floored so
    idle devices can re-enter)."""
    fb = 120.0
    spec = F.FleetSpec(5, seed=3, time_spread=0.3, fleet_power_budget=fb)
    wins = F.serve_fleet(W_IN, 30.0, 0.05, [400.0, 800.0, 300.0], spec,
                         window_duration=2.0, arrivals="poisson", seed=11,
                         backend="numpy", controller=_cfg("shed"))
    K = 5
    floor = fb / (4.0 * K)
    for prev, cur in zip(wins, wins[1:]):
        attr = np.array([(wr.report.attributed_power or 0.0)
                         if wr.report is not None else 0.0
                         for wr in prev.devices])
        assert np.all(cur.power_budgets >= floor - 1e-12)
        if attr.max() > attr.min():              # skewed demand last window
            assert cur.power_budgets[int(attr.argmax())] \
                >= cur.power_budgets[int(attr.argmin())]


# ---------------------------------------------------------------------------
# (e) the features are provably opt-in: PR-8 defaults, byte-for-byte
# ---------------------------------------------------------------------------

# serve_fleet(mobilenet, 30.0, 0.1, [60, 90, 45], FleetSpec(3, seed=2,
# dispatch="least-backlog"), wd=5.0, poisson seed 9, numpy) under the PR-5
# closed-loop config — captured on the PR-8 code before this PR's features
_PR8_FINGERPRINT = [
    ([95, 108, 95], 298, 1.0, 88.97228327172972,
     [('8c/1958/1300/3199', 1, 95, 1.7360800077866878, 5.015016350494625),
      ('8c/2201/1300/3199', 1, 108, 1.694506938705475, 4.995914466680478),
      ('8c/2201/1300/3199', 1, 95, 1.7007526113773164, 4.951472835322781)]),
    ([146, 167, 147], 460, 1.0, 88.97228327172972,
     [('8c/1958/1300/3199', 1, 146, 2.7869726480459205, 10.009887555913291),
      ('8c/2201/1300/3199', 1, 167, 2.679105710393067, 10.011081786031085),
      ('8c/2201/1300/3199', 1, 147, 2.7030223667635243, 9.993803171137206)]),
    ([71, 80, 71], 222, 1.0, 88.97228327172972,
     [('8c/1958/1300/3199', 1, 71, 1.2997414865362735, 14.979021337146028),
      ('8c/2201/1300/3199', 1, 80, 1.2702685196490382, 14.93161267356513),
      ('8c/2201/1300/3199', 1, 71, 1.2654876022190038, 14.950858563553371)])]


def test_fleet_defaults_reproduce_pr8_byte_identically():
    spec = F.FleetSpec(3, seed=2, dispatch="least-backlog")
    cfg = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                           feedback=True, carry_backlog=True,
                           mode_switch_s=0.25)
    wins = F.serve_fleet(W_IN, 30.0, 0.1, [60.0, 90.0, 45.0], spec,
                         window_duration=5.0, arrivals="poisson", seed=9,
                         backend="numpy", controller=cfg)
    got = [(list(map(int, fw.dispatch_counts)), fw.offered_requests,
            fw.goodput, fw.attributed_power,
            [(str(wr.solution.pm), wr.solution.bs,
              len(wr.report.latencies),
              float(np.sum(wr.report.latencies)),
              float(wr.report.queue_state.clock))
             for wr in fw.devices]) for fw in wins]
    assert got == _PR8_FINGERPRINT
    for fw in wins:                              # and the new accounts stay
        assert fw.shed_requests == 0             # inert at the defaults
        assert fw.deferred_requests == 0
        assert fw.migrated_requests == 0
        assert fw.power_budgets is None


def test_fused_run_leaves_default_path_byte_identical():
    """The fused-window PR's opt-in proof: running the fused jax program
    first (caches warmed, grid device columns uploaded, counters bumped)
    must leave the default unfused NumPy run reproducing the PR-8
    fingerprint byte-for-byte."""
    from repro.core.backend import jax_available
    if not jax_available():
        pytest.skip("jax unavailable")
    spec = F.FleetSpec(3, seed=2, dispatch="least-backlog")
    cfg = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                           feedback=True, carry_backlog=True,
                           mode_switch_s=0.25)
    kw = dict(window_duration=5.0, arrivals="poisson", seed=9,
              controller=cfg)
    F.serve_fleet(W_IN, 30.0, 0.1, [60.0, 90.0, 45.0], spec,
                  backend="jax", fused=True, **kw)
    wins = F.serve_fleet(W_IN, 30.0, 0.1, [60.0, 90.0, 45.0], spec,
                         backend="numpy", **kw)
    got = [(list(map(int, fw.dispatch_counts)), fw.offered_requests,
            fw.goodput, fw.attributed_power,
            [(str(wr.solution.pm), wr.solution.bs,
              len(wr.report.latencies),
              float(np.sum(wr.report.latencies)),
              float(wr.report.queue_state.clock))
             for wr in fw.devices]) for fw in wins]
    assert got == _PR8_FINGERPRINT


# ---------------------------------------------------------------------------
# (f) per-feature capability checks: one clear error per unsupported combo
# ---------------------------------------------------------------------------

def test_split_backlog_still_rejected_with_clear_message():
    with pytest.raises(ValueError, match="split_backlog"):
        F.serve_fleet(W_IN, 30.0, 0.2, [50.0], F.FleetSpec(2),
                      controller=ControllerConfig(split_backlog=1))
    with pytest.raises(ValueError, match="split_backlog"):
        F.serve_fleet_sequential(W_IN, 30.0, 0.2, [50.0], F.FleetSpec(2),
                                 controller=ControllerConfig(split_backlog=1))


def test_migration_requires_carry_backlog_with_clear_message():
    spec = F.FleetSpec(2, migrate_backlog=True)
    with pytest.raises(ValueError, match="carry_backlog"):
        F.serve_fleet(W_IN, 30.0, 0.2, [50.0], spec,
                      controller=ControllerConfig())
    with pytest.raises(ValueError, match="carry_backlog"):
        F.serve_fleet_sequential(W_IN, 30.0, 0.2, [50.0], spec,
                                 controller=ControllerConfig())


def test_fleet_spec_validates_power_budget():
    with pytest.raises(ValueError, match="fleet_power_budget"):
        F.FleetSpec(2, fleet_power_budget=0.0)
    with pytest.raises(ValueError, match="fleet_power_budget"):
        F.FleetSpec(2, fleet_power_budget=-5.0)
    assert F.FleetSpec(2, fleet_power_budget=60.0).fleet_power_budget == 60.0
