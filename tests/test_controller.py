"""Closed-loop dynamic serving: rate estimation, executed-latency feedback,
backlog carryover, and runtime-vs-engine parity.

The carryover contract is the load-bearing piece: replaying one long trace
as K windows chained through ``QueueState`` must be *bitwise identical* on
NumPy to replaying it in one engine call (tolerance-identical on jax), per
docs/exactness.md. The controller pieces are unit-tested (EWMA convergence
and warm start, feedback monotonicity) and integration-tested through
``serve_dynamic``; the ported interleave runtime under a ``FakeClock`` must
reproduce the engine's scalar reference bitwise, with the drift recorded.

Run with ``FULCRUM_ENGINE_BACKEND=jax`` to drive every engine call in the
serve_dynamic integration tests through the max-plus scan backend (CI does).
"""
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import problem as P
from repro.core import simulate as S
from repro.core.controller import (ControllerConfig, ControllerState,
                                   FeedbackPolicy, RateEstimator)
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.powermode import PowerModeSpace
from repro.core.scheduler import Fulcrum, _poisson_seed
from repro.runtime.clock import FakeClock, WallClock
from repro.runtime.interleave_runtime import (InterleaveConfig,
                                              ManagedInterleaveRuntime,
                                              attach_drift)

DEV = DeviceModel()
SPACE = PowerModeSpace()
MODES = SPACE.all_modes()
needs_jax = pytest.mark.skipif(not B.jax_available(),
                               reason="jax unavailable")
TOL = dict(rtol=1e-9, atol=1e-8)


# ---------------------------------------------------------------------------
# rate estimation
# ---------------------------------------------------------------------------

def test_ewma_converges_to_constant_rate():
    est = RateEstimator("ewma", alpha=0.05)
    for k in range(4):
        tr = S.ArrivalTrace.uniform(40.0, 30.0).shifted(k * 30.0)
        est.observe(tr.times, 30.0)
    assert est.estimate(999.0) == pytest.approx(40.0, rel=1e-6)


def test_ewma_warm_starts_from_previous_window():
    """State carries across windows: after a rate change the estimate moves
    toward the new rate; a fresh estimator knows nothing."""
    est = RateEstimator("ewma", alpha=0.01)
    est.observe(S.ArrivalTrace.uniform(30.0, 30.0).times, 30.0)
    first = est.estimate(0.0)
    est.observe(S.ArrivalTrace.uniform(90.0, 30.0).shifted(30.0).times, 30.0)
    second = est.estimate(0.0)
    assert first == pytest.approx(30.0, rel=1e-3)
    assert second == pytest.approx(90.0, rel=0.05)
    assert RateEstimator("ewma")._mean_gap is None   # fresh: no state


def test_ewma_bootstrap_and_idle_windows():
    est = RateEstimator("ewma", alpha=0.1)
    assert est.estimate(55.0) == 55.0          # window 0: announced rate
    est.observe(np.empty(0), 30.0)             # idle window: pseudo-gap
    assert 0.0 < est.estimate(55.0) <= 1.0 / 30.0 + 1e-12


def test_oracle_estimator_passthrough():
    est = RateEstimator("oracle")
    est.observe(S.ArrivalTrace.uniform(90.0, 10.0).times, 10.0)
    assert est.estimate(42.0) == 42.0


def test_controller_config_validation():
    with pytest.raises(ValueError, match="estimator"):
        ControllerConfig(rate_estimator="magic")
    with pytest.raises(ValueError, match="ewma_alpha"):
        ControllerConfig(ewma_alpha=0.0)
    with pytest.raises(ValueError, match="mode_switch_s"):
        ControllerConfig(mode_switch_s=-1.0)
    assert not ControllerConfig().closed_loop
    for cfg in (ControllerConfig(rate_estimator="ewma"),
                ControllerConfig(feedback=True),
                ControllerConfig(carry_backlog=True),
                ControllerConfig(mode_switch_s=0.5),
                ControllerConfig(rate_margin=1.2)):
        assert cfg.closed_loop


# ---------------------------------------------------------------------------
# feedback policy
# ---------------------------------------------------------------------------

def test_feedback_monotone_in_violation_rate():
    """A higher executed violation rate never yields a looser next budget."""
    cfg = ControllerConfig(feedback=True)
    scales = []
    for v in (0.0, 0.05, 0.2, 0.5, 1.0):
        pol = FeedbackPolicy(cfg)
        pol.update(v, tail_latency=0.2, nominal=0.1)
        scales.append(pol.scale)
    assert scales == sorted(scales, reverse=True)
    assert scales[0] == 1.0 and scales[-1] < 1.0


def test_feedback_tightens_then_relaxes_toward_nominal():
    cfg = ControllerConfig(feedback=True, tighten=0.5, relax=0.5)
    pol = FeedbackPolicy(cfg)
    pol.update(1.0, tail_latency=1.0, nominal=0.1)
    tightened = pol.effective_budget(0.1)
    assert tightened < 0.1
    for _ in range(20):
        pol.update(0.0, tail_latency=0.01, nominal=0.1)
    assert tightened < pol.effective_budget(0.1) <= 0.1


def test_feedback_floor_and_inert_without_flag():
    tight = ControllerConfig(feedback=True, tighten=1.0, min_budget_scale=0.3)
    pol = FeedbackPolicy(tight)
    for _ in range(50):
        pol.update(1.0, tail_latency=10.0, nominal=0.1)
    assert pol.scale == pytest.approx(0.3)
    inert = FeedbackPolicy(ControllerConfig())
    inert.update(1.0, tail_latency=10.0, nominal=0.1)
    assert inert.scale == 1.0


# ---------------------------------------------------------------------------
# backlog carryover: windowed == one long trace (the exactness contract)
# ---------------------------------------------------------------------------

def _carryover_configs(seed):
    rng = np.random.default_rng(seed)
    w_tr = (list(TRAIN_WORKLOADS.values())[rng.integers(5)]
            if rng.random() < 0.7 else None)
    w_in = list(INFER_WORKLOADS.values())[rng.integers(5)]
    pm = MODES[rng.integers(len(MODES))]
    bs = [1, 4, 16, 32][rng.integers(4)]
    rate = float(rng.uniform(5.0, 120.0))
    duration = float(rng.uniform(20.0, 60.0))
    trace = (S.ArrivalTrace.uniform(rate, duration) if rng.random() < 0.5
             else S.ArrivalTrace.poisson(rate, duration,
                                         int(rng.integers(1000))))
    cap = None if rng.random() < 0.7 else int(rng.integers(0, 4))
    K = int(rng.integers(2, 6))
    return w_tr, w_in, pm, bs, trace, cap, K


def _run_windowed(w_tr, w_in, pm, bs, trace, cap, K, backend="numpy"):
    W = trace.duration / K
    carry, lats, trained = None, [], 0
    for k in range(K):
        hi = (k + 1) * W if k < K - 1 else trace.duration + 1.0
        rep = S.simulate(DEV, w_tr, w_in, pm, bs, trace.clip(k * W, hi),
                         "managed", tau_cap=cap, carry_in=carry,
                         backend=backend)
        carry = rep.queue_state
        lats.extend(np.asarray(rep.latencies, np.float64).tolist())
        trained += rep.train_minibatches
    return lats, trained, carry


@pytest.mark.parametrize("seed", range(8))
def test_windowed_carryover_equals_long_trace_bitwise(seed):
    # bitwise is the NumPy reference's contract: pin the backend so the
    # test still checks it when FULCRUM_ENGINE_BACKEND=jax (CI does)
    w_tr, w_in, pm, bs, trace, cap, K = _carryover_configs(seed)
    long = S.simulate(DEV, w_tr, w_in, pm, bs, trace, "managed", tau_cap=cap,
                      backend="numpy")
    lats, trained, carry = _run_windowed(w_tr, w_in, pm, bs, trace, cap, K,
                                         backend="numpy")
    assert lats == np.asarray(long.latencies, np.float64).tolist()
    assert trained == long.train_minibatches
    assert carry.pending.tolist() == long.queue_state.pending.tolist()
    assert carry.clock == long.queue_state.clock


@needs_jax
@pytest.mark.parametrize("seed", range(3))
def test_windowed_carryover_jax_within_tolerance(seed):
    w_tr, w_in, pm, bs, trace, cap, K = _carryover_configs(100 + seed)
    long = S.simulate(DEV, w_tr, w_in, pm, bs, trace, "managed", tau_cap=cap,
                      backend="numpy")
    lats, trained, carry = _run_windowed(w_tr, w_in, pm, bs, trace, cap, K,
                                         backend="jax")
    np.testing.assert_allclose(np.asarray(lats),
                               np.asarray(long.latencies, np.float64), **TOL)
    # each window may flip a quotient-boundary fill (docs/exactness.md)
    assert abs(trained - long.train_minibatches) <= 2 * K
    assert abs(carry.clock - long.queue_state.clock) < 1e-7


def test_windowed_carryover_multi_tenant_bitwise():
    ws = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]]
    w_tr = TRAIN_WORKLOADS["resnet18"]
    bss = [4, 16]
    traces = [S.ArrivalTrace.poisson(30.0, 24.0, seed=1),
              S.ArrivalTrace.uniform(50.0, 24.0)]
    long = S.simulate_multi_tenant(DEV, w_tr, ws, SPACE.maxn(), bss, traces,
                                   backend="numpy")
    carry, lats, trained = None, [[], []], 0
    for k in range(3):
        hi = (k + 1) * 8.0 if k < 2 else 25.0
        wins = [tr.clip(k * 8.0, hi) for tr in traces]
        rep = S.simulate_multi_tenant(DEV, w_tr, ws, SPACE.maxn(), bss, wins,
                                      carry_in=carry, backend="numpy")
        carry = rep.queue_state
        trained += rep.train_minibatches
        for j, r in enumerate(rep.streams):
            lats[j].extend(np.asarray(r.latencies, np.float64).tolist())
    for j, r in enumerate(long.streams):
        assert lats[j] == np.asarray(r.latencies, np.float64).tolist()
    assert trained == long.train_minibatches
    assert carry.pending.tolist() == long.queue_state.pending.tolist()
    assert carry.stream_ids.tolist() == long.queue_state.stream_ids.tolist()
    assert carry.clock == long.queue_state.clock


def test_queue_state_contents_and_scalar_identity():
    """Pending = the trailing partial minibatch (original times); clock =
    the last completion; the scalar reference agrees bitwise."""
    w_in = INFER_WORKLOADS["mobilenet"]
    trace = S.ArrivalTrace.uniform(10.0, 1.05)   # 10 arrivals, bs=4
    rep = S.simulate(DEV, None, w_in, SPACE.maxn(), 4, trace, "managed",
                     backend="numpy")
    qs = rep.queue_state
    assert qs.pending.tolist() == trace.times[8:].tolist()
    assert qs.clock == float(np.asarray(rep.latencies)[-1] + trace.times[7])
    carry = S.QueueState(np.array([0.01, 0.02]), 0.6)
    ref = S.managed_scalar(DEV, None, w_in, SPACE.maxn(), 4, trace,
                           carry_in=carry)
    vec = S.simulate(DEV, None, w_in, SPACE.maxn(), 4, trace, "managed",
                     carry_in=carry, backend="numpy")
    assert np.asarray(vec.latencies).tolist() == ref.latencies
    assert vec.queue_state.pending.tolist() == \
        ref.queue_state.pending.tolist()
    assert vec.queue_state.clock == ref.queue_state.clock


def test_carry_in_rejected_for_stochastic_approaches():
    trace = S.ArrivalTrace.uniform(20.0, 2.0)
    qs = S.QueueState(np.empty(0), 1.0)
    for approach in ("native", "streams"):
        with pytest.raises(ValueError, match="managed"):
            S.simulate(DEV, TRAIN_WORKLOADS["mobilenet"],
                       INFER_WORKLOADS["mobilenet"], SPACE.maxn(), 4, trace,
                       approach, carry_in=qs)


def test_trace_clip_and_concat_roundtrip():
    trace = S.ArrivalTrace.poisson(40.0, 30.0, seed=2)
    parts = [trace.clip(0.0, 10.0), trace.clip(10.0, 20.0),
             trace.clip(20.0, 31.0)]
    back = S.ArrivalTrace.concat(parts, duration=trace.duration)
    assert back.times.tolist() == trace.times.tolist()
    rebased = trace.clip(10.0, 20.0, rebase=True)
    assert rebased.duration == 10.0
    assert np.all(rebased.times >= 0.0) and np.all(rebased.times < 10.0)
    with pytest.raises(ValueError, match="nondecreasing"):
        S.ArrivalTrace.concat([parts[1], parts[0]])


# ---------------------------------------------------------------------------
# closed-loop serve_dynamic
# ---------------------------------------------------------------------------

def test_open_loop_default_matches_explicit_config():
    """serve_dynamic() with no controller == the default ControllerConfig:
    the open-loop batched path, with the new report fields populated."""
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [40.0, 70.0, 55.0]
    a = f.serve_dynamic(w, 40.0, 0.5, rates, "gmd", window_duration=10.0)
    b = f.serve_dynamic(w, 40.0, 0.5, rates, "gmd", window_duration=10.0,
                        controller=ControllerConfig())
    for wa, wb in zip(a, b):
        assert np.asarray(wa.report.latencies).tolist() == \
            np.asarray(wb.report.latencies).tolist()
        assert wa.solution == wb.solution
        assert wa.estimated_rate == wa.rate       # oracle passthrough
        assert wa.mode_switch_s == 0.0 and wa.carried_requests == 0
    assert a[0].replanned                         # first window commits


def test_closed_loop_reports_estimates_and_carryover():
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [40.0, 70.0, 40.0, 40.0]
    cfg = ControllerConfig(rate_estimator="ewma", carry_backlog=True)
    wins = f.serve_dynamic(w, 40.0, 0.5, rates, "gmd", window_duration=10.0,
                           arrivals="poisson", controller=cfg)
    assert len(wins) == len(rates)
    assert wins[0].estimated_rate == rates[0]     # bootstrap: announced
    for wr in wins[1:]:
        assert wr.estimated_rate != wr.rate       # estimated, not oracle
        assert wr.report is not None
    # window 1 was planned for ~40 while 70 arrived: the estimate tracks
    assert wins[1].estimated_rate == pytest.approx(40.0, rel=0.3)
    assert wins[2].estimated_rate == pytest.approx(70.0, rel=0.3)
    # carryover accounting is reported
    assert any(wr.carried_requests > 0 for wr in wins[1:]) or \
        all(len(wr.report.queue_state) == 0 for wr in wins[:-1])


def test_closed_loop_mode_switch_charged_and_delays_first_batch():
    """A window whose plan changes power mode pays mode_switch_s: the
    engine clock starts at t0 + switch, so the first batch completes no
    earlier than the switch allows."""
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    state = ControllerState(ControllerConfig(mode_switch_s=2.0), 1)
    assert state.mode_switch(MODES[0]) == 0.0     # first commit: free
    assert state.mode_switch(MODES[0]) == 0.0     # unchanged: free
    assert state.mode_switch(MODES[1]) == 2.0     # switch: charged
    qs = state.window_carry_in(10.0, 2.0)
    assert qs.clock == 12.0 and len(qs) == 0
    # integration: a switch-cost config still serves every window
    cfg = ControllerConfig(mode_switch_s=0.5)
    wins = f.serve_dynamic(w, 40.0, 0.5, [40.0, 60.0], "gmd",
                           window_duration=10.0, controller=cfg)
    assert all(wr.report is not None for wr in wins)
    assert all(wr.mode_switch_s in (0.0, 0.5) for wr in wins)


def test_closed_loop_ewma_meets_budget_on_most_windows():
    """The acceptance bar, on a deterministic slice of the bench sweep:
    EWMA-estimated rates (no oracle rates) with feedback keep the executed
    p95 within the budget on >= 90% of windows under uniform arrivals."""
    import math
    import random
    rng = random.Random(42)
    rates = [max(30.0, min(76.0, rng.gauss(60, math.sqrt(60))))
             for _ in range(10)]
    f = Fulcrum(DEV)
    cfg = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                           feedback=True, carry_backlog=True)
    wins = f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1, rates,
                           "gmd", window_duration=30.0, controller=cfg)
    ok = sum(wr.report is not None
             and wr.report.violation_rate(0.1) <= 0.05 for wr in wins)
    assert ok / len(wins) >= 0.9


def test_closed_loop_multi_tenant_per_stream_state():
    f = Fulcrum(DEV)
    specs = (P.StreamSpec(40.0, 1.0, INFER_WORKLOADS["mobilenet"]),
             P.StreamSpec(50.0, 0.6, INFER_WORKLOADS["lstm"]))
    windows = [(40.0, 50.0), (70.0, 20.0), (30.0, 60.0)]
    cfg = ControllerConfig(rate_estimator="ewma", feedback=True,
                           carry_backlog=True)
    wins = f.serve_dynamic(specs, 40.0, None, windows, "gmd",
                           window_duration=10.0, arrivals="poisson",
                           w_tr=TRAIN_WORKLOADS["mobilenet"],
                           controller=cfg)
    assert len(wins) == 3
    for wr in wins:
        assert wr.report is not None and len(wr.report.streams) == 2
        assert isinstance(wr.estimated_rate, tuple)
    # per-stream estimates track each tenant's own rate, not the other's
    assert wins[2].estimated_rate[0] == pytest.approx(70.0, rel=0.35)
    assert wins[2].estimated_rate[1] == pytest.approx(20.0, rel=0.35)


def test_poisson_seed_scheme_collision_free():
    """Regression for the ``seed + 101*i + j`` scheme: per-(window, stream)
    seeds must be unique for any window count x stream count grid."""
    seen = {}
    for i in range(300):          # far beyond the old 101-window collision
        for j in range(4):
            s = _poisson_seed(7, i, j, 4)
            assert s not in seen, f"collision: {(i, j)} vs {seen[s]}"
            seen[s] = (i, j)
    # the old scheme really collided (documents why it changed)
    old = {7 + 101 * i + j for i in range(2) for j in range(102)}
    assert len(old) < 2 * 102


# ---------------------------------------------------------------------------
# runtime-vs-engine parity under the fake clock
# ---------------------------------------------------------------------------

class _StubTrainer:
    def __init__(self, clock, t_tr):
        self.clock, self.t_tr = clock, t_tr

    def train_minibatch_time(self):
        return self.t_tr

    def step_minibatch(self):
        self.clock.advance(self.t_tr)


class _StubServer:
    def __init__(self, clock, t_in):
        self.clock, self.t_in = clock, t_in

    def infer(self):
        self.clock.advance(self.t_in)
        return None


def test_fake_clock_semantics():
    c = FakeClock()
    c.sleep_until(2.5)
    assert c.now() == 2.5
    c.sleep_until(1.0)                 # never backwards
    assert c.now() == 2.5
    c.advance(0.25)
    assert c.now() == 2.75
    w = WallClock()
    assert w.now() >= 0.0


@pytest.mark.parametrize("seed", range(3))
def test_runtime_matches_engine_bitwise_under_fake_clock(seed):
    """The ported runtime under a FakeClock with fixed step times replays
    the engine's scalar reference exactly — zero drift, recorded."""
    rng = np.random.default_rng(seed)
    pm = MODES[rng.integers(len(MODES))]
    bs = [1, 4, 16][rng.integers(3)]
    w_tr = TRAIN_WORKLOADS["mobilenet"] if seed != 1 else None
    w_in = list(INFER_WORKLOADS.values())[rng.integers(5)]
    t_in, _ = DEV.time_power(w_in, pm, bs)
    t_tr = DEV.time_power(w_tr, pm)[0] if w_tr else None
    trace = S.ArrivalTrace.poisson(float(rng.uniform(10, 80)), 20.0,
                                   seed=seed)
    clock = FakeClock()
    rt = ManagedInterleaveRuntime(
        _StubTrainer(clock, t_tr) if w_tr else None,
        _StubServer(clock, t_in),
        InterleaveConfig(arrival_rate=60.0, infer_bs=bs, latency_budget=0.5),
        trace=trace, clock=clock)
    rep = rt.run()
    ref = S.managed_scalar(DEV, w_tr, w_in, pm, bs, trace)
    assert rep.latencies == ref.latencies
    assert rep.train_minibatches == ref.train_minibatches
    assert attach_drift(rep, ref) == 0.0 and rep.drift_s == 0.0


def test_runtime_multi_tenant_merged_trace_parity():
    pm = SPACE.maxn()
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    ws = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]]
    bss = [4, 16]
    tins = [DEV.time_power(w, pm, b)[0] for w, b in zip(ws, bss)]
    t_tr = DEV.time_power(w_tr, pm)[0]
    traces = [S.ArrivalTrace.poisson(30.0, 15.0, seed=1),
              S.ArrivalTrace.uniform(50.0, 15.0)]
    clock = FakeClock()
    rt = ManagedInterleaveRuntime(
        _StubTrainer(clock, t_tr), None,
        InterleaveConfig(arrival_rate=0.0, infer_bs=4, latency_budget=0.5),
        trace=S.ArrivalTrace.merge(traces), clock=clock,
        servers=[_StubServer(clock, t) for t in tins], bss=bss)
    rep = rt.run()
    ref = S.multi_tenant_scalar(DEV, w_tr, ws, pm, bss, traces)
    assert len(rep.streams) == 2
    for a, b in zip(rep.streams, ref.streams):
        assert a.latencies == b.latencies
    assert rep.train_minibatches == ref.train_minibatches


def test_runtime_vs_vectorized_engine_drift_within_tolerance():
    """Drift against the *vectorized* engine (what the controller runs) is
    zero too — the vectorized kernel is bitwise to the scalar loop."""
    pm = SPACE.maxn()
    w_in = INFER_WORKLOADS["resnet50"]
    t_in, _ = DEV.time_power(w_in, pm, 8)
    trace = S.ArrivalTrace.uniform(40.0, 10.0)
    clock = FakeClock()
    rt = ManagedInterleaveRuntime(
        None, _StubServer(clock, t_in),
        InterleaveConfig(arrival_rate=40.0, infer_bs=8, latency_budget=0.5),
        trace=trace, clock=clock)
    rep = rt.run()
    eng = S.simulate(DEV, None, w_in, pm, 8, trace, "managed")
    # zero against the NumPy reference; within the documented scan
    # tolerance when FULCRUM_ENGINE_BACKEND=jax picks the jax engine
    assert attach_drift(rep, eng) <= 1e-8
    assert rep.drift_s == attach_drift(rep, eng)


def test_attach_drift_requires_shared_trace():
    a = S.ExecutionReport("managed-real", [0.1, 0.2], 0, 1.0, 0.0)
    b = S.ExecutionReport("managed", [0.1], 0, 1.0, 0.0)
    with pytest.raises(ValueError, match="shared"):
        attach_drift(a, b)
