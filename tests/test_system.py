"""End-to-end system tests: the Fulcrum scheduler over the device model,
strategy comparisons, dynamic rates, and approach comparison (Fig 2)."""
import dataclasses
import statistics

import pytest

from repro.core import problem as P
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS, Profiler,
                                     TRAIN_WORKLOADS, workload_from_model_config)
from repro.core.interleave import simulate_managed, simulate_native, simulate_streams
from repro.core.oracle import Oracle
from repro.core.scheduler import Fulcrum
from repro.configs import get_config

DEV = DeviceModel()


def test_fulcrum_concurrent_end_to_end():
    """Solve + execute a concurrent workload; executed latencies must respect
    the budget and training must progress (the paper's headline behavior)."""
    f = Fulcrum(DEV)
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    prob = P.ConcurrentProblem(power_budget=35.0, latency_budget=1.0,
                               arrival_rate=60.0)
    plan = f.solve_concurrent(w_tr, w_in, prob, strategy="gmd")
    assert plan is not None
    assert plan.profiling_runs <= 20
    rep = f.execute(plan, w_in, w_tr, arrival_rate=60.0, duration=60.0)
    assert rep.violation_rate(prob.latency_budget) == 0.0
    assert rep.train_throughput > 0
    assert rep.power <= prob.power_budget + 1e-9


def test_managed_beats_native_on_latency_stability():
    """Fig. 2: managed interleaving has tight latency; native violates."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    f = Fulcrum(DEV)
    prob = P.ConcurrentProblem(30.0, 0.8, 60.0)
    plan = f.solve_concurrent(w_tr, w_in, prob, strategy="gmd")
    pm, bs = plan.solution.pm, plan.solution.bs
    man = simulate_managed(DEV, w_tr, w_in, pm, bs, 60.0, duration=60.0)
    nat = simulate_native(DEV, w_tr, w_in, pm, bs, 60.0, duration=60.0)
    stc = simulate_streams(DEV, w_tr, w_in, pm, bs, 60.0, duration=60.0)
    assert man.violation_rate(0.8) == 0.0
    assert nat.latency_quantile(0.75) > man.latency_quantile(0.75)
    # streams keeps decent median but fatter tail than managed
    assert stc.latency_quantile(0.95) > man.latency_quantile(0.95)


def test_oracle_dominates_all_strategies_train():
    f = Fulcrum(DEV, nn_epochs=100)
    w = TRAIN_WORKLOADS["lstm"]
    oracle = f.oracle
    for strat in ("gmd", "rnd50"):
        for budget in (18.0, 30.0, 42.0):
            prob = P.TrainProblem(budget)
            opt = oracle.solve_train(w, prob)
            plan = f.solve_train(w, prob, strategy=strat)
            if plan is None:
                continue
            sol = plan.solution
            assert sol.power <= budget + 1e-9, strat
            if opt is not None:
                assert opt.time <= sol.time + 1e-9, strat


def test_dynamic_rates_reuse_profiles():
    """§5.4: GMD re-profiles only when existing observations stop satisfying
    the new arrival rate."""
    f = Fulcrum(DEV)
    w = INFER_WORKLOADS["mobilenet"]
    rates = [30.0, 35.0, 40.0, 60.0, 80.0, 110.0, 40.0]
    sols = f.solve_dynamic(w, power_budget=40.0, latency_budget=0.5,
                           rates=rates, strategy="gmd")
    assert sum(1 for s in sols if s is not None) >= len(rates) - 1
    for s, rate in zip(sols, rates):
        if s is not None:
            assert s.time <= 0.5 + 1e-9


def test_concurrent_inference_pair_as_concurrent_problem():
    """§5.4 concurrent inferences: urgent (latency QoS) + non-urgent
    (throughput QoS) via the same concurrent machinery."""
    f = Fulcrum(DEV)
    urgent = INFER_WORKLOADS["mobilenet"]
    # non-urgent inference at fixed bs=32 plays the training role
    nonurgent = dataclasses.replace(INFER_WORKLOADS["resnet50"],
                                    name="resnet50-nonurgent", train_bs=32)
    prob = P.ConcurrentProblem(power_budget=38.0, latency_budget=1.0,
                               arrival_rate=60.0)
    plan = f.solve_concurrent(nonurgent, urgent, prob, strategy="gmd")
    assert plan is not None
    assert plan.solution.power <= 38.0 + 1e-9


def test_assigned_arch_workload_mapping():
    """The assigned architectures map onto schedulable workload profiles."""
    cfg = get_config("mamba2-780m")
    w = workload_from_model_config(cfg, "infer")
    t, p = DEV.time_power(w, Fulcrum(DEV).space.maxn(), 16)
    assert 0 < t < 60 and 5 < p < 65
    prob = P.InferProblem(30.0, 5.0, 2.0)
    plan = Fulcrum(DEV).solve_infer(w, prob, strategy="gmd")
    # solvable or honestly unsolvable; never a violation
    if plan is not None:
        assert plan.solution.power <= 30.0 + 1e-9


def test_profiling_cost_accounting():
    """Table 1: GMD time-to-solution is minutes-scale."""
    w = TRAIN_WORKLOADS["resnet18"]
    prof = Profiler(DEV, w)
    from repro.core.gmd import GMDTrain
    GMDTrain(prof).solve(P.TrainProblem(30.0))
    assert prof.profile_cost_s < 600           # < 10 min (paper Table 1)
    assert prof.num_runs <= 10


def test_fitted_concurrent_strategies_actually_solve():
    """Regression: RND/ALS concurrent solvers must key train observations by
    power mode (Profiler caches key by (pm, bs=None))."""
    from repro.core.baselines import RNDConcurrent
    from repro.core.gmd import ConcurrentProfiler
    cp = ConcurrentProfiler(Profiler(DEV, TRAIN_WORKLOADS["mobilenet"]),
                            Profiler(DEV, INFER_WORKLOADS["mobilenet"]))
    strat = RNDConcurrent(cp, 150)
    solved = 0
    for budget in (30.0, 40.0, 50.0):
        sol = strat.solve(P.ConcurrentProblem(budget, 1.5, 60.0))
        if sol is not None:
            solved += 1
            assert sol.power <= budget + 1e-9
    assert solved >= 2
