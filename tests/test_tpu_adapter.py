"""GMD-for-TPU (beyond-paper adaptation) tests."""
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.tpu_adapter import (GMDForTPU, RooflineTPUModel, TPUKnobSpace,
                                    exhaustive_best)
from repro.launch.mesh import HBM_BYTES


def test_hbm_monotone_in_every_knob():
    """The resource-monotonicity GMD pruning requires."""
    m = RooflineTPUModel(get_config("qwen2.5-14b"), 4096, 256, "train")
    sp = TPUKnobSpace()
    for dim, vals in sp.values.items():
        base = sp.midpoint()
        prev = None
        for v in vals:
            _, hbm = m.time_power(base.replace(**{dim: v}))
            if prev is not None:
                assert hbm >= prev - 1e-6, (dim, v)
            prev = hbm


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "mamba2-780m", "stablelm-12b",
                                  "zamba2-1.2b", "minitron-4b"])
def test_gmd_tpu_close_to_oracle(arch):
    m = RooflineTPUModel(get_config(arch), 4096, 256, "train")
    g = GMDForTPU(m)
    sol = g.solve()
    opt = exhaustive_best(m)
    assert sol is not None and opt is not None
    assert sol.power <= HBM_BYTES           # never violates the HBM budget
    assert sol.time <= opt[1] * 1.25        # within 25% of the knob oracle
    assert g.num_profiles <= 18             # few "profiles", as on the Jetson


def test_arctic_needs_multipod():
    """arctic-480b + fp32 Adam cannot fit one 256-chip pod; fits 512."""
    cfg = get_config("arctic-480b")
    assert exhaustive_best(RooflineTPUModel(cfg, 4096, 256, "train", 256),
                           TPUKnobSpace(256)) is None
    assert exhaustive_best(RooflineTPUModel(cfg, 4096, 256, "train", 512),
                           TPUKnobSpace(512)) is not None


def test_all_archs_have_a_serving_config():
    for arch in ARCH_IDS:
        m = RooflineTPUModel(get_config(arch), 32768, 32, "prefill")
        assert exhaustive_best(m) is not None, arch
