"""Multi-tenant core: the N=1 problem/solver/engine paths must be *bitwise*
identical to the pair-shaped originals (the refactor's exactness contract),
the N>1 vectorized paths must equal their scalar references, and
ArrivalTrace.merge must round-trip stream provenance."""
import numpy as np
import pytest

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core import simulate as S
from repro.core.device_model import (DeviceModel, INFER_WORKLOADS,
                                     TRAIN_WORKLOADS)
from repro.core.powermode import PowerModeSpace

try:                                   # hypothesis is optional: the merge
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                    # property tests degrade to skips, the
    HAVE_HYPOTHESIS = False            # numpy-randomized ones always run

DEV = DeviceModel()
SPACE = PowerModeSpace()
MODES = SPACE.all_modes()

TRAIN_WS = list(TRAIN_WORKLOADS.values())
INFER_WS = list(INFER_WORKLOADS.values())


def _random_obs(rng, n_modes=40):
    sub = [MODES[i] for i in rng.choice(len(MODES), n_modes, replace=False)]
    w_tr = TRAIN_WS[rng.integers(len(TRAIN_WS))]
    w_in = INFER_WS[rng.integers(len(INFER_WS))]
    tobs = {pm: DEV.time_power(w_tr, pm) for pm in sub}
    iobs = {(pm, bs): DEV.time_power(w_in, pm, bs)
            for pm in sub for bs in P.INFER_BATCH_SIZES}
    return tobs, iobs


def _assert_pair_equal(sol, msol):
    assert (sol is None) == (msol is None)
    if sol is None:
        return
    assert sol.pm == msol.pm
    assert sol.bs == msol.bss[0]
    assert sol.tau_tr == msol.tau_tr
    assert sol.time == msol.times[0]        # bitwise float equality
    assert sol.power == msol.power
    assert sol.throughput == msol.throughput


# ---------------------------------------------------------------------------
# (a) one stream == the existing pair solver / kernel, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_single_stream_solver_bitwise_identical_to_concurrent(seed):
    rng = np.random.default_rng(seed)
    tobs, iobs = _random_obs(rng)
    for _ in range(20):
        prob = P.ConcurrentProblem(float(rng.uniform(10, 55)),
                                   float(rng.uniform(0.05, 2.0)),
                                   float(rng.uniform(5, 120)))
        ref = P.solve_concurrent(prob, tobs, iobs)
        got = P.solve_multi_tenant(prob.as_multi_tenant(), tobs, [iobs])
        _assert_pair_equal(ref, got)


@pytest.mark.parametrize("seed", range(4))
def test_single_stream_solver_bitwise_identical_to_infer(seed):
    rng = np.random.default_rng(seed)
    _, iobs = _random_obs(rng)
    for _ in range(20):
        prob = P.InferProblem(float(rng.uniform(10, 55)),
                              float(rng.uniform(0.05, 2.0)),
                              float(rng.uniform(5, 120)))
        ref = P.solve_infer(prob, iobs)
        got = P.solve_multi_tenant(prob.as_multi_tenant(), None, [iobs])
        assert (ref is None) == (got is None)
        if ref is not None:
            assert (ref.pm, ref.bs) == (got.pm, got.bss[0])
            assert ref.time == got.times[0] and ref.power == got.power


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_single_stream_batch_bitwise_identical_to_concurrent_batch(backend):
    rng = np.random.default_rng(7)
    tobs, iobs = _random_obs(rng, n_modes=60)
    tg = G.ObservationGrid.from_train_dict(tobs)
    ig = G.ObservationGrid.from_infer_dict(iobs)
    probs = [P.ConcurrentProblem(float(pb), float(lb), float(ar))
             for pb in (12, 25, 40, 55) for lb in (0.1, 0.6, 1.5)
             for ar in (20, 60, 110)]
    ref = G.solve_concurrent_batch(probs, tg, ig)
    got = G.solve_multi_tenant_batch([p.as_multi_tenant() for p in probs],
                                     tg, [ig], backend=backend)
    for r, g in zip(ref, got):
        _assert_pair_equal(r, g)


@pytest.mark.parametrize("seed", range(5))
def test_single_stream_kernel_bitwise_identical_to_managed_scalar(seed):
    rng = np.random.default_rng(seed)
    for _ in range(15):
        w_tr = TRAIN_WS[rng.integers(len(TRAIN_WS))] \
            if rng.random() < 0.8 else None
        w_in = INFER_WS[rng.integers(len(INFER_WS))]
        pm = MODES[rng.integers(len(MODES))]
        bs = [1, 4, 16, 32, 64][rng.integers(5)]
        rate = float(rng.uniform(1.0, 120.0))
        dur = float(rng.uniform(5.0, 40.0))
        trace = (S.ArrivalTrace.uniform(rate, dur) if rng.random() < 0.5
                 else S.ArrivalTrace.poisson(rate, dur, seed=seed))
        cap = None if rng.random() < 0.7 else int(rng.integers(0, 4))
        ref = S.managed_scalar(DEV, w_tr, w_in, pm, bs, trace, tau_cap=cap)
        got = S.simulate_multi_tenant(DEV, w_tr, [w_in], pm, [bs], [trace],
                                      tau_cap=cap)
        assert got.streams[0].latencies.tolist() == ref.latencies
        assert got.train_minibatches == ref.train_minibatches
        assert got.power == ref.power
        assert got.duration == ref.duration


# ---------------------------------------------------------------------------
# N > 1: vectorized == scalar reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_multi_stream_kernel_identical_to_scalar(seed):
    rng = np.random.default_rng(seed + 50)
    for _ in range(8):
        n = int(rng.integers(2, 5))
        w_tr = TRAIN_WS[rng.integers(len(TRAIN_WS))] \
            if rng.random() < 0.8 else None
        ws = [INFER_WS[rng.integers(len(INFER_WS))] for _ in range(n)]
        pm = MODES[rng.integers(len(MODES))]
        bss = [int([1, 4, 16, 32][rng.integers(4)]) for _ in range(n)]
        traces = [S.ArrivalTrace.uniform(float(rng.uniform(1, 60)),
                                         float(rng.uniform(5, 25)))
                  if rng.random() < 0.5 else
                  S.ArrivalTrace.poisson(float(rng.uniform(1, 60)),
                                         float(rng.uniform(5, 25)),
                                         seed=seed * 31 + j)
                  for j in range(n)]
        cap = None if rng.random() < 0.7 else int(rng.integers(0, 4))
        a = S.simulate_multi_tenant(DEV, w_tr, ws, pm, bss, traces,
                                    tau_cap=cap)
        b = S.multi_tenant_scalar(DEV, w_tr, ws, pm, bss, traces, tau_cap=cap)
        for ra, rb in zip(a.streams, b.streams):
            assert ra.latencies.tolist() == rb.latencies
        assert a.train_minibatches == b.train_minibatches
        assert a.power == b.power


@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_multi_stream_batch_equals_scalar_solver(backend):
    rng = np.random.default_rng(11)
    tobs, iobs1 = _random_obs(rng, n_modes=30)
    _, iobs2 = _random_obs(rng, n_modes=30)
    # align mode sets: stream obs must share modes with the train grid
    iobs2 = {(pm, bs): DEV.time_power(INFER_WS[2], pm, bs)
             for pm in tobs for bs in P.INFER_BATCH_SIZES}
    probs = [P.MultiTenantProblem(
        float(pb), (P.StreamSpec(40.0, float(l)), P.StreamSpec(60.0, 0.7 * l)))
        for pb in (18, 30, 45) for l in (0.3, 0.8, 1.6)]
    got = G.solve_multi_tenant_batch(probs, tobs, [iobs1, iobs2],
                                     backend=backend)
    for prob, g in zip(probs, got):
        ref = P.solve_multi_tenant(prob, tobs, [iobs1, iobs2])
        assert (ref is None) == (g is None)
        if ref is None:
            continue
        assert (ref.pm, ref.bss, ref.tau_tr) == (g.pm, g.bss, g.tau_tr)
        assert ref.times == g.times
        assert ref.power == g.power and ref.throughput == g.throughput


def test_stream_batch_size_restriction_honoured():
    rng = np.random.default_rng(3)
    tobs, iobs = _random_obs(rng)
    spec = P.StreamSpec(60.0, 1.0, batch_sizes=(4, 16))
    prob = P.MultiTenantProblem(50.0, (spec,))
    sol = P.solve_multi_tenant(prob, tobs, [iobs])
    assert sol is not None and sol.bss[0] in (4, 16)
    batch = G.solve_multi_tenant_batch([prob], tobs, [iobs])[0]
    assert batch.bss == sol.bss and batch.times == sol.times


# ---------------------------------------------------------------------------
# (b) ArrivalTrace.merge: provenance + order, Hypothesis-randomized
# ---------------------------------------------------------------------------

def _check_merge_round_trip(stream_times):
    traces = [S.ArrivalTrace(np.asarray(ts, np.float64), 10.0 + j)
              for j, ts in enumerate(stream_times)]
    merged = S.ArrivalTrace.merge(traces)
    assert len(merged) == sum(len(t) for t in traces)
    assert merged.duration == max(t.duration for t in traces)
    assert np.all(np.diff(merged.times) >= 0)          # sorted
    # provenance round-trip (this also pins the stable tie order: equal
    # times must come back to their source streams intact)
    back = merged.split(len(traces))
    for orig, rt in zip(traces, back):
        assert rt.times.tolist() == orig.times.tolist()
    for j, tr in enumerate(traces):
        sel = merged.times[merged.stream_ids == j]
        assert sel.tolist() == tr.times.tolist()


if HAVE_HYPOTHESIS:
    sorted_times = st.lists(
        st.floats(min_value=0.0, max_value=1e4, allow_nan=False,
                  allow_infinity=False, width=64),
        min_size=0, max_size=60).map(sorted)

    @settings(max_examples=80, deadline=None)
    @given(st.lists(sorted_times, min_size=1, max_size=5))
    def test_merge_round_trips_provenance_and_sorted_order(stream_times):
        _check_merge_round_trip(stream_times)


@pytest.mark.parametrize("seed", range(10))
def test_merge_round_trip_randomized(seed):
    """numpy-randomized fallback of the Hypothesis property (always runs):
    duplicated timestamps across and within streams included."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 6))
    streams = []
    for _ in range(n):
        k = int(rng.integers(0, 50))
        ts = np.sort(np.round(rng.uniform(0, 20, k), 2))  # rounding => ties
        streams.append(ts.tolist())
    _check_merge_round_trip(streams)


def test_split_requires_provenance():
    with pytest.raises(ValueError, match="provenance"):
        S.ArrivalTrace.uniform(10.0, 1.0).split()


def test_merge_split_keeps_idle_tenants():
    """A rate-0 tenant (empty trace) must survive the round-trip — the
    stream count is recorded on the merged trace, not inferred."""
    traces = [S.ArrivalTrace.uniform(10.0, 2.0),
              S.ArrivalTrace.poisson(0.0, 2.0, seed=1),   # idle tenant
              S.ArrivalTrace.uniform(5.0, 2.0)]
    merged = S.ArrivalTrace.merge(traces)
    assert merged.n_streams == 3
    back = merged.split()
    assert len(back) == 3 and len(back[1]) == 0
    assert back[0].times.tolist() == traces[0].times.tolist()
    assert back[2].times.tolist() == traces[2].times.tolist()


def test_batch_rejects_mixed_stream_workloads():
    """A problem batch shares one observation set per stream, so mixing
    stream workloads across the batch must be an error, not a silent solve
    against the wrong grid."""
    rng = np.random.default_rng(5)
    tobs, iobs = _random_obs(rng)
    p1 = P.MultiTenantProblem(
        40.0, (P.StreamSpec(40.0, 1.0, INFER_WS[0]),))
    p2 = P.MultiTenantProblem(
        40.0, (P.StreamSpec(40.0, 1.0, INFER_WS[1]),))
    with pytest.raises(ValueError, match="uniform"):
        G.solve_multi_tenant_batch([p1, p2], tobs, [iobs])
