"""Per-arch smoke tests (reduced configs) + serving-cache consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, make_batch, reduced
from repro.models import (decode_step, forward, init_cache, init_params,
                          train_loss)
from repro.models.model import cache_len_for, prefill
from repro.launch.steps import make_train_step
from repro.optim.adamw import init_opt_state


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant of each assigned architecture: one forward + one full
    train step on CPU; asserts output shapes and finiteness."""
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    batch = make_batch(cfg, 64, 2, "train")

    logits, aux = forward(params, batch, cfg)
    s_expected = 64 if cfg.arch_type != "vlm" else 64
    if cfg.arch_type == "audio":
        assert logits.shape == (2, 64, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (2, s_expected, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    step = jax.jit(make_train_step(cfg))
    opt = init_opt_state(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["step"]) == 1
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda x, y: float(jnp.sum(jnp.abs(x - y))), params, new_params))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = init_params(jax.random.key(0), cfg)
    cache = init_cache(cfg, 2, 64)
    db = make_batch(cfg, 1, 2, "decode")
    logits, new_cache = decode_step(params, cache, db, jnp.zeros((2,), jnp.int32), cfg)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", [
    "stablelm-1.6b", "qwen2.5-14b",
    pytest.param("mixtral-8x22b", marks=pytest.mark.xfail(
        strict=True, reason=(
            "capacity-factor MoE dispatch cannot give exact prefill/decode "
            "parity: a token forward() drops (expert queue full over the "
            "whole sequence) is kept by decode_step's fresh one-token queue. "
            "Per-row dispatch groups (layers.moe_apply) removed the cross-"
            "row leakage; exact parity would need expert-occupancy carried "
            "in the decode cache. Seed-era debt, tracked in ROADMAP.md."))),
    "mamba2-780m", "zamba2-1.2b", "internvl2-1b", "musicgen-medium"])
def test_prefill_decode_matches_forward(arch):
    """prefill(T-1) + decode(1) must reproduce forward(T)'s last logits."""
    cfg = dataclasses.replace(reduced(get_config(arch)), compute_dtype=jnp.float32)
    params = init_params(jax.random.key(0), cfg)
    T = 64
    batch = make_batch(cfg, T, 2, "prefill")
    logits_full, _ = forward(params, batch, cfg)
    if cfg.arch_type == "vlm":
        pre = {"tokens": batch["tokens"][:, :-1], "vision": batch["vision"]}
        db = {"tokens": batch["tokens"][:, -1:]}
    else:
        pre = {"tokens": batch["tokens"][:, :T - 1]}
        db = {"tokens": batch["tokens"][:, T - 1:T]}
    _, cache = prefill(params, pre, cfg, T, cache_dtype=jnp.float32)
    pos = jnp.full((2,), logits_full.shape[1] - 1, jnp.int32)
    logits_dec, _ = decode_step(params, cache, db, pos, cfg)
    a = np.asarray(logits_full[:, -1], np.float32)
    b = np.asarray(logits_dec[:, 0], np.float32)
    np.testing.assert_allclose(a, b, atol=2e-3 * max(1.0, np.abs(a).max()))


def test_ring_buffer_equals_full_cache_within_window():
    """With window >= seq, the ring buffer must be exact; decode with a
    window w must equal full attention restricted to the last w tokens."""
    cfg = dataclasses.replace(reduced(get_config("stablelm-1.6b")),
                              compute_dtype=jnp.float32,
                              long_context_mode="swa", serve_window=32,
                              swa_activation_len=16)
    params = init_params(jax.random.key(0), cfg)
    T = 64
    assert cache_len_for(cfg, T) == 32
    batch = make_batch(cfg, T, 1, "prefill")
    _, cache = prefill(params, {"tokens": batch["tokens"][:, :T - 1]}, cfg, T,
                       cache_dtype=jnp.float32)
    # every live slot holds one of the last 32 positions
    kv_pos = np.asarray(cache["kv_pos"][0, 0])
    live = kv_pos[kv_pos >= 0]
    assert live.min() >= T - 1 - 32 and live.max() == T - 2
    db = {"tokens": batch["tokens"][:, T - 1:T]}
    logits, _ = decode_step(params, cache, db,
                            jnp.full((1,), T - 1, jnp.int32), cfg)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_ssd_chunked_matches_stepwise_recurrence():
    """The chunked SSD scan must equal the naive per-token recurrence."""
    from repro.models.layers import ssd_chunked
    key = jax.random.key(3)
    b, s, h, p, n, chunk = 1, 32, 2, 8, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y_chunk, final = ssd_chunked(x, dt, A, B, C, chunk)

    # naive recurrence
    hstate = np.zeros((b, h, p, n))
    ys = []
    xn, dtn, Bn, Cn = map(np.asarray, (x, dt, B[:, :, 0], C[:, :, 0]))
    An = np.asarray(A)
    for t in range(s):
        decay = np.exp(dtn[:, t] * An[None, :])                     # (b,h)
        upd = dtn[:, t, :, None, None] * xn[:, t, :, :, None] * Bn[:, t, None, None, :]
        hstate = hstate * decay[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", hstate, Cn[:, t]))
    y_naive = np.stack(ys, axis=1)                                   # (b,s,h,p)
    np.testing.assert_allclose(np.asarray(y_chunk), y_naive, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), hstate, rtol=1e-4, atol=1e-4)


def test_moe_aux_loss_and_capacity():
    """MoE: balanced routing gives aux ~1; capacity drops are bounded."""
    from repro.models.layers import MoeSpec, moe_apply, moe_init
    spec = MoeSpec(d_model=32, d_ff=64, n_experts=4, top_k=2, group_size=64)
    p = moe_init(jax.random.key(0), spec)
    x = jax.random.normal(jax.random.key(1), (2, 64, 32), jnp.float32)
    y, aux = moe_apply(p, x, spec)
    assert y.shape == x.shape
    assert 0.9 < float(aux) < 4.0    # ~1 when balanced; n_experts if collapsed
