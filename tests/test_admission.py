"""Burst survival: SLO-aware admission control, deferral, and mid-window
re-planning (paper §5.4 under overload).

The load-bearing property is the admission gate's exactness: the deadline-
drop mask runs the managed engine's own batching recurrence (identical
float64 ops) over the admitted subsequence, so whatever it admits replays
through the engine with *zero* nominal-budget violations by construction —
no tolerance, no predictor slack. The second contract is PR-5's carryover
exactness extended to splitting: clipping a window at an arrival timestamp
and chaining ``QueueState`` reproduces the unsplit run bitwise on NumPy,
which is what makes mid-window re-planning a pure control decision.
``AdmissionPolicy("none")`` must leave the closed loop byte-identical to
the PR-5 controller (fingerprint regression below).

Bitwise assertions pin ``backend="numpy"`` so they still check the
reference contract when ``FULCRUM_ENGINE_BACKEND=jax`` (CI does).
"""
import math

import numpy as np
import pytest

from repro.core import problem as P
from repro.core import simulate as S
from repro.core.controller import (AdmissionPolicy, ControllerConfig,
                                   ControllerState, _admit_mask,
                                   _admit_mask_multi)
from repro.core.device_model import DeviceModel, INFER_WORKLOADS
from repro.core.powermode import PowerModeSpace
from repro.core.scheduler import Fulcrum
from repro.runtime.clock import FakeClock
from repro.runtime.interleave_runtime import (InterleaveConfig,
                                              ManagedInterleaveRuntime)

DEV = DeviceModel()
SPACE = PowerModeSpace()
MODES = SPACE.all_modes()


# ---------------------------------------------------------------------------
# burst quantiles (problem.poisson_quantile / burst_rate)
# ---------------------------------------------------------------------------

def _brute_poisson_quantile(mean, q):
    p = math.exp(-mean)
    cdf, k = p, 0
    while cdf < q:
        k += 1
        p *= mean / k
        cdf += p
    return k


@pytest.mark.parametrize("mean", [0.5, 3.0, 20.0, 200.0])
@pytest.mark.parametrize("q", [0.0, 0.5, 0.9, 0.95, 0.999])
def test_poisson_quantile_matches_brute_cdf(mean, q):
    assert P.poisson_quantile(mean, q) == _brute_poisson_quantile(mean, q)


def test_poisson_quantile_tail_regime_sane():
    """Above the exact-pmf regime (mean > 700) the Cornish-Fisher branch
    must stay monotone in q and bracket the mean."""
    qs = [0.5, 0.9, 0.95, 0.99, 0.999]
    ks = [P.poisson_quantile(2000.0, q) for q in qs]
    assert ks == sorted(ks)
    assert ks[0] >= 1990 and ks[-1] <= 2200   # ~mean + 4.4*sqrt(mean)


def test_poisson_quantile_validation_and_edges():
    with pytest.raises(ValueError, match="quantile"):
        P.poisson_quantile(10.0, 1.0)
    with pytest.raises(ValueError, match="quantile"):
        P.poisson_quantile(10.0, -0.1)
    assert P.poisson_quantile(0.0, 0.95) == 0
    assert P.poisson_quantile(5.0, 0.0) == 0


def test_burst_rate_never_below_mean_and_off_switch():
    assert P.burst_rate(40.0, 10.0, 0.95) >= 40.0
    assert P.burst_rate(40.0, 10.0, 0.0) == 40.0      # quantile planning off
    assert P.burst_rate(0.0, 10.0, 0.95) == 0.0
    # longer windows concentrate: the quantile rate approaches the mean
    assert P.burst_rate(40.0, 300.0, 0.95) < P.burst_rate(40.0, 5.0, 0.95)


# ---------------------------------------------------------------------------
# drainability / minimal shed set
# ---------------------------------------------------------------------------

def test_drain_capacity_full_minibatches_only():
    # 30 s / 0.05 s = 600 batches of 4 — a trailing partial batch never runs
    assert P.drain_capacity(4, 0.05, 30.0) == 2400
    assert P.drain_capacity(4, 0.05, 0.0) == 0
    assert P.drain_capacity(4, 0.0, 30.0) >= int(1e18)


def test_min_shed_and_drainable():
    assert P.min_shed(2400, 4, 0.05, 30.0) == 0
    assert P.min_shed(2500, 4, 0.05, 30.0) == 100
    assert P.drainable(0, 80.0, 4, 0.05, 30.0)         # 2400 demand, exact
    assert not P.drainable(1, 80.0, 4, 0.05, 30.0)     # one carried too many
    assert not P.drainable(0, 81.0, 4, 0.05, 30.0)


# ---------------------------------------------------------------------------
# interval solve, N-stream path (satellite: solve_multi_tenant_interval)
# ---------------------------------------------------------------------------

OBS = {("pmA", 1): (0.010, 20.0), ("pmA", 4): (0.030, 22.0),
       ("pmB", 4): (0.020, 30.0), ("pmB", 8): (0.036, 33.0)}


def test_interval_solve_n1_replays_single_stream_bitwise():
    """With one stream, solve_multi_tenant_interval must replay
    solve_infer_interval op-for-op: same plan, bitwise-equal latency."""
    for rate, hi, bud in [(30.0, 90.0, 0.1), (100.0, 180.0, 0.08),
                          (50.0, 50.0, 0.2), (200.0, 400.0, 0.5)]:
        single = P.solve_infer_interval(P.InferProblem(32.0, bud, rate),
                                        hi, OBS)
        multi = P.solve_multi_tenant_interval(
            P.MultiTenantProblem(32.0, (P.StreamSpec(rate, bud),),
                                 train=False), [hi], None, [OBS])
        if single is None:
            assert multi is None
            continue
        assert (multi.pm, multi.bss[0]) == (single.pm, single.bs)
        assert multi.times[0] == single.time          # bitwise
        assert multi.power == single.power


def test_interval_solve_sustains_high_rate_judges_latency_low():
    # at hi=210 only pmB/bs=8 sustains (8/0.036 = 222 rps; pmB/4 = 200);
    # latency is judged at the low rate: (8-1)/30 + 0.036 = 0.269
    s = P.solve_infer_interval(P.InferProblem(40.0, 0.5, 30.0), 210.0, OBS)
    assert (s.pm, s.bs) == ("pmB", 8)
    assert s.time == pytest.approx(7 / 30.0 + 0.036)
    # same interval, tight budget: the fill wait at the low rate kills it
    assert P.solve_infer_interval(P.InferProblem(40.0, 0.1, 30.0),
                                  210.0, OBS) is None
    # at hi=180 pmB/4 still sustains and wins on low-rate latency
    s4 = P.solve_infer_interval(P.InferProblem(40.0, 0.5, 30.0), 180.0, OBS)
    assert (s4.pm, s4.bs) == ("pmB", 4)


def test_interval_solve_rejects_rate_his_length_mismatch():
    prob = P.MultiTenantProblem(40.0, (P.StreamSpec(30.0, 0.2),
                                       P.StreamSpec(40.0, 0.2)), train=False)
    with pytest.raises(ValueError, match="high rates"):
        P.solve_multi_tenant_interval(prob, [90.0], None, [OBS, OBS])


def test_solve_infer_capacity_max_service_rate():
    # max bs/t under power alone: pmA/1=100, pmA/4=133, pmB/4=200, pmB/8=222
    assert (P.solve_infer_capacity(40.0, OBS).pm,
            P.solve_infer_capacity(40.0, OBS).bs) == ("pmB", 8)
    # power 25 leaves only pmA candidates
    assert (P.solve_infer_capacity(25.0, OBS).pm,
            P.solve_infer_capacity(25.0, OBS).bs) == ("pmA", 4)
    assert P.solve_infer_capacity(5.0, OBS) is None


# ---------------------------------------------------------------------------
# the admission mask: exactness is the whole point
# ---------------------------------------------------------------------------

def test_admit_mask_uncongested_admits_everything():
    trace = S.ArrivalTrace.uniform(20.0, 10.0)
    pol = AdmissionPolicy("shed")
    mask = pol.admit(trace.times, 0.5, 4, 0.01, 0.0)
    assert mask.all()


@pytest.mark.parametrize("seed", range(4))
def test_admitted_subsequence_replays_with_zero_violations(seed):
    """The key property: the gate runs the engine's own recurrence, so the
    admitted requests — simulated for real under the same plan — meet the
    nominal budget exactly, while the flood guarantees sheds happened."""
    rng = np.random.default_rng(seed)
    w = list(INFER_WORKLOADS.values())[rng.integers(5)]
    pm = MODES[rng.integers(len(MODES))]
    bs = [2, 4, 8][rng.integers(3)]
    t_in = DEV.time_power(w, pm, bs)[0]
    budget = float(rng.uniform(2.5, 6.0)) * t_in
    rate = 3.0 * bs / t_in                        # 3x sustainable: a flood
    trace = S.ArrivalTrace.poisson(rate, 5.0, seed=seed)
    pol = AdmissionPolicy("shed")
    mask = pol.admit(trace.times, budget, bs, t_in, 0.0)
    assert not mask.all() and mask.any()
    admitted = S.ArrivalTrace(trace.times[mask], trace.duration, trace.kind)
    rep = S.simulate(DEV, None, w, pm, bs, admitted, "managed",
                     backend="numpy")
    assert rep.violation_rate(budget) == 0.0
    # and dropping the gate would have violated: the flood is real
    raw = S.simulate(DEV, None, w, pm, bs, trace, "managed",
                     backend="numpy")
    assert raw.violation_rate(budget) > 0.0


def test_admit_mask_sheds_stale_carry_first():
    """Carried backlog already past its deadline (device clock far ahead)
    is shed; fresh arrivals still admit."""
    times = np.concatenate([np.zeros(4),                 # stale carry
                            5.0 + np.arange(8) * 0.01])  # fresh, fast
    budgets = np.full(times.size, 0.2)
    mask = _admit_mask(times, budgets, 4, 0.01, clock=5.0)
    assert not mask[:4].any()
    assert mask[4:].all()


def test_admit_mask_trailing_partial_batch_admitted():
    # 3 requests, bs=4: the batch never fills, nothing can be judged — the
    # engine carries it to the next window where admission re-judges it
    mask = _admit_mask(np.array([0.0, 0.1, 0.2]), np.full(3, 1e-6), 4,
                       10.0, 0.0)
    assert mask.all()


def test_admit_mask_empty():
    assert _admit_mask(np.empty(0), np.empty(0), 4, 0.01, 0.0).size == 0
    assert AdmissionPolicy("shed").admit(np.empty(0), 0.1, 4, 0.01,
                                         0.0).size == 0


def test_admit_multi_priorities_shed_low_priority_first():
    """Two identical flood streams sharing the device: the low-priority
    stream's scaled budget makes it shed strictly more."""
    n = 400
    t = np.repeat(np.arange(n) * 0.004, 2)        # 500 rps merged, paired
    sids = np.tile([0, 1], n)
    pol = AdmissionPolicy("shed", priorities=(1.0, 0.25))
    mask = pol.admit_multi(t, sids, [4, 4], [0.02, 0.02], [0.15, 0.15], 0.0)
    shed0 = int(np.count_nonzero(~mask[sids == 0]))
    shed1 = int(np.count_nonzero(~mask[sids == 1]))
    assert shed1 > shed0
    # equal priorities restore symmetry of budgets (not necessarily of
    # sheds — device-order ties break by arrival order)
    even = AdmissionPolicy("shed").stream_budget_scales(2)
    assert np.array_equal(even, np.ones(2))


def test_admit_multi_matches_single_stream_degenerate():
    trace = S.ArrivalTrace.poisson(300.0, 3.0, seed=7)
    pol = AdmissionPolicy("shed")
    single = pol.admit(trace.times, 0.12, 4, 0.02, 0.0)
    merged = pol.admit_multi(trace.times, np.zeros(len(trace), np.int64),
                             [4], [0.02], [0.12], 0.0)
    assert np.array_equal(single, merged)


def test_admission_policy_validation():
    with pytest.raises(ValueError, match="admission mode"):
        AdmissionPolicy("drop-tail")
    with pytest.raises(ValueError, match="headroom"):
        AdmissionPolicy("shed", headroom=0.0)
    with pytest.raises(ValueError, match="priorities"):
        AdmissionPolicy("shed", priorities=(1.0,)).stream_budget_scales(2)
    assert not AdmissionPolicy("none").active
    assert AdmissionPolicy("defer").trims
    assert not AdmissionPolicy("degrade-bs").trims


def test_controller_config_admission_validation():
    with pytest.raises(ValueError, match="admission"):
        ControllerConfig(admission="magic")
    with pytest.raises(ValueError, match="burst_quantile"):
        ControllerConfig(burst_quantile=1.0)
    with pytest.raises(ValueError, match="split_backlog"):
        ControllerConfig(split_backlog=0)
    # admission alone flips the loop closed
    assert ControllerConfig(admission="shed").closed_loop
    assert ControllerConfig(split_backlog=64).closed_loop
    assert ControllerConfig(burst_quantile=0.95).closed_loop
    assert not ControllerConfig(admission="none").closed_loop


# ---------------------------------------------------------------------------
# deferral state
# ---------------------------------------------------------------------------

def test_push_pop_deferred_retimestamps_at_window_start():
    state = ControllerState(ControllerConfig(admission="defer"), n_streams=2)
    assert state.push_deferred([3, 5]) == 0
    arrs = state.pop_deferred(12.5)
    assert [a.size for a in arrs] == [3, 5]
    assert all((a == 12.5).all() for a in arrs)        # clock restarts
    assert [a.size for a in state.pop_deferred(0.0)] == [0, 0]  # drained


def test_defer_cap_overflow_is_shed_largest_first():
    cfg = ControllerConfig(admission="defer", defer_cap=6)
    state = ControllerState(cfg, n_streams=2)
    dropped = state.push_deferred([5, 4])              # 9 > cap=6
    assert dropped == 3
    assert sum(a.size for a in state.pop_deferred(1.0)) == 6


# ---------------------------------------------------------------------------
# mid-window re-planning: backlog crossing + exact split replay
# ---------------------------------------------------------------------------

def test_first_backlog_crossing_counts_uncompleted():
    times = np.arange(8, dtype=np.float64)             # one per second
    comps = np.array([2.5, 4.5])                       # two bs=2 batches
    # backlog after each arrival: 1 2 3 2 3 2 3 4
    assert S.first_backlog_crossing(times, comps, 2, 3) == 7
    assert S.first_backlog_crossing(times, comps, 2, 2) == 2
    assert S.first_backlog_crossing(times, comps, 2, 99) is None
    assert S.first_backlog_crossing(np.empty(0), comps, 2, 0) is None


@pytest.mark.parametrize("seed", range(4))
def test_split_at_arrival_timestamp_replays_bitwise(seed):
    """The contract the split driver leans on: clip a window at any arrival
    timestamp, chain the QueueState, and the two halves reproduce the
    unsplit run bitwise on NumPy — so splitting is purely a chance to
    re-plan, never a numerical perturbation."""
    rng = np.random.default_rng(seed)
    w = list(INFER_WORKLOADS.values())[rng.integers(5)]
    pm = MODES[rng.integers(len(MODES))]
    bs = [1, 4, 8][rng.integers(3)]
    trace = S.ArrivalTrace.poisson(float(rng.uniform(30, 120)), 8.0,
                                   seed=seed)
    split_t = float(trace.times[rng.integers(1, len(trace) - 1)])
    whole = S.simulate(DEV, None, w, pm, bs, trace, "managed",
                       backend="numpy")
    head = S.simulate(DEV, None, w, pm, bs, trace.clip(0.0, split_t),
                      "managed", backend="numpy")
    tail = S.simulate(DEV, None, w, pm, bs, trace.clip(split_t, 9.0),
                      "managed", carry_in=head.queue_state, backend="numpy")
    lats = np.concatenate([np.asarray(head.latencies, np.float64),
                           np.asarray(tail.latencies, np.float64)])
    assert np.array_equal(lats, np.asarray(whole.latencies, np.float64))


def test_closed_loop_splits_on_backlog_crossing():
    """A rate jump the EWMA estimator lags behind floods the second window;
    with split_backlog set the loop re-enters the controller mid-window
    (splits recorded), without admission trimming anything."""
    f = Fulcrum(DEV)
    cfg = ControllerConfig(rate_estimator="ewma", rate_margin=1.0,
                           carry_backlog=True, admission="none",
                           split_backlog=24, max_splits=2)
    wins = f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1,
                           [20.0, 120.0, 120.0], "gmd",
                           window_duration=10.0, arrivals="poisson", seed=5,
                           controller=cfg, backend="numpy")
    assert sum(wr.splits for wr in wins) >= 2
    assert all(wr.splits <= 2 for wr in wins)
    assert all(wr.shed_requests == 0 for wr in wins)
    # every offered request is still accounted for across the splits
    for wr in wins:
        assert wr.offered_requests > 0
        assert wr.report is not None


# ---------------------------------------------------------------------------
# closed loop end to end
# ---------------------------------------------------------------------------

_PR5_CFG = dict(rate_estimator="ewma", rate_margin=1.5, feedback=True,
                carry_backlog=True, mode_switch_s=0.5)

# serve_dynamic(mobilenet, 40 W, 0.1 s, [60, 80, 45, 70], gmd, 10 s windows,
# poisson seed 3, ewma+feedback+carry+switch) on the NumPy reference —
# recorded from the PR-5 loop; the admission-aware loop must reproduce it.
_PR5_FINGERPRINT = [
    ("12c/2201/1300/3199", 4, 572, 27.243475908860727,
     10.014343123258966, 0.0, 0),
    ("12c/2201/1300/3199", 4, 772, 31.45140962804028,
     20.007964592759695, 0.0, 1),
    ("12c/2201/1300/3199", 4, 476, 25.13270795253002,
     30.01919892420535, 0.0, 0),
    ("12c/2201/1300/3199", 4, 732, 30.85720815969366,
     40.01063504205469, 0.0, 0),
]


def _pr5_run(**extra):
    f = Fulcrum(DEV)
    return f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1,
                           [60.0, 80.0, 45.0, 70.0], "gmd",
                           window_duration=10.0, arrivals="poisson", seed=3,
                           backend="numpy",
                           controller=ControllerConfig(**_PR5_CFG, **extra))


def _fingerprint(wins):
    out = []
    for wr in wins:
        lats = np.asarray(wr.report.latencies, np.float64)
        out.append((str(wr.solution.pm), wr.solution.bs, lats.size,
                    float(lats.sum()), float(wr.report.queue_state.clock),
                    wr.mode_switch_s, wr.carried_requests))
    return out


def test_pr5_closed_loop_fingerprint_regression():
    """The admission-aware rewrite of the closed loop reproduces the PR-5
    controller bitwise when admission is off (recorded fingerprint)."""
    assert _fingerprint(_pr5_run()) == _PR5_FINGERPRINT


def test_admission_none_byte_identical_to_plain_closed_loop():
    base = _pr5_run()
    none = _pr5_run(admission="none")
    assert _fingerprint(none) == _fingerprint(base)
    for a, b in zip(base, none):
        assert np.array_equal(np.asarray(a.report.latencies),
                              np.asarray(b.report.latencies))
        assert a.shed_requests == b.shed_requests == 0
        assert a.deferred_requests == b.deferred_requests == 0


def test_shed_closed_loop_zero_admitted_violations():
    """Overload the PR-5 scenario: shedding keeps every *admitted* request
    inside the nominal budget while recording goodput and sheds."""
    f = Fulcrum(DEV)
    cfg = ControllerConfig(**_PR5_CFG, admission="shed",
                           burst_quantile=0.95)
    wins = f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1,
                           [300.0, 300.0, 300.0], "gmd",
                           window_duration=10.0, arrivals="poisson", seed=3,
                           controller=cfg, backend="numpy")
    assert sum(wr.shed_requests for wr in wins) > 0
    for wr in wins:
        assert wr.report is not None
        assert wr.report.violation_rate(0.1) == 0.0
        assert wr.goodput is not None and 0.0 < wr.goodput <= 1.0 + 1e-12
        assert wr.report.shed_requests == wr.shed_requests
        assert wr.offered_requests > 0


def test_defer_closed_loop_records_and_reoffers():
    f = Fulcrum(DEV)
    cfg = ControllerConfig(**_PR5_CFG, admission="defer",
                           burst_quantile=0.95, defer_cap=2000)
    wins = f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1,
                           [300.0, 60.0, 60.0], "gmd",
                           window_duration=10.0, arrivals="poisson", seed=3,
                           controller=cfg, backend="numpy")
    assert wins[0].deferred_requests > 0
    assert all(wr.shed_requests == 0 or wr.deferred_requests >= 0
               for wr in wins)
    # re-offered requests land in later windows: drain goodput can top 1,
    # and admitted service still meets the nominal budget everywhere
    for wr in wins:
        assert wr.report.violation_rate(0.1) == 0.0


def test_degrade_bs_sheds_nothing():
    f = Fulcrum(DEV)
    cfg = ControllerConfig(**_PR5_CFG, admission="degrade-bs",
                           burst_quantile=0.95)
    wins = f.serve_dynamic(INFER_WORKLOADS["mobilenet"], 40.0, 0.1,
                           [300.0, 500.0], "gmd", window_duration=10.0,
                           arrivals="poisson", seed=3, controller=cfg,
                           backend="numpy")
    assert all(wr.shed_requests == 0 and wr.deferred_requests == 0
               for wr in wins)
    assert all(wr.goodput is not None for wr in wins)


def test_multi_tenant_shed_keeps_admitted_in_budget():
    f = Fulcrum(DEV)
    streams = (P.StreamSpec(100.0, 0.1, INFER_WORKLOADS["mobilenet"]),
               P.StreamSpec(60.0, 0.2, INFER_WORKLOADS["lstm"]))
    cfg = ControllerConfig(rate_estimator="ewma", carry_backlog=True,
                           admission="shed", burst_quantile=0.95,
                           priorities=(1.0, 0.5))
    wins = f.serve_dynamic(streams, 55.0, None,
                           [(100.0, 60.0), (130.0, 78.0)], "gmd",
                           window_duration=10.0, arrivals="poisson", seed=2,
                           controller=cfg, backend="numpy")
    assert sum(wr.shed_requests for wr in wins) > 0
    for wr in wins:
        assert wr.solution is not None
        for rep, spec in zip(wr.report.streams, streams):
            assert rep.violation_rate(spec.latency_budget) == 0.0
        assert wr.goodput is not None


# ---------------------------------------------------------------------------
# open-loop goodput + runtime gate parity
# ---------------------------------------------------------------------------

def test_open_loop_reports_goodput():
    f = Fulcrum(DEV)
    wins = f.serve_dynamic(INFER_WORKLOADS["resnet50"], 40.0, 0.1,
                           [40.0, 60.0], "gmd", window_duration=5.0,
                           backend="numpy")
    for wr in wins:
        assert wr.goodput is not None
        assert wr.offered_requests == len(wr.report.trace)
        assert wr.shed_requests == 0


def test_runtime_gate_parity_with_engine_mask():
    """The runtime-side admission gate sheds the identical request set as
    the engine-side mask, and the gated runtime run under a FakeClock
    replays the engine on the admitted trace bitwise."""
    w = INFER_WORKLOADS["mobilenet"]
    pm = SPACE.maxn()
    bs = 4
    t_in = DEV.time_power(w, pm, bs)[0]
    budget = 4.0 * t_in
    trace = S.ArrivalTrace.poisson(3.0 * bs / t_in, 4.0, seed=11)
    pol = AdmissionPolicy("shed")
    mask = pol.admit(trace.times, budget, bs, t_in, 0.0)
    admitted = S.ArrivalTrace(trace.times[mask], trace.duration, trace.kind)

    clock = FakeClock()

    class _Server:
        def infer(self):
            clock.advance(t_in)

    rt = ManagedInterleaveRuntime(
        None, _Server(),
        InterleaveConfig(arrival_rate=0.0, infer_bs=bs,
                         latency_budget=budget),
        trace=trace, clock=clock, admission=pol.gate(bs, t_in, budget))
    rep = rt.run()
    assert rep.shed_requests == int(np.count_nonzero(~mask))
    ref = S.simulate(DEV, None, w, pm, bs, admitted, "managed",
                     backend="numpy")
    assert np.array_equal(np.asarray(rep.latencies, np.float64),
                          np.asarray(ref.latencies, np.float64))
    assert rep.violation_rate(budget) == 0.0


def test_runtime_gate_rejects_multi_stream_trace():
    merged = S.ArrivalTrace.merge([S.ArrivalTrace.uniform(10.0, 2.0),
                                   S.ArrivalTrace.uniform(10.0, 2.0)])
    pol = AdmissionPolicy("shed")
    with pytest.raises(ValueError, match="single-stream"):
        ManagedInterleaveRuntime(
            None, None,
            InterleaveConfig(arrival_rate=0.0, infer_bs=4,
                             latency_budget=0.1),
            trace=merged, admission=pol.gate(4, 0.01, 0.1))
