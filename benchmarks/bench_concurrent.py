"""Paper Fig. 11: concurrent training+inference — % training-throughput loss
vs optimal, per strategy, over the paper's 5 {train, infer} DNN pairs.

Oracle optima and fitted-strategy answers for the whole sweep come from one
batched reduction each (core.grid_eval); strategies resolve through the
Fulcrum scenario registry (GMD re-profiles per problem, fitted models are
built once per pair). The GMD plan for the median solvable problem is also
*executed* with the trace-driven engine as an end-to-end check."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.als import QuadrantRanges
from repro.core.device_model import INFER_WORKLOADS, TRAIN_WORKLOADS
from repro.core.scheduler import Fulcrum, Scenario

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, \
    gmd_executed_row, loss_pct, median, row, concurrent_problem_grid

# {train, infer} pairs from §7.3
PAIRS = [("yolov8n", "resnet50"), ("resnet18", "mobilenet"),
         ("mobilenet", "mobilenet"), ("resnet18", "bert"),
         ("mobilenet", "lstm")]
NN_EPOCHS = 300
STRATEGIES = ("gmd15", "als145", "rnd150", "rnd250", "nn250")


def _quadrants(bert: bool) -> QuadrantRanges:
    if bert:
        return QuadrantRanges(latency=(2.0, 6.0), arrival=(1.0, 15.0))
    return QuadrantRanges(latency=(0.5, 2.0), arrival=(30.0, 120.0))


def run(full: bool = False, pairs=None) -> list[str]:
    rows = []
    for tr_name, in_name in (pairs or PAIRS):
        w_tr, w_in = TRAIN_WORKLOADS[tr_name], INFER_WORKLOADS[in_name]
        bert = in_name == "bert"
        f = Fulcrum(DEV, SPACE, _quadrants(bert), nn_epochs=NN_EPOCHS)
        probs = concurrent_problem_grid(full, bert=bert)
        opts = ORACLE.solve_concurrent_batch(w_tr, w_in, probs, backend=BACKEND)
        solvable_pairs = [(prob, opt) for prob, opt in zip(probs, opts)
                          if opt is not None and opt.throughput > 0]
        solvable = len(solvable_pairs)
        gmd_plans = []
        for sname in STRATEGIES:
            losses, viols, solved = [], 0, 0
            if sname == "gmd15":
                gmd_plans = [f.solve_concurrent(w_tr, w_in, prob, "gmd")
                             for prob, _ in solvable_pairs]
                sols = [pl.solution if pl else None for pl in gmd_plans]
            else:
                strat = f.strategy_for(Scenario.CONCURRENT, sname, w_tr, w_in)
                sols = strat.solve_batch([prob for prob, _ in solvable_pairs])
            for (prob, opt), sol in zip(solvable_pairs, sols):
                if sol is None:
                    continue
                t_in, p_in = ORACLE.true_infer(w_in, sol.pm, sol.bs)
                t_tr, p_tr = ORACLE.true_train(w_tr, sol.pm)
                lam = P.peak_latency(sol.bs, prob.arrival_rate, t_in)
                if (max(p_in, p_tr) > prob.power_budget + 1e-9
                        or lam > prob.latency_budget + 1e-9
                        or not P.sustainable(sol.bs, prob.arrival_rate, t_in)):
                    viols += 1
                    continue
                solved += 1
                theta = P.train_throughput(sol.bs, prob.arrival_rate, t_in, t_tr)
                losses.append(loss_pct(opt.throughput, theta))
            pct = 100.0 * solved / max(solvable, 1)
            rows.append(row(
                f"concurrent/{tr_name}+{in_name}/{sname}/median_tput_loss_pct",
                median(losses),
                f"solved_pct={pct:.1f};violations={viols};solvable={solvable}"))
        erow = gmd_executed_row(f, solvable_pairs, gmd_plans, w_in, w_tr,
                                f"concurrent/{tr_name}+{in_name}/gmd15",
                                "tput")
        if erow:
            rows.append(erow)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
