"""Benchmark harness: one module per paper table/figure + the roofline
deliverable. Prints ``name,value,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,fig9,...]

Default grids are strided for CPU wall-time; --full uses the paper's exact
grids (273k+ problem configurations).
"""
import argparse
import time

from benchmarks import (bench_arch_fulcrum, bench_concurrent,
                        bench_concurrent_inference, bench_dynamic,
                        bench_infer, bench_interleave_engine,
                        bench_interleaving, bench_multi_tenant,
                        bench_roofline, bench_solver, bench_table1,
                        bench_train)

SUITES = {
    "fig2_interleaving": bench_interleaving.run,
    "fig9_train": bench_train.run,
    "fig10_infer": bench_infer.run,
    "fig11_concurrent": bench_concurrent.run,
    "fig12_dynamic": bench_dynamic.run,
    "fig14_concurrent_infer": bench_concurrent_inference.run,
    "multi_tenant": bench_multi_tenant.run,
    "table1_practitioner": bench_table1.run,
    "arch_fulcrum": bench_arch_fulcrum.run,
    "roofline": bench_roofline.run,
    "solver_microbench": bench_solver.run,
    "interleave_engine": bench_interleave_engine.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids (273k+ configs)")
    ap.add_argument("--only", default="",
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,value,derived")
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn(full=args.full):
                print(row, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,1,{type(e).__name__}: {e}", flush=True)
        print(f"{name}/wall_s,{time.time()-t0:.1f},", flush=True)


if __name__ == "__main__":
    main()
