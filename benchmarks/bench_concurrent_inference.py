"""Paper Fig. 14: two concurrent inferences — maximize non-urgent inference
throughput subject to the urgent inference's latency deadline and the power
budget. Pairs {non-urgent, urgent}: {ResNet50, BERT} and {ResNet50, MNet}
modeled as the concurrent problem with the non-urgent batch inference
(fixed bs=32) playing the training role (§5.4). Oracle optima and fitted
strategies answer the whole sweep via batched grid reductions."""
from __future__ import annotations

import dataclasses

from repro.core import problem as P
from repro.core.als import ALSConcurrent, QuadrantRanges
from repro.core.baselines import NNConcurrentBaseline, RNDConcurrent
from repro.core.device_model import INFER_WORKLOADS, Profiler
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, loss_pct, \
    median, row, concurrent_problem_grid

NN_EPOCHS = 300
PAIRS = [("resnet50", "bert"), ("resnet50", "mobilenet")]


def _nonurgent(name: str):
    return dataclasses.replace(INFER_WORKLOADS[name],
                               name=f"{name}-nonurgent", train_bs=32)


def run(full: bool = False) -> list[str]:
    rows = []
    for nu_name, u_name in PAIRS:
        w_nu = _nonurgent(nu_name)
        w_u = INFER_WORKLOADS[u_name]
        bert = u_name == "bert"
        probs = concurrent_problem_grid(full, bert=bert)
        opts = ORACLE.solve_concurrent_batch(w_nu, w_u, probs, backend=BACKEND)
        solvable_pairs = [(prob, opt) for prob, opt in zip(probs, opts)
                          if opt is not None and opt.throughput > 0]
        solvable = len(solvable_pairs)
        quad = (QuadrantRanges((2.0, 6.0), (1.0, 15.0)) if bert
                else QuadrantRanges((0.5, 2.0), (30.0, 120.0)))
        mk = lambda: ConcurrentProfiler(Profiler(DEV, w_nu), Profiler(DEV, w_u))
        fitted = {
            "als145": ALSConcurrent(mk(), quad, SPACE, nn_epochs=NN_EPOCHS),
            "rnd150": RNDConcurrent(mk(), 150, SPACE),
            "rnd250": RNDConcurrent(mk(), 250, SPACE),
            "nn250": NNConcurrentBaseline(mk(), 250, SPACE, nn_epochs=NN_EPOCHS),
        }
        strategies = {"gmd15": None, **fitted}
        for sname, strat in strategies.items():
            losses, solved = [], 0
            if sname == "gmd15":
                sols = [GMDConcurrent(mk(), SPACE).solve(prob)
                        for prob, _ in solvable_pairs]
            else:
                sols = strat.solve_batch([prob for prob, _ in solvable_pairs])
            for (prob, opt), sol in zip(solvable_pairs, sols):
                if sol is None:
                    continue
                t_u, p_u = ORACLE.true_infer(w_u, sol.pm, sol.bs)
                t_nu, p_nu = ORACLE.true_train(w_nu, sol.pm)
                lam = P.peak_latency(sol.bs, prob.arrival_rate, t_u)
                if (max(p_u, p_nu) > prob.power_budget + 1e-9
                        or lam > prob.latency_budget + 1e-9
                        or not P.sustainable(sol.bs, prob.arrival_rate, t_u)):
                    continue
                solved += 1
                theta = P.train_throughput(sol.bs, prob.arrival_rate, t_u, t_nu)
                losses.append(loss_pct(opt.throughput, theta))
            pct = 100.0 * solved / max(solvable, 1)
            rows.append(row(
                f"concurrent_infer/{nu_name}+{u_name}/{sname}/median_tput_loss_pct",
                median(losses), f"solved_pct={pct:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
