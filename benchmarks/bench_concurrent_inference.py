"""Paper Fig. 14: two concurrent inferences — maximize non-urgent inference
throughput subject to the urgent inference's latency deadline and the power
budget. Pairs {non-urgent, urgent}: {ResNet50, BERT} and {ResNet50, MNet}
modeled as the concurrent problem with the non-urgent batch inference
(fixed bs=32) playing the training role (§5.4). Strategies resolve through
the Fulcrum registry under Scenario.CONCURRENT_INFERENCE; oracle optima and
fitted strategies answer the whole sweep via batched grid reductions, and
the urgent stream's GMD plan is executed with the trace-driven engine."""
from __future__ import annotations

import dataclasses

from repro.core import problem as P
from repro.core.als import QuadrantRanges
from repro.core.device_model import INFER_WORKLOADS
from repro.core.scheduler import Fulcrum, Scenario

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, \
    gmd_executed_row, loss_pct, median, row, concurrent_problem_grid

NN_EPOCHS = 300
PAIRS = [("resnet50", "bert"), ("resnet50", "mobilenet")]
STRATEGIES = ("gmd15", "als145", "rnd150", "rnd250", "nn250")


def _nonurgent(name: str):
    return dataclasses.replace(INFER_WORKLOADS[name],
                               name=f"{name}-nonurgent", train_bs=32)


def run(full: bool = False) -> list[str]:
    rows = []
    for nu_name, u_name in PAIRS:
        w_nu = _nonurgent(nu_name)
        w_u = INFER_WORKLOADS[u_name]
        bert = u_name == "bert"
        quad = (QuadrantRanges((2.0, 6.0), (1.0, 15.0)) if bert
                else QuadrantRanges((0.5, 2.0), (30.0, 120.0)))
        f = Fulcrum(DEV, SPACE, quad, nn_epochs=NN_EPOCHS)
        probs = concurrent_problem_grid(full, bert=bert)
        opts = ORACLE.solve_concurrent_batch(w_nu, w_u, probs, backend=BACKEND)
        solvable_pairs = [(prob, opt) for prob, opt in zip(probs, opts)
                          if opt is not None and opt.throughput > 0]
        solvable = len(solvable_pairs)
        gmd_plans = []
        for sname in STRATEGIES:
            losses, solved = [], 0
            if sname == "gmd15":
                gmd_plans = [f.solve_concurrent_inference(w_nu, w_u, prob,
                                                          "gmd")
                             for prob, _ in solvable_pairs]
                sols = [pl.solution if pl else None for pl in gmd_plans]
            else:
                strat = f.strategy_for(Scenario.CONCURRENT_INFERENCE, sname,
                                       w_nu, w_u)
                sols = strat.solve_batch([prob for prob, _ in solvable_pairs])
            for (prob, opt), sol in zip(solvable_pairs, sols):
                if sol is None:
                    continue
                t_u, p_u = ORACLE.true_infer(w_u, sol.pm, sol.bs)
                t_nu, p_nu = ORACLE.true_train(w_nu, sol.pm)
                lam = P.peak_latency(sol.bs, prob.arrival_rate, t_u)
                if (max(p_u, p_nu) > prob.power_budget + 1e-9
                        or lam > prob.latency_budget + 1e-9
                        or not P.sustainable(sol.bs, prob.arrival_rate, t_u)):
                    continue
                solved += 1
                theta = P.train_throughput(sol.bs, prob.arrival_rate, t_u, t_nu)
                losses.append(loss_pct(opt.throughput, theta))
            pct = 100.0 * solved / max(solvable, 1)
            rows.append(row(
                f"concurrent_infer/{nu_name}+{u_name}/{sname}/median_tput_loss_pct",
                median(losses), f"solved_pct={pct:.1f}"))
        erow = gmd_executed_row(
            f, solvable_pairs, gmd_plans, w_u, w_nu,
            f"concurrent_infer/{nu_name}+{u_name}/gmd15", "nonurgent_tput")
        if erow:
            rows.append(erow)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
