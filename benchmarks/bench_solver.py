"""Solver microbenchmark: configs solved per second, scalar reference loop vs
the vectorized grid engine (NumPy and jax backends), on the paper-scale
(--full) problem grids against the dense 441-mode x 5-bs observation grid.

The scalar loop is timed on a subsample (it is the hours-scale path the
engine replaces) and extrapolated to configs/s; the vectorized paths solve
the *entire* sweep. Results are printed as CSV rows and snapshotted to
``benchmarks/results/BENCH_solver.json`` so the speedup is tracked across
PRs."""
from __future__ import annotations

import time
from pathlib import Path

from repro.core import grid_eval as G
from repro.core import problem as P
from repro.core.device_model import INFER_WORKLOADS, TRAIN_WORKLOADS

from benchmarks.common import ORACLE, row, snapshot, \
    concurrent_problem_grid, infer_problem_grid, train_problem_grid

SNAPSHOT = Path(__file__).parent / "results" / "BENCH_solver.json"
SCALAR_SAMPLE = 60          # scalar-loop problems timed per variant


def _time_scalar(solve_one, probs) -> float:
    sample = probs[:: max(1, len(probs) // SCALAR_SAMPLE)][:SCALAR_SAMPLE]
    t0 = time.perf_counter()
    for pr in sample:
        solve_one(pr)
    dt = time.perf_counter() - t0
    return len(sample) / dt


def _time_batch(solve_batch, probs, backend: str) -> float:
    solve_batch(probs[:8], backend)         # warm caches / jit compile
    t0 = time.perf_counter()
    solve_batch(probs, backend)
    return len(probs) / (time.perf_counter() - t0)


def _variant(name, probs, solve_one, solve_batch, results, rows):
    scalar = _time_scalar(solve_one, probs)
    numpy_ = _time_batch(solve_batch, probs, "numpy")
    try:
        jax_ = _time_batch(solve_batch, probs, "jax")
    except RuntimeError:                    # jax unavailable: record honestly
        jax_ = None
    rec = {"problems": len(probs),
           "scalar_configs_per_s": scalar,
           "numpy_configs_per_s": numpy_,
           "speedup_numpy": numpy_ / scalar}
    if jax_ is not None:
        rec["jax_configs_per_s"] = jax_
        rec["speedup_jax"] = jax_ / scalar
    results[name] = rec
    rows.append(row(f"solver/{name}/speedup_numpy", rec["speedup_numpy"],
                    f"scalar={scalar:.0f}cfg/s;numpy={numpy_:.0f}cfg/s;"
                    f"n={len(probs)}"))
    if jax_ is not None:
        rows.append(row(f"solver/{name}/speedup_jax", rec["speedup_jax"],
                        f"jax={jax_:.0f}cfg/s"))


def run(full: bool = False) -> list[str]:
    # the microbenchmark always measures at paper scale: the whole point is
    # the --full-size sweep as one array program
    w_tr = TRAIN_WORKLOADS["resnet18"]
    w_in = INFER_WORKLOADS["mobilenet"]
    tgrid = ORACLE.train_grid(w_tr)
    igrid = ORACLE.infer_grid(w_in)
    tobs, iobs = tgrid.to_dict(), igrid.to_dict()

    rows: list[str] = []
    results: dict = {"observations": {"train_modes": len(tgrid),
                                      "infer_entries": len(igrid)}}

    _variant("train", train_problem_grid(True),
             lambda pr: P.solve_train(pr, tobs),
             lambda ps, b: G.solve_train_batch(ps, tgrid, b),
             results, rows)
    _variant("infer", infer_problem_grid(True),
             lambda pr: P.solve_infer(pr, iobs),
             lambda ps, b: G.solve_infer_batch(ps, igrid, b),
             results, rows)
    _variant("concurrent", concurrent_problem_grid(True),
             lambda pr: P.solve_concurrent(pr, tobs, iobs),
             lambda ps, b: G.solve_concurrent_batch(ps, tgrid, igrid, b),
             results, rows)

    # headline number: the whole --full sweep (every variant) as one batch
    # program vs the scalar loop, configs/s weighted by sweep size
    total = sum(results[v]["problems"] for v in ("train", "infer", "concurrent"))
    for path in ("scalar", "numpy", "jax"):
        key = f"{path}_configs_per_s"
        if any(key not in results[v] for v in ("train", "infer", "concurrent")):
            continue
        secs = sum(results[v]["problems"] / results[v][key]
                   for v in ("train", "infer", "concurrent"))
        results.setdefault("full_sweep", {})[key] = total / secs
    fs = results["full_sweep"]
    fs["problems"] = total
    fs["speedup_numpy"] = fs["numpy_configs_per_s"] / fs["scalar_configs_per_s"]
    if "jax_configs_per_s" in fs:
        fs["speedup_jax"] = fs["jax_configs_per_s"] / fs["scalar_configs_per_s"]
    rows.append(row("solver/full_sweep/speedup_numpy", fs["speedup_numpy"],
                    f"n={total};numpy={fs['numpy_configs_per_s']:.0f}cfg/s"))

    snapshot(SNAPSHOT, results, configs=total)
    rows.append(row("solver/snapshot", 1, str(SNAPSHOT)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
