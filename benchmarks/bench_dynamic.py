"""Paper Fig. 12/13: inference with dynamic arrival rates — median excess
latency over optimal and % solutions found, per strategy, over Poisson /
Alibaba-like / Azure-like traces (24 x 5-min windows, rate changes per
window; power 40 W, latency 100 ms as in §7.4)."""
from __future__ import annotations

import math
import random

from repro.core import problem as P
from repro.core.als import ALSInfer, QuadrantRanges
from repro.core.baselines import NNInferBaseline, RNDInfer
from repro.core.device_model import INFER_WORKLOADS, Profiler
from repro.core.scheduler import Fulcrum

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, excess_pct, \
    median, row

POWER, LATENCY = 40.0, 0.1
NN_EPOCHS = 300


def make_traces(windows: int = 24) -> dict[str, list[float]]:
    rng = random.Random(42)
    poisson = [max(30.0, min(76.0, rng.gauss(60, math.sqrt(60))))
               for _ in range(windows)]
    alibaba = [30 + 23 * (1 + math.sin(2 * math.pi * i / windows - 1.2))
               + rng.uniform(-3, 3) for i in range(windows)]      # <= ~76
    azure = []
    for i in range(windows):       # bursty: baseline + spikes to 115
        base = 45 + rng.uniform(-10, 10)
        azure.append(min(115.0, base + (70 if rng.random() < 0.2 else 0)))
    return {"poisson": poisson, "alibaba": alibaba, "azure": azure}


def run(full: bool = False, dnns=None) -> list[str]:
    rows = []
    dnns = dnns or ["resnet50", "mobilenet", "yolov8n", "lstm"]
    traces = make_traces(24 if full else 12)
    for name in dnns:
        w = INFER_WORKLOADS[name]
        fitted = {
            "als145": ALSInfer(Profiler(DEV, w),
                               QuadrantRanges((0.05, 1.0), (30.0, 90.0)),
                               SPACE, nn_epochs=NN_EPOCHS),
            "rnd150": RNDInfer(Profiler(DEV, w), 150, SPACE),
            "rnd250": RNDInfer(Profiler(DEV, w), 250, SPACE),
            "nn250": NNInferBaseline(Profiler(DEV, w), 250, SPACE,
                                     nn_epochs=NN_EPOCHS),
        }
        for trace_name, rates in traces.items():
            # GMD: shared profiling history across windows (§5.4)
            f = Fulcrum(DEV, SPACE)
            probs = [P.InferProblem(POWER, LATENCY, r) for r in rates]
            opts = ORACLE.solve_infer_batch(w, probs, backend=BACKEND)
            strategies = {"gmd": None, **fitted}
            for sname, strat in strategies.items():
                exc, found = [], 0
                if sname == "gmd":
                    sols = f.solve_dynamic(w, POWER, LATENCY, rates, "gmd")
                else:
                    sols = strat.solve_batch(probs)
                for sol, rate, opt in zip(sols, rates, opts):
                    if opt is None:
                        continue
                    if sol is None:
                        continue
                    t_true, p_true = ORACLE.true_infer(w, sol.pm, sol.bs)
                    lam = P.peak_latency(sol.bs, rate, t_true)
                    if (p_true > POWER + 1e-9 or lam > LATENCY + 1e-9
                            or not P.sustainable(sol.bs, rate, t_true)):
                        continue
                    found += 1
                    exc.append(excess_pct(lam, opt.time))
                rows.append(row(
                    f"dynamic/{name}/{trace_name}/{sname}/median_excess_pct",
                    median(exc), f"found={found}/{len(rates)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
