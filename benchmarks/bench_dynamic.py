"""Paper Fig. 12/13: inference with dynamic arrival rates — median excess
latency over optimal and % solutions found, per strategy, over Poisson /
Alibaba-like / Azure-like traces (24 x 5-min windows, rate changes per
window; power 40 W, latency 100 ms as in §7.4).

All strategies run through Fulcrum's re-planning controller
(``solve_dynamic``): GMD shares its profiler cache across windows, fitted
strategies (ALS/RND/NN) are fitted once per DNN via the scenario registry
and answer every window. The GMD plan sequence is additionally *executed*
window-by-window with the trace-driven engine (core.simulate), reporting the
realized tail latency and violation rate.

Closed-loop section (``core.controller``): the same sweep served end-to-end
by ``serve_dynamic`` over seeded Poisson arrivals under a config matrix —
oracle vs EWMA-estimated rates, executed-latency feedback on/off,
mode-switch cost on/off — reporting executed violation rate, p95, request
throughput, their deltas against the open-loop oracle baseline, and the
fraction of windows whose executed p95 meets the latency budget. Everything
is snapshotted to ``benchmarks/results/BENCH_dynamic.json``; the solver
rows (``rows``) are byte-stable across PRs."""
from __future__ import annotations

import math
import random
from pathlib import Path

import numpy as np

from repro.core import problem as P
from repro.core.als import QuadrantRanges
from repro.core.controller import ControllerConfig
from repro.core.device_model import INFER_WORKLOADS
from repro.core.scheduler import Fulcrum
from repro.core.simulate import ArrivalTrace, ExecutionReport, simulate

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, excess_pct, \
    median, row, snapshot

POWER, LATENCY = 40.0, 0.1
NN_EPOCHS = 300
WINDOW_S = 30.0          # engine execution horizon per rate window
STRATEGIES = ("gmd", "als145", "rnd150", "rnd250", "nn250")
SNAPSHOT = Path(__file__).parent / "results" / "BENCH_dynamic.json"

# A window "satisfies the latency budget" when its executed p95 is within
# it, i.e. executed violation rate <= 5%; an unsolved window does not.
SATISFIED_VIOL = 0.05

# The closed-loop config matrix: rate estimation x feedback x switch cost.
# EWMA configs carry backlog (requests do not vanish at window boundaries)
# and plan with a 1.5x rate margin — the estimator only knows the previous
# window's arrivals, so the margin buys service headroom against upward
# rate moves, and the interval solve keeps the latency budget pinned at the
# unmargined estimate, so headroom costs power, not fill latency (azure's
# 2.5x spikes stay out of reach of any window-boundary planner; admission
# control is the ROADMAP follow-up for those).
# 0.5 s per power-mode switch is the measured Jetson nvpmodel ballpark.
# The matrix runs over both arrival models: uniform ticks are the §5.4
# contract the plans guarantee (the >=90%-of-windows criterion is judged
# there); seeded Poisson is the burst stress, where the 100 ms budget is
# queueing-infeasible for most plans regardless of controller.
_EWMA = dict(rate_estimator="ewma", rate_margin=1.5, carry_backlog=True)
CLOSED_LOOP_CONFIGS = {
    "oracle": ControllerConfig(),
    "oracle_fb": ControllerConfig(feedback=True),
    "oracle_fb_switch": ControllerConfig(feedback=True, mode_switch_s=0.5),
    "ewma": ControllerConfig(**_EWMA),
    "ewma_fb": ControllerConfig(feedback=True, **_EWMA),
    "ewma_fb_switch": ControllerConfig(feedback=True, mode_switch_s=0.5,
                                       **_EWMA),
}
CLOSED_LOOP_ARRIVALS = ("uniform", "poisson")

# Burst-survival matrix (§5.4 admission control): estimator x admission
# mode x arrival model, snapshotting the goodput-vs-violation tradeoff.
# "none" under the ewma estimator is *the same config* as the closed-loop
# "ewma" row — the byte-identity anchor (--check) proving the admission
# machinery is inert when off. "shed" drops requests the committed plan
# provably cannot serve within budget (deadline-drop against the engine's
# own recurrence, so admitted-request satisfaction is exact by
# construction); "defer" re-offers them at the next window start instead.
ADMISSION_ESTIMATORS = ("oracle", "ewma")
ADMISSION_MODES = ("none", "shed", "defer")


def _admission_config(estimator: str, mode: str) -> ControllerConfig:
    base = dict(carry_backlog=True) if estimator == "oracle" \
        else dict(_EWMA)
    knobs = {}
    if mode != "none":
        knobs = dict(admission=mode, burst_quantile=0.95)
        if mode == "defer":
            knobs["defer_cap"] = 2000
    return ControllerConfig(**base, **knobs)


def make_traces(windows: int = 24) -> dict[str, list[float]]:
    rng = random.Random(42)
    poisson = [max(30.0, min(76.0, rng.gauss(60, math.sqrt(60))))
               for _ in range(windows)]
    alibaba = [30 + 23 * (1 + math.sin(2 * math.pi * i / windows - 1.2))
               + rng.uniform(-3, 3) for i in range(windows)]      # <= ~76
    azure = []
    for i in range(windows):       # bursty: baseline + spikes to 115
        base = 45 + rng.uniform(-10, 10)
        azure.append(min(115.0, base + (70 if rng.random() < 0.2 else 0)))
    return {"poisson": poisson, "alibaba": alibaba, "azure": azure}


def _closed_loop_rows(traces: dict, dnns, records: dict) -> list[str]:
    """Serve every (dnn, trace, arrival model) end-to-end under the
    closed-loop config matrix; per-config records land in ``records`` and
    CSV rows return."""
    rows = []
    sat_counts: dict[tuple, list[int]] = {
        (a, c): [0, 0] for a in CLOSED_LOOP_ARRIVALS
        for c in CLOSED_LOOP_CONFIGS}
    for name in dnns:
        w = INFER_WORKLOADS[name]
        f = Fulcrum(DEV, SPACE, QuadrantRanges((0.05, 1.0), (30.0, 90.0)),
                    nn_epochs=NN_EPOCHS)
        for trace_name, rates in traces.items():
            for arrivals in CLOSED_LOOP_ARRIVALS:
                base = None
                for cname, cfg in CLOSED_LOOP_CONFIGS.items():
                    wins = f.serve_dynamic(w, POWER, LATENCY, rates, "gmd",
                                           window_duration=WINDOW_S,
                                           arrivals=arrivals, seed=7,
                                           controller=cfg)
                    lats = np.concatenate(
                        [np.asarray(wr.report.latencies, np.float64)
                         for wr in wins if wr.report is not None]
                        or [np.empty(0)])
                    agg = ExecutionReport("managed", lats, 0, 1.0, 0.0)
                    sat = [wr.report is not None
                           and wr.report.violation_rate(LATENCY)
                           <= SATISFIED_VIOL for wr in wins]
                    rec = {
                        "viol_pct": 100.0 * agg.violation_rate(LATENCY),
                        "p95_ms": 1e3 * agg.latency_quantile(0.95),
                        "throughput_rps": lats.size / (WINDOW_S * len(rates)),
                        "satisfied_frac": sum(sat) / len(wins),
                        "windows": len(wins),
                        "served_windows": sum(wr.report is not None
                                              for wr in wins),
                        "mode_switches": sum(wr.mode_switch_s > 0
                                             for wr in wins),
                        "carried_requests": sum(wr.carried_requests
                                                for wr in wins),
                        "configs": len(wins),
                    }
                    if cname == "oracle":
                        base = rec
                    rec["d_viol_pct"] = rec["viol_pct"] - base["viol_pct"]
                    rec["d_throughput_rps"] = (rec["throughput_rps"]
                                               - base["throughput_rps"])
                    records[f"closed_loop/{name}/{trace_name}/{arrivals}/"
                            f"{cname}"] = rec
                    sat_counts[(arrivals, cname)][0] += sum(sat)
                    sat_counts[(arrivals, cname)][1] += len(wins)
                    rows.append(row(
                        f"dynamic_closed/{name}/{trace_name}/{arrivals}/"
                        f"{cname}/viol_pct", rec["viol_pct"],
                        f"sat={rec['satisfied_frac']:.3f};"
                        f"d_tput={rec['d_throughput_rps']:+.2f}rps;"
                        f"p95={rec['p95_ms']:.1f}ms"))
    for (arrivals, cname), (good, total) in sat_counts.items():
        frac = good / total if total else float("nan")
        records[f"closed_loop_summary/{arrivals}/{cname}"] = {
            "satisfied_frac": frac, "windows": total, "configs": total}
        rows.append(row(
            f"dynamic_closed/summary/{arrivals}/{cname}/satisfied_frac",
            frac, f"windows={total}"))
    return rows


def _admission_rows(traces: dict, dnns, records: dict) -> list[str]:
    """The burst-survival sweep: every (dnn, trace, arrival model) served
    under the estimator x admission-mode matrix, recording admitted-request
    violation, per-window satisfaction on admitted requests, goodput
    against offered load, and the shed/deferred/split counters."""
    rows = []
    agg: dict[tuple, list] = {
        (a, e, m): [0, 0, 0.0, 0, 0, 0]   # sat, wins, good, offered,
        for a in CLOSED_LOOP_ARRIVALS     # shed, deferred
        for e in ADMISSION_ESTIMATORS for m in ADMISSION_MODES}
    for name in dnns:
        w = INFER_WORKLOADS[name]
        f = Fulcrum(DEV, SPACE, QuadrantRanges((0.05, 1.0), (30.0, 90.0)),
                    nn_epochs=NN_EPOCHS)
        for trace_name, rates in traces.items():
            for arrivals in CLOSED_LOOP_ARRIVALS:
                for est in ADMISSION_ESTIMATORS:
                    for mode in ADMISSION_MODES:
                        cfg = _admission_config(est, mode)
                        wins = f.serve_dynamic(
                            w, POWER, LATENCY, rates, "gmd",
                            window_duration=WINDOW_S, arrivals=arrivals,
                            seed=7, controller=cfg)
                        lats = np.concatenate(
                            [np.asarray(wr.report.latencies, np.float64)
                             for wr in wins if wr.report is not None]
                            or [np.empty(0)])
                        ag = ExecutionReport("managed", lats, 0, 1.0, 0.0)
                        sat = [wr.report is not None
                               and wr.report.violation_rate(LATENCY)
                               <= SATISFIED_VIOL for wr in wins]
                        offered = sum(wr.offered_requests for wr in wins)
                        good = sum(wr.goodput * wr.offered_requests
                                   for wr in wins
                                   if wr.goodput is not None)
                        shed = sum(wr.shed_requests for wr in wins)
                        deferred = sum(wr.deferred_requests for wr in wins)
                        rec = {
                            "viol_pct": 100.0 * ag.violation_rate(LATENCY),
                            "p95_ms": 1e3 * ag.latency_quantile(0.95),
                            "satisfied_frac": sum(sat) / len(wins),
                            "goodput_frac": good / offered if offered
                            else 1.0,
                            "offered_requests": offered,
                            "served_requests": int(lats.size),
                            "shed_requests": shed,
                            "deferred_requests": deferred,
                            "splits": sum(wr.splits for wr in wins),
                            "windows": len(wins),
                            "configs": len(wins),
                        }
                        records[f"admission/{name}/{trace_name}/{arrivals}/"
                                f"{est}_{mode}"] = rec
                        a = agg[(arrivals, est, mode)]
                        a[0] += sum(sat)
                        a[1] += len(wins)
                        a[2] += good
                        a[3] += offered
                        a[4] += shed
                        a[5] += deferred
                        rows.append(row(
                            f"dynamic_admission/{name}/{trace_name}/"
                            f"{arrivals}/{est}_{mode}/goodput_frac",
                            rec["goodput_frac"],
                            f"sat={rec['satisfied_frac']:.3f};"
                            f"viol={rec['viol_pct']:.2f}%;"
                            f"shed={shed};deferred={deferred}"))
    for (arrivals, est, mode), (s, n, g, o, sh, df) in agg.items():
        records[f"admission_summary/{arrivals}/{est}_{mode}"] = {
            "satisfied_frac": s / n if n else float("nan"),
            "goodput_frac": g / o if o else 1.0,
            "shed_requests": sh, "deferred_requests": df,
            "windows": n, "configs": n}
        rows.append(row(
            f"dynamic_admission/summary/{arrivals}/{est}_{mode}",
            g / o if o else 1.0,
            f"sat={s / n if n else float('nan'):.3f};windows={n}"))
    return rows


def check(records: dict) -> list[str]:
    """CI acceptance gates (issue 6): Poisson admitted-request budget
    satisfaction >= 0.90 with goodput >= 0.70 of offered load under
    shedding, and the admission-"none" rows byte-identical to the
    admission-free closed-loop rows (the machinery is inert when off).
    Returns a list of failure strings (empty == pass)."""
    fails = []
    for est in ADMISSION_ESTIMATORS:
        key = f"admission_summary/poisson/{est}_shed"
        rec = records.get(key)
        if rec is None:
            fails.append(f"missing {key}")
            continue
        if rec["satisfied_frac"] < 0.90:
            fails.append(f"{key}: satisfied_frac "
                         f"{rec['satisfied_frac']:.3f} < 0.90")
        if rec["goodput_frac"] < 0.70:
            fails.append(f"{key}: goodput_frac "
                         f"{rec['goodput_frac']:.3f} < 0.70")
    anchors = 0
    for key, rec in records.items():
        if not key.startswith("admission/") \
                or not key.endswith("/ewma_none"):
            continue
        base_key = key.replace("admission/", "closed_loop/") \
                      .replace("/ewma_none", "/ewma")
        base = records.get(base_key)
        if base is None:
            continue
        anchors += 1
        for field in ("viol_pct", "p95_ms", "satisfied_frac"):
            if rec[field] != base[field]:
                fails.append(f"{key}.{field}={rec[field]!r} != "
                             f"{base_key}.{field}={base[field]!r}")
    if anchors == 0:
        fails.append("no ewma_none byte-identity anchors found")
    return fails


def run(full: bool = False, dnns=None, closed_loop: bool = True,
        do_check: bool = False) -> list[str]:
    rows = []
    # a restricted DNN subset (e.g. the --quick CI sweep) snapshots to a
    # side file so it can never clobber the committed full-sweep snapshot,
    # whose solver rows are byte-stable across PRs
    path = SNAPSHOT if dnns is None \
        else SNAPSHOT.with_name("BENCH_dynamic_partial.json")
    dnns = dnns or ["resnet50", "mobilenet", "yolov8n", "lstm"]
    traces = make_traces(24 if full else 12)
    for name in dnns:
        w = INFER_WORKLOADS[name]
        # one Fulcrum per DNN: the registry caches each fitted strategy once
        # and reuses it across every trace; GMD re-profiles per trace with a
        # shared per-call profiler (§5.4)
        f = Fulcrum(DEV, SPACE,
                    QuadrantRanges((0.05, 1.0), (30.0, 90.0)),
                    nn_epochs=NN_EPOCHS)
        for trace_name, rates in traces.items():
            probs = [P.InferProblem(POWER, LATENCY, r) for r in rates]
            opts = ORACLE.solve_infer_batch(w, probs, backend=BACKEND)
            for sname in STRATEGIES:
                sols = f.solve_dynamic(w, POWER, LATENCY, rates, sname)
                exc, found = [], 0
                for sol, rate, opt in zip(sols, rates, opts):
                    if opt is None:
                        continue
                    if sol is None:
                        continue
                    t_true, p_true = ORACLE.true_infer(w, sol.pm, sol.bs)
                    lam = P.peak_latency(sol.bs, rate, t_true)
                    if (p_true > POWER + 1e-9 or lam > LATENCY + 1e-9
                            or not P.sustainable(sol.bs, rate, t_true)):
                        continue
                    found += 1
                    exc.append(excess_pct(lam, opt.time))
                rows.append(row(
                    f"dynamic/{name}/{trace_name}/{sname}/median_excess_pct",
                    median(exc), f"found={found}/{len(rates)}"))
                if sname != "gmd":
                    continue
                # execute the GMD plan sequence window-by-window: realized
                # p95 latency and violation rate over the whole trace
                lats = []
                for i, (sol, rate) in enumerate(zip(sols, rates)):
                    if sol is None:
                        continue
                    tr = ArrivalTrace.uniform(rate, WINDOW_S)
                    rep = simulate(DEV, None, w, sol.pm, sol.bs, tr,
                                   approach="managed", seed=i)
                    lats.extend(rep.latencies.tolist())
                if lats:
                    agg = ExecutionReport("managed", lats, 0, 1.0, 0.0)
                    rows.append(row(
                        f"dynamic/{name}/{trace_name}/gmd/executed_p95_ms",
                        agg.latency_quantile(0.95) * 1e3,
                        f"viol_pct={100.0*agg.violation_rate(LATENCY):.2f};"
                        f"requests={len(lats)}"))
    records: dict = {"rows": list(rows)}
    if closed_loop:
        rows += _closed_loop_rows(traces, dnns, records)
        rows += _admission_rows(traces, dnns, records)
    total = sum(len(rates) for rates in traces.values()) * len(dnns)
    snapshot(path, records, configs=total)
    if do_check:
        fails = check(records)
        for fl in fails:
            print(f"CHECK FAIL: {fl}")
        if fails:
            raise SystemExit(1)
        print(f"check passed: poisson shed satisfaction >= 0.90, "
              f"goodput >= 0.70, admission-off rows byte-identical")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="2-DNN sweep (CI-sized)")
    ap.add_argument("--check", action="store_true",
                    help="assert the burst-survival acceptance gates "
                         "(poisson shed satisfaction/goodput, admission-"
                         "off byte-identity)")
    args = ap.parse_args()
    for r in run(full=args.full,
                 dnns=["mobilenet", "lstm"] if args.quick else None,
                 do_check=args.check):
        print(r)
