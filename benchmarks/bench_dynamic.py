"""Paper Fig. 12/13: inference with dynamic arrival rates — median excess
latency over optimal and % solutions found, per strategy, over Poisson /
Alibaba-like / Azure-like traces (24 x 5-min windows, rate changes per
window; power 40 W, latency 100 ms as in §7.4).

All strategies run through Fulcrum's re-planning controller
(``solve_dynamic``): GMD shares its profiler cache across windows, fitted
strategies (ALS/RND/NN) are fitted once per DNN via the scenario registry
and answer every window. The GMD plan sequence is additionally *executed*
window-by-window with the trace-driven engine (core.simulate), reporting the
realized tail latency and violation rate."""
from __future__ import annotations

import math
import random

from repro.core import problem as P
from repro.core.als import QuadrantRanges
from repro.core.device_model import INFER_WORKLOADS
from repro.core.scheduler import Fulcrum
from repro.core.simulate import ArrivalTrace, ExecutionReport, simulate

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, excess_pct, \
    median, row

POWER, LATENCY = 40.0, 0.1
NN_EPOCHS = 300
WINDOW_S = 30.0          # engine execution horizon per rate window
STRATEGIES = ("gmd", "als145", "rnd150", "rnd250", "nn250")


def make_traces(windows: int = 24) -> dict[str, list[float]]:
    rng = random.Random(42)
    poisson = [max(30.0, min(76.0, rng.gauss(60, math.sqrt(60))))
               for _ in range(windows)]
    alibaba = [30 + 23 * (1 + math.sin(2 * math.pi * i / windows - 1.2))
               + rng.uniform(-3, 3) for i in range(windows)]      # <= ~76
    azure = []
    for i in range(windows):       # bursty: baseline + spikes to 115
        base = 45 + rng.uniform(-10, 10)
        azure.append(min(115.0, base + (70 if rng.random() < 0.2 else 0)))
    return {"poisson": poisson, "alibaba": alibaba, "azure": azure}


def run(full: bool = False, dnns=None) -> list[str]:
    rows = []
    dnns = dnns or ["resnet50", "mobilenet", "yolov8n", "lstm"]
    traces = make_traces(24 if full else 12)
    for name in dnns:
        w = INFER_WORKLOADS[name]
        # one Fulcrum per DNN: the registry caches each fitted strategy once
        # and reuses it across every trace; GMD re-profiles per trace with a
        # shared per-call profiler (§5.4)
        f = Fulcrum(DEV, SPACE,
                    QuadrantRanges((0.05, 1.0), (30.0, 90.0)),
                    nn_epochs=NN_EPOCHS)
        for trace_name, rates in traces.items():
            probs = [P.InferProblem(POWER, LATENCY, r) for r in rates]
            opts = ORACLE.solve_infer_batch(w, probs, backend=BACKEND)
            for sname in STRATEGIES:
                sols = f.solve_dynamic(w, POWER, LATENCY, rates, sname)
                exc, found = [], 0
                for sol, rate, opt in zip(sols, rates, opts):
                    if opt is None:
                        continue
                    if sol is None:
                        continue
                    t_true, p_true = ORACLE.true_infer(w, sol.pm, sol.bs)
                    lam = P.peak_latency(sol.bs, rate, t_true)
                    if (p_true > POWER + 1e-9 or lam > LATENCY + 1e-9
                            or not P.sustainable(sol.bs, rate, t_true)):
                        continue
                    found += 1
                    exc.append(excess_pct(lam, opt.time))
                rows.append(row(
                    f"dynamic/{name}/{trace_name}/{sname}/median_excess_pct",
                    median(exc), f"found={found}/{len(rates)}"))
                if sname != "gmd":
                    continue
                # execute the GMD plan sequence window-by-window: realized
                # p95 latency and violation rate over the whole trace
                lats = []
                for i, (sol, rate) in enumerate(zip(sols, rates)):
                    if sol is None:
                        continue
                    tr = ArrivalTrace.uniform(rate, WINDOW_S)
                    rep = simulate(DEV, None, w, sol.pm, sol.bs, tr,
                                   approach="managed", seed=i)
                    lats.extend(rep.latencies.tolist())
                if lats:
                    agg = ExecutionReport("managed", lats, 0, 1.0, 0.0)
                    rows.append(row(
                        f"dynamic/{name}/{trace_name}/gmd/executed_p95_ms",
                        agg.latency_quantile(0.95) * 1e3,
                        f"viol_pct={100.0*agg.violation_rate(LATENCY):.2f};"
                        f"requests={len(lats)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
