"""Fleet tier (``Scenario.FLEET``): K heterogeneous devices stepped as ONE
batched program per window vs the same fleet served as K sequential
single-device closed loops.

For each fleet size K the same aggregate trace is dispatched, planned and
executed twice — ``fleet.serve_fleet`` (one batched grid solve per ladder
rung, one ``simulate_batch`` with per-lane devices per window) and
``fleet.serve_fleet_sequential`` (the existing scalar loop per device) —
and the wall clock, device-window planning throughput (configs/s), batched
speedup, and the parity between the two are snapshotted to
``benchmarks/results/BENCH_fleet.json``. Parity is the PR's contract: the
NumPy rows must agree *bitwise* (max |diff| exactly 0.0) and the jax rows
within engine tolerance; ``--check`` gates batched >= sequential configs/s
at K=64 and the parity bounds on every recorded backend.

The ``fleet/fused/*`` rows time the fused window (``serve_fleet(...,
fused=True)``): the whole solve + admit + simulate pass as ONE compiled
launch per window instead of up to four solver rungs plus an engine call.
Each jax-tier row also records ``host_dispatches_per_window`` (measured
from ``backend.dispatch_count`` deltas) — the number the fused program
exists to drive to 1. The per-rung ``fleet/jax/*`` rows keep the PR-8
methodology (no warmup; per-shape compile churn is part of that path's
cost model, and the recorded baselines stay comparable), while the
``fleet/fused/*`` rows are warmed over the full rate schedule first —
the fused contract is steady state, one compile per shape bucket
amortized over the serving lifetime. ``--check`` gates fused >= 3x the
per-rung jax path on configs/s at K=64, fused parity within the jax
tolerance, and at most 2 host dispatches per fused window.

The ``admission/*`` matrix exercises fleet-wide resource control under a
burst/drain overload (per-device rate multipliers 3.0 / 4.5 / 1.0 / 2.5)
with a tight shared power budget (27 W x K water-filled across devices):
shed vs defer admission, uniform vs poisson arrivals, backlog migration
off vs on, at K in {8} (quick) or {8, 64} (full). ``--check`` gates the
overload SLO story (poisson shed satisfied_frac >= 0.90 — admission must
trim the flood down to windows that meet the deadline) and the migration
story (on the K=8 uniform-shed drain scenario, migrating carried backlog
to less-loaded devices must improve worst-device goodput)."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import fleet as F
from repro.core.backend import dispatch_count, jax_available
from repro.core.controller import ControllerConfig
from repro.core.device_model import INFER_WORKLOADS

from benchmarks.common import row, snapshot

POWER, LATENCY = 30.0, 0.1
WINDOW_S = 5.0
RATE_PER_DEVICE = 30.0        # aggregate rate scales with the fleet
SNAPSHOT = Path(__file__).parent / "results" / "BENCH_fleet.json"
JAX_TOL = 1e-6                # engine parity bound (atol 1e-8 per lane,
                              # loose headroom for reduction ordering)

CFG = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                       feedback=True, carry_backlog=True,
                       mode_switch_s=0.25)

# --- admission/* matrix: fleet-wide resource control under overload ------
ADM_RATES = [3.0, 4.5, 1.0, 2.5]  # burst, peak, drain, recover (x base rate)
ADM_DEFER_CAP = 2000              # fleet-wide parking-lot bound (defer mode)
ADM_BUDGET_PER_DEV_W = 27.0       # shared cap = 27 W x K, below the 30 W
                                  # nameplate so water-filling has to choose
SATISFIED_VIOL = 0.05             # a window "satisfies" the SLO when its
                                  # pooled executed violation rate is <= 5%
MIGRATION_GATE_KEY = "admission/uniform/shed/k8"  # the drain scenario the
                                  # migration gate is judged on


def _adm_cfg(mode: str) -> ControllerConfig:
    return ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                            feedback=True, carry_backlog=True,
                            mode_switch_s=0.25, burst_quantile=0.95,
                            admission=mode,
                            defer_cap=ADM_DEFER_CAP if mode == "defer"
                            else None)


def _adm_serve(K: int, arrivals: str, mode: str, migrate: bool):
    spec = F.FleetSpec(K, seed=3, dispatch="least-backlog",
                       migrate_backlog=migrate,
                       fleet_power_budget=ADM_BUDGET_PER_DEV_W * K)
    return F.serve_fleet(INFER_WORKLOADS["mobilenet"], POWER, LATENCY,
                         [RATE_PER_DEVICE * m * K for m in ADM_RATES],
                         spec, window_duration=WINDOW_S, arrivals=arrivals,
                         seed=11, backend="numpy", controller=_adm_cfg(mode))


def _adm_metrics(wins, K: int) -> dict:
    dev_good = np.zeros(K)
    dev_off = np.zeros(K)
    served = violations = satisfied = 0
    for fw in wins:
        window_lats = []
        for d, wr in enumerate(fw.devices):
            dev_off[d] += wr.offered_requests
            if wr.report is None:
                continue
            lats = np.asarray(wr.report.latencies, np.float64)
            window_lats.append(lats)
            served += lats.size
            violations += int(np.count_nonzero(lats > LATENCY))
            dev_good[d] += int(np.count_nonzero(lats <= LATENCY))
        if window_lats:
            pooled = np.concatenate(window_lats)
            if pooled.size and \
                    float(np.mean(pooled > LATENCY)) <= SATISFIED_VIOL:
                satisfied += 1
    return {
        "windows": len(wins),
        "offered": int(dev_off.sum()),
        "served": served,
        "shed": int(sum(w.shed_requests for w in wins)),
        "deferred": int(sum(w.deferred_requests for w in wins)),
        "migrated": int(sum(w.migrated_requests for w in wins)),
        "viol_pct": 100.0 * violations / served if served else 0.0,
        "satisfied_frac": satisfied / len(wins) if wins else 0.0,
        "goodput_frac": float(dev_good.sum() / max(dev_off.sum(), 1)),
        "worst_device_goodput": float(np.min(np.where(
            dev_off > 0, dev_good / np.maximum(dev_off, 1), 1.0))),
    }


def _windows(full: bool) -> list[float]:
    # per-device offered rates around the planner's sweet spot with one
    # overload window (backlog carryover + feedback get exercised)
    base = [0.9, 1.4, 0.7, 1.1] if full else [0.9, 1.4]
    return [RATE_PER_DEVICE * m for m in base]


def _serve(fn, K: int, rates, backend: str, fused: bool = False):
    spec = F.FleetSpec(K, seed=3, dispatch="least-backlog")
    kw = dict(window_duration=WINDOW_S, arrivals="poisson", seed=11,
              backend=backend, controller=CFG)
    if fused:
        kw["fused"] = True
    t0 = time.perf_counter()
    wins = fn(INFER_WORKLOADS["mobilenet"], POWER, LATENCY,
              [r * K for r in rates], spec, **kw)
    return wins, time.perf_counter() - t0


def parity_diff(a, b) -> float:
    """Max |diff| across every per-device executed latency array, plus the
    plan/shape fields that must agree exactly; ``inf`` on any structural
    mismatch (a device solved in one run but not the other, different plan,
    different dispatch)."""
    worst = 0.0
    for wa, wb in zip(a, b):
        if not np.array_equal(wa.dispatch_counts, wb.dispatch_counts):
            return float("inf")
        for da, db in zip(wa.devices, wb.devices):
            if (da.solution is None) != (db.solution is None):
                return float("inf")
            if da.solution is None:
                continue
            if (da.solution.pm, da.solution.bs) \
                    != (db.solution.pm, db.solution.bs):
                return float("inf")
            la = np.asarray(da.report.latencies, np.float64)
            lb = np.asarray(db.report.latencies, np.float64)
            if la.shape != lb.shape:
                return float("inf")
            if la.size:
                worst = max(worst, float(np.max(np.abs(la - lb))))
    return worst


def run(full: bool = False, quick: bool = False,
        do_check: bool = False) -> list[str]:
    ks = [8, 64, 512] if full else [8, 64]
    rates = _windows(full)
    path = SNAPSHOT if full \
        else SNAPSHOT.with_name("BENCH_fleet_partial.json")
    rows, records = [], {}
    configs_total = 0
    # warm the memoized grids/caches outside the timed region so the first
    # K doesn't absorb one-time materialization cost
    _serve(F.serve_fleet, 2, rates[:1], "numpy")
    _serve(F.serve_fleet_sequential, 2, rates[:1], "numpy")
    for K in ks:
        configs = K * len(rates)           # device-window planning decisions
        configs_total += configs
        batched, t_b = _serve(F.serve_fleet, K, rates, "numpy")
        seq, t_s = _serve(F.serve_fleet_sequential, K, rates, "numpy")
        diff = parity_diff(batched, seq)
        rec = {
            "batched_s": t_b, "sequential_s": t_s,
            "speedup": t_s / t_b,
            "configs": configs,
            "configs_per_s_batched": configs / t_b,
            "configs_per_s_sequential": configs / t_s,
            "parity_max_abs_diff": diff,
            "goodput_frac": float(np.mean([w.goodput for w in batched])),
            "offered_requests": int(sum(w.offered_requests
                                        for w in batched)),
            "attributed_power_w": float(np.mean(
                [w.attributed_power for w in batched])),
        }
        records[f"fleet/numpy/k{K}"] = rec
        rows.append(row(
            f"fleet/numpy/k{K}/speedup", rec["speedup"],
            f"batched={t_b:.3f}s;sequential={t_s:.3f}s;"
            f"parity={diff:g};goodput={rec['goodput_frac']:.3f}"))
        if jax_available():
            # the per-rung row keeps the PR-9 methodology — no warmup, so
            # its configs/s stays comparable with the recorded baseline
            # (each new K bucket recompiles the rung ladder; that per-shape
            # compile churn is part of the per-rung path's cost model)
            d0 = dispatch_count()
            batched_j, t_j = _serve(F.serve_fleet, K, rates, "jax")
            d1 = dispatch_count()
            jdiff = parity_diff(batched_j, seq)
            records[f"fleet/jax/k{K}"] = {
                "batched_s": t_j, "configs": configs,
                "configs_per_s_batched": configs / t_j,
                "parity_max_abs_diff": jdiff,
                "host_dispatches_per_window": (d1 - d0) / len(rates),
            }
            rows.append(row(
                f"fleet/jax/k{K}/parity_max_abs_diff", jdiff,
                f"batched={t_j:.3f}s;vs=sequential-numpy;"
                f"dispatches={(d1 - d0) / len(rates):.1f}/win"))
            # the fused row is warmed over the full rate schedule — every
            # pow2 (K, event) bucket the timed run will hit — because its
            # contract is steady state: ONE launch per window, compile paid
            # once per shape bucket for the whole serving lifetime
            _serve(F.serve_fleet, K, rates, "jax", fused=True)
            d2 = dispatch_count()
            fused_w, t_f = _serve(F.serve_fleet, K, rates, "jax",
                                  fused=True)
            d3 = dispatch_count()
            fdiff = parity_diff(fused_w, seq)
            records[f"fleet/fused/k{K}"] = {
                "fused_s": t_f, "configs": configs,
                "configs_per_s_fused": configs / t_f,
                "parity_max_abs_diff": fdiff,
                "speedup_vs_jax": t_j / t_f,
                "host_dispatches_per_window": (d3 - d2) / len(rates),
            }
            rows.append(row(
                f"fleet/fused/k{K}/speedup_vs_jax", t_j / t_f,
                f"fused={t_f:.3f}s;jax={t_j:.3f}s;parity={fdiff:g};"
                f"dispatches={(d3 - d2) / len(rates):.1f}/win"))
    # admission/* — fleet-wide resource control under overload; the rate
    # pattern is always the 4-window burst/drain (migration only pays off
    # once a drain window follows the burst), quick just restricts K
    for K in ([8, 64] if full else [8]):
        for arr in ("uniform", "poisson"):
            for mode in ("shed", "defer"):
                for mig in (0, 1):
                    wins = _adm_serve(K, arr, mode, bool(mig))
                    rec = _adm_metrics(wins, K)
                    records[f"admission/{arr}/{mode}/k{K}/mig{mig}"] = rec
                    rows.append(row(
                        f"admission/{arr}/{mode}/k{K}/mig{mig}"
                        f"/satisfied_frac", rec["satisfied_frac"],
                        f"worst={rec['worst_device_goodput']:.3f};"
                        f"goodput={rec['goodput_frac']:.3f};"
                        f"shed={rec['shed']};deferred={rec['deferred']};"
                        f"migrated={rec['migrated']}"))
    snapshot(path, records, configs=configs_total)
    if do_check:
        fails = check(records)
        for fl in fails:
            print(f"CHECK FAIL: {fl}")
        if fails:
            raise SystemExit(1)
        print("check passed: batched >= sequential configs/s at K=64, "
              "numpy parity bitwise, jax/fused parity within tolerance, "
              "fused >= 3x per-rung jax at K=64 with <= 2 host dispatches "
              "per window, poisson shed satisfied_frac >= 0.90, migration "
              "improves worst-device goodput on the drain scenario")
    return rows


def check(records: dict) -> list[str]:
    """CI acceptance gates (issues 8 + 9): the batched fleet step must beat
    the sequential loop on planning throughput at K=64, the NumPy parity
    must be *bitwise* (max |diff| exactly 0.0 — the correctness contract),
    every recorded jax row must sit within engine tolerance of the
    sequential NumPy reference, every poisson-shed admission cell must hold
    satisfied_frac >= 0.90 under the flood, and backlog migration must
    improve worst-device goodput on the K=8 uniform-shed drain scenario.
    Returns failure strings (empty == pass)."""
    fails = []
    k64 = records.get("fleet/numpy/k64")
    if k64 is None:
        fails.append("missing fleet/numpy/k64")
    elif k64["configs_per_s_batched"] < k64["configs_per_s_sequential"]:
        fails.append(
            f"fleet/numpy/k64: batched {k64['configs_per_s_batched']:.1f} "
            f"configs/s < sequential "
            f"{k64['configs_per_s_sequential']:.1f} configs/s")
    for key, rec in records.items():
        if not key.startswith("fleet/"):
            continue
        diff = rec.get("parity_max_abs_diff")
        if diff is None:
            fails.append(f"{key}: parity not recorded")
        elif key.startswith("fleet/numpy/") and diff != 0.0:
            fails.append(f"{key}: numpy parity must be bitwise, "
                         f"max_abs_diff={diff!r}")
        elif (key.startswith(("fleet/jax/", "fleet/fused/"))
              and not diff <= JAX_TOL):
            fails.append(f"{key}: jax parity {diff!r} > {JAX_TOL}")
    # fused gates: the fused window must make the jax tier worth running —
    # >= 3x the per-rung jax path on planning throughput at K=64, with the
    # launch count it promises (1 per window; <= 2 leaves slack for a
    # stray cache upload)
    jk, fk = records.get("fleet/jax/k64"), records.get("fleet/fused/k64")
    if jk is not None:
        if fk is None:
            fails.append("missing fleet/fused/k64")
        else:
            if fk["configs_per_s_fused"] \
                    < 3.0 * jk["configs_per_s_batched"]:
                fails.append(
                    f"fleet/fused/k64: {fk['configs_per_s_fused']:.1f} "
                    f"configs/s < 3x per-rung jax "
                    f"{jk['configs_per_s_batched']:.1f}")
            if fk["host_dispatches_per_window"] > 2.0:
                fails.append(
                    f"fleet/fused/k64: {fk['host_dispatches_per_window']} "
                    f"host dispatches per window > 2")
    # admission gates (issue 9): under the poisson flood, shed admission
    # must trim every window down to the SLO — satisfied_frac >= 0.90
    found_poisson_shed = False
    for key, rec in records.items():
        if key.startswith("admission/poisson/shed/"):
            found_poisson_shed = True
            if rec["satisfied_frac"] < 0.90:
                fails.append(f"{key}: poisson shed satisfied_frac "
                             f"{rec['satisfied_frac']:.3f} < 0.90")
    if not found_poisson_shed:
        fails.append("missing admission/poisson/shed/* records")
    # migration gate: on the drain scenario, moving carried backlog to
    # less-loaded devices must improve worst-device goodput
    off = records.get(f"{MIGRATION_GATE_KEY}/mig0")
    on = records.get(f"{MIGRATION_GATE_KEY}/mig1")
    if off is None or on is None:
        fails.append(f"missing {MIGRATION_GATE_KEY}/mig0 or /mig1")
    elif not on["worst_device_goodput"] > off["worst_device_goodput"]:
        fails.append(
            f"{MIGRATION_GATE_KEY}: migration did not improve worst-device "
            f"goodput ({off['worst_device_goodput']:.4f} -> "
            f"{on['worst_device_goodput']:.4f})")
    return fails


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="K in {8, 64, 512}, 4 rate windows, admission "
                         "matrix at K in {8, 64} (snapshots "
                         "BENCH_fleet.json)")
    ap.add_argument("--quick", action="store_true",
                    help="K in {8, 64}, 2 rate windows, admission matrix "
                         "at K=8 (CI-sized; side snapshot)")
    ap.add_argument("--check", action="store_true",
                    help="assert the fleet acceptance gates (batched >= "
                         "sequential at K=64, bitwise numpy parity, jax "
                         "parity within tolerance, poisson shed "
                         "satisfied_frac >= 0.90, migration improves "
                         "worst-device goodput)")
    args = ap.parse_args()
    for r in run(full=args.full, quick=args.quick, do_check=args.check):
        print(r)
