"""Fleet tier (``Scenario.FLEET``): K heterogeneous devices stepped as ONE
batched program per window vs the same fleet served as K sequential
single-device closed loops.

For each fleet size K the same aggregate trace is dispatched, planned and
executed twice — ``fleet.serve_fleet`` (one batched grid solve per ladder
rung, one ``simulate_batch`` with per-lane devices per window) and
``fleet.serve_fleet_sequential`` (the existing scalar loop per device) —
and the wall clock, device-window planning throughput (configs/s), batched
speedup, and the parity between the two are snapshotted to
``benchmarks/results/BENCH_fleet.json``. Parity is the PR's contract: the
NumPy rows must agree *bitwise* (max |diff| exactly 0.0) and the jax rows
within engine tolerance; ``--check`` gates batched >= sequential configs/s
at K=64 and the parity bounds on every recorded backend."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import fleet as F
from repro.core.backend import jax_available
from repro.core.controller import ControllerConfig
from repro.core.device_model import INFER_WORKLOADS

from benchmarks.common import row, snapshot

POWER, LATENCY = 30.0, 0.1
WINDOW_S = 5.0
RATE_PER_DEVICE = 30.0        # aggregate rate scales with the fleet
SNAPSHOT = Path(__file__).parent / "results" / "BENCH_fleet.json"
JAX_TOL = 1e-6                # engine parity bound (atol 1e-8 per lane,
                              # loose headroom for reduction ordering)

CFG = ControllerConfig(rate_estimator="ewma", rate_margin=1.5,
                       feedback=True, carry_backlog=True,
                       mode_switch_s=0.25)


def _windows(full: bool) -> list[float]:
    # per-device offered rates around the planner's sweet spot with one
    # overload window (backlog carryover + feedback get exercised)
    base = [0.9, 1.4, 0.7, 1.1] if full else [0.9, 1.4]
    return [RATE_PER_DEVICE * m for m in base]


def _serve(fn, K: int, rates, backend: str):
    spec = F.FleetSpec(K, seed=3, dispatch="least-backlog")
    t0 = time.perf_counter()
    wins = fn(INFER_WORKLOADS["mobilenet"], POWER, LATENCY,
              [r * K for r in rates], spec, window_duration=WINDOW_S,
              arrivals="poisson", seed=11, backend=backend,
              controller=CFG)
    return wins, time.perf_counter() - t0


def parity_diff(a, b) -> float:
    """Max |diff| across every per-device executed latency array, plus the
    plan/shape fields that must agree exactly; ``inf`` on any structural
    mismatch (a device solved in one run but not the other, different plan,
    different dispatch)."""
    worst = 0.0
    for wa, wb in zip(a, b):
        if not np.array_equal(wa.dispatch_counts, wb.dispatch_counts):
            return float("inf")
        for da, db in zip(wa.devices, wb.devices):
            if (da.solution is None) != (db.solution is None):
                return float("inf")
            if da.solution is None:
                continue
            if (da.solution.pm, da.solution.bs) \
                    != (db.solution.pm, db.solution.bs):
                return float("inf")
            la = np.asarray(da.report.latencies, np.float64)
            lb = np.asarray(db.report.latencies, np.float64)
            if la.shape != lb.shape:
                return float("inf")
            if la.size:
                worst = max(worst, float(np.max(np.abs(la - lb))))
    return worst


def run(full: bool = False, quick: bool = False,
        do_check: bool = False) -> list[str]:
    ks = [8, 64, 512] if full else [8, 64]
    rates = _windows(full)
    path = SNAPSHOT if full \
        else SNAPSHOT.with_name("BENCH_fleet_partial.json")
    rows, records = [], {}
    configs_total = 0
    # warm the memoized grids/caches outside the timed region so the first
    # K doesn't absorb one-time materialization cost
    _serve(F.serve_fleet, 2, rates[:1], "numpy")
    _serve(F.serve_fleet_sequential, 2, rates[:1], "numpy")
    for K in ks:
        configs = K * len(rates)           # device-window planning decisions
        configs_total += configs
        batched, t_b = _serve(F.serve_fleet, K, rates, "numpy")
        seq, t_s = _serve(F.serve_fleet_sequential, K, rates, "numpy")
        diff = parity_diff(batched, seq)
        rec = {
            "batched_s": t_b, "sequential_s": t_s,
            "speedup": t_s / t_b,
            "configs": configs,
            "configs_per_s_batched": configs / t_b,
            "configs_per_s_sequential": configs / t_s,
            "parity_max_abs_diff": diff,
            "goodput_frac": float(np.mean([w.goodput for w in batched])),
            "offered_requests": int(sum(w.offered_requests
                                        for w in batched)),
            "attributed_power_w": float(np.mean(
                [w.attributed_power for w in batched])),
        }
        records[f"fleet/numpy/k{K}"] = rec
        rows.append(row(
            f"fleet/numpy/k{K}/speedup", rec["speedup"],
            f"batched={t_b:.3f}s;sequential={t_s:.3f}s;"
            f"parity={diff:g};goodput={rec['goodput_frac']:.3f}"))
        if jax_available():
            batched_j, t_j = _serve(F.serve_fleet, K, rates, "jax")
            jdiff = parity_diff(batched_j, seq)
            records[f"fleet/jax/k{K}"] = {
                "batched_s": t_j, "configs": configs,
                "configs_per_s_batched": configs / t_j,
                "parity_max_abs_diff": jdiff,
            }
            rows.append(row(
                f"fleet/jax/k{K}/parity_max_abs_diff", jdiff,
                f"batched={t_j:.3f}s;vs=sequential-numpy"))
    snapshot(path, records, configs=configs_total)
    if do_check:
        fails = check(records)
        for fl in fails:
            print(f"CHECK FAIL: {fl}")
        if fails:
            raise SystemExit(1)
        print("check passed: batched >= sequential configs/s at K=64, "
              "numpy parity bitwise, jax parity within tolerance")
    return rows


def check(records: dict) -> list[str]:
    """CI acceptance gates (issue 8): the batched fleet step must beat the
    sequential loop on planning throughput at K=64, the NumPy parity must
    be *bitwise* (max |diff| exactly 0.0 — the correctness contract), and
    every recorded jax row must sit within engine tolerance of the
    sequential NumPy reference. Returns failure strings (empty == pass)."""
    fails = []
    k64 = records.get("fleet/numpy/k64")
    if k64 is None:
        fails.append("missing fleet/numpy/k64")
    elif k64["configs_per_s_batched"] < k64["configs_per_s_sequential"]:
        fails.append(
            f"fleet/numpy/k64: batched {k64['configs_per_s_batched']:.1f} "
            f"configs/s < sequential "
            f"{k64['configs_per_s_sequential']:.1f} configs/s")
    for key, rec in records.items():
        if not key.startswith("fleet/"):
            continue
        diff = rec.get("parity_max_abs_diff")
        if diff is None:
            fails.append(f"{key}: parity not recorded")
        elif key.startswith("fleet/numpy/") and diff != 0.0:
            fails.append(f"{key}: numpy parity must be bitwise, "
                         f"max_abs_diff={diff!r}")
        elif key.startswith("fleet/jax/") and not diff <= JAX_TOL:
            fails.append(f"{key}: jax parity {diff!r} > {JAX_TOL}")
    return fails


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="K in {8, 64, 512}, 4 rate windows (snapshots "
                         "BENCH_fleet.json)")
    ap.add_argument("--quick", action="store_true",
                    help="K in {8, 64}, 2 rate windows (CI-sized; side "
                         "snapshot)")
    ap.add_argument("--check", action="store_true",
                    help="assert the fleet acceptance gates (batched >= "
                         "sequential at K=64, bitwise numpy parity, jax "
                         "parity within tolerance)")
    args = ap.parse_args()
    for r in run(full=args.full, quick=args.quick, do_check=args.check):
        print(r)
