"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import statistics
from pathlib import Path
from typing import Iterable, Optional

from repro.core import problem as P
from repro.core.device_model import DeviceModel, Profiler
from repro.core.gmd import ConcurrentProfiler
from repro.core.oracle import Oracle
from repro.core.powermode import PowerModeSpace

DEV = DeviceModel()
SPACE = PowerModeSpace()
ORACLE = Oracle(DEV, SPACE)

# Backend for the batched grid reductions (oracle sweeps): "numpy" (default,
# bitwise-identical reference) or "jax" (jit+vmap, runs on-accelerator).
BACKEND = os.environ.get("FULCRUM_SOLVER_BACKEND", "numpy")


def median(xs: Iterable[float]) -> float:
    xs = list(xs)
    return statistics.median(xs) if xs else float("nan")


def excess_pct(val: float, opt: float) -> float:
    return 100.0 * (val - opt) / max(opt, 1e-12)


def loss_pct(opt: float, val: float) -> float:
    return 100.0 * (opt - val) / max(opt, 1e-12)


def row(name: str, value, derived: str = "") -> str:
    if isinstance(value, float):
        value = f"{value:.4g}"
    return f"{name},{value},{derived}"


def snapshot(path: Path, results: dict, configs: Optional[int] = None) -> None:
    """Write a ``benchmarks/results/BENCH_*.json`` snapshot. Every bench row
    (top-level dict record) carries a ``configs`` count — the number of
    problem/simulation configurations behind it — so the files are
    self-describing across PRs. Records that already state a count under
    another key (``problems``, ``configs``) keep it; ``configs`` is added."""
    if configs is not None:
        results.setdefault("configs", configs)
    records = list(results.values())
    if isinstance(results.get("rows"), list):
        records += results["rows"]
    for rec in records:
        if isinstance(rec, dict) and "configs" not in rec:
            for key in ("problems", "n_configs", "n"):
                if key in rec:
                    rec["configs"] = rec[key]
                    break
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(results, indent=1))


def gmd_executed_row(fulcrum, solvable_pairs, plans, w_serve, w_fill,
                     prefix: str, tput_label: str) -> Optional[str]:
    """Engine end-to-end check shared by the concurrent benches: execute the
    median solvable problem's GMD plan with the trace-driven engine; the
    realized latencies must respect the budget the plan was solved for."""
    executed = [(prob, pl) for (prob, _), pl in zip(solvable_pairs, plans)
                if pl is not None]
    if not executed:
        return None
    prob, plan = executed[len(executed) // 2]
    rep = fulcrum.execute(plan, w_serve, w_fill,
                          arrival_rate=prob.arrival_rate, duration=30.0)
    return row(f"{prefix}/executed_q3_ms", rep.latency_quantile(0.75) * 1e3,
               f"viol_pct={100*rep.violation_rate(prob.latency_budget):.1f};"
               f"{tput_label}={rep.train_throughput:.2f}mb_s")


def train_problem_grid(full: bool, bert: bool = False):
    """Paper §7.1: power 10-50 W step 1 (10-60 for BERT)."""
    hi = 61 if bert else 51
    step = 1 if full else 2
    return [P.TrainProblem(float(b)) for b in range(10, hi, step)]


def infer_problem_grid(full: bool, bert: bool = False):
    """Paper §7.2: power 10-50 step 1; latency 50-1000 ms step 10;
    rate 30-90 step 5. BERT: latency 1-10 s step 200 ms, rate 1-5."""
    if bert:
        pows = range(10, 61, 1 if full else 5)
        lats = [1 + 0.2 * i for i in range(46)] if full else [1, 2, 4, 6, 8, 10]
        rates = [1, 2, 3, 4, 5]
    else:
        pows = range(10, 51, 1 if full else 5)
        lats = ([0.05 + 0.01 * i for i in range(96)] if full
                else [0.05, 0.1, 0.2, 0.4, 0.7, 1.0])
        rates = range(30, 91, 5 if full else 20)
    return [P.InferProblem(float(p), float(l), float(r))
            for p in pows for l in lats for r in rates]


def concurrent_problem_grid(full: bool, bert: bool = False):
    """Paper §7.3: rate 30-120, latency 0.5-2 s step 100 ms (BERT: 2-6 s,
    rate 1-15), power as in training."""
    if bert:
        pows = range(10, 61, 1 if full else 5)
        lats = [2 + 0.4 * i for i in range(11)] if full else [2, 3, 4, 6]
        rates = [1, 5, 10, 15]
    else:
        pows = range(10, 51, 1 if full else 5)
        lats = ([0.5 + 0.1 * i for i in range(16)] if full
                else [0.5, 1.0, 1.5, 2.0])
        rates = range(30, 121, 10 if full else 30)
    return [P.ConcurrentProblem(float(p), float(l), float(r))
            for p in pows for l in lats for r in rates]
