"""Paper Fig. 10: standalone inference — excess-over-optimal latency, %
problems solved, budget violations, per strategy.

The (power x latency x arrival-rate) sweep is solved in one batched
reduction per strategy (core.grid_eval); only GMD profiles per problem."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.als import ALSInfer, QuadrantRanges
from repro.core.baselines import NNInferBaseline, RNDInfer
from repro.core.device_model import INFER_WORKLOADS, Profiler
from repro.core.gmd import GMDInfer

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, excess_pct, \
    median, row, infer_problem_grid

NN_EPOCHS = 300


def _quadrants(bert: bool) -> QuadrantRanges:
    if bert:
        return QuadrantRanges(latency=(1.0, 10.0), arrival=(1.0, 5.0))
    return QuadrantRanges(latency=(0.05, 1.0), arrival=(30.0, 90.0))


def run(full: bool = False, dnns=None) -> list[str]:
    rows = []
    for name in (dnns or INFER_WORKLOADS):
        w = INFER_WORKLOADS[name]
        bert = name == "bert"
        probs = infer_problem_grid(full, bert=bert)
        opts = ORACLE.solve_infer_batch(w, probs, backend=BACKEND)
        solvable_pairs = [(prob, opt) for prob, opt in zip(probs, opts)
                          if opt is not None]
        solvable = len(solvable_pairs)
        fitted = {
            "als145": ALSInfer(Profiler(DEV, w), _quadrants(bert), SPACE,
                               nn_epochs=NN_EPOCHS),
            "rnd150": RNDInfer(Profiler(DEV, w), 150, SPACE),
            "rnd250": RNDInfer(Profiler(DEV, w), 250, SPACE),
            "nn250": NNInferBaseline(Profiler(DEV, w), 250, SPACE,
                                     nn_epochs=NN_EPOCHS),
        }
        strategies = {"gmd11": None, **fitted}
        for sname, strat in strategies.items():
            exc, viols, solved = [], 0, 0
            if sname == "gmd11":
                sols = [GMDInfer(Profiler(DEV, w), SPACE).solve(prob)
                        for prob, _ in solvable_pairs]
            else:
                sols = strat.solve_batch([prob for prob, _ in solvable_pairs])
            for (prob, opt), sol in zip(solvable_pairs, sols):
                if sol is None:
                    continue
                t_true, p_true = ORACLE.true_infer(w, sol.pm, sol.bs)
                lam_true = P.peak_latency(sol.bs, prob.arrival_rate, t_true)
                if (p_true > prob.power_budget + 1e-9
                        or lam_true > prob.latency_budget + 1e-9
                        or not P.sustainable(sol.bs, prob.arrival_rate, t_true)):
                    viols += 1       # NN's prediction errors surface here
                    continue
                solved += 1
                exc.append(excess_pct(lam_true, opt.time))
            pct = 100.0 * solved / max(solvable, 1)
            rows.append(row(f"infer/{name}/{sname}/median_excess_latency_pct",
                            median(exc),
                            f"solved_pct={pct:.1f};violations={viols};"
                            f"solvable={solvable}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
