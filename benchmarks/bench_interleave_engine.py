"""Execution-engine microbenchmark: the vectorized trace-driven simulators
(core.simulate) vs the seed's scalar per-request loops, over the *full*
Fig. 2 interleaving sweep (10 GMD-planned configs x 3 approaches at 120 s),
plus the NumPy-vs-jax *engine backend* comparison: the same managed sweep
run lane-by-lane on NumPy vs as one batched max-plus-scan program on jax.

The managed outputs of both paths are asserted identical before timing (the
engine's exactness contract); the jax engine is cross-checked against NumPy
within the documented tolerance (atol=1e-8 s, rtol=1e-9 — see
``docs/exactness.md``). Speedups are printed as CSV rows and snapshotted to
``benchmarks/results/BENCH_interleave.json`` so they are tracked across PRs,
mirroring bench_solver's BENCH_solver.json.

The ``lane_scaling`` section sweeps the lane axis — 10 to 100k concurrent
managed lanes sharing one short trace — through ``simulate_batch`` on every
engine backend (numpy / jax / pallas), recording configs/s per backend so
the NumPy-vs-accelerator crossover is a measured curve, not folklore.
``--quick`` caps the sweep at 1k lanes and snapshots to
``BENCH_interleave_partial.json`` (the committed full snapshot stays
canonical); ``--check`` gates the result: jax must beat NumPy at 1k lanes
and every pre-existing snapshot key must still be present."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import simulate as S
from repro.core.backend import jax_available, pallas_available

from benchmarks.bench_interleaving import solve_configs
from benchmarks.common import DEV, row, snapshot

SNAPSHOT = Path(__file__).parent / "results" / "BENCH_interleave.json"
QUICK_SNAPSHOT = SNAPSHOT.with_name("BENCH_interleave_partial.json")

LANE_COUNTS = (10, 100, 1000, 10000, 100000)
QUICK_LANE_COUNTS = (10, 100, 1000)
# the lane-count at which the --check gate requires jax >= NumPy configs/s
GATE_LANES = 1000

SCALAR = {"managed": S.managed_scalar,
          "native": lambda *a: S.native_scalar(*a, seed=0),
          "streams": lambda *a: S.streams_scalar(*a, seed=0)}
VECTOR = {"managed": lambda *a: S.simulate(*a, approach="managed"),
          "native": lambda *a: S.simulate(*a, approach="native", seed=0),
          "streams": lambda *a: S.simulate(*a, approach="streams", seed=0)}


def _time(sims, repeats: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        for fn, args in sims:
            fn(*args)
    return (time.perf_counter() - t0) / repeats


def _lane_scaling(w_tr, w_in, solved, lane_counts) -> dict:
    """Sweep the lane axis through simulate_batch on every engine backend.

    All lanes share ONE short trace object (~128 arrivals) so the 100k-lane
    point measures engine throughput, not trace-generation memory; (pm, bs)
    cycle through the GMD-planned configs so event shapes stay realistic."""
    trace = S.ArrivalTrace.poisson(32.0, 4.0, seed=7)
    pms = [p.pm for _, p, _ in solved]
    bss = [p.bs for _, p, _ in solved]
    backends = ["numpy"]
    if jax_available():
        backends.append("jax")
    if pallas_available():
        backends.append("pallas")
    rows = []
    for lanes in lane_counts:
        pml = [pms[i % len(pms)] for i in range(lanes)]
        bsl = [bss[i % len(bss)] for i in range(lanes)]
        traces = [trace] * lanes
        args = (DEV, w_tr, w_in, pml, bsl, traces)
        rec = {"lanes": lanes, "configs": lanes}
        for bk in backends:
            S.simulate_batch(*args, backend=bk)          # warm jit / caches
            t0 = time.perf_counter()
            S.simulate_batch(*args, backend=bk)
            rec[f"{bk}_configs_per_s"] = lanes / (time.perf_counter() - t0)
        rows.append(rec)
    return {"trace_arrivals": len(trace), "backends": backends,
            "lane_counts": list(lane_counts), "rows": rows}


# top-level snapshot keys every run must produce — the --check gate's
# byte-identity floor for pre-existing BENCH structure
_REQUIRED_KEYS = ("configs", "duration_s", "requests_total", "approaches",
                  "scalar_s", "vector_s", "speedup")
_APPROACH_KEYS = ("configs", "scalar_s", "vector_s", "speedup")


def check(results: dict) -> None:
    """--check gate: pre-existing snapshot structure intact, and the jax
    engine at least matches NumPy throughput at the 1k-lane point."""
    for key in _REQUIRED_KEYS:
        assert key in results, f"missing snapshot key {key!r}"
    for name in ("managed", "native", "streams"):
        app = results["approaches"][name]
        for key in _APPROACH_KEYS:
            assert key in app, f"missing approaches.{name}.{key}"
    if jax_available():
        for key in ("configs", "numpy_s", "jax_s", "speedup",
                    "max_abs_latency_diff"):
            assert key in results["engine_backends"], \
                f"missing engine_backends.{key}"
        gate = [r for r in results["lane_scaling"]["rows"]
                if r["lanes"] == GATE_LANES]
        assert gate, f"lane_scaling has no {GATE_LANES}-lane row"
        np_cps = gate[0]["numpy_configs_per_s"]
        jax_cps = gate[0]["jax_configs_per_s"]
        assert jax_cps >= np_cps, (
            f"jax engine lost to NumPy at {GATE_LANES} lanes: "
            f"{jax_cps:.0f} vs {np_cps:.0f} configs/s")


def run(full: bool = False, quick: bool = False) -> list[str]:
    # always measure the full Fig. 2 sweep: the point is paper-scale traces
    w_tr, w_in, configs = solve_configs(duration=120.0)
    solved = [(prob, plan, trace) for _, prob, plan, trace in configs
              if plan is not None]

    # exactness gate: vectorized managed == scalar reference on every config
    for prob, plan, trace in solved:
        a = S.simulate(DEV, w_tr, w_in, plan.pm, plan.bs, trace, "managed")
        b = S.managed_scalar(DEV, w_tr, w_in, plan.pm, plan.bs, trace)
        assert a.latencies.tolist() == b.latencies, "managed engine diverged"
        assert a.train_minibatches == b.train_minibatches
        assert a.power == b.power

    repeats = 3 if full else 1
    results: dict = {"configs": len(solved), "duration_s": 120.0,
                     "requests_total": sum(len(t) for _, _, t in solved),
                     "approaches": {}}
    rows: list[str] = []
    total_scalar = total_vector = 0.0
    for name in ("managed", "native", "streams"):
        sims_s = [(SCALAR[name], (DEV, w_tr, w_in, p.pm, p.bs, t))
                  for _, p, t in solved]
        sims_v = [(VECTOR[name], (DEV, w_tr, w_in, p.pm, p.bs, t))
                  for _, p, t in solved]
        _time(sims_v, 1)                       # warm allocator / caches
        scalar_s = _time(sims_s, repeats)
        vector_s = _time(sims_v, repeats)
        total_scalar += scalar_s
        total_vector += vector_s
        speedup = scalar_s / vector_s
        results["approaches"][name] = {
            "configs": len(solved), "scalar_s": scalar_s,
            "vector_s": vector_s, "speedup": speedup}
        rows.append(row(f"interleave_engine/{name}/speedup", speedup,
                        f"scalar={scalar_s*1e3:.1f}ms;"
                        f"vector={vector_s*1e3:.1f}ms;n={len(solved)}"))
    results["scalar_s"] = total_scalar
    results["vector_s"] = total_vector
    results["speedup"] = total_scalar / total_vector
    rows.append(row("interleave_engine/full_sweep/speedup",
                    results["speedup"],
                    f"requests={results['requests_total']};"
                    f"configs={len(solved)}x3"))

    # -- engine backends: NumPy lane loop vs one batched jax scan program ----
    if jax_available():
        pms = [p.pm for _, p, _ in solved]
        bss = [p.bs for _, p, _ in solved]
        traces = [t for _, _, t in solved]
        args = (DEV, w_tr, w_in, pms, bss, traces)
        ref = S.simulate_batch(*args, backend="numpy")
        got = S.simulate_batch(*args, backend="jax")   # also warms the jit
        for a, b in zip(ref, got):
            np.testing.assert_allclose(b.latencies, a.latencies,
                                       rtol=1e-9, atol=1e-8,
                                       err_msg="jax engine out of tolerance")
            assert abs(a.train_minibatches - b.train_minibatches) <= 2
        numpy_s = _time([(lambda: S.simulate_batch(*args, backend="numpy"),
                          ())], repeats)
        jax_s = _time([(lambda: S.simulate_batch(*args, backend="jax"),
                        ())], repeats)
        results["engine_backends"] = {
            "configs": len(solved), "numpy_s": numpy_s, "jax_s": jax_s,
            "speedup": numpy_s / jax_s,
            "max_abs_latency_diff": max(
                float(np.abs(np.asarray(b.latencies)
                             - np.asarray(a.latencies)).max(initial=0.0))
                for a, b in zip(ref, got))}
        rows.append(row("interleave_engine/managed_batch/jax_vs_numpy",
                        numpy_s / jax_s,
                        f"numpy={numpy_s*1e3:.1f}ms;jax={jax_s*1e3:.1f}ms;"
                        f"n={len(solved)}"))

    # -- lane scaling: the NumPy-vs-jax-vs-Pallas crossover curve ------------
    lane_counts = QUICK_LANE_COUNTS if quick else LANE_COUNTS
    results["lane_scaling"] = _lane_scaling(w_tr, w_in, solved, lane_counts)
    for rec in results["lane_scaling"]["rows"]:
        parts = [f"{bk}={rec[f'{bk}_configs_per_s']:.0f}cfg_s"
                 for bk in results["lane_scaling"]["backends"]]
        rows.append(row(f"interleave_engine/lane_scaling/{rec['lanes']}",
                        rec.get("jax_configs_per_s",
                                rec["numpy_configs_per_s"]),
                        ";".join(parts)))

    snapshot(QUICK_SNAPSHOT if quick else SNAPSHOT, results,
             configs=len(solved) * 3)
    run.last_results = results          # for --check / tests
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="more timing repeats")
    ap.add_argument("--quick", action="store_true",
                    help="cap the lane sweep at 1k lanes; snapshot to "
                         "BENCH_interleave_partial.json")
    ap.add_argument("--check", action="store_true",
                    help="assert snapshot structure + jax>=NumPy at 1k lanes")
    cli = ap.parse_args()
    for r in run(full=cli.full, quick=cli.quick):
        print(r)
    if cli.check:
        check(run.last_results)
        print("interleave_engine/check,1,"
              f"jax_ge_numpy_at_{GATE_LANES}_lanes;keys_ok")
