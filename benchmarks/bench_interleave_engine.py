"""Execution-engine microbenchmark: the vectorized trace-driven simulators
(core.simulate) vs the seed's scalar per-request loops, over the *full*
Fig. 2 interleaving sweep (10 GMD-planned configs x 3 approaches at 120 s),
plus the NumPy-vs-jax *engine backend* comparison: the same managed sweep
run lane-by-lane on NumPy vs as one batched max-plus-scan program on jax.

The managed outputs of both paths are asserted identical before timing (the
engine's exactness contract); the jax engine is cross-checked against NumPy
within the documented tolerance (atol=1e-8 s, rtol=1e-9 — see
``docs/exactness.md``). Speedups are printed as CSV rows and snapshotted to
``benchmarks/results/BENCH_interleave.json`` so they are tracked across PRs,
mirroring bench_solver's BENCH_solver.json."""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import simulate as S
from repro.core.backend import jax_available

from benchmarks.bench_interleaving import solve_configs
from benchmarks.common import DEV, row, snapshot

SNAPSHOT = Path(__file__).parent / "results" / "BENCH_interleave.json"

SCALAR = {"managed": S.managed_scalar,
          "native": lambda *a: S.native_scalar(*a, seed=0),
          "streams": lambda *a: S.streams_scalar(*a, seed=0)}
VECTOR = {"managed": lambda *a: S.simulate(*a, approach="managed"),
          "native": lambda *a: S.simulate(*a, approach="native", seed=0),
          "streams": lambda *a: S.simulate(*a, approach="streams", seed=0)}


def _time(sims, repeats: int) -> float:
    t0 = time.perf_counter()
    for _ in range(repeats):
        for fn, args in sims:
            fn(*args)
    return (time.perf_counter() - t0) / repeats


def run(full: bool = False) -> list[str]:
    # always measure the full Fig. 2 sweep: the point is paper-scale traces
    w_tr, w_in, configs = solve_configs(duration=120.0)
    solved = [(prob, plan, trace) for _, prob, plan, trace in configs
              if plan is not None]

    # exactness gate: vectorized managed == scalar reference on every config
    for prob, plan, trace in solved:
        a = S.simulate(DEV, w_tr, w_in, plan.pm, plan.bs, trace, "managed")
        b = S.managed_scalar(DEV, w_tr, w_in, plan.pm, plan.bs, trace)
        assert a.latencies.tolist() == b.latencies, "managed engine diverged"
        assert a.train_minibatches == b.train_minibatches
        assert a.power == b.power

    repeats = 3 if full else 1
    results: dict = {"configs": len(solved), "duration_s": 120.0,
                     "requests_total": sum(len(t) for _, _, t in solved),
                     "approaches": {}}
    rows: list[str] = []
    total_scalar = total_vector = 0.0
    for name in ("managed", "native", "streams"):
        sims_s = [(SCALAR[name], (DEV, w_tr, w_in, p.pm, p.bs, t))
                  for _, p, t in solved]
        sims_v = [(VECTOR[name], (DEV, w_tr, w_in, p.pm, p.bs, t))
                  for _, p, t in solved]
        _time(sims_v, 1)                       # warm allocator / caches
        scalar_s = _time(sims_s, repeats)
        vector_s = _time(sims_v, repeats)
        total_scalar += scalar_s
        total_vector += vector_s
        speedup = scalar_s / vector_s
        results["approaches"][name] = {
            "configs": len(solved), "scalar_s": scalar_s,
            "vector_s": vector_s, "speedup": speedup}
        rows.append(row(f"interleave_engine/{name}/speedup", speedup,
                        f"scalar={scalar_s*1e3:.1f}ms;"
                        f"vector={vector_s*1e3:.1f}ms;n={len(solved)}"))
    results["scalar_s"] = total_scalar
    results["vector_s"] = total_vector
    results["speedup"] = total_scalar / total_vector
    rows.append(row("interleave_engine/full_sweep/speedup",
                    results["speedup"],
                    f"requests={results['requests_total']};"
                    f"configs={len(solved)}x3"))

    # -- engine backends: NumPy lane loop vs one batched jax scan program ----
    if jax_available():
        pms = [p.pm for _, p, _ in solved]
        bss = [p.bs for _, p, _ in solved]
        traces = [t for _, _, t in solved]
        args = (DEV, w_tr, w_in, pms, bss, traces)
        ref = S.simulate_batch(*args, backend="numpy")
        got = S.simulate_batch(*args, backend="jax")   # also warms the jit
        for a, b in zip(ref, got):
            np.testing.assert_allclose(b.latencies, a.latencies,
                                       rtol=1e-9, atol=1e-8,
                                       err_msg="jax engine out of tolerance")
            assert abs(a.train_minibatches - b.train_minibatches) <= 2
        numpy_s = _time([(lambda: S.simulate_batch(*args, backend="numpy"),
                          ())], repeats)
        jax_s = _time([(lambda: S.simulate_batch(*args, backend="jax"),
                        ())], repeats)
        results["engine_backends"] = {
            "configs": len(solved), "numpy_s": numpy_s, "jax_s": jax_s,
            "speedup": numpy_s / jax_s,
            "max_abs_latency_diff": max(
                float(np.abs(np.asarray(b.latencies)
                             - np.asarray(a.latencies)).max(initial=0.0))
                for a, b in zip(ref, got))}
        rows.append(row("interleave_engine/managed_batch/jax_vs_numpy",
                        numpy_s / jax_s,
                        f"numpy={numpy_s*1e3:.1f}ms;jax={jax_s*1e3:.1f}ms;"
                        f"n={len(solved)}"))

    snapshot(SNAPSHOT, results, configs=len(solved) * 3)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
