"""Generate the §Roofline markdown table from dryrun.json and splice it into
EXPERIMENTS.md at the <!-- ROOFLINE_TABLE --> marker."""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.bench_roofline import roofline_terms

ROOT = Path(__file__).parent.parent
MARKER = "<!-- ROOFLINE_TABLE -->"


def fmt(v, scale=1.0, digits=3):
    if v is None:
        return "-"
    return f"{v * scale:.{digits}g}"


def build_table() -> str:
    # single-pod baselines: the pre-hillclimb archive (one consistent code
    # version for all 40 pairs); dryrun.json carries the final-code proof
    # sweep + hillclimb variant rows.
    data = json.loads(
        (ROOT / "benchmarks/results/dryrun_hillclimb.json").read_text())
    final = json.loads((ROOT / "benchmarks/results/dryrun.json").read_text())
    lines = [
        "## §Roofline — baseline table (40 pairs, single-pod, one code version)",
        "",
        "Per-device terms in seconds; `useful` = MODEL_FLOPS / HLO_FLOPs;",
        "HBM = production-compile args+temp per device (CPU allocator, see",
        "caveat 2). Variant rows (hillclimb artifacts) keep their tags.",
        "",
        "| pair | mesh | compute_s | memory_s | collective_s | dominant | useful | HBM GB | fits |",
        "|---|---|--:|--:|--:|---|--:|--:|---|",
    ]
    n_ok = n_fail = 0
    for key in sorted(data):
        r = data[key]
        parts = key.split("|")
        pair = f"{parts[0]}·{parts[1]}" + (f" [{parts[3]}]" if len(parts) > 3 else "")
        if not r.get("ok"):
            n_fail += 1
            lines.append(f"| {pair} | {parts[2]} | FAILED | | | | | | |")
            continue
        n_ok += 1
        t = roofline_terms(r)
        hbm = (t["hbm_args_gb"] or 0) + (t["hbm_temp_gb"] or 0)
        lines.append(
            f"| {pair} | {r['mesh']} | {fmt(t['compute_s'])} | {fmt(t['memory_s'])} "
            f"| {fmt(t['collective_s'])} | {t['dominant'].replace('_s','')} "
            f"| {fmt(t['useful_ratio'])} | {hbm:.1f} | "
            f"{'yes' if t['fits_hbm'] else 'NO'} |")
    lines.append("")
    lines.append(f"{n_ok} compiles OK, {n_fail} failed.")

    # final-code proof sweep summary
    ok_single = sum(1 for k, r in final.items()
                    if r.get("ok") and r.get("mesh") == "16x16"
                    and len(k.split("|")) == 3)
    ok_multi = sum(1 for k, r in final.items()
                   if r.get("ok") and r.get("mesh") == "2x16x16"
                   and len(k.split("|")) == 3)
    fails = [k for k, r in final.items() if not r.get("ok")]
    lines += ["", "### Final-code lowering proof (dryrun.json)",
              "",
              f"* single-pod 16x16: {ok_single} pairs compile OK",
              f"* multi-pod 2x16x16 (512 chips): {ok_multi} pairs compile OK",
              f"* failures: {fails if fails else 'none'}"]
    return "\n".join(lines)


def main() -> None:
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    head = exp.split(MARKER)[0]
    (ROOT / "EXPERIMENTS.md").write_text(head + MARKER + "\n\n" + build_table() + "\n")
    print("EXPERIMENTS.md roofline table updated")


if __name__ == "__main__":
    main()
