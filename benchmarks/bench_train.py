"""Paper Fig. 9: standalone training — excess-over-optimal minibatch time and
power-budget violations, per strategy, across the power-budget sweep.

Oracle optima and fitted-strategy answers are computed for the whole
power-budget sweep in one batched reduction (core.grid_eval); only GMD, which
profiles per problem, still runs problem-by-problem."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.als import ALSTrain
from repro.core.baselines import NNTrainBaseline, RNDTrain
from repro.core.device_model import Profiler, TRAIN_WORKLOADS
from repro.core.gmd import GMDTrain

from benchmarks.common import BACKEND, DEV, ORACLE, SPACE, excess_pct, \
    median, row, train_problem_grid

NN_EPOCHS = 300


def run(full: bool = False, dnns=None) -> list[str]:
    rows = []
    for name in (dnns or TRAIN_WORKLOADS):
        w = TRAIN_WORKLOADS[name]
        probs = train_problem_grid(full, bert=(name == "bert"))
        opts = ORACLE.solve_train_batch(w, probs, backend=BACKEND)
        solvable = [(prob, opt) for prob, opt in zip(probs, opts)
                    if opt is not None]
        fitted = {
            "als50": ALSTrain(Profiler(DEV, w), SPACE, nn_epochs=NN_EPOCHS),
            "rnd50": RNDTrain(Profiler(DEV, w), 50, SPACE),
            "rnd250": RNDTrain(Profiler(DEV, w), 250, SPACE),
            "nn250": NNTrainBaseline(Profiler(DEV, w), 250, SPACE,
                                     nn_epochs=NN_EPOCHS),
        }
        strategies = {"gmd10": None, **fitted}
        for sname, strat in strategies.items():
            exc, viols, solved, runs = [], 0, 0, []
            if sname == "gmd10":
                sols = []
                for prob, _ in solvable:
                    prof = Profiler(DEV, w)
                    sols.append(GMDTrain(prof, SPACE).solve(prob))
                    runs.append(prof.num_runs)
            else:
                sols = strat.solve_batch([prob for prob, _ in solvable])
            for (prob, opt), sol in zip(solvable, sols):
                if sol is None:
                    continue
                solved += 1
                t_true, p_true = ORACLE.true_train(w, sol.pm)  # ground truth
                if p_true > prob.power_budget + 1e-9:
                    viols += 1
                exc.append(excess_pct(t_true, opt.time))
            nruns = (max(runs) if runs else
                     strat.profiler.num_runs if strat else 0)
            rows.append(row(f"train/{name}/{sname}/median_excess_time_pct",
                            median(exc),
                            f"solved={solved};violations={viols};modes={nruns}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
