"""Paper Fig. 9: standalone training — excess-over-optimal minibatch time and
power-budget violations, per strategy, across the power-budget sweep."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.als import ALSTrain
from repro.core.baselines import NNTrainBaseline, RNDTrain
from repro.core.device_model import Profiler, TRAIN_WORKLOADS
from repro.core.gmd import GMDTrain

from benchmarks.common import DEV, ORACLE, SPACE, excess_pct, median, row, \
    train_problem_grid

NN_EPOCHS = 300


def run(full: bool = False, dnns=None) -> list[str]:
    rows = []
    for name in (dnns or TRAIN_WORKLOADS):
        w = TRAIN_WORKLOADS[name]
        probs = train_problem_grid(full, bert=(name == "bert"))
        fitted = {
            "als50": ALSTrain(Profiler(DEV, w), SPACE, nn_epochs=NN_EPOCHS),
            "rnd50": RNDTrain(Profiler(DEV, w), 50, SPACE),
            "rnd250": RNDTrain(Profiler(DEV, w), 250, SPACE),
            "nn250": NNTrainBaseline(Profiler(DEV, w), 250, SPACE,
                                     nn_epochs=NN_EPOCHS),
        }
        strategies = {"gmd10": None, **fitted}
        for sname, strat in strategies.items():
            exc, viols, solved, runs = [], 0, 0, []
            for prob in probs:
                opt = ORACLE.solve_train(w, prob)
                if opt is None:
                    continue
                if sname == "gmd10":
                    prof = Profiler(DEV, w)
                    sol = GMDTrain(prof, SPACE).solve(prob)
                    runs.append(prof.num_runs)
                else:
                    sol = strat.solve(prob)
                if sol is None:
                    continue
                solved += 1
                t_true, p_true = DEV.time_power(w, sol.pm)   # ground truth
                if p_true > prob.power_budget + 1e-9:
                    viols += 1
                exc.append(excess_pct(t_true, opt.time))
            nruns = (max(runs) if runs else
                     strat.profiler.num_runs if strat else 0)
            rows.append(row(f"train/{name}/{sname}/median_excess_time_pct",
                            median(exc),
                            f"solved={solved};violations={viols};modes={nruns}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
