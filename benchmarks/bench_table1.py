"""Paper Table 1 (practitioner's matrix): time-to-solution per scenario x
approach — MAXN (0 profiling, violates power budgets), GMD (<10 min), ALS
(0.5-1.5 h) — using the simulated profiling clock (40 minibatches/mode +
5 s switch overhead, as on the Orin)."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.als import ALSInfer, ALSTrain, QuadrantRanges
from repro.core.device_model import INFER_WORKLOADS, Profiler, TRAIN_WORKLOADS
from repro.core.gmd import GMDInfer, GMDTrain

from benchmarks.common import DEV, ORACLE, SPACE, row


def run(full: bool = False) -> list[str]:
    rows = []

    # --- Train only: personalization/fine-tuning -> GMD <10 min -----------
    w = TRAIN_WORKLOADS["resnet18"]
    prof = Profiler(DEV, w)
    sol = GMDTrain(prof, SPACE).solve(P.TrainProblem(30.0))
    rows.append(row("table1/train_only/gmd/time_to_solution_min",
                    prof.profile_cost_s / 60,
                    f"modes={prof.num_runs};paper=<10min"))

    # --- Continuous inference -> ALS 0.5-1.5 h ----------------------------
    w = INFER_WORKLOADS["mobilenet"]
    prof = Profiler(DEV, w)
    als = ALSInfer(prof, QuadrantRanges((0.05, 1.0), (30.0, 90.0)), SPACE,
                   nn_epochs=200 if not full else 1000)
    als.fit()
    rows.append(row("table1/inference_continuous/als/time_to_solution_hr",
                    prof.profile_cost_s / 3600,
                    f"modes={prof.num_runs};paper=0.5-1.5hr"))

    # --- On-demand inference -> GMD <10 min --------------------------------
    prof = Profiler(DEV, w)
    GMDInfer(prof, SPACE).solve(P.InferProblem(35.0, 0.3, 60.0))
    rows.append(row("table1/inference_ondemand/gmd/time_to_solution_min",
                    prof.profile_cost_s / 60,
                    f"modes={prof.num_runs};paper=<10min"))

    # --- Outlier tasks -> MAXN: zero time, but power-budget violations -----
    maxn = SPACE.maxn()
    viol = 0
    total = 0
    for name, wk in INFER_WORKLOADS.items():
        for budget in (15.0, 25.0, 35.0, 45.0):
            t, p = ORACLE.true_infer(wk, maxn, 1)
            total += 1
            if p > budget:
                viol += 1
    rows.append(row("table1/maxn/power_violation_pct", 100.0 * viol / total,
                    "time_to_solution=0;paper=violates most budgets"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
