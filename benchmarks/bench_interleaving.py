"""Paper Fig. 2: native vs streams vs managed interleaving — inference
latency distribution and training throughput over 10 problem configs."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.device_model import INFER_WORKLOADS, Profiler, TRAIN_WORKLOADS
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent
from repro.core.interleave import (simulate_managed, simulate_native,
                                   simulate_streams)

from benchmarks.common import DEV, SPACE, row

# Fig. 2's setup: concurrent MobileNet train + MobileNet infer, 10 configs
CONFIGS = [(40, 0.6, 22), (50, 0.8, 24), (60, 0.8, 26), (70, 1.0, 28),
           (80, 1.0, 30), (90, 1.0, 32), (100, 1.2, 34), (110, 1.2, 36),
           (120, 1.2, 38), (60, 0.6, 40)]   # (rate RPS, latency s, power W)


def run(full: bool = False) -> list[str]:
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    rows = []
    duration = 120.0 if full else 60.0
    for i, (rate, lat, power) in enumerate(CONFIGS, 1):
        prob = P.ConcurrentProblem(float(power), lat, float(rate))
        cp = ConcurrentProfiler(Profiler(DEV, w_tr), Profiler(DEV, w_in))
        plan = GMDConcurrent(cp, SPACE).solve(prob)
        if plan is None:
            rows.append(row(f"interleave/cfg{i}/unsolved", 1))
            continue
        pm, bs = plan.pm, plan.bs
        for sim, name in ((simulate_managed, "managed"),
                          (simulate_native, "native"),
                          (simulate_streams, "streams")):
            rep = sim(DEV, w_tr, w_in, pm, bs, float(rate), duration=duration)
            rows.append(row(
                f"interleave/cfg{i}/{name}/q3_latency_ms",
                rep.latency_quantile(0.75) * 1e3,
                f"viol_pct={100*rep.violation_rate(lat):.1f};"
                f"tput={rep.train_throughput:.2f}mb_s;"
                f"median_ms={rep.latency_quantile(0.5)*1e3:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
