"""Paper Fig. 2: native vs streams vs managed interleaving — inference
latency distribution and training throughput over 10 problem configs,
executed by the vectorized trace-driven engine (core.simulate)."""
from __future__ import annotations

from repro.core import problem as P
from repro.core.device_model import INFER_WORKLOADS, Profiler, TRAIN_WORKLOADS
from repro.core.gmd import ConcurrentProfiler, GMDConcurrent
from repro.core.simulate import ArrivalTrace, simulate

from benchmarks.common import DEV, SPACE, row

# Fig. 2's setup: concurrent MobileNet train + MobileNet infer, 10 configs
CONFIGS = [(40, 0.6, 22), (50, 0.8, 24), (60, 0.8, 26), (70, 1.0, 28),
           (80, 1.0, 30), (90, 1.0, 32), (100, 1.2, 34), (110, 1.2, 36),
           (120, 1.2, 38), (60, 0.6, 40)]   # (rate RPS, latency s, power W)


def solve_configs(duration: float):
    """GMD plan + arrival trace per Fig. 2 config (shared with the engine
    microbenchmark in bench_interleave_engine)."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    w_in = INFER_WORKLOADS["mobilenet"]
    out = []
    for i, (rate, lat, power) in enumerate(CONFIGS, 1):
        prob = P.ConcurrentProblem(float(power), lat, float(rate))
        cp = ConcurrentProfiler(Profiler(DEV, w_tr), Profiler(DEV, w_in))
        plan = GMDConcurrent(cp, SPACE).solve(prob)
        trace = ArrivalTrace.uniform(float(rate), duration)
        out.append((i, prob, plan, trace))
    return w_tr, w_in, out


def run(full: bool = False) -> list[str]:
    duration = 120.0 if full else 60.0
    w_tr, w_in, configs = solve_configs(duration)
    rows = []
    for i, prob, plan, trace in configs:
        if plan is None:
            rows.append(row(f"interleave/cfg{i}/unsolved", 1))
            continue
        for name in ("managed", "native", "streams"):
            rep = simulate(DEV, w_tr, w_in, plan.pm, plan.bs, trace,
                           approach=name)
            rows.append(row(
                f"interleave/cfg{i}/{name}/q3_latency_ms",
                rep.latency_quantile(0.75) * 1e3,
                f"viol_pct={100*rep.violation_rate(prob.latency_budget):.1f};"
                f"tput={rep.train_throughput:.2f}mb_s;"
                f"median_ms={rep.latency_quantile(0.5)*1e3:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
