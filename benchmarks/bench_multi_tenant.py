"""Multi-tenant benchmark: N∈{2,3,4} inference streams concurrent with
training under one power budget, swept across the 15 (train workload x N)
combinations drawn from the paper's 5 train + 5 infer DNNs.

Per combination: the oracle solves the whole problem grid with the batched
multi-tenant grid solver on the NumPy *and* jax backends (both timed, results
cross-checked), GMD plans the median solvable problem, and the N-stream
managed engine executes it — per-tenant violation rates and training
throughput are reported. The executed plan is replayed on both *engine*
backends too (NumPy reference vs the jax max-plus scan) and cross-checked
within the documented tolerance (``docs/exactness.md``). Rows are printed as
CSV and snapshotted to ``benchmarks/results/BENCH_multi_tenant.json``.

The ``lane_scaling`` section mirrors bench_interleave_engine's: 10 to 100k
two-stream multi-tenant lanes (shared traces) through
``simulate_multi_tenant_batch`` on every engine backend, recording the
NumPy-vs-jax-vs-Pallas configs/s crossover on the N-stream path.
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import problem as P
from repro.core import simulate as S
from repro.core.backend import jax_available, pallas_available
from repro.core.device_model import INFER_WORKLOADS, TRAIN_WORKLOADS
from repro.core.scheduler import Fulcrum

from benchmarks.common import DEV, ORACLE, SPACE, loss_pct, median, row, \
    snapshot

SNAPSHOT = Path(__file__).parent / "results" / "BENCH_multi_tenant.json"

LANE_COUNTS = (10, 100, 1000, 10000, 100000)

# per-stream (rate, latency budget) matched to each DNN's service time scale
STREAM_DEFAULTS = {
    "mobilenet": (40.0, 0.8),
    "lstm": (60.0, 0.5),
    "resnet50": (25.0, 1.2),
    "yolov8n": (20.0, 1.5),
    "bert": (2.0, 4.0),
}
INFER_ORDER = ["mobilenet", "lstm", "resnet50", "yolov8n", "bert"]
TRAIN_ORDER = ["resnet18", "mobilenet", "yolov8n", "bert", "lstm"]


def _streams(train_idx: int, n: int) -> tuple:
    """N heterogeneous streams: rotate the infer pool per train workload so
    the 15 combos cover every pairing."""
    names = [INFER_ORDER[(train_idx + k) % len(INFER_ORDER)]
             for k in range(n)]
    specs = []
    for name in names:
        rate, lat = STREAM_DEFAULTS[name]
        specs.append(P.StreamSpec(rate, lat, INFER_WORKLOADS[name]))
    return tuple(specs), names


def _problem_grid(specs: tuple, full: bool) -> list:
    """(power budget, latency scale, rate scale) sweep around the per-stream
    defaults."""
    pows = range(20, 56, 5) if full else (25, 35, 45, 55)
    lat_scales = (0.75, 1.0, 1.5, 2.0) if full else (1.0, 1.5)
    rate_scales = (0.5, 0.75, 1.0) if full else (0.5, 1.0)
    probs = []
    for pb in pows:
        for ls in lat_scales:
            for rs in rate_scales:
                streams = tuple(
                    P.StreamSpec(s.arrival_rate * rs, s.latency_budget * ls,
                                 s.workload)
                    for s in specs)
                probs.append(P.MultiTenantProblem(float(pb), streams))
    return probs


def _lane_scaling(lane_counts=LANE_COUNTS) -> dict:
    """Lane-axis sweep of the N-stream engine: every lane is the same
    2-stream (mobilenet + lstm) scenario over two shared short traces, with
    (pm, per-stream bs) cycling so event shapes vary realistically."""
    w_tr = TRAIN_WORKLOADS["mobilenet"]
    streams = [INFER_WORKLOADS["mobilenet"], INFER_WORKLOADS["lstm"]]
    tr_a = S.ArrivalTrace.poisson(20.0, 4.0, seed=11)
    tr_b = S.ArrivalTrace.poisson(12.0, 4.0, seed=13)
    modes = SPACE.all_modes()
    bs_cycle = [[4, 8], [8, 16], [16, 4], [32, 8]]
    backends = ["numpy"]
    if jax_available():
        backends.append("jax")
    if pallas_available():
        backends.append("pallas")
    rows = []
    for lanes in lane_counts:
        args = (DEV, w_tr, [streams] * lanes,
                [modes[(7 * i) % len(modes)] for i in range(lanes)],
                [bs_cycle[i % len(bs_cycle)] for i in range(lanes)],
                [[tr_a, tr_b]] * lanes)
        rec = {"lanes": lanes, "configs": lanes}
        for bk in backends:
            S.simulate_multi_tenant_batch(*args, backend=bk)   # warm
            t0 = time.perf_counter()
            S.simulate_multi_tenant_batch(*args, backend=bk)
            rec[f"{bk}_configs_per_s"] = lanes / (time.perf_counter() - t0)
        rows.append(rec)
    return {"trace_arrivals": len(tr_a) + len(tr_b), "n_streams": 2,
            "backends": backends, "lane_counts": list(lane_counts),
            "rows": rows}


def run(full: bool = False) -> list[str]:
    rows: list[str] = []
    results: dict = {"rows": []}
    for n in (2, 3, 4):
        for ti, tr_name in enumerate(TRAIN_ORDER):
            w_tr = TRAIN_WORKLOADS[tr_name]
            specs, stream_names = _streams(ti, n)
            probs = _problem_grid(specs, full)
            label = f"multi_tenant/{tr_name}+{n}x"

            t0 = time.perf_counter()
            opts_np = ORACLE.solve_multi_tenant_batch(w_tr, probs, "numpy")
            numpy_s = time.perf_counter() - t0
            try:
                ORACLE.solve_multi_tenant_batch(w_tr, probs[:2], "jax")
                t0 = time.perf_counter()
                opts_jax = ORACLE.solve_multi_tenant_batch(w_tr, probs, "jax")
                jax_s = time.perf_counter() - t0
            except RuntimeError:          # jax unavailable: record honestly
                opts_jax, jax_s = None, None
            if opts_jax is not None:
                for a, b in zip(opts_np, opts_jax):
                    assert (a is None) == (b is None), "backend divergence"
                    assert a is None or (a.pm, a.bss, a.tau_tr) == \
                        (b.pm, b.bss, b.tau_tr), "backend divergence"

            solvable = [(pr, opt) for pr, opt in zip(probs, opts_np)
                        if opt is not None]
            rec = {"n_streams": n, "train": tr_name,
                   "streams": stream_names, "configs": len(probs),
                   "solvable": len(solvable),
                   "numpy_configs_per_s": len(probs) / numpy_s}
            if jax_s is not None:
                rec["jax_configs_per_s"] = len(probs) / jax_s
            rows.append(row(f"{label}/solvable_pct",
                            100.0 * len(solvable) / len(probs),
                            f"streams={'+'.join(stream_names)};"
                            f"configs={len(probs)}"))

            if solvable:
                # GMD on the median solvable problem + engine execution
                prob, opt = solvable[len(solvable) // 2]
                f = Fulcrum(DEV, SPACE)
                plan = f.solve_multi_tenant(w_tr, prob, "gmd")
                if plan is not None:
                    sol = plan.solution
                    rec["gmd"] = {
                        "tput_loss_pct": loss_pct(opt.throughput,
                                                  sol.throughput),
                        "profiling_runs": plan.profiling_runs}
                    rep = f.execute_multi_tenant(plan, prob, w_tr,
                                                 duration=30.0)
                    if jax_available():
                        # engine-backend cross-check: jax scan vs reference
                        rj = f.execute_multi_tenant(plan, prob, w_tr,
                                                    duration=30.0,
                                                    backend="jax")
                        diff = 0.0
                        for ra, rb in zip(rep.streams, rj.streams):
                            np.testing.assert_allclose(
                                rb.latencies, ra.latencies,
                                rtol=1e-9, atol=1e-8,
                                err_msg="jax engine out of tolerance")
                            if len(ra.latencies):
                                diff = max(diff, float(np.abs(
                                    np.asarray(rb.latencies)
                                    - np.asarray(ra.latencies)).max()))
                        assert abs(rep.train_minibatches
                                   - rj.train_minibatches) <= 2
                        rec["engine_backend_max_abs_diff"] = diff
                    viols = rep.violation_rates(
                        [s.latency_budget for s in prob.streams])
                    rec["executed"] = {
                        "configs": 1,
                        "train_mb_per_s": rep.train_throughput,
                        "power": rep.power,
                        "per_tenant_violation_pct":
                            [100.0 * v for v in viols],
                        "worst_q95_ms":
                            rep.worst_latency_quantile(0.95) * 1e3}
                    rows.append(row(
                        f"{label}/gmd/executed_worst_q95_ms",
                        rep.worst_latency_quantile(0.95) * 1e3,
                        f"viol_max_pct={100.0 * max(viols):.1f};"
                        f"tput={rep.train_throughput:.2f}mb_s"))
                oracle_tputs = [o.throughput for _, o in solvable]
                rows.append(row(f"{label}/oracle/median_tput_mb_s",
                                median(oracle_tputs),
                                f"solvable={len(solvable)}"))
            results["rows"].append(rec)

    total = sum(r["configs"] for r in results["rows"])
    results["configs"] = total
    rows.append(row("multi_tenant/total_configs", total,
                    f"combos={len(results['rows'])}"))

    # -- lane scaling: N-stream engine crossover curve -----------------------
    results["lane_scaling"] = _lane_scaling()
    for rec in results["lane_scaling"]["rows"]:
        parts = [f"{bk}={rec[f'{bk}_configs_per_s']:.0f}cfg_s"
                 for bk in results["lane_scaling"]["backends"]]
        rows.append(row(f"multi_tenant/lane_scaling/{rec['lanes']}",
                        rec.get("jax_configs_per_s",
                                rec["numpy_configs_per_s"]),
                        ";".join(parts)))

    snapshot(SNAPSHOT, results, configs=total)
    rows.append(row("multi_tenant/snapshot", 1, str(SNAPSHOT)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale problem grids")
    cli = ap.parse_args()
    for r in run(full=cli.full):
        print(r)
