"""Multi-tenant benchmark: N∈{2,3,4} inference streams concurrent with
training under one power budget, swept across the 15 (train workload x N)
combinations drawn from the paper's 5 train + 5 infer DNNs.

Per combination: the oracle solves the whole problem grid with the batched
multi-tenant grid solver on the NumPy *and* jax backends (both timed, results
cross-checked), GMD plans the median solvable problem, and the N-stream
managed engine executes it — per-tenant violation rates and training
throughput are reported. The executed plan is replayed on both *engine*
backends too (NumPy reference vs the jax max-plus scan) and cross-checked
within the documented tolerance (``docs/exactness.md``). Rows are printed as
CSV and snapshotted to ``benchmarks/results/BENCH_multi_tenant.json``.
"""
from __future__ import annotations

import time
from pathlib import Path

import numpy as np

from repro.core import problem as P
from repro.core.backend import jax_available
from repro.core.device_model import INFER_WORKLOADS, TRAIN_WORKLOADS
from repro.core.scheduler import Fulcrum

from benchmarks.common import DEV, ORACLE, SPACE, loss_pct, median, row, \
    snapshot

SNAPSHOT = Path(__file__).parent / "results" / "BENCH_multi_tenant.json"

# per-stream (rate, latency budget) matched to each DNN's service time scale
STREAM_DEFAULTS = {
    "mobilenet": (40.0, 0.8),
    "lstm": (60.0, 0.5),
    "resnet50": (25.0, 1.2),
    "yolov8n": (20.0, 1.5),
    "bert": (2.0, 4.0),
}
INFER_ORDER = ["mobilenet", "lstm", "resnet50", "yolov8n", "bert"]
TRAIN_ORDER = ["resnet18", "mobilenet", "yolov8n", "bert", "lstm"]


def _streams(train_idx: int, n: int) -> tuple:
    """N heterogeneous streams: rotate the infer pool per train workload so
    the 15 combos cover every pairing."""
    names = [INFER_ORDER[(train_idx + k) % len(INFER_ORDER)]
             for k in range(n)]
    specs = []
    for name in names:
        rate, lat = STREAM_DEFAULTS[name]
        specs.append(P.StreamSpec(rate, lat, INFER_WORKLOADS[name]))
    return tuple(specs), names


def _problem_grid(specs: tuple, full: bool) -> list:
    """(power budget, latency scale, rate scale) sweep around the per-stream
    defaults."""
    pows = range(20, 56, 5) if full else (25, 35, 45, 55)
    lat_scales = (0.75, 1.0, 1.5, 2.0) if full else (1.0, 1.5)
    rate_scales = (0.5, 0.75, 1.0) if full else (0.5, 1.0)
    probs = []
    for pb in pows:
        for ls in lat_scales:
            for rs in rate_scales:
                streams = tuple(
                    P.StreamSpec(s.arrival_rate * rs, s.latency_budget * ls,
                                 s.workload)
                    for s in specs)
                probs.append(P.MultiTenantProblem(float(pb), streams))
    return probs


def run(full: bool = False) -> list[str]:
    rows: list[str] = []
    results: dict = {"rows": []}
    for n in (2, 3, 4):
        for ti, tr_name in enumerate(TRAIN_ORDER):
            w_tr = TRAIN_WORKLOADS[tr_name]
            specs, stream_names = _streams(ti, n)
            probs = _problem_grid(specs, full)
            label = f"multi_tenant/{tr_name}+{n}x"

            t0 = time.perf_counter()
            opts_np = ORACLE.solve_multi_tenant_batch(w_tr, probs, "numpy")
            numpy_s = time.perf_counter() - t0
            try:
                ORACLE.solve_multi_tenant_batch(w_tr, probs[:2], "jax")
                t0 = time.perf_counter()
                opts_jax = ORACLE.solve_multi_tenant_batch(w_tr, probs, "jax")
                jax_s = time.perf_counter() - t0
            except RuntimeError:          # jax unavailable: record honestly
                opts_jax, jax_s = None, None
            if opts_jax is not None:
                for a, b in zip(opts_np, opts_jax):
                    assert (a is None) == (b is None), "backend divergence"
                    assert a is None or (a.pm, a.bss, a.tau_tr) == \
                        (b.pm, b.bss, b.tau_tr), "backend divergence"

            solvable = [(pr, opt) for pr, opt in zip(probs, opts_np)
                        if opt is not None]
            rec = {"n_streams": n, "train": tr_name,
                   "streams": stream_names, "configs": len(probs),
                   "solvable": len(solvable),
                   "numpy_configs_per_s": len(probs) / numpy_s}
            if jax_s is not None:
                rec["jax_configs_per_s"] = len(probs) / jax_s
            rows.append(row(f"{label}/solvable_pct",
                            100.0 * len(solvable) / len(probs),
                            f"streams={'+'.join(stream_names)};"
                            f"configs={len(probs)}"))

            if solvable:
                # GMD on the median solvable problem + engine execution
                prob, opt = solvable[len(solvable) // 2]
                f = Fulcrum(DEV, SPACE)
                plan = f.solve_multi_tenant(w_tr, prob, "gmd")
                if plan is not None:
                    sol = plan.solution
                    rec["gmd"] = {
                        "tput_loss_pct": loss_pct(opt.throughput,
                                                  sol.throughput),
                        "profiling_runs": plan.profiling_runs}
                    rep = f.execute_multi_tenant(plan, prob, w_tr,
                                                 duration=30.0)
                    if jax_available():
                        # engine-backend cross-check: jax scan vs reference
                        rj = f.execute_multi_tenant(plan, prob, w_tr,
                                                    duration=30.0,
                                                    backend="jax")
                        diff = 0.0
                        for ra, rb in zip(rep.streams, rj.streams):
                            np.testing.assert_allclose(
                                rb.latencies, ra.latencies,
                                rtol=1e-9, atol=1e-8,
                                err_msg="jax engine out of tolerance")
                            if len(ra.latencies):
                                diff = max(diff, float(np.abs(
                                    np.asarray(rb.latencies)
                                    - np.asarray(ra.latencies)).max()))
                        assert abs(rep.train_minibatches
                                   - rj.train_minibatches) <= 2
                        rec["engine_backend_max_abs_diff"] = diff
                    viols = rep.violation_rates(
                        [s.latency_budget for s in prob.streams])
                    rec["executed"] = {
                        "configs": 1,
                        "train_mb_per_s": rep.train_throughput,
                        "power": rep.power,
                        "per_tenant_violation_pct":
                            [100.0 * v for v in viols],
                        "worst_q95_ms":
                            rep.worst_latency_quantile(0.95) * 1e3}
                    rows.append(row(
                        f"{label}/gmd/executed_worst_q95_ms",
                        rep.worst_latency_quantile(0.95) * 1e3,
                        f"viol_max_pct={100.0 * max(viols):.1f};"
                        f"tput={rep.train_throughput:.2f}mb_s"))
                oracle_tputs = [o.throughput for _, o in solvable]
                rows.append(row(f"{label}/oracle/median_tput_mb_s",
                                median(oracle_tputs),
                                f"solvable={len(solvable)}"))
            results["rows"].append(rec)

    total = sum(r["configs"] for r in results["rows"])
    results["configs"] = total
    rows.append(row("multi_tenant/total_configs", total,
                    f"combos={len(results['rows'])}"))
    snapshot(SNAPSHOT, results, configs=total)
    rows.append(row("multi_tenant/snapshot", 1, str(SNAPSHOT)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
